"""Import shim: `benchmarks.roofline` moved to `benchmarks.hlo_report`.

The old name collided with the measured kernel roofline
(`benchmarks.codec_roofline`); this module re-exports the HLO table
formatter so existing `python -m benchmarks.roofline results.json`
invocations keep working.
"""
from __future__ import annotations

import sys
import warnings

from benchmarks.hlo_report import (HEADER, main, markdown,  # noqa: F401
                                   table_rows)

warnings.warn(
    "benchmarks.roofline is a deprecated alias — import benchmarks."
    "hlo_report (HLO table) or run the codec_roofline benchmark "
    "(measured kernel roofline) instead", DeprecationWarning, stacklevel=2)

if __name__ == "__main__":
    main(*sys.argv[1:])
