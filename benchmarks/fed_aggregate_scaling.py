"""Stacked on-device server aggregation: decode→aggregate wall-clock scaling.

    PYTHONPATH=src python -m benchmarks.fed_aggregate_scaling

PR 3 made the CLIENT side one compiled program per cohort, which left the
server half as the wall-clock bound at large m: the host-loop path fetches
the whole decoded cohort (m × params-sized device→host transfer), unstacks
it into m trees, walks them through `server.aggregate`'s O(m·L) eager
`jax.tree.map` reduction and round-trips every leaf through numpy again for
the delta norms. The stacked path (`server.aggregate_stacked`) keeps the
decoded lanes on device from the cohort decode through the params update:
one compiled decode+norm program, one compiled O(m) lane reduction, an
m-independent eager tail, and a transfer of m SCALARS (the norms) instead
of m trees.

Same numerics: with `sum_mode="sequential"` the stacked server step is
bit-exact with the host-loop reference (asserted below on params and
fedmem memory every run); `sum_mode="pairwise"` trades the reference
summation order for a balanced fold and is reported alongside.

Headline: ≥ 5× faster server step (decode→aggregate) at m = 512 on CPU.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table
from repro.fed import (ServerConfig, server as server_lib,
                       clients as clients_lib)
from repro import codecs as registry
from repro.optimizer import sgd


def _make_inputs(m: int, sizes: dict, budget: float, chunk: int, seed: int):
    """m per-lane delta trees, encoded once into a stacked wire payload."""
    key = jax.random.key(seed)
    params = {name: jax.random.normal(jax.random.fold_in(key, 7 + i),
                                      shape, jnp.float32)
              for i, (name, shape) in enumerate(sorted(sizes.items()))}
    codec = registry.make("ndsc", budget=budget, chunk=chunk)
    meta = codec.meta(params)
    deltas = jax.vmap(
        lambda k: jax.tree.map(
            lambda p, s: jax.random.normal(s, p.shape, jnp.float32),
            params,
            dict(zip(sorted(sizes), jax.random.split(k, len(sizes))))))(
        jax.random.split(jax.random.fold_in(key, 1), m))
    encode = jax.jit(jax.vmap(lambda k, t: codec.encode(k, t, 0)))
    wires = encode(jax.random.split(jax.random.fold_in(key, 2), m), deltas)
    jax.block_until_ready(wires)
    return params, codec, meta, wires


def _host_loop_step(state, cfg, decode_fn, wires, weights, ids):
    """The PR-3 server half: vmapped decode, then everything through host."""
    decoded = decode_fn(wires)
    h_decoded = jax.device_get(decoded)
    deltas = clients_lib.unstack_tree(h_decoded, len(ids))
    norms = server_lib.delta_norms(deltas)
    state = server_lib.aggregate(state, cfg, deltas, weights, ids)
    jax.block_until_ready(state.params)
    return state, norms


def _stacked_step(state, cfg, decode_norm_fn, wires, weights, ids):
    """The stacked pipeline: decode+norms and the lane reduction compiled,
    deltas never leave the device, m scalars fetched for the allocator."""
    decoded, norms = decode_norm_fn(wires)
    state = server_lib.aggregate_stacked(state, cfg, decoded, weights, ids)
    fetched = np.asarray(norms)
    jax.block_until_ready(state.params)
    return state, fetched


def _timed(fn, reps: int) -> float:
    fn()                                   # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run(m_values=(64, 512), dim: int = 1024, budget: float = 2.0,
        chunk: int = 64, reps: int = 5, seed: int = 0) -> dict:
    sizes = {"w1": (dim // 2, 2), "b1": (dim // 4,),
             "w2": (dim // 4, 2), "b2": (dim // 4,)}
    aggregators = {
        "fedavg": lambda: ServerConfig(),
        "fedopt": lambda: ServerConfig(aggregator="fedopt",
                                       optimizer=sgd(1.0, momentum=0.9)),
        "fedmem": lambda: ServerConfig(aggregator="fedmem"),
    }
    rows, speedups = [], {}
    for m in m_values:
        params, codec, meta, wires = _make_inputs(m, sizes, budget, chunk,
                                                  seed)
        weights = np.ones(m)
        ids = list(range(m))
        decode_fn = jax.jit(jax.vmap(lambda w: codec.decode(w, meta)))

        def decode_norm(wires):
            decoded = jax.vmap(lambda w: codec.decode(w, meta))(wires)
            return decoded, server_lib.stacked_norms(decoded)

        decode_norm_fn = jax.jit(decode_norm)
        for agg, mk_cfg in aggregators.items():
            cfg = mk_cfg()
            state0 = server_lib.init_server(params, cfg, m)
            # correctness gate: the two pipelines agree bit for bit
            # (sequential sum mode) before any timing happens
            ref, ref_norms = _host_loop_step(state0, cfg, decode_fn, wires,
                                             weights, ids)
            got, got_norms = _stacked_step(state0, cfg, decode_norm_fn,
                                           wires, weights, ids)
            for r, g in zip(jax.tree.leaves(ref.params),
                            jax.tree.leaves(got.params)):
                assert np.array_equal(np.asarray(r), np.asarray(g)), \
                    f"{agg}: stacked params diverged from host-loop"
            for r, g in zip(jax.tree.leaves(ref.memory),
                            jax.tree.leaves(got.memory)):
                assert np.array_equal(np.asarray(r), np.asarray(g)), \
                    f"{agg}: stacked fedmem memory diverged"
            np.testing.assert_allclose(got_norms, ref_norms, rtol=1e-5)

            t_host = _timed(lambda: _host_loop_step(
                state0, cfg, decode_fn, wires, weights, ids), reps)
            t_stack = _timed(lambda: _stacked_step(
                state0, cfg, decode_norm_fn, wires, weights, ids), reps)
            pw_cfg = dataclasses.replace(mk_cfg(), sum_mode="pairwise")
            t_pw = _timed(lambda: _stacked_step(
                state0, pw_cfg, decode_norm_fn, wires, weights, ids), reps)
            speedups.setdefault(agg, {})[m] = t_host / t_stack
            rows.append([m, agg, f"{t_host * 1e3:.2f}",
                         f"{t_stack * 1e3:.2f}", f"{t_pw * 1e3:.2f}",
                         f"{t_host / t_stack:.1f}×"])
    print_table(
        f"fed server step (decode→aggregate), ms: host loop vs stacked "
        f"(dim≈{dim}, ndsc R={budget:g})",
        ["m", "aggregator", "host loop", "stacked seq", "stacked pairwise",
         "speedup"], rows)
    for agg, per_m in speedups.items():
        for m, s in per_m.items():
            if m >= 512:
                assert s >= 5.0, (
                    f"stacked {agg} server step only {s:.1f}× faster at "
                    f"m={m} (need ≥5×)")
    return {"speedup": {agg: {str(m): round(s, 2) for m, s in per_m.items()}
                        for agg, per_m in speedups.items()}}


if __name__ == "__main__":
    run()
