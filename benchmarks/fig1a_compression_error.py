"""Paper Fig. 1a: normalized compression error vs bit budget R, with and
without near-democratic embeddings (Gaussian³ vectors, n=1000).

Reproduces: SD (standard dithering), Top-K, and Kashin(λ) baselines against
NDH (near-democratic Hadamard) and NDO (near-democratic orthonormal).
The paper's observation to validate: NDE variants dominate their vanilla
counterparts, and λ close to 1 is best under a FIXED budget.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (gaussian_cubed, make_codec, normalized_error,
                               print_table)
from repro.core import baselines as B
from repro.core.coding import compress_in_embedded_space
from repro.core import frames as F
from repro.core import quantizers as q


def run(n: int = 1000, trials: int = 20, seed: int = 0,
        budgets=(1.0, 2.0, 3.0, 4.0, 6.0)):
    key = jax.random.key(seed)
    y = gaussian_cubed(key, (n,))
    kerr = jax.random.key(seed + 1)

    header = ["R (bits/dim)"] + [f"{r:g}" for r in budgets]
    rows = []

    def sweep(name, fn_of_R):
        errs = []
        for R in budgets:
            rt = fn_of_R(R)
            errs.append(f"{normalized_error(rt, y, kerr, trials):.4f}")
        rows.append([name] + errs)

    # SD: standard dithering at 2^R levels (no embedding)
    sweep("SD", lambda R: B.standard_dither(
        max(2, int(2 ** R))).roundtrip)
    # SD + NDE (Hadamard): Thm. 4 composition
    frame_h = F.make_frame("hadamard", jax.random.key(2), n, F.next_pow2(n))

    def sd_nde(R):
        lam = frame_h.N / n
        levels = max(2, int(2 ** (R / lam)))

        def rt(k, v):
            return compress_in_embedded_space(
                frame_h, lambda kk, x: q.dithered_quantize(
                    kk, x / jnp.max(jnp.abs(x)), levels) * jnp.max(jnp.abs(x)),
                v, k)
        return rt
    sweep("SD + NDH", sd_nde)
    # Top-K (keep 10%, quantize kept coords with the remaining budget)
    sweep("Top-10%", lambda R: B.topk(
        0.1, quant_levels=max(2, int(2 ** min(R / 0.1, 20)))).roundtrip)
    # Kashin λ=1.5 / 1.8 (democratic embedding, budget R/λ per coordinate)
    for lam in (1.5, 1.8):
        def kashin(R, lam=lam):
            codec = make_codec("haar", n, R, embedding="democratic",
                               aspect=lam)
            return lambda k, v: codec.roundtrip(v, k)
        sweep(f"Kashin λ={lam}", kashin)
    # NDO (λ=1) and NDH
    sweep("NDO (λ=1)", lambda R: (
        lambda codec: (lambda k, v: codec.roundtrip(v, k)))(
            make_codec("haar", n, R, aspect=1.0)))
    sweep("NDH", lambda R: (
        lambda codec: (lambda k, v: codec.roundtrip(v, k)))(
            make_codec("hadamard", n, R)))

    print_table("Fig. 1a — normalized error vs R (n=1000, Gaussian³)",
                header, rows)
    return rows


if __name__ == "__main__":
    run()
