"""Measured roofline for the fused codec encoder (`kernels.quantencode`).

Two jobs, per (N, bits, mode) sweep point:

  1. GATE (always, every run): the fused Pallas kernel's (words, scale)
     must be BIT-EXACT with the composed `kernels.ref.encode` oracle —
     deterministically and on the dithered path with shared pre-drawn
     dither. A fused kernel whose payload drifts from the reference would
     silently change every wire byte in the repo, so the bench refuses to
     report numbers for a config that fails the gate.
  2. ROOFLINE: time the dispatched `kernels.ops.encode` path and report
     achieved bytes/s against the analytic MINIMUM-traffic model — the
     fused kernel's whole point is that HBM traffic collapses to

         read  u        rows · N · 4 B     (+ dither rows · N · 4 B)
         read  signs    N · 4 B            (+ mask   rows · 4 B)
         write words    rows · N · bits/8 B
         write scale    rows · 4 B

     i.e. the f32 embedding never round-trips HBM. On TPU the ratio
     achieved/minimum is the roofline figure of merit; on CPU (interpret
     mode under REPRO_FORCE_PALLAS=1, or the jnp reference by default)
     the timing is informational and the GATE is the payload.

Run via `python -m benchmarks.run codec_roofline [--tiny]`; CI's
bench-smoke lane runs the tiny sweep under REPRO_FORCE_PALLAS=1 so the
gate exercises the actual kernel, not the reference against itself.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kernel_ops
from repro.kernels import quantencode
from repro.kernels import ref as kernel_ref


def min_traffic_bytes(rows: int, n: int, bits: int, dithered: bool) -> int:
    """The fused encoder's analytic minimum HBM traffic (bytes)."""
    read = rows * n * 4 + n * 4              # u + signs
    if dithered:
        read += rows * n * 4                 # pre-drawn dither rows
    write = rows * (n * bits // 8) + rows * 4  # packed words + scale
    return read + write


def _time_call(fn, *args, reps: int) -> float:
    out = fn(*args)                          # warmup/compile
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _gate(chunks, signs, bits, dither) -> None:
    """Assert the kernel payload is bit-exact with the composed oracle."""
    kw, ks = quantencode.encode_pallas(chunks, signs, bits, dither=dither)
    rw, rs = kernel_ref.encode(chunks, signs, bits, dither=dither)
    if not np.array_equal(np.asarray(kw), np.asarray(rw)):
        raise AssertionError(
            f"payload words diverged from ref.encode at N={chunks.shape[-1]} "
            f"bits={bits} dithered={dither is not None}")
    if not np.array_equal(np.asarray(ks).view(np.int32),
                          np.asarray(rs).view(np.int32)):
        raise AssertionError(
            f"payload scale diverged from ref.encode at N={chunks.shape[-1]} "
            f"bits={bits} dithered={dither is not None}")


def run(n_values=(256, 1024, 4096), bits_values=(1, 2, 4, 8), rows: int = 256,
        reps: int = 3, seed: int = 0):
    key = jax.random.key(seed)
    records = []
    for n in n_values:
        k_x, k_s, k_d = jax.random.split(jax.random.fold_in(key, n), 3)
        chunks = jax.random.normal(k_x, (rows, n), jnp.float32)
        signs = jnp.where(
            jax.random.bernoulli(k_s, 0.5, (n,)), 1.0, -1.0
        ).astype(jnp.float32)
        for bits in bits_values:
            delta = 2.0 / (2 ** bits)
            dither = jax.random.uniform(k_d, (rows, n), jnp.float32,
                                        -delta / 2, delta / 2)
            for mode, dth in (("det", None), ("dither", dither)):
                _gate(chunks, signs, bits, dth)
                sec = _time_call(
                    lambda c, s, d, b=bits: kernel_ops.encode(
                        c, s, b, dither=d),
                    chunks, signs, dth, reps=reps)
                mn = min_traffic_bytes(rows, n, bits, dth is not None)
                records.append({
                    "n": n, "bits": bits, "mode": mode, "usec": sec * 1e6,
                    "min_traffic_bytes": mn,
                    "gbps": mn / sec / 1e9,
                })
    print(f"{'N':>6} {'bits':>4} {'mode':>6} {'usec':>10} "
          f"{'min B':>10} {'GB/s':>8}")
    for r in records:
        print(f"{r['n']:>6} {r['bits']:>4} {r['mode']:>6} "
              f"{r['usec']:>10.1f} {r['min_traffic_bytes']:>10} "
              f"{r['gbps']:>8.3f}")
    gate = f"{len(records)} configs bitwise vs ref.encode"
    print(f"[gate: {gate}; backend={jax.default_backend()}]")
    return {"gate": gate, "backend": jax.default_backend(),
            "best_gbps": max(r["gbps"] for r in records),
            "records": records}


if __name__ == "__main__":
    run()
