"""Codec frontier: equal-total-bits error sweep over the repro.codecs stack.

For each budget R ∈ r_values the NDSC codec's ANALYTIC wire size on an
n-dim leaf anchors an equal-total-bits target; every other codec is then
calibrated to spend at most that many bits (binary search over its own
`wire_bits` audit — survivors for the sparsifiers, budget for RATQ/QSGD)
and the compression error E‖C(y)−y‖/‖y‖ is measured on the paper's
heavy-tailed Gaussian³ vectors (§5). Compared at every point:

  ndsc                  the paper's chunked embedding codec (the anchor)
  sparsify_then_embed   top-k survivors, democratically embedded + quantized
                        (quantizer bits chosen per point from a small grid)
  topk (plain)          top-k with EXACT f32 survivor values — the classic
                        sparsifier the paper's hybrid is measured against
  topk (q8)             the repo baseline default (256-level survivors)
  ratq                  adaptive fixed-length ladder quantizer (M&T)
  qsgd                  stochastic level + sign baseline (n/a when even
                        s = 1 exceeds the target)

Three gates ride the sweep and the benchmark REFUSES to report without
them (they raise, so `benchmarks.run` records the failure):

  * `ndsc_bitexact` — the repro.codecs ndsc pipeline must produce wire
    payloads (words / scales / masks), decodes, fused EF residuals and
    ledger bytes BITWISE identical to the direct `repro.dist.gradcomp`
    encode across bits ∈ {1,2,4,8} × keep ∈ {0.25, 1} × {det, dither}.
    CI runs this with and without REPRO_FORCE_PALLAS=1.
  * `ste_beats_plain_topk` — at every swept R the sparsify-then-embed
    hybrid must beat plain (exact-value) top-k at equal total bits: the
    bits saved by coarse embedded quantization buy more survivors than
    exact values do.
  * `ratq_single_compile` — one jitted encode→decode per R serves EVERY
    round: sweeping round_idx never changes a shape, so the compile cache
    stays at exactly one entry per swept budget.

A small §5 convex protocol (the Fig. 1d ℓ2-regularized least-squares
problem with DGD-DEF) closes the loop: the same calibrated codecs drive
`optim.dqgd` at `protocol_r` bits/dim and the final normalized distance
is reported next to unquantized GD.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import gaussian_cubed, print_table
from repro import codecs
from repro.codecs import stages
from repro.core import optim as O
from repro.dist import gradcomp as G
from repro.obs import recompile as recompile_lib

R_VALUES = (0.1, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0)
STE_BITS_GRID = (1, 2, 4, 8)


# ---------------------------------------------------------------------------
# Gate 1: the codecs ndsc pipeline is bitwise the gradcomp encode
# ---------------------------------------------------------------------------
def _bitwise_equal(a, b) -> bool:
    a, b = np.asarray(a), np.asarray(b)
    return (a.dtype == b.dtype and a.shape == b.shape
            and np.array_equal(a.view(np.uint8), b.view(np.uint8)))


def ndsc_bitexact_gate(n: int = 512, chunk: int = 64, seed: int = 0,
                       round_idx: int = 3) -> int:
    """Assert payload/decode/EF/ledger equality of `codecs.make("ndsc")`
    against the direct gradcomp path on every (bits, keep, dither) point;
    returns the number of grid points checked."""
    key = jax.random.key(seed)
    tree = {"w": gaussian_cubed(jax.random.fold_in(key, 0), (n,)),
            "b": gaussian_cubed(jax.random.fold_in(key, 1), (3, 7))}
    leaves, _ = jax.tree.flatten(tree)
    ekey = jax.random.fold_in(key, 7)
    checked = 0
    for bits in (1, 2, 4, 8):
        for kf in (0.25, 1.0):
            for dith in (False, True):
                drop = kf < 1.0
                cfg = G.GradCompConfig(
                    bits=bits, chunk=chunk, keep_fraction=kf,
                    exact_keep=drop, dithered=dith, error_feedback=True,
                    seed=0)
                pipeline = stages.Pipeline(
                    transform=stages.Transform("hadamard", seed=0),
                    sparsify=(stages.Sparsify("chunk_drop", fraction=kf)
                              if drop else stages.Sparsify()),
                    quantize=stages.Quantize(
                        "dithered" if dith else "uniform", bits=bits),
                    chunk=chunk)
                codec = pipeline.tree_codec(f"gate(b{bits},k{kf},d{dith})")
                meta = codec.meta(tree)
                tag = f"bits={bits} keep={kf} dithered={dith}"

                wire = codec.encode(ekey, tree, round_idx)
                plist = meta.treedef.flatten_up_to(wire)
                direct = [G.encode_leaf(x, i, cfg, round_idx,
                                        key=jax.random.fold_in(ekey, i))
                          for i, x in enumerate(leaves)]
                for p, d in zip(plist, direct):
                    assert set(p) == set(d), f"payload keys differ at {tag}"
                    for field in p:
                        assert _bitwise_equal(p[field], d[field]), \
                            f"{field} not bitwise equal at {tag}"

                dec = jax.tree.leaves(codec.decode(wire, meta))
                for i, (d, (size, shape, dtype)) in enumerate(
                        zip(direct, meta.infos)):
                    ref = G.decode_leaf(d, i, size, shape, dtype, cfg)
                    assert _bitwise_equal(dec[i], ref), \
                        f"decode differs at {tag}"

                wire_ef, resid = codec.encode_ef(ekey, tree, meta, round_idx)
                for i, (x, p, r, info) in enumerate(zip(
                        leaves, meta.treedef.flatten_up_to(wire_ef),
                        jax.tree.leaves(resid), meta.infos)):
                    dp, dr = G.encode_leaf_ef(
                        x, i, cfg, round_idx,
                        key=jax.random.fold_in(ekey, i),
                        residual_dtype=info[2])
                    for field in p:
                        assert _bitwise_equal(p[field], dp[field]), \
                            f"EF {field} differs at {tag}"
                    assert _bitwise_equal(r, dr), f"EF residual at {tag}"

                realized = codec.wire_bytes(wire, meta)
                direct_bytes = sum(G.wire_bytes_payload(d, cfg)
                                   for d in direct)
                assert abs(realized - direct_bytes) < 1e-9, \
                    f"ledger bytes differ at {tag}"
                audit = codec.wire_bits(tree)
                direct_bits = G.wire_bytes_tree(
                    leaves, cfg)["payload_bytes"] * 8.0
                assert abs(audit - direct_bits) < 1e-6, \
                    f"analytic audit differs at {tag}"
                checked += 1
    return checked


# ---------------------------------------------------------------------------
# Equal-total-bits calibration
# ---------------------------------------------------------------------------
def _template(n: int) -> dict:
    return {"y": jax.ShapeDtypeStruct((n,), jnp.float32)}


def _max_k(n: int, bits_of_k, target_bits: float) -> int:
    """Largest k ∈ [1, n] with bits_of_k(k) ≤ target_bits (monotone)."""
    if bits_of_k(1) > target_bits:
        return 0
    lo, hi = 1, n
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if bits_of_k(mid) <= target_bits:
            lo = mid
        else:
            hi = mid - 1
    return lo


def _calibrate_ste(n: int, chunk: int, target_bits: float, seed: int):
    """Best (codec, bits, k) on the quantizer grid fitting the target."""
    tmpl = _template(n)
    out = []
    for bits in STE_BITS_GRID:
        def bits_of_k(k, bits=bits):
            return codecs.make("sparsify_then_embed", budget=1.0, bits=bits,
                               chunk=chunk, k_fraction=k / n,
                               seed=seed).wire_bits(tmpl)
        k = _max_k(n, bits_of_k, target_bits)
        if k >= 1:
            out.append((codecs.make("sparsify_then_embed", budget=1.0,
                                    bits=bits, chunk=chunk, k_fraction=k / n,
                                    seed=seed), bits, k))
    return out


def _calibrate_topk(n: int, target_bits: float,
                    quant_levels: Optional[int]):
    tmpl = _template(n)

    def bits_of_k(k):
        return codecs.make("topk", k_fraction=k / n,
                           quant_levels=quant_levels).wire_bits(tmpl)

    k = _max_k(n, bits_of_k, target_bits)
    if k < 1:
        return None, 0
    return codecs.make("topk", k_fraction=k / n,
                       quant_levels=quant_levels), k


def _calibrate_ratq(n: int, chunk: int, target_bits: float, seed: int):
    """Feasible (codec, budget) candidates: the whole-bits rungs that fit
    plus the largest continuous budget (which may trade bits for chunk
    dropping); the caller keeps whichever measures best."""
    tmpl = _template(n)

    def fits(b: float) -> bool:
        return codecs.make("ratq", budget=b, chunk=chunk,
                           seed=seed).wire_bits(tmpl) <= target_bits

    out = [(codecs.make("ratq", budget=float(b), chunk=chunk, seed=seed),
            float(b)) for b in stages.PACKABLE_BITS if fits(float(b))]
    lo, hi = 0.01, 8.0
    if fits(lo):
        for _ in range(30):
            mid = 0.5 * (lo + hi)
            if fits(mid):
                lo = mid
            else:
                hi = mid
        if all(abs(lo - b) > 1e-3 for _, b in out):
            out.append((codecs.make("ratq", budget=lo, chunk=chunk,
                                    seed=seed), lo))
    return out


def _calibrate_qsgd(n: int, target_bits: float):
    """Largest level count s with n·(1 + log2(s+1)) + 32 ≤ target."""
    per_dim = (target_bits - 32.0) / n - 1.0
    if per_dim < 1.0:                       # even s = 1 (ternary) won't fit
        return None, 0
    s = max(1, int(2.0 ** per_dim - 1.0))
    codec = codecs.make("qsgd", budget=math.log2(s + 1) + 1.0)
    if codec.wire_bits(_template(n)) > target_bits + 1e-6:
        return None, 0
    return codec, s


def _mean_err(codec, n: int, key, trials: int) -> float:
    """E‖C(y)−y‖/‖y‖ over heavy-tailed draws (one jitted roundtrip)."""
    y0 = gaussian_cubed(jax.random.fold_in(key, 0), (n,))
    meta = codec.meta({"y": y0})

    @jax.jit
    def roundtrip(k, y):
        wire = codec.encode(k, {"y": y}, 0)
        return codec.decode(wire, meta)["y"]

    tot = 0.0
    for t in range(trials):
        y = gaussian_cubed(jax.random.fold_in(key, 100 + t), (n,))
        out = roundtrip(jax.random.fold_in(key, t), y)
        tot += float(jnp.linalg.norm(out - y) / jnp.linalg.norm(y))
    return tot / trials


# ---------------------------------------------------------------------------
# Gate 3: RATQ shapes are static across rounds at every swept budget
# ---------------------------------------------------------------------------
def ratq_recompile_gate(n: int, chunk: int, r_values, rounds: int,
                        seed: int) -> dict:
    """One compiled encode→decode per R serves every round_idx; asserts the
    jit cache holds exactly one entry after the round sweep."""
    key = jax.random.key(seed)
    y = gaussian_cubed(key, (n,))
    sizes = {}
    for R in r_values:
        codec = codecs.make("ratq", budget=R, chunk=chunk, seed=seed)
        meta = codec.meta({"y": y})

        def roundtrip(k, tree, round_idx, codec=codec, meta=meta):
            return codec.decode(codec.encode(k, tree, round_idx), meta)

        fn = recompile_lib.register(f"codec_frontier.ratq[R={R:g}]",
                                    jax.jit(roundtrip))
        for r in range(rounds):
            jax.block_until_ready(
                fn(jax.random.fold_in(key, r), {"y": y}, jnp.uint32(r)))
        sizes[f"{R:g}"] = int(fn._cache_size())
        assert sizes[f"{R:g}"] == 1, \
            f"ratq recompiled across rounds at R={R}: " \
            f"{sizes[f'{R:g}']} cache entries"
    return sizes


# ---------------------------------------------------------------------------
# §5 convex protocol: DGD-DEF on heavy-tailed regularized least squares
# ---------------------------------------------------------------------------
def _protocol(named_codecs, n: int, m: int, steps: int, lam: float,
              seed: int) -> list:
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    feats = gaussian_cubed(k1, (m, n))
    feats = feats / jnp.linalg.norm(feats, axis=0, keepdims=True)
    y_lab = jnp.sign(jax.random.normal(k2, (m,)))
    h = feats.T @ feats / m + lam * jnp.eye(n)
    rhs = feats.T @ y_lab / m
    x_star = jnp.linalg.solve(h, rhs)
    eigs = jnp.linalg.eigvalsh(h)
    alpha = O.alpha_star(float(eigs[-1]), float(eigs[0]))
    grad = lambda x: h @ x - rhs                               # noqa: E731
    x0 = jnp.zeros((n,))
    d0 = float(jnp.linalg.norm(x_star))

    rows = []
    for label, codec in named_codecs:
        meta = codec.meta({"g": x0})

        def roundtrip(k, g, codec=codec, meta=meta):
            return codec.decode(codec.encode(k, {"g": g}, 0), meta)["g"]

        trace = O.dqgd(grad, x0, roundtrip, alpha, steps, x_star=x_star)
        rows.append([label, float(trace.dist_history[-1]) / d0])
    trace = O.gd(grad, x0, alpha, steps, x_star=x_star)
    rows.append(["unquantized GD", float(trace.dist_history[-1]) / d0])
    return rows


# ---------------------------------------------------------------------------
def run(n: int = 1024, m: int = 400, chunk: int = 64,
        r_values=R_VALUES, trials: int = 8, rounds: int = 5,
        steps: int = 40, protocol_r: float = 0.5, lam: float = 0.05,
        seed: int = 0) -> dict:
    key = jax.random.key(seed)
    bitexact_points = ndsc_bitexact_gate(n=min(n, 512), chunk=chunk,
                                         seed=seed)
    recompile_sizes = ratq_recompile_gate(n, chunk, r_values, rounds, seed)

    tmpl = _template(n)
    frontier, beats = [], {}
    protocol_codecs = None
    for R in r_values:
        ndsc = codecs.make("ndsc", budget=R, chunk=chunk, seed=seed)
        target = ndsc.wire_bits(tmpl)
        kq = jax.random.fold_in(key, int(R * 1000))
        row = {"R": R, "target_bits_per_dim": target / n,
               "ndsc": _mean_err(ndsc, n, kq, trials)}

        ste_best = None
        for codec, bits, k in _calibrate_ste(n, chunk, target, seed):
            err = _mean_err(codec, n, kq, trials)
            if ste_best is None or err < ste_best[0]:
                ste_best = (err, bits, k, codec)
        if ste_best is None:
            raise AssertionError(
                f"sparsify_then_embed infeasible at R={R} "
                f"(target {target:.0f} bits < one chunk) — shrink chunk")
        row["ste"], row["ste_bits"], row["ste_k"] = ste_best[:3]

        plain, k32 = _calibrate_topk(n, target, quant_levels=None)
        if plain is None:
            raise AssertionError(f"plain top-k infeasible at R={R}")
        row["topk_plain"] = _mean_err(plain, n, kq, trials)
        row["topk_plain_k"] = k32
        q8, k8 = _calibrate_topk(n, target, quant_levels=256)
        row["topk_q8"] = None if q8 is None else _mean_err(q8, n, kq, trials)
        row["topk_q8_k"] = k8

        ratq_best = None
        for codec, budget in _calibrate_ratq(n, chunk, target, seed):
            err = _mean_err(codec, n, kq, trials)
            if ratq_best is None or err < ratq_best[0]:
                ratq_best = (err, budget, codec)
        row["ratq"] = None if ratq_best is None else ratq_best[0]
        row["ratq_budget"] = 0.0 if ratq_best is None else ratq_best[1]
        ratq = None if ratq_best is None else ratq_best[2]
        ratq_budget = row["ratq_budget"]
        qsgd, s = _calibrate_qsgd(n, target)
        row["qsgd"] = None if qsgd is None else _mean_err(qsgd, n, kq,
                                                          trials)
        row["qsgd_levels"] = s

        beats[f"{R:g}"] = bool(row["ste"] < row["topk_plain"])
        frontier.append(row)
        if abs(R - protocol_r) < 1e-9:
            protocol_codecs = [
                (f"ndsc(R={R:g})", ndsc),
                (f"sparsify_then_embed(b{ste_best[1]},k={ste_best[2]})",
                 ste_best[3]),
                (f"plain top-k (k={k32})", plain),
            ] + ([(f"ratq(R={ratq_budget:.2f})", ratq)] if ratq else []) \
              + ([(f"qsgd(s={s})", qsgd)] if qsgd else [])

    losing = [R for R, ok in beats.items() if not ok]
    assert not losing, \
        f"sparsify_then_embed did not beat plain top-k at R ∈ {losing}"

    def fmt(v, digits=3):
        return "n/a" if v is None else f"{v:.{digits}f}"

    print_table(
        f"codec frontier — E‖C(y)−y‖/‖y‖ at equal total bits "
        f"(n={n}, heavy-tailed §5 vectors, {trials} trials)",
        ["R", "bits/dim", "ndsc", "ste (bits,k)", "topk plain (k)",
         "topk q8 (k)", "ratq", "qsgd"],
        [[f"{r['R']:g}", f"{r['target_bits_per_dim']:.2f}",
          fmt(r["ndsc"]),
          f"{fmt(r['ste'])} (b{r['ste_bits']},k{r['ste_k']})",
          f"{fmt(r['topk_plain'])} (k{r['topk_plain_k']})",
          f"{fmt(r['topk_q8'])} (k{r['topk_q8_k']})",
          fmt(r["ratq"]), fmt(r["qsgd"])] for r in frontier])

    protocol_rows = None
    if protocol_codecs is not None:
        protocol_rows = _protocol(protocol_codecs, n=min(n, 784), m=m,
                                  steps=steps, lam=lam, seed=seed)
        print_table(
            f"§5 convex protocol — ‖x_T − x*‖/‖x*‖ after {steps} steps "
            f"(R = {protocol_r:g} bits/dim, DGD-DEF)",
            ["method", "final normalized distance"],
            [[label, f"{v:.3e}"] for label, v in protocol_rows])

    return {
        "ndsc_bitexact": True,                 # the gate raised otherwise
        "ndsc_bitexact_points": bitexact_points,
        "ratq_single_compile": True,
        "ratq_cache_sizes": recompile_sizes,
        "ste_beats_plain_topk": True,
        "ste_beats_by_r": beats,
        "frontier": frontier,
        "protocol": protocol_rows,
    }


if __name__ == "__main__":
    run()
