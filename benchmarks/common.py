"""Shared helpers for the paper-reproduction benchmarks."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.coding import Codec, CodecConfig
from repro.core.embeddings import EmbeddingSpec
from repro.core import frames as F


def gaussian_cubed(key, shape):
    """The paper's heavy-tailed test vectors (§5): N(0,1)³ element-wise."""
    return jax.random.normal(key, shape) ** 3


def student_t(key, shape, df=1.0):
    return jax.random.t(key, df=df, shape=shape)


def make_codec(kind: str, n: int, R: float, *, dithered=False,
               embedding="near_democratic", aspect=1.0, seed=0) -> Codec:
    if kind == "hadamard":
        N = F.next_pow2(n)
    else:
        N = max(n, int(round(aspect * n)))
    frame = F.make_frame(kind, jax.random.key(seed), n, N)
    return Codec(frame, CodecConfig(
        bits_per_dim=R, dithered=dithered,
        embedding=EmbeddingSpec(kind=embedding)))


def normalized_error(roundtrip, y, key, trials=50):
    keys = jax.random.split(key, trials)
    errs = jax.vmap(lambda k: jnp.linalg.norm(roundtrip(k, y) - y)
                    / jnp.linalg.norm(y))(keys)
    return float(jnp.mean(errs))


def timed(fn, *args, repeats=5):
    fn(*args)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats


def print_table(title, header, rows):
    print(f"\n== {title} ==")
    widths = [max(len(str(r[i])) for r in [header] + rows)
              for i in range(len(header))]
    fmt = "  ".join(f"{{:<{w}}}" for w in widths)
    print(fmt.format(*header))
    for r in rows:
        print(fmt.format(*[str(x) for x in r]))
