"""Mesh federation backend: lanes-per-device sweep with a bitwise gate.

    XLA_FLAGS=--xla_force_host_platform_device_count=2 \
        PYTHONPATH=src python -m benchmarks.fed_mesh_scaling

The claim under test is CORRECTNESS under placement, not CPU speed: the
shard_map backend places cohort lanes on mesh devices (fed ∘ dist — each
device runs local-SGD → encode → decode for its lane slice and the server
reduce is a collective fold), and under `sum_mode="sequential"` it must be
**bit-exact** with the single-device vmap cohort engine — params, EF
memories, fedopt optimizer state and the byte ledger — for every lane
count, divisible by the device axis or not. The sweep varies m (hence
lanes/device and padding) and asserts the gate on every run; per-round
wall-clock for both backends is reported so real multi-host runs have a
baseline (on a virtual-device CPU host the mesh backend pays collective
overhead for no parallel compute — the devices share one CPU — so parity
< 1 here is expected and NOT asserted).

When imported first (standalone or `benchmarks.run fed_mesh ...`) the module
forces 2 virtual host devices before jax initializes; if another benchmark
already initialized jax single-device, the run reports itself skipped
rather than failing the whole bench lane.
"""
from __future__ import annotations

import os
import sys
import time

if "jax" not in sys.modules:       # only effective before jax initializes
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=2")

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table
from benchmarks.fed_heterogeneous import make_problem
from repro.dist.sharding import padded_lanes
from repro.fed import (ClientConfig, FedConfig, Federation, ServerConfig,
                       mesh as mesh_lib)
from repro import codecs as registry


def _timed_rounds(fed: Federation, cfg: FedConfig, rounds: int) -> float:
    """Seconds per round, excluding the round-0 compile."""
    fed.run_round(cfg, 0)
    t0 = time.perf_counter()
    for t in range(1, rounds + 1):
        fed.run_round(cfg, t)
    return (time.perf_counter() - t0) / rounds


def _assert_bitwise(fed_v: Federation, fed_m: Federation, m: int) -> None:
    for name, a, b in (("params", fed_v.server.params, fed_m.server.params),
                       ("opt_state", fed_v.server.opt_state,
                        fed_m.server.opt_state)):
        for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            if not np.array_equal(np.asarray(la), np.asarray(lb)):
                raise AssertionError(
                    f"mesh backend diverged from vmap on {name} at m={m}")
    for sv, sm in zip(fed_v.states, fed_m.states):
        for la, lb in zip(jax.tree.leaves(sv.ef), jax.tree.leaves(sm.ef)):
            if not np.array_equal(np.asarray(la), np.asarray(lb)):
                raise AssertionError(
                    f"mesh backend diverged from vmap on EF at m={m}")


def run(m_values=(6, 16, 64), dim: int = 96, per_client: int = 32,
        rounds: int = 4, chunk: int = 64, seed: int = 0) -> dict:
    devices = jax.device_count()
    if devices < 2:
        print("[fed_mesh] skipped: needs ≥ 2 devices (set XLA_FLAGS="
              "--xla_force_host_platform_device_count=2 before jax "
              f"initializes), have {devices}")
        return {"skipped": f"single device (have {devices})"}
    mesh = mesh_lib.default_mesh()
    rows, per_m = [], {}
    for m in m_values:
        shards, loss_fn, _, _, lr = make_problem(
            m, dim, per_client=per_client, scale_span=0.0, seed=seed)
        params = {"x": jnp.zeros(dim)}
        codec = registry.make("ndsc", budget=2.0, chunk=chunk)
        ccfg = ClientConfig(local_steps=1, lr=lr)
        cfg = FedConfig(num_rounds=rounds + 1, seed=seed)

        feds, times, ledgers = {}, {}, {}
        for backend in ("vmap", "mesh"):
            fed = Federation(loss_fn, params, shards, codec, ccfg,
                             ServerConfig(), seed=seed, backend=backend,
                             mesh=mesh if backend == "mesh" else None)
            times[backend] = _timed_rounds(fed, cfg, rounds)
            ledgers[backend] = fed.run_round(cfg, rounds + 1)["wire_bytes"]
            feds[backend] = fed
        assert ledgers["mesh"] == ledgers["vmap"], "mesh ledger diverged"
        _assert_bitwise(feds["vmap"], feds["mesh"], m)
        lanes = padded_lanes(m, devices)
        per_m[m] = {"lanes_per_device": lanes // devices,
                    "padded": lanes - m,
                    "vmap_ms": times["vmap"] * 1e3,
                    "mesh_ms": times["mesh"] * 1e3,
                    "parity": times["vmap"] / times["mesh"]}
        rows.append([m, devices, lanes // devices, lanes - m,
                     f"{times['vmap'] * 1e3:.1f}",
                     f"{times['mesh'] * 1e3:.1f}",
                     f"{per_m[m]['parity']:.2f}×", "✓"])
    print_table(
        f"fed mesh backend: ms/round, vmap vs shard_map lanes-on-devices "
        f"(dim={dim}, ndsc R=2, {devices} host devices, bitwise gate "
        f"asserted per run)",
        ["m", "devices", "lanes/dev", "pad", "vmap", "mesh", "parity",
         "bitwise"], rows)
    return {"devices": devices,
            "bitwise": True,
            "per_m": {str(m): {k: (round(v, 3) if isinstance(v, float)
                                   else v)
                               for k, v in rec.items()}
                      for m, rec in per_m.items()}}


if __name__ == "__main__":
    run()
