"""Paper Fig. 1b: empirical convergence rate of DGD-DEF vs bit budget R.

Least squares min ½‖y − Ax‖² with A ~ Gaussian³ (n=116). Empirical rate =
(‖x_T − x*‖/‖x_0 − x*‖)^(1/T), clipped at 1 when divergent. The paper's
claim to validate: DE/NDE track unquantized GD down to R ≈ log(1/σ)+log β
while naive scalar quantization needs R ≳ log(√n/σ).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import gaussian_cubed, make_codec, print_table
from repro.core import baselines as B
from repro.core import optim as O


def run(n: int = 116, m: int = 200, steps: int = 120, seed: int = 0,
        budgets=(1, 2, 3, 4, 5, 6, 8, 10)):
    key = jax.random.key(seed)
    ka, kx = jax.random.split(key)
    a = gaussian_cubed(ka, (m, n)) / jnp.sqrt(m)
    x_star = jax.random.normal(kx, (n,))
    b = a @ x_star
    h = a.T @ a
    eigs = jnp.linalg.eigvalsh(h)
    big_l, mu = float(eigs[-1]), float(max(eigs[0], 1e-6))
    alpha = O.alpha_star(big_l, mu)
    sigma = O.sigma_rate(big_l, mu)
    grad = lambda x: h @ x - a.T @ b
    x0 = jnp.zeros((n,))
    d0 = float(jnp.linalg.norm(x0 - x_star))

    def emp_rate(trace):
        fin = float(trace.dist_history[-1])
        rate = (fin / d0) ** (1.0 / steps) if fin > 0 else 0.0
        return min(rate, 1.0)

    header = ["method"] + [f"R={r}" for r in budgets] + ["(unquantized)"]
    rows = []

    d_range = float(jnp.linalg.norm(x_star)) * 1.5
    rates = []
    for R in budgets:
        t = O.dqgd_schedule(grad, x0, max(2, int(2 ** R)), alpha, steps,
                            big_l, mu, d_range, n, x_star=x_star)
        rates.append(f"{emp_rate(t):.4f}")
    rows.append(["DQGD [6] (naive scalar)"] + rates + [f"{sigma:.4f}"])

    rates = []
    for R in budgets:
        naive = B.naive_uniform(max(2, int(2 ** R)))
        t = O.dqgd(grad, x0, naive.roundtrip, alpha, steps, x_star=x_star)
        rates.append(f"{emp_rate(t):.4f}")
    rows.append(["EF-QGD (naive + ‖·‖∞ scale)"] + rates + [f"{sigma:.4f}"])

    for name, emb in (("DGD-DEF (DE)", "democratic"),
                      ("DGD-DEF (NDE-H)", "near_democratic")):
        kind = "haar" if emb == "democratic" else "hadamard"
        rates = []
        for R in budgets:
            codec = make_codec(kind, n, float(R), embedding=emb, aspect=1.0)
            t = O.dgd_def(grad, x0, codec, alpha, steps, x_star=x_star)
            rates.append(f"{emp_rate(t):.4f}")
        rows.append([name] + rates + [f"{sigma:.4f}"])

    print_table(
        f"Fig. 1b — empirical rate vs R (least squares n={n}, σ={sigma:.4f})",
        header, rows)
    return rows


if __name__ == "__main__":
    run()
