"""Paper Fig. 1c: wall-clock time, democratic (LV iterations) vs
near-democratic (one transform) embeddings, vs dimension.

The paper solved (5) with CVX (interior point); our DE uses the
Lyubarskii–Vershynin iterative algorithm (O(n²)/iter for dense frames), so
absolute numbers differ, but the headline — NDE is orders of magnitude
cheaper and the gap widens with n — must reproduce. The FWHT path is also
timed to show the O(n log n) relaxation.
"""
from __future__ import annotations

import jax

from benchmarks.common import gaussian_cubed, print_table, timed
from repro.core import embeddings as E
from repro.core import frames as F


def run(dims=(128, 256, 512, 1024, 2048, 4096), seed: int = 0):
    rows = []
    for n in dims:
        key = jax.random.key(seed)
        y = gaussian_cubed(jax.random.fold_in(key, n), (n,))
        n_pow = F.next_pow2(n)
        haar = F.haar_frame(jax.random.fold_in(key, 1), n, n_pow)
        had = F.hadamard_frame(jax.random.fold_in(key, 2), n, n_pow)

        t_de = timed(jax.jit(lambda yy: E.democratic(haar, yy)), y,
                     repeats=3) * 1e3
        t_nde_o = timed(jax.jit(lambda yy: E.near_democratic(haar, yy)), y,
                        repeats=10) * 1e3
        t_nde_h = timed(jax.jit(lambda yy: E.near_democratic(had, yy)), y,
                        repeats=10) * 1e3
        rows.append([n, f"{t_de:.3f}", f"{t_nde_o:.3f}", f"{t_nde_h:.3f}",
                     f"{t_de / max(t_nde_h, 1e-9):.0f}×"])
    print_table("Fig. 1c — embedding wall-clock (ms)",
                ["n", "DE (LV iter)", "NDE orthonormal", "NDE Hadamard/FWHT",
                 "DE/NDE-H"], rows)
    return rows


if __name__ == "__main__":
    run()
