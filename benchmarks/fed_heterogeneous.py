"""Federated convergence vs. total bits: heterogeneous budgets beat uniform.

    PYTHONPATH=src python -m benchmarks.fed_heterogeneous

The client–server counterpart of the paper's consensus experiments: m clients
hold least-squares shards whose signal scales span two orders of magnitude,
so their update norms are wildly heterogeneous. At a FIXED total budget
(Σ R_i = m·R̄ bits per model dimension per round), splitting the budget
  * uniformly starves the dominant clients (their NDSC contraction factor
    2^{2−R}√log(2·chunk) exceeds 1 at R̄ = 1 — the run destabilizes), while
  * norm-proportionally / by water-filling gives the heavy clients enough
    bits to stay contractive and spends ~nothing on the negligible ones —
    same total bits, orders of magnitude lower final loss.

The run also checks the per-round wire-bytes ledger against the analytic
`wire_bits` audit TO THE BYTE (exact_keep chunk subsampling makes the
realized kept-chunk count deterministic), and exercises partial
participation + straggler dropout with the EF21-style fedmem aggregator.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table
from repro.fed import (ClientConfig, FedConfig, Federation, ServerConfig,
                       budget)
from repro import codecs as registry


def make_problem(m: int = 8, dim: int = 128, per_client: int = 256,
                 scale_span: float = 1.0, seed: int = 0):
    """Least-squares shards with per-client signal scales logspace(±span)."""
    ka, kx = jax.random.split(jax.random.key(seed))
    scales = np.logspace(-scale_span, scale_span, m)
    a = jax.random.normal(ka, (m, per_client, dim)) / jnp.sqrt(per_client)
    x_true = jax.random.normal(kx, (dim,))
    shards = [{"a": scales[i] * a[i], "b": scales[i] * (a[i] @ x_true)}
              for i in range(m)]

    def loss_fn(p, batch):
        r = batch["a"] @ p["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r)

    all_a = jnp.concatenate([s["a"] for s in shards])
    all_b = jnp.concatenate([s["b"] for s in shards])

    def global_loss(p):
        r = all_a @ p["x"] - all_b
        return 0.5 * jnp.mean(r * r)

    h = (all_a.T @ all_a) / all_a.shape[0]
    eigs = jnp.linalg.eigvalsh(h)
    lr = float(2.0 / (eigs[-1] + eigs[0]))     # α* for the global quadratic
    return shards, loss_fn, global_loss, x_true, lr


def probe_norms(loss_fn, params, shards) -> list:
    """Per-client update-norm estimates ‖∇f_i(x₀)‖ for the allocators."""
    return [float(jnp.linalg.norm(jax.grad(loss_fn)(params, s)["x"]))
            for s in shards]


def run(m: int = 8, dim: int = 128, avg_rate: float = 1.0, rounds: int = 50,
        chunk: int = 64, seed: int = 0):
    shards, loss_fn, global_loss, x_true, lr = make_problem(m, dim, seed=seed)
    params = {"x": jnp.zeros(dim)}
    norms = probe_norms(loss_fn, params, shards)
    total = avg_rate * m
    ccfg = ClientConfig(local_steps=1, lr=lr)

    rows, results = [], {}
    for policy in ("uniform", "norm_proportional", "waterfill"):
        rates = budget.allocate(policy, total, m, norms=norms, min_rate=0.25)
        codecs = [registry.make("ndsc", budget=float(r), chunk=chunk)
                  for r in rates]
        fed = Federation(loss_fn, params, shards, codecs, ccfg,
                         ServerConfig(), seed=seed)
        hist = fed.run(FedConfig(num_rounds=rounds, seed=seed),
                       eval_fn=global_loss)
        ledger_exact = all(
            real == ana for real, ana in zip(hist["wire_bytes"],
                                             hist["analytic_bytes"]))
        assert ledger_exact, (
            f"{policy}: realized wire bytes diverged from the analytic audit")
        final = float(np.mean(hist["loss"][-5:]))
        dist = float(jnp.linalg.norm(fed.server.params["x"] - x_true))
        results[policy] = final
        rows.append([policy,
                     np.array2string(np.round(rates, 2), separator=","),
                     f"{rates.sum():.2f}",
                     f"{hist['wire_bytes'][0]:.0f}",
                     f"{final:.3e}", f"{dist:.3e}",
                     "byte-exact" if ledger_exact else "MISMATCH"])

    print_table(
        f"fed: convergence at equal total budget "
        f"(m={m}, dim={dim}, R̄={avg_rate} bit/dim, {rounds} rounds)",
        ["policy", "per-client R_i", "ΣR", "bytes/round", "final loss",
         "‖x−x*‖", "ledger"], rows)

    for hetero in ("norm_proportional", "waterfill"):
        assert results[hetero] < results["uniform"], (
            f"{hetero} ({results[hetero]:.3e}) should beat uniform "
            f"({results['uniform']:.3e}) at equal total bits")
    print("   heterogeneous allocation beats uniform at equal total bits: "
          f"uniform {results['uniform']:.2e} → waterfill "
          f"{results['waterfill']:.2e}")

    # -- partial participation + stragglers, EF21-style server memory -------
    rates = budget.allocate("waterfill", total, m, norms=norms, min_rate=0.25)
    codecs = [registry.make("ndsc", budget=float(r), chunk=chunk)
              for r in rates]
    # stale memory slots re-apply old deltas: damp the server step (plain
    # fedavg at server_lr=1 destabilizes under 50% participation here)
    fed = Federation(loss_fn, params, shards, codecs, ccfg,
                     ServerConfig(aggregator="fedmem", server_lr=0.25),
                     seed=seed)
    hist = fed.run(
        FedConfig(num_rounds=rounds, participation=0.5, dropout=0.2,
                  seed=seed),
        eval_fn=global_loss)
    assert all(r == a for r, a in zip(hist["wire_bytes"],
                                      hist["analytic_bytes"]))
    sampled = sum(len(p) + len(s) for p, s in zip(hist["participants"],
                                                  hist["stragglers"]))
    dropped = sum(len(s) for s in hist["stragglers"])
    print_table(
        "fed: 50% participation, 20% stragglers, fedmem aggregation",
        ["rounds", "sampled", "dropped", "total MB", "final loss"],
        [[rounds, sampled, dropped,
          f"{hist['cum_bytes'][-1] / 1e6:.4f}",
          f"{np.mean(hist['loss'][-5:]):.3e}"]])
    return results


if __name__ == "__main__":
    run()
