"""Paper Fig. 1d: ℓ2-regularized least squares with sparsified + 1-bit
quantized gradients at an effective R = 0.5 bits/dim, with vs without NDE.

Protocol: the SAME compressor (random-50% sparsification → 1-bit ‖·‖∞
nearest-neighbour quantization, error feedback) is applied either to the raw
gradient (vanilla) or to its near-democratic embedding (NDE, Thm. 4
composition). The paper uses MNIST (784-dim); MNIST does not ship offline,
so the protocol runs on a heavy-tailed synthetic 784-dim problem (noted in
EXPERIMENTS.md). Claim to validate: the NDE-wrapped scheme converges markedly
faster — heavy-tailed gradients are exactly where flattening pays.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import print_table
from repro.core import frames as F
from repro.core import optim as O
from repro.core import quantizers as q


def _sparse1bit(k, g):
    """rand-50% + 1-bit NN quantization on g/‖g‖∞ (R = 0.5 bits/dim)."""
    mask = q.subsample_mask(k, g.shape, 0.5)
    scale = jnp.max(jnp.abs(g))
    return q.uniform_quantize(g / jnp.maximum(scale, 1e-30), 2) * scale * mask


def run(n: int = 784, m: int = 500, steps: int = 60, lam: float = 0.05,
        seed: int = 0):
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    # heavy-tailed design (Gaussian³ features — the paper's §5 protocol)
    feats = jax.random.normal(k1, (m, n)) ** 3
    feats = feats / jnp.linalg.norm(feats, axis=0, keepdims=True)
    y_lab = jnp.sign(jax.random.normal(k2, (m,)))
    h = feats.T @ feats / m + lam * jnp.eye(n)
    rhs = feats.T @ y_lab / m
    x_star = jnp.linalg.solve(h, rhs)
    eigs = jnp.linalg.eigvalsh(h)
    alpha = O.alpha_star(float(eigs[-1]), float(eigs[0]))
    grad = lambda x: h @ x - rhs
    x0 = jnp.zeros((n,))
    d0 = float(jnp.linalg.norm(x_star))

    t_v = O.dqgd(grad, x0, _sparse1bit, alpha, steps, x_star=x_star)

    frame = F.make_frame("haar", jax.random.key(1), n, n)

    def nde_wrapped(k, g):                      # Thm. 4 composition
        return frame.apply(_sparse1bit(k, frame.apply_t(g)))

    t_n = O.dqgd(grad, x0, nde_wrapped, alpha, steps, x_star=x_star)
    t_gd = O.gd(grad, x0, alpha, steps, x_star=x_star)

    rows = [
        ["rand-50% + 1-bit (vanilla)",
         f"{float(t_v.dist_history[-1]) / d0:.3e}"],
        ["rand-50% + 1-bit + NDE (Thm. 4)",
         f"{float(t_n.dist_history[-1]) / d0:.3e}"],
        ["unquantized GD", f"{float(t_gd.dist_history[-1]) / d0:.3e}"],
    ]
    print_table(
        f"Fig. 1d — ‖x_T − x*‖/‖x*‖ after {steps} steps (R = 0.5 bits/dim)",
        ["method", "final normalized distance"], rows)
    return rows


if __name__ == "__main__":
    run()
