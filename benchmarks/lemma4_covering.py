"""Paper Lemma 4 / §3.2: covering efficiency of (near-)democratic coding.

ρ(Q) = |range|^{1/n} · d(Q)/r. For the uniform scalar quantizer ρ = √n
(dimension-DEPENDENT); for DSC ρ_d = 2^{1+R(1−1/λ)}·K_u and for NDSC
ρ_nd = 2^{2+R(1−1/λ)}·√log(2N) — dimension-free / weakly-log. This
benchmark estimates d(Q) empirically as the max relative error over many
worst-case-ish inputs and reports the implied ρ̂ alongside the theory.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from benchmarks.common import gaussian_cubed, make_codec, print_table
from repro.core import baselines as B


def run(R: float = 4.0, trials: int = 40, seed: int = 0,
        dims=(64, 256, 1024)):
    rows = []
    for n in dims:
        keys = jax.random.split(jax.random.key(seed), trials)
        # worst-case-seeking inputs: heavy-tailed + a few canonical spikes
        ys = [gaussian_cubed(k, (n,)) for k in keys[: trials // 2]]
        ys += [jnp.zeros((n,)).at[int(i % n)].set(1.0)
               for i in range(trials // 2)]

        naive = B.naive_uniform(int(2 ** R))
        codec = make_codec("hadamard", n, R)

        def max_rel(rt):
            worst = 0.0
            for i, y in enumerate(ys):
                y_hat = rt(jax.random.fold_in(keys[0], i), y)
                worst = max(worst, float(jnp.linalg.norm(y_hat - y)
                                         / jnp.linalg.norm(y)))
            return worst

        d_naive = max_rel(naive.roundtrip)
        d_ndsc = max_rel(lambda k, y: codec.roundtrip(y, k))
        # ρ̂ = 2^R · d(Q) (range 2^{nR}, r = ‖y‖; per-dimension normalized)
        rho_naive = 2 ** R * d_naive
        rho_ndsc = 2 ** R * d_ndsc
        lam = codec.aspect_ratio
        rho_theory = 2 ** (2 + R * (1 - 1 / lam)) * math.sqrt(
            math.log(2 * codec.N))
        rows.append([n, f"{rho_naive:.2f}", f"{math.sqrt(n):.2f}",
                     f"{rho_ndsc:.2f}", f"{rho_theory:.2f}"])
    print_table(
        f"Lemma 4 — covering efficiency ρ̂ = 2^R·d(Q) at R={R:g}",
        ["n", "naive ρ̂", "√n (theory)", "NDSC ρ̂", "NDSC ρ bound"], rows)
    return rows


if __name__ == "__main__":
    run()
