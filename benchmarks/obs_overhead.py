"""Gate: obs instrumentation overhead on the cohort round loop < 2%.

    PYTHONPATH=src python -m benchmarks.obs_overhead

The `repro.obs` contract is zero-overhead-when-disabled and cheap-when-
enabled: spans and counters live entirely on the host side of the jit
boundary, so an instrumented round adds only perf_counter reads and dict
appends around the device dispatch. This benchmark measures both arms on
the fed_cohort round loop (the hottest instrumented driver — one
`fed.round` span + ~13 host-side events per round).

Methodology — the effect is percent-level on a ~10 ms round, well below
CPU frequency/scheduler drift between separate timing windows, so the two
arms are PAIRED: one Federation, one compiled program cache, rounds
alternating between `obs.suspended()` (blanks the ambient session —
benchmarks.run executes every benchmark under obs, so without the blanking
the "disabled" arm would silently be enabled) and an enabled session
activated via `obs.use()`. Slow drift then hits both arms equally and
cancels in the ratio. Each round is individually timed through a
`block_until_ready` on the updated server params, so neither arm can hide
device work in the async dispatch queue; compile time is excluded by a
warmup round per arm. The written trace.json is schema-validated before
the gate. Raises if the enabled/disabled time ratio exceeds `threshold`.
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp

from benchmarks.common import print_table
from benchmarks.fed_heterogeneous import make_problem
from repro.fed import ClientConfig, FedConfig, Federation, ServerConfig
from repro import codecs as registry
from repro.obs import core as obs_lib
from repro.obs import trace as trace_lib
from repro.obs.sinks import MemorySink


def _one_round(fed, cfg, t: int) -> float:
    t0 = time.perf_counter()
    fed.run_round(cfg, t)
    jax.block_until_ready(fed.server.params)
    return time.perf_counter() - t0


def run(m: int = 32, dim: int = 96, per_client: int = 32, rounds: int = 60,
        chunk: int = 32, threshold: float = 0.02, seed: int = 0) -> dict:
    """`rounds` timed rounds PER ARM, interleaved round-by-round."""
    shards, loss_fn, _, _, lr = make_problem(m, dim, per_client=per_client,
                                             scale_span=0.0, seed=seed)
    fed = Federation(loss_fn, {"x": jnp.zeros(dim)}, shards,
                     registry.make("ndsc", 2.0, chunk=chunk),
                     ClientConfig(local_steps=1, lr=lr), ServerConfig(),
                     seed=seed)
    cfg = FedConfig(num_rounds=2 * rounds + 2, seed=seed)

    trace_path = os.path.join(tempfile.mkdtemp(prefix="obs_overhead_"),
                              "trace.json")
    session = obs_lib.Obs(sinks=(MemorySink(),
                                 trace_lib.ChromeTraceSink(trace_path)))
    # warmup: compile the cohort round program and touch both arms' paths
    with obs_lib.suspended():
        fed.run_round(cfg, 0)
    with obs_lib.use(session):
        fed.run_round(cfg, 1)

    t_off, t_on = [], []
    for t in range(2, 2 * rounds + 2):
        if t % 2 == 0:
            with obs_lib.suspended():
                t_off.append(_one_round(fed, cfg, t))
        else:
            with obs_lib.use(session):
                t_on.append(_one_round(fed, cfg, t))
    session.close()

    n_events = trace_lib.validate_trace(trace_path)
    # trimmed means: drop the slowest 10% per arm (GC pauses / scheduler
    # preemption land on single rounds and are not what's being gated)
    keep = max(1, int(round(len(t_off) * 0.9)))
    mean_off = sum(sorted(t_off)[:keep]) / keep
    mean_on = sum(sorted(t_on)[:keep]) / keep
    overhead = mean_on / mean_off - 1.0
    print_table(
        "obs overhead on the cohort round loop (paired rounds)",
        ("arm", "s/round (10% trimmed mean)", "events"),
        [("disabled", f"{mean_off * 1e3:.3f} ms", "-"),
         ("enabled", f"{mean_on * 1e3:.3f} ms", n_events),
         ("overhead", f"{overhead * 100:+.2f}%", f"gate < {threshold:.0%}")])
    if overhead >= threshold:
        raise AssertionError(
            f"obs overhead {overhead:.2%} >= {threshold:.0%} "
            f"(disabled {mean_off * 1e3:.3f} ms/round, "
            f"enabled {mean_on * 1e3:.3f} ms/round)")
    recompiles = session.summary()["recompiles"]
    return {"overhead": round(overhead, 5), "threshold": threshold,
            "s_per_round_disabled": mean_off, "s_per_round_enabled": mean_on,
            "trace_events": n_events, "recompiles": recompiles}


if __name__ == "__main__":
    run()
