"""Serve engine under bursty open-loop load: TTFT and throughput.

Drives `repro.serve.Engine` with the piecewise-Poisson load generator and
reports p50/p99 time-to-first-token and tokens/s/device over the grid

    {quantized NDSC KV cache, unquantized f32 cache}
  × {prefix-hit admission, cold admission}

where every request covers the same tokens (hits carry `prefix_id` plus a
short suffix; cold requests carry the full prefix+suffix prompt), so the
TTFT gap between the classes is pure prefill amortization and the gap
between the cache configs is the bits/32 HBM story at serve time.

GATE — the benchmark REFUSES to report numbers unless the prefix-cache
bit-exactness contract holds first, for both cache configs: a prefix-hit
admission's cached K/V words (packed int32 + scales when quantized),
positions, and all subsequent greedy tokens must be bitwise identical to a
cold admission that prefills the same prefix on the spot
(`repro.serve.verify_prefix_contract`).

Each config gets an untimed warmup pass over a clone of the trace (the
engine's jitted programs are shared per (config, max_seq) process-wide), so
the timed pass measures steady-state serving, not XLA compiles.

  PYTHONPATH=src python -m benchmarks.serve_load
  PYTHONPATH=src python -m benchmarks.run serve_load --tiny
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro import configs
from repro.models import model as model_lib
from repro.serve import (Engine, LoadConfig, ServeConfig, generate, play,
                         verify_prefix_contract)


def _percentiles(vals: list) -> dict:
    if not vals:
        return {"p50_ms": None, "p99_ms": None, "n": 0}
    arr = np.asarray(vals) * 1e3
    return {"p50_ms": round(float(np.percentile(arr, 50)), 3),
            "p99_ms": round(float(np.percentile(arr, 99)), 3),
            "n": len(vals)}


def _run_config(cfg, params, serve_cfg: ServeConfig, load_cfg: LoadConfig,
                prefix_tokens: np.ndarray) -> dict:
    def fresh_trace():
        # generation is deterministic in the seed: warmup and timed pass
        # replay the identical trace on fresh Request objects
        return generate(load_cfg, cfg.vocab_size, prefix_id="system",
                        prefix_tokens=prefix_tokens)

    def fresh_engine():
        eng = Engine(cfg, params, serve_cfg)
        eng.register_prefix("system", prefix_tokens, prefill=True)
        return eng

    # untimed warmup: same trace shape -> same jitted specializations
    play(fresh_engine(), fresh_trace())
    out = play(fresh_engine(), fresh_trace())

    finished = out["finished"]
    ttft = {"prefix_hit": [], "cold": []}
    for r in finished:
        kind = "prefix_hit" if r.admission == "prefix_hit" else "cold"
        ttft[kind].append(r.ttft_s)
    total_tokens = sum(len(r.tokens_out) for r in finished)
    tok_per_s = total_tokens / out["wall_s"]
    return {
        "requests": len(finished),
        "decode_steps": out["steps"],
        "wall_s": round(out["wall_s"], 3),
        "tokens": total_tokens,
        "tokens_per_s_per_device": round(tok_per_s / jax.device_count(), 1),
        "ttft": {k: _percentiles(v) for k, v in ttft.items()},
    }


def run(arch: str = "yi-6b", bits: int = 8, slots: int = 4,
        max_seq: int = 128, prefix_len: int = 24, n_requests: int = 48,
        base_rate: float = 20.0, burst_rate: float = 120.0,
        burst_period_s: float = 2.0, burst_len_s: float = 0.5,
        prompt_len: tuple = (4, 10), max_new_tokens: tuple = (4, 12),
        prefix_ratio: float = 0.5, seed: int = 0) -> dict:
    base = configs.get_reduced(arch)
    qcfg = dataclasses.replace(base, kv_quant_bits=bits)
    params = model_lib.init_params(jax.random.key(0), base)
    rng = np.random.default_rng(seed)
    prefix_tokens = rng.integers(0, base.vocab_size, prefix_len,
                                 dtype=np.int32)
    contract_prompt = rng.integers(0, base.vocab_size, 6, dtype=np.int32)
    serve_cfg = ServeConfig(slots=slots, max_seq=max_seq)
    load_cfg = LoadConfig(n_requests=n_requests, base_rate=base_rate,
                          burst_rate=burst_rate,
                          burst_period_s=burst_period_s,
                          burst_len_s=burst_len_s, prompt_len=prompt_len,
                          max_new_tokens=max_new_tokens,
                          prefix_ratio=prefix_ratio, seed=seed)

    results: dict = {"arch": arch, "bits": bits, "slots": slots,
                     "devices": jax.device_count(), "contract": {}}
    for label, cfg in (("quantized", qcfg), ("unquantized", base)):
        # the gate: no contract, no numbers
        try:
            evidence = verify_prefix_contract(
                cfg, params, serve_cfg, prefix_tokens, contract_prompt)
        except AssertionError as exc:
            raise RuntimeError(
                f"prefix-cache contract FAILED for the {label} config — "
                f"refusing to report load numbers: {exc}") from exc
        results["contract"][label] = {"bitexact": True, **evidence}

    for label, cfg in (("quantized", qcfg), ("unquantized", base)):
        results[label] = _run_config(cfg, params, serve_cfg, load_cfg,
                                     prefix_tokens)

    q, u = results["quantized"], results["unquantized"]
    results["headline"] = {
        "quant_tokens_per_s_per_device": q["tokens_per_s_per_device"],
        "unquant_tokens_per_s_per_device": u["tokens_per_s_per_device"],
        "quant_hit_p50_ms": q["ttft"]["prefix_hit"]["p50_ms"],
        "quant_cold_p50_ms": q["ttft"]["cold"]["p50_ms"],
    }
    return results


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
