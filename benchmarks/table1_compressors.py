"""Paper Table 1: compression schemes — bits, normalized error, wall time.

Empirical counterpart of the theory table: for each scheme, measure the
normalized ℓ2 error E‖C(y)−y‖/‖y‖ on Gaussian³ vectors (n=1024) and the
wire-bit budget, at a matched R≈4 bits/dim where the scheme allows it.
"""
from __future__ import annotations

import jax

from benchmarks.common import (gaussian_cubed, make_codec, normalized_error,
                               print_table, timed)
from repro.core import baselines as B


def run(n: int = 1024, trials: int = 20, seed: int = 0):
    key = jax.random.key(seed)
    y = gaussian_cubed(key, (n,))
    kerr = jax.random.key(seed + 1)

    rows = []

    def add(name, roundtrip, bits):
        err = normalized_error(roundtrip, y, kerr, trials)
        t = timed(lambda: roundtrip(kerr, y)) * 1e3
        rows.append([name, f"{bits:.0f}", f"{err:.4f}", f"{t:.2f}ms"])

    for comp in [B.sign_compressor(), B.ternary(), B.qsgd(s=16),
                 B.naive_uniform(16), B.standard_dither(16),
                 B.topk(0.125, quant_levels=256),
                 B.randk(0.125, quant_levels=256)]:
        add(comp.name, comp.roundtrip, comp.wire_bits(n))

    dsc = make_codec("haar", n, 4.0, embedding="democratic", aspect=1.0)
    add("DSC (haar, λ=1)", lambda k, v: dsc.roundtrip(v, k),
        dsc.wire_bits() + 32)
    ndsc_h = make_codec("hadamard", n, 4.0)
    add("NDSC (hadamard)", lambda k, v: ndsc_h.roundtrip(v, k),
        ndsc_h.wire_bits() + 32)
    ndsc_o = make_codec("haar", n, 4.0)
    add("NDSC (orthonormal)", lambda k, v: ndsc_o.roundtrip(v, k),
        ndsc_o.wire_bits() + 32)

    print_table("Table 1 — compression schemes (n=1024, Gaussian³)",
                ["scheme", "wire bits", "‖C(y)−y‖/‖y‖", "time"], rows)
    return rows


if __name__ == "__main__":
    run()
