"""Paper Fig. 2: SVM hinge-loss training with DQ-PSGD at sub-linear budgets.

Fig 2a/2b protocol: two Gaussian classes, n=30, m=100 datapoints, R = 0.5:
random-50% sparsification + 1-bit, with vs without NDE; top-10% + 5 bits;
unquantized PSGD reference. Metric: suboptimality gap f(x̄_T) − f* and
training classification error.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import print_table
from repro.core.coding import Codec, CodecConfig
from repro.core import baselines as B
from repro.core import frames as F
from repro.core import optim as O
from repro.data import synthetic_two_class


def run(n: int = 30, m: int = 100, steps: int = 600, seed: int = 0,
        batch: int = 20, alpha: float = 0.05):
    key = jax.random.key(seed)
    a, b = synthetic_two_class(key, m // 2, n)

    def full_loss(x):
        return jnp.mean(jnp.maximum(0.0, 1.0 - b * (a @ x)))

    def class_err(x):
        return jnp.mean((jnp.sign(a @ x) != b).astype(jnp.float32))

    def subgrad(k, x):
        idx = jax.random.randint(k, (batch,), 0, m)
        ai, bi = a[idx], b[idx]
        g = -(bi[:, None] * ai) * ((bi * (ai @ x)) < 1.0)[:, None]
        return jnp.mean(g, axis=0)

    # f* via many-step unquantized PSGD (stands in for the CVX solution)
    ref = O.dq_psgd(subgrad, jnp.zeros((n,)), None, alpha, steps * 4,
                    key=jax.random.key(99))
    f_star = float(full_loss(ref.x_avg))

    rows = []

    def record(name, trace):
        rows.append([name, f"{float(full_loss(trace.x_avg)) - f_star:.4f}",
                     f"{float(class_err(trace.x_avg)):.3f}"])

    x0 = jnp.zeros((n,))
    record("unquantized PSGD",
           O.dq_psgd(subgrad, x0, None, alpha, steps, key=jax.random.key(1)))

    frame = F.make_frame("haar", jax.random.key(2), n, n)
    codec = Codec(frame, CodecConfig(bits_per_dim=0.5, dithered=True))
    record("DQ-PSGD rand-50%+1b + NDE (R=0.5)",
           O.dq_psgd(subgrad, x0, codec, alpha, steps, key=jax.random.key(1)))

    rand_naive = B.randk(0.5, quant_levels=2, unbiased=True)
    record("rand-50%+1b (vanilla, R=0.5)",
           O.dq_psgd(subgrad, x0, None, alpha, steps, key=jax.random.key(1),
                     compressor_roundtrip=rand_naive.roundtrip))

    topk = B.topk(0.1, quant_levels=32)
    record("top-10%+5b (vanilla)",
           O.dq_psgd(subgrad, x0, None, alpha, steps, key=jax.random.key(1),
                     compressor_roundtrip=topk.roundtrip))

    def topk_nde(k, g):
        x_emb = frame.apply_t(g)
        x_hat = topk.roundtrip(k, x_emb)
        return frame.apply(x_hat)
    record("top-10%+5b + NDE",
           O.dq_psgd(subgrad, x0, None, alpha, steps, key=jax.random.key(1),
                     compressor_roundtrip=topk_nde))

    print_table(f"Fig. 2 — SVM (n={n}, m={m}, {steps} steps, f*={f_star:.4f})",
                ["method", "subopt gap", "train class err"], rows)
    return rows


if __name__ == "__main__":
    run()
