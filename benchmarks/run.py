"""Run every paper-table/figure benchmark (CPU-friendly sizes).

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig1b fig2 # subset

The multi-pod dry-run / §Roofline table is produced separately by
`python -m repro.launch.dryrun --sweep` (it needs a 512-device process) and
formatted by benchmarks.roofline.
"""
from __future__ import annotations

import sys
import time

from benchmarks import (appJ_frames, appN_aspect_ratio,
                        fed_heterogeneous, fig1a_compression_error,
                        fig1b_dgddef_rate, fig1c_timing, fig1d_sparsified_gd,
                        fig2_svm, fig3_multiworker, lemma4_covering,
                        modelscale_ablation, table1_compressors)

ALL = {
    "fed": fed_heterogeneous.run,
    "table1": table1_compressors.run,
    "fig1a": fig1a_compression_error.run,
    "fig1b": fig1b_dgddef_rate.run,
    "fig1c": fig1c_timing.run,
    "fig1d": fig1d_sparsified_gd.run,
    "fig2": fig2_svm.run,
    "fig3": fig3_multiworker.run,
    "appJ": appJ_frames.run,
    "appN": appN_aspect_ratio.run,
    "lemma4": lemma4_covering.run,
    "modelscale": modelscale_ablation.run,
}


def main(argv=None) -> None:
    names = (argv or sys.argv[1:]) or list(ALL)
    for name in names:
        t0 = time.time()
        ALL[name]()
        print(f"[{name} done in {time.time()-t0:.1f}s]")


if __name__ == "__main__":
    main()
