"""Run every paper-table/figure benchmark (CPU-friendly sizes).

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig1b fig2 # subset
  PYTHONPATH=src python -m benchmarks.run fed table1 fig1c --tiny \
      --json BENCH_smoke.json                        # CI smoke lane

Each benchmark module is imported lazily when selected, so one broken module
can't kill the whole runner; failures are reported per benchmark and the run
continues (nonzero exit at the end if anything failed). `--tiny` substitutes
CPU-tiny kwargs for the CI smoke lane; `--json` writes per-benchmark
wall-time + the headline result for the perf-trajectory artifact.

Every benchmark executes inside its own `repro.obs` session, so the --json
payload carries a per-benchmark `obs` summary (span timings, dispatch
counters, recompile counts) next to the headline metric, plus a top-level
`schema_version` and `env` block (jax/jaxlib versions, backend, devices)
that make payloads comparable across commits and machines. `--obs DIR`
additionally writes `<name>.events.jsonl` and `<name>.trace.json`
(Perfetto-loadable) per benchmark into DIR.

The multi-pod dry-run HLO table is produced separately by
`python -m repro.launch.dryrun --sweep` (it needs a 512-device process) and
formatted by benchmarks.hlo_report (formerly misnamed benchmarks.roofline;
the measured kernel roofline is the `codec_roofline` benchmark below).
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import platform
import sys
import time
import traceback

from repro.obs import core as obs_lib

# Version of the --json payload layout. Bump when records/env/obs keys
# change shape, so the perf-trajectory tooling can branch on it.
SCHEMA_VERSION = 2

# benchmark name -> module under benchmarks/ exposing run(**kwargs)
ALL = {
    "fed": "fed_heterogeneous",
    "fed_agg": "fed_aggregate_scaling",
    "fed_cohort": "fed_cohort_scaling",
    "fed_mesh": "fed_mesh_scaling",
    "codec_roofline": "codec_roofline",
    "codec_frontier": "codec_frontier",
    "serve_load": "serve_load",
    "table1": "table1_compressors",
    "fig1a": "fig1a_compression_error",
    "fig1b": "fig1b_dgddef_rate",
    "fig1c": "fig1c_timing",
    "fig1d": "fig1d_sparsified_gd",
    "fig2": "fig2_svm",
    "fig3": "fig3_multiworker",
    "appJ": "appJ_frames",
    "appN": "appN_aspect_ratio",
    "lemma4": "lemma4_covering",
    "modelscale": "modelscale_ablation",
    "obs_overhead": "obs_overhead",
}

# --tiny kwargs: small enough for the CI smoke lane, large enough that each
# benchmark's internal assertions still hold
TINY = {
    "fed": dict(m=6, dim=96, rounds=30, chunk=32),
    "fed_agg": dict(m_values=(8, 64), dim=256, reps=3),
    "fed_cohort": dict(m_values=(8, 32), dim=48, per_client=16, rounds=3,
                       adaptive_m=8, adaptive_rounds=25),
    "fed_mesh": dict(m_values=(3, 8), dim=48, per_client=16, rounds=2,
                     chunk=32),
    "codec_roofline": dict(n_values=(128, 512), bits_values=(1, 4),
                           rows=16, reps=1),
    "codec_frontier": dict(n=512, m=160, chunk=32, trials=3, rounds=3,
                           steps=15),
    "serve_load": dict(slots=2, max_seq=64, prefix_len=24, n_requests=16,
                       base_rate=10.0, burst_rate=40.0, burst_period_s=1.0,
                       burst_len_s=0.3, prompt_len=(3, 6),
                       max_new_tokens=(3, 6)),
    "table1": dict(n=256, trials=5),
    "fig1c": dict(dims=(128, 256, 512)),
    "obs_overhead": dict(m=8, dim=48, per_client=16, rounds=30,
                         threshold=0.10),
}


def env_info() -> dict:
    """The environment fingerprint embedded in every --json payload."""
    info = {
        "python": platform.python_version(),
        "platform": platform.platform(),
        "repro_force_pallas": os.environ.get("REPRO_FORCE_PALLAS"),
    }
    try:
        import jax
        import jaxlib
        info["jax"] = jax.__version__
        info["jaxlib"] = jaxlib.__version__
        info["backend"] = jax.default_backend()
        devs = jax.devices()
        info["device_kind"] = devs[0].device_kind if devs else None
        info["device_count"] = len(devs)
    except Exception as exc:                       # pragma: no cover
        info["jax"] = None
        info["error"] = repr(exc)
    return info


def _jsonable(obj, depth: int = 0):
    """Best-effort conversion of a benchmark's return value to JSON."""
    if depth > 4:
        return str(obj)
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, dict):
        return {str(k): _jsonable(v, depth + 1) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v, depth + 1) for v in obj[:50]]
    if hasattr(obj, "item") and getattr(obj, "ndim", None) == 0:
        return obj.item()                       # numpy scalar
    if hasattr(obj, "tolist"):
        return _jsonable(obj.tolist(), depth + 1)
    return str(obj)


def run_one(name: str, tiny: bool = False, obs_dir: str = None) -> dict:
    """Import + run one benchmark; never raises — failures land in the
    record (`ok`/`error`) so the rest of the run proceeds.

    Each benchmark gets its own obs session; its summary lands in the
    record under "obs". With `obs_dir` the raw events and a Perfetto trace
    are written there as `<name>.events.jsonl` / `<name>.trace.json`."""
    rec = {"name": name, "ok": False, "seconds": None, "headline": None,
           "error": None, "obs": None}
    jsonl = trace = None
    if obs_dir is not None:
        os.makedirs(obs_dir, exist_ok=True)
        jsonl = os.path.join(obs_dir, f"{name}.events.jsonl")
        trace = os.path.join(obs_dir, f"{name}.trace.json")
    session = obs_lib.enable(jsonl=jsonl, trace=trace)
    t0 = time.perf_counter()
    try:
        mod = importlib.import_module(f"benchmarks.{ALL[name]}")
        kwargs = TINY.get(name, {}) if tiny else {}
        with obs_lib.span(f"bench.{name}", tiny=tiny):
            rec["headline"] = _jsonable(mod.run(**kwargs))
        rec["ok"] = True
    except Exception:
        rec["error"] = traceback.format_exc(limit=8)
    rec["seconds"] = round(time.perf_counter() - t0, 3)
    obs_lib.disable()
    rec["obs"] = _jsonable(session.summary())
    return rec


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(
        prog="benchmarks.run", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    parser.add_argument("names", nargs="*", default=[], metavar="name",
                        help=f"benchmarks to run (default: all) from "
                             f"{', '.join(ALL)}")
    parser.add_argument("--tiny", action="store_true",
                        help="CPU-tiny sizes for the CI smoke lane")
    parser.add_argument("--json", metavar="PATH", default=None,
                        help="write per-benchmark wall-time + headline "
                             "metric to PATH")
    parser.add_argument("--obs", metavar="DIR", default=None,
                        help="write per-benchmark obs artifacts "
                             "(<name>.events.jsonl, <name>.trace.json) "
                             "into DIR")
    args = parser.parse_args(argv)
    unknown = [n for n in args.names if n not in ALL]
    if unknown:
        parser.error(f"unknown benchmark(s) {', '.join(unknown)}; "
                     f"choose from {', '.join(ALL)}")
    names = args.names or list(ALL)

    records = []
    for name in names:
        rec = run_one(name, tiny=args.tiny, obs_dir=args.obs)
        records.append(rec)
        if rec["ok"]:
            print(f"[{name} done in {rec['seconds']:.1f}s]")
        else:
            print(f"[{name} FAILED after {rec['seconds']:.1f}s]\n"
                  f"{rec['error']}", file=sys.stderr)

    failed = [r["name"] for r in records if not r["ok"]]
    if args.json:
        payload = {
            "schema_version": SCHEMA_VERSION,
            "tiny": args.tiny,
            "env": env_info(),
            "total_seconds": round(sum(r["seconds"] for r in records), 3),
            "failed": failed,
            "benchmarks": records,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"[wrote {args.json}]")
    if failed:
        print(f"[{len(failed)}/{len(records)} benchmarks failed: "
              f"{', '.join(failed)}]", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
