"""Run every paper-table/figure benchmark (CPU-friendly sizes).

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig1b fig2 # subset
  PYTHONPATH=src python -m benchmarks.run fed table1 fig1c --tiny \
      --json BENCH_smoke.json                        # CI smoke lane

Each benchmark module is imported lazily when selected, so one broken module
can't kill the whole runner; failures are reported per benchmark and the run
continues (nonzero exit at the end if anything failed). `--tiny` substitutes
CPU-tiny kwargs for the CI smoke lane; `--json` writes per-benchmark
wall-time + the headline result for the perf-trajectory artifact.

Every benchmark executes inside its own `repro.obs` session, so the --json
payload carries a per-benchmark `obs` summary (span timings, dispatch
counters, recompile counts, and — new in schema v3 — the cost model's
per-program FLOPs/bytes plus per-span roofline attribution) next to the
headline metric, plus a top-level `schema_version` and `env` block
(jax/jaxlib versions, backend, devices, git SHA + dirty flag) that make
payloads comparable across commits and machines. `--obs DIR` additionally
writes `<name>.events.jsonl` and `<name>.trace.json` (Perfetto-loadable)
per benchmark into DIR.

Perf trajectory: `--append-history` folds the run into the append-only
`BENCH_history.jsonl` (see `repro.obs.history`), `--check-regressions`
gates the CURRENT run against the trailing baseline of comparable history
rows BEFORE anything is appended (exit code 2 on a regression;
`--regress-report-only` demotes it to a report, the PR-lane mode), and
`--bless` marks this run as an intentional perf change so the baseline
window restarts here. `--from-json PATH` re-checks/appends an existing
payload without re-running anything; `--repeats N` runs each benchmark N
times (median wall time as `seconds`, all N as `repeat_seconds` — the
sentinel's within-run noise floor).

The multi-pod dry-run HLO table is produced separately by
`python -m repro.launch.dryrun --sweep` (it needs a 512-device process) and
formatted by benchmarks.hlo_report (formerly misnamed benchmarks.roofline;
the measured kernel roofline is the `codec_roofline` benchmark below).
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import platform
import sys
import time
import traceback

from repro.obs import core as obs_lib
from repro.obs import history as history_lib
from repro.obs import regress as regress_lib

# Version of the --json payload layout. Bump when records/env/obs keys
# change shape, so the perf-trajectory tooling can branch on it.
# v3: env gains git_sha/git_dirty; records gain repeat_seconds/directions;
# obs summaries gain costs + per-span attrib. Strictly additive over v2 —
# v2 readers (and history.records_from_payload) keep working.
SCHEMA_VERSION = 3

# benchmark name -> module under benchmarks/ exposing run(**kwargs)
ALL = {
    "fed": "fed_heterogeneous",
    "fed_agg": "fed_aggregate_scaling",
    "fed_cohort": "fed_cohort_scaling",
    "fed_mesh": "fed_mesh_scaling",
    "codec_roofline": "codec_roofline",
    "codec_frontier": "codec_frontier",
    "serve_load": "serve_load",
    "table1": "table1_compressors",
    "fig1a": "fig1a_compression_error",
    "fig1b": "fig1b_dgddef_rate",
    "fig1c": "fig1c_timing",
    "fig1d": "fig1d_sparsified_gd",
    "fig2": "fig2_svm",
    "fig3": "fig3_multiworker",
    "appJ": "appJ_frames",
    "appN": "appN_aspect_ratio",
    "lemma4": "lemma4_covering",
    "modelscale": "modelscale_ablation",
    "obs_overhead": "obs_overhead",
}

# --tiny kwargs: small enough for the CI smoke lane, large enough that each
# benchmark's internal assertions still hold
TINY = {
    "fed": dict(m=6, dim=96, rounds=30, chunk=32),
    "fed_agg": dict(m_values=(8, 64), dim=256, reps=3),
    "fed_cohort": dict(m_values=(8, 32), dim=48, per_client=16, rounds=3,
                       adaptive_m=8, adaptive_rounds=25),
    "fed_mesh": dict(m_values=(3, 8), dim=48, per_client=16, rounds=2,
                     chunk=32),
    "codec_roofline": dict(n_values=(128, 512), bits_values=(1, 4),
                           rows=16, reps=1),
    "codec_frontier": dict(n=512, m=160, chunk=32, trials=3, rounds=3,
                           steps=15),
    "serve_load": dict(slots=2, max_seq=64, prefix_len=24, n_requests=16,
                       base_rate=10.0, burst_rate=40.0, burst_period_s=1.0,
                       burst_len_s=0.3, prompt_len=(3, 6),
                       max_new_tokens=(3, 6)),
    "table1": dict(n=256, trials=5),
    "fig1c": dict(dims=(128, 256, 512)),
    "obs_overhead": dict(m=8, dim=48, per_client=16, rounds=30,
                         threshold=0.10),
}


def _git_info() -> tuple:
    """(sha, dirty) of the repo this file lives in; (None, None) when git
    is unavailable (tarball installs, sandboxed CI)."""
    import subprocess
    cwd = os.path.dirname(os.path.abspath(__file__))
    try:
        sha = subprocess.run(["git", "rev-parse", "HEAD"], cwd=cwd,
                             capture_output=True, text=True, timeout=10)
        if sha.returncode != 0:
            return None, None
        status = subprocess.run(["git", "status", "--porcelain"], cwd=cwd,
                                capture_output=True, text=True, timeout=10)
        dirty = bool(status.stdout.strip()) if status.returncode == 0 \
            else None
        return sha.stdout.strip(), dirty
    except Exception:                              # pragma: no cover
        return None, None


def env_info() -> dict:
    """The environment fingerprint embedded in every --json payload."""
    sha, dirty = _git_info()
    info = {
        "python": platform.python_version(),
        "platform": platform.platform(),
        "repro_force_pallas": os.environ.get("REPRO_FORCE_PALLAS"),
        "git_sha": sha,
        "git_dirty": dirty,
    }
    try:
        import jax
        import jaxlib
        info["jax"] = jax.__version__
        info["jaxlib"] = jaxlib.__version__
        info["backend"] = jax.default_backend()
        devs = jax.devices()
        info["device_kind"] = devs[0].device_kind if devs else None
        info["device_count"] = len(devs)
    except Exception as exc:                       # pragma: no cover
        info["jax"] = None
        info["error"] = repr(exc)
    return info


def _jsonable(obj, depth: int = 0):
    """Best-effort conversion of a benchmark's return value to JSON."""
    if depth > 8:       # deep enough for obs costs: summary → costs →
        return str(obj)  # programs → name → specializations → spec fields
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, dict):
        return {str(k): _jsonable(v, depth + 1) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v, depth + 1) for v in obj[:50]]
    if hasattr(obj, "item") and getattr(obj, "ndim", None) == 0:
        return obj.item()                       # numpy scalar
    if hasattr(obj, "tolist"):
        return _jsonable(obj.tolist(), depth + 1)
    return str(obj)


def run_one(name: str, tiny: bool = False, obs_dir: str = None,
            repeats: int = 1) -> dict:
    """Import + run one benchmark; never raises — failures land in the
    record (`ok`/`error`) so the rest of the run proceeds.

    Each benchmark gets its own obs session; its summary lands in the
    record under "obs". With `obs_dir` the raw events and a Perfetto trace
    are written there as `<name>.events.jsonl` / `<name>.trace.json`.
    `repeats > 1` re-runs the benchmark (same session): `seconds` is the
    median per-repeat wall time, `repeat_seconds` carries every repeat —
    the regression sentinel's within-run noise floor. The headline is the
    last repeat's. A module-level `DIRECTIONS` dict on the benchmark
    ({metric: "lower"|"higher"}) declares which headline metrics the
    sentinel may gate."""
    rec = {"name": name, "ok": False, "seconds": None, "headline": None,
           "error": None, "obs": None, "repeat_seconds": None,
           "directions": None}
    jsonl = trace = None
    if obs_dir is not None:
        os.makedirs(obs_dir, exist_ok=True)
        jsonl = os.path.join(obs_dir, f"{name}.events.jsonl")
        trace = os.path.join(obs_dir, f"{name}.trace.json")
    session = obs_lib.enable(jsonl=jsonl, trace=trace)
    times = []
    try:
        mod = importlib.import_module(f"benchmarks.{ALL[name]}")
        kwargs = TINY.get(name, {}) if tiny else {}
        directions = getattr(mod, "DIRECTIONS", None)
        if isinstance(directions, dict):
            rec["directions"] = dict(directions)
        for rep in range(max(1, repeats)):
            t0 = time.perf_counter()
            with obs_lib.span(f"bench.{name}", tiny=tiny, rep=rep):
                rec["headline"] = _jsonable(mod.run(**kwargs))
            times.append(round(time.perf_counter() - t0, 3))
        rec["ok"] = True
    except Exception:
        rec["error"] = traceback.format_exc(limit=8)
        if not times:
            times = [0.0]
    rec["seconds"] = sorted(times)[len(times) // 2]
    if len(times) > 1:
        rec["repeat_seconds"] = times
    obs_lib.disable()
    rec["obs"] = _jsonable(session.summary())
    return rec


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(
        prog="benchmarks.run", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    parser.add_argument("names", nargs="*", default=[], metavar="name",
                        help=f"benchmarks to run (default: all) from "
                             f"{', '.join(ALL)}")
    parser.add_argument("--tiny", action="store_true",
                        help="CPU-tiny sizes for the CI smoke lane")
    parser.add_argument("--json", metavar="PATH", default=None,
                        help="write per-benchmark wall-time + headline "
                             "metric to PATH")
    parser.add_argument("--obs", metavar="DIR", default=None,
                        help="write per-benchmark obs artifacts "
                             "(<name>.events.jsonl, <name>.trace.json) "
                             "into DIR")
    parser.add_argument("--repeats", type=int, default=1, metavar="N",
                        help="run each benchmark N times (median seconds; "
                             "per-repeat times feed the sentinel's noise "
                             "floor)")
    parser.add_argument("--history", metavar="PATH",
                        default="BENCH_history.jsonl",
                        help="benchmark history file (default: "
                             "BENCH_history.jsonl)")
    parser.add_argument("--check-regressions", action="store_true",
                        help="gate this run against the trailing baseline "
                             "of comparable history rows (exit code 2 on "
                             "regression)")
    parser.add_argument("--regress-report-only", action="store_true",
                        help="with --check-regressions: print findings but "
                             "keep exit code 0 (PR-lane mode)")
    parser.add_argument("--append-history", action="store_true",
                        help="append this run's records to --history "
                             "(after any regression check)")
    parser.add_argument("--bless", action="store_true",
                        help="mark this run as an intentional perf change "
                             "and append it: the baseline window restarts "
                             "here (implies --append-history)")
    parser.add_argument("--regress-window", type=int, default=8,
                        metavar="K", help="baseline = trimmed mean of the "
                                          "last K comparable runs")
    parser.add_argument("--regress-threshold", type=float, default=0.35,
                        metavar="R", help="relative regression threshold "
                                          "(default 0.35 = 35%%)")
    parser.add_argument("--from-json", metavar="PATH", default=None,
                        help="load an existing --json payload instead of "
                             "running benchmarks (history/regression ops "
                             "only)")
    args = parser.parse_args(argv)
    unknown = [n for n in args.names if n not in ALL]
    if unknown:
        parser.error(f"unknown benchmark(s) {', '.join(unknown)}; "
                     f"choose from {', '.join(ALL)}")

    if args.from_json is not None:
        if args.names:
            parser.error("--from-json replaces running benchmarks; drop "
                         "the benchmark names")
        with open(args.from_json) as f:
            payload = json.load(f)
        records = payload.get("benchmarks", [])
        failed = payload.get("failed", [])
    else:
        names = args.names or list(ALL)
        records = []
        for name in names:
            rec = run_one(name, tiny=args.tiny, obs_dir=args.obs,
                          repeats=args.repeats)
            records.append(rec)
            if rec["ok"]:
                print(f"[{name} done in {rec['seconds']:.1f}s]")
            else:
                print(f"[{name} FAILED after {rec['seconds']:.1f}s]\n"
                      f"{rec['error']}", file=sys.stderr)
        failed = [r["name"] for r in records if not r["ok"]]
        payload = {
            "schema_version": SCHEMA_VERSION,
            "tiny": args.tiny,
            "env": env_info(),
            "total_seconds": round(sum(r["seconds"] for r in records), 3),
            "failed": failed,
            "benchmarks": records,
        }
        if args.json:
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=2)
            print(f"[wrote {args.json}]")

    exit_code = 0
    if failed:
        print(f"[{len(failed)}/{len(records)} benchmarks failed: "
              f"{', '.join(failed)}]", file=sys.stderr)
        exit_code = 1

    if args.check_regressions or args.append_history or args.bless:
        current = history_lib.records_from_payload(payload)
        if args.bless:
            for rec in current:
                rec["blessed"] = True
        if args.check_regressions:
            hist = history_lib.load(args.history)
            if hist.truncated:
                print(f"[warning: {args.history} ended mid-record; using "
                      f"the parsed prefix]", file=sys.stderr)
            result = regress_lib.check(
                hist, current, window=args.regress_window,
                rel_threshold=args.regress_threshold)
            print(regress_lib.render(result))
            if result["findings"] and not args.regress_report_only:
                exit_code = max(exit_code, 2)
        if args.append_history or args.bless:
            n = history_lib.append(args.history, current)
            print(f"[appended {n} record(s) to {args.history}]")
    if exit_code:
        sys.exit(exit_code)


if __name__ == "__main__":
    main()
