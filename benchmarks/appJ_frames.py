"""Paper App. J: comparison of randomized frame classes.

Empirically measures, per frame family (sub-Gaussian / Haar orthonormal /
randomized Hadamard):
  * frame bounds A, B (min/max eigenvalue of S Sᵀ),
  * the democratic-embedding flatness ‖x_d‖∞·√N/‖y‖₂ (≈ K_u),
  * the near-democratic flatness ‖x_nd‖∞·√N/‖y‖₂ (the √log N factor),
  * NDSC quantization error at R = 4.

Validates App. J's ordering: orthonormal/Hadamard are exactly Parseval
(A = B = 1); sub-Gaussian is approximately Parseval; Hadamard NDE matches
orthonormal NDE while costing O(n log n) adds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import gaussian_cubed, make_codec, print_table
from repro.core import embeddings as E
from repro.core import frames as F


def run(n: int = 256, aspect: float = 2.0, trials: int = 10, seed: int = 0):
    nn = int(n * aspect)
    n_had = F.next_pow2(nn)
    rows = []
    for kind, N in (("subgaussian", nn), ("haar", nn), ("hadamard", n_had)):
        a_min, b_max, flat_d, flat_nd, qerr = [], [], [], [], []
        for t in range(trials):
            key = jax.random.key(seed + t)
            frame = F.make_frame(kind, key, n, N)
            s_mat = F.dense_matrix(frame)
            eigs = np.linalg.eigvalsh(np.asarray(s_mat @ s_mat.T))
            a_min.append(eigs.min())
            b_max.append(eigs.max())
            y = gaussian_cubed(jax.random.fold_in(key, 1), (n,))
            ynorm = float(jnp.linalg.norm(y))
            if kind != "subgaussian":     # DE needs (approx) Parseval
                x_d = E.democratic(frame, y)
                flat_d.append(float(jnp.max(jnp.abs(x_d)))
                              * np.sqrt(N) / ynorm)
            x_nd = E.near_democratic(frame, y)
            flat_nd.append(float(jnp.max(jnp.abs(x_nd)))
                           * np.sqrt(N) / ynorm)
            codec = make_codec(kind if kind != "subgaussian" else "haar",
                               n, 4.0, aspect=aspect, seed=seed + t)
            y_hat = codec.roundtrip(y, jax.random.fold_in(key, 2))
            qerr.append(float(jnp.linalg.norm(y_hat - y)) / ynorm)
        rows.append([
            kind, f"{np.mean(a_min):.3f}", f"{np.mean(b_max):.3f}",
            (f"{np.mean(flat_d):.2f}" if flat_d else "—"),
            f"{np.mean(flat_nd):.2f}",
            f"{np.mean(qerr):.4f}",
        ])
    print_table(
        f"App. J — frame classes (n={n}, λ={aspect}, {trials} trials)",
        ["frame", "A (min eig)", "B (max eig)", "K̂_u (DE)",
         "‖x_nd‖∞√N/‖y‖ (NDE)", "NDSC err @R=4"], rows)
    return rows


if __name__ == "__main__":
    run()
