"""Dry-run HLO report generator: aggregates dry-run JSON records into the
EXPERIMENTS.md table (one row per arch × shape × mesh).

Formerly `benchmarks.roofline` — renamed because it formats the HLO
cost-model table of `repro.launch.dryrun`, not a measured kernel roofline
(that's `benchmarks.codec_roofline` now). A shim module keeps the old
import path working.

The records are produced by `python -m repro.launch.dryrun --sweep
--both-meshes --json-out results.json` (512-device process). This module only
formats — it never imports the 512-device env.
"""
from __future__ import annotations

import json
import sys


def _fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(b) < 1024:
            return f"{b:.2f}{unit}"
        b /= 1024
    return f"{b:.2f}PiB"


def _fmt_s(s):
    return f"{s*1e3:.2f}ms" if s is not None else "-"


def table_rows(records):
    rows = []
    for r in records:
        base = [r["arch"], r["shape"], r["mesh"]]
        if r["status"] == "SKIP":
            rows.append(base + ["SKIP: " + r["reason"][:48]] + ["-"] * 7)
            continue
        if r["status"] == "FAIL":
            rows.append(base + ["FAIL: " + r["error"][:48]] + ["-"] * 7)
            continue
        roof = r["roofline"]
        rows.append(base + [
            "OK",
            _fmt_bytes(r.get("bytes_per_device")),
            _fmt_s(roof["compute_s"]), _fmt_s(roof["memory_s"]),
            _fmt_s(roof["collective_s"]), roof["dominant"],
            (f"{roof['useful_flops_ratio']:.2f}"
             if roof.get("useful_flops_ratio") else "-"),
            f"{roof['flops_per_device']:.2e}",
        ])
    return rows


HEADER = ["arch", "shape", "mesh", "status", "bytes/dev", "compute",
          "memory", "collective", "bound", "MF/HLO", "flops/dev"]


def markdown(records) -> str:
    rows = table_rows(records)
    out = ["| " + " | ".join(HEADER) + " |",
           "|" + "|".join("---" for _ in HEADER) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)


def main(path="dryrun_results.json"):
    with open(path) as f:
        records = json.load(f)
    print(markdown(records))
    ok = sum(1 for r in records if r["status"] == "OK")
    skip = sum(1 for r in records if r["status"] == "SKIP")
    fail = sum(1 for r in records if r["status"] == "FAIL")
    print(f"\n{ok} OK / {skip} documented skips / {fail} FAIL "
          f"of {len(records)} combos")


if __name__ == "__main__":
    main(*sys.argv[1:])
