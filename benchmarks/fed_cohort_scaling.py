"""Cohort-vectorized federated rounds: wall-clock scaling + adaptive budgets.

    PYTHONPATH=src python -m benchmarks.fed_cohort_scaling

Two claims, both on the least-squares federation from
`benchmarks.fed_heterogeneous`:

1. SCALING — at large m the sequential round driver is wall-clock-bound by
   m jit dispatches per round; the cohort engine runs every client sharing a
   (codec spec, client config, data signature) as ONE compiled vmapped
   program. Same numerics (the drivers are bit-exact — the run checks the
   ledgers agree), ≥5× faster at m = 128 on CPU, and the gap widens with m.

2. ADAPTIVE BUDGETS — re-running the allocator every `realloc_every` rounds
   from the server-side EMA of decoded delta norms (no extra communication)
   tracks the CURRENT gradient geometry: clients that converge early stop
   hogging bits. At equal total budget Σ R_i, adaptive water-filling matches
   or beats the static norm-proportional split probed once at x₀.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table
from benchmarks.fed_heterogeneous import make_problem, probe_norms
from repro.fed import (AdaptiveConfig, ClientConfig, FedConfig, Federation,
                       ServerConfig, budget)
from repro import codecs as registry


def _timed_rounds(fed: Federation, cfg: FedConfig, rounds: int) -> float:
    """Seconds per round, excluding the round-0 compile."""
    fed.run_round(cfg, 0)                          # warmup / compile
    t0 = time.perf_counter()
    for t in range(1, rounds + 1):
        fed.run_round(cfg, t)
    return (time.perf_counter() - t0) / rounds


def scaling(m_values=(32, 128, 512), dim: int = 128, per_client: int = 32,
            rounds: int = 4, chunk: int = 64, seed: int = 0) -> dict:
    rows, speedups = [], {}
    for m in m_values:
        shards, loss_fn, _, _, lr = make_problem(
            m, dim, per_client=per_client, scale_span=0.0, seed=seed)
        params = {"x": jnp.zeros(dim)}
        codec = registry.make("ndsc", budget=2.0, chunk=chunk)
        ccfg = ClientConfig(local_steps=1, lr=lr)
        cfg = FedConfig(num_rounds=rounds + 1, seed=seed)

        times, ledgers = {}, {}
        for use_cohorts in (False, True):
            fed = Federation(loss_fn, params, shards, codec, ccfg,
                             ServerConfig(), seed=seed,
                             use_cohorts=use_cohorts)
            times[use_cohorts] = _timed_rounds(fed, cfg, rounds)
            ledgers[use_cohorts] = fed.run_round(cfg, rounds + 1)["wire_bytes"]
        assert ledgers[True] == ledgers[False], "cohort ledger diverged"
        speedups[m] = times[False] / times[True]
        rows.append([m, f"{times[False] * 1e3:.1f}", f"{times[True] * 1e3:.1f}",
                     f"{speedups[m]:.1f}×"])
    print_table(
        f"fed cohorts: ms/round, sequential vs vmapped "
        f"(dim={dim}, {per_client} examples/client, ndsc R=2)",
        ["m", "sequential", "cohort (vmap)", "speedup"], rows)
    for m, s in speedups.items():
        if m >= 128:
            assert s >= 5.0, (
                f"cohort driver only {s:.1f}× faster at m={m} (need ≥5×)")
    return speedups


def make_drift_problem(m: int = 16, dim: int = 128, per_client: int = 64,
                       scale_hi: float = 8.0, drift: float = 4.0,
                       seed: int = 0):
    """Least squares where the x₀ probe is genuinely misleading.

    Half the clients ("loud") carry a large signal scale but share the global
    optimum — their gradients dominate at x₀ and then vanish as the server
    converges. The other half ("drifting") look quiet at x₀ but pull toward
    client-specific optima x* + drift·u_i, so their update norms PERSIST
    round after round. A static norm-proportional split probed at x₀ hands
    the loud clients the bits forever; tracking the decoded delta norms
    re-routes them to the drifting clients once the loud ones converge.
    """
    ka, kx, ku = jax.random.split(jax.random.key(seed), 3)
    a = jax.random.normal(ka, (m, per_client, dim)) / jnp.sqrt(per_client)
    x_true = jax.random.normal(kx, (dim,))
    u = jax.random.normal(ku, (m, dim))
    u = u / jnp.linalg.norm(u, axis=-1, keepdims=True)
    shards = []
    for i in range(m):
        loud = i < m // 2
        scale = scale_hi if loud else 1.0
        target = x_true if loud else x_true + drift * u[i]
        shards.append({"a": scale * a[i], "b": scale * (a[i] @ target)})

    def loss_fn(p, batch):
        r = batch["a"] @ p["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r)

    all_a = jnp.concatenate([s["a"] for s in shards])
    all_b = jnp.concatenate([s["b"] for s in shards])

    def global_loss(p):
        r = all_a @ p["x"] - all_b
        return 0.5 * jnp.mean(r * r)

    h = (all_a.T @ all_a) / all_a.shape[0]
    eigs = jnp.linalg.eigvalsh(h)
    lr = float(2.0 / (eigs[-1] + eigs[0]))
    # the heterogeneity floor: loss at the exact global optimum (client
    # drift keeps it > 0; allocation quality shows in the EXCESS over it)
    x_opt = jnp.linalg.solve(all_a.T @ all_a, all_a.T @ all_b)
    floor = float(global_loss({"x": x_opt}))
    return shards, loss_fn, global_loss, lr, floor


def adaptive_vs_static(m: int = 16, dim: int = 128, per_client: int = 64,
                       avg_rate: float = 1.5, rounds: int = 60,
                       realloc_every: int = 5, chunk: int = 64,
                       seed: int = 0) -> dict:
    """Equal Σ R_i (the budget unit everywhere in repro.fed — realized bytes
    differ slightly per allocation because scales/masks ride per kept chunk):
    static norm-proportional probed at x₀ vs adaptive water-filling from the
    decoded-norm EMA. Scored on the EXCESS loss over the heterogeneity floor
    (the loss at the exact global optimum, > 0 under client drift)."""
    shards, loss_fn, global_loss, lr, floor = make_drift_problem(
        m, dim, per_client=per_client, seed=seed)
    params = {"x": jnp.zeros(dim)}
    norms0 = probe_norms(loss_fn, params, shards)
    total = avg_rate * m
    ccfg = ClientConfig(local_steps=1, lr=lr)
    factory = lambda r: registry.make("ndsc", budget=float(r), chunk=chunk)

    grid = 0.25
    rates0 = budget.quantize_rates(
        budget.allocate("norm_proportional", total, m, norms=norms0,
                        min_rate=0.25), grid, total, 0.25, 8.0)
    results, rows = {}, []
    for mode in ("static", "adaptive"):
        adaptive = (AdaptiveConfig(total_rate=total, policy="waterfill",
                                   realloc_every=realloc_every, grid=grid,
                                   hysteresis=grid, min_rate=0.25)
                    if mode == "adaptive" else None)
        fed = Federation(loss_fn, params, shards, [factory(r) for r in rates0],
                         ccfg, ServerConfig(), seed=seed, adaptive=adaptive,
                         codec_factory=factory if adaptive else None)
        hist = fed.run(FedConfig(num_rounds=rounds, seed=seed),
                       eval_fn=global_loss)
        assert all(r == a for r, a in zip(hist["wire_bytes"],
                                          hist["analytic_bytes"]))
        excess = float(np.mean(hist["loss"][-5:])) - floor
        results[mode] = {"excess_loss": excess,
                         "cum_mb": hist["cum_bytes"][-1] / 1e6,
                         "reallocs": sum(hist["realloc"])}
        rows.append([mode, f"{excess:.3e}",
                     f"{hist['cum_bytes'][-1] / 1e6:.3f}",
                     sum(hist["realloc"])])
    print_table(
        f"fed adaptive budgets: equal ΣR_i = {total:g} bits/dim "
        f"(m={m}, {rounds} rounds, realloc every {realloc_every}, "
        f"floor {floor:.3e})",
        ["allocation", "excess loss", "total MB", "reallocs"], rows)
    assert results["adaptive"]["excess_loss"] <= \
        1.05 * results["static"]["excess_loss"], (
        "adaptive re-allocation should match or beat the static "
        f"norm-proportional split: {results['adaptive']['excess_loss']:.3e} "
        f"vs {results['static']['excess_loss']:.3e}")
    print("   adaptive matches/beats static at equal total bits: excess "
          f"loss {results['static']['excess_loss']:.2e} → "
          f"{results['adaptive']['excess_loss']:.2e}")
    return results


def run(m_values=(32, 128, 512), dim: int = 128, per_client: int = 32,
        rounds: int = 4, adaptive_m: int = 16, adaptive_rounds: int = 60,
        seed: int = 0) -> dict:
    speedups = scaling(m_values, dim, per_client, rounds, seed=seed)
    adaptive = adaptive_vs_static(m=adaptive_m, rounds=adaptive_rounds,
                                  seed=seed)
    return {"speedup": {str(m): round(s, 2) for m, s in speedups.items()},
            "static_excess_loss": adaptive["static"]["excess_loss"],
            "adaptive_excess_loss": adaptive["adaptive"]["excess_loss"]}


if __name__ == "__main__":
    run()
