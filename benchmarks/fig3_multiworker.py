"""Paper Fig. 3a / App. I Figs. 5–6: multi-worker linear regression.

m = 10 workers × s = 10 local datapoints, n = 30, planted model
x* ~ Student-t(1) (Fig. 3a) or Gaussian³ (Fig. 5), R ∈ {0.5, 1} bits/dim
per worker. Compares naive stochastic-uniform quantization, DSC, NDSC at the
parameter server's consensus mean (Alg. 3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import print_table
from repro.core.coding import Codec, CodecConfig
from repro.core.embeddings import EmbeddingSpec
from repro.core import baselines as B
from repro.core import frames as F
from repro.core import optim as O
from repro.data import synthetic_regression
from repro.dist.gradcomp import GradCompConfig, decode_leaf, encode_leaf


def _dist_gradcomp_compressor(R: float, chunk: int = 32):
    """The model-scale chunked codec (repro.dist.gradcomp) as a §5-style
    compressor roundtrip — the same code path the distributed train step
    puts on the wire, dithered/unbiased with per-worker keys.

    Returns (roundtrip, R_eff): the packed wire format only supports bit
    widths {1,2,4,8}, so a budget between them rounds DOWN and R_eff is the
    rate actually spent (use it in the row label)."""
    if R < 1.0:
        cfg = GradCompConfig(bits=1, chunk=chunk, keep_fraction=R,
                             dithered=True, error_feedback=False)
    else:
        bits = max(b for b in (1, 2, 4, 8) if b <= R)
        cfg = GradCompConfig(bits=bits, chunk=chunk, dithered=True,
                             error_feedback=False)

    def roundtrip(key, g):
        payload = encode_leaf(g, 0, cfg, key=key)
        return decode_leaf(payload, 0, g.size, g.shape, g.dtype, cfg)

    return roundtrip, cfg.effective_bits


def run(n: int = 30, workers: int = 10, s: int = 10, steps: int = 1500,
        alpha: float = 0.1, seed: int = 0, budgets=(0.5, 1.0, 4.0)):
    key = jax.random.key(seed)
    a, b, x_star = synthetic_regression(key, workers * s, n,
                                        design="gauss", model="student_t")
    # normalize the planted model scale (Student-t(1) tails can put x* at
    # huge norm, drowning every method's 1500-step budget identically)
    scale = jnp.maximum(jnp.linalg.norm(x_star) / jnp.sqrt(n), 1.0)
    x_star = x_star / scale
    b = b / scale
    a_w = a.reshape(workers, s, n)
    b_w = b.reshape(workers, s)

    def subgrad_i(i, k, x):
        ai, bi = a_w[i], b_w[i]
        idx = jax.random.randint(k, (4,), 0, s)
        return jnp.mean((ai[idx] @ x - bi[idx])[:, None] * ai[idx], axis=0)

    def total_loss(x):
        return 0.5 * jnp.mean((a @ x - b) ** 2)

    x0 = jnp.zeros((n,))
    rows = []

    def record(name, codec=None, compressor=None):
        t = O.dq_psgd_multiworker(subgrad_i, workers, x0, codec, alpha,
                                  steps, key=jax.random.key(1),
                                  compressor_roundtrip=compressor)
        rows.append([name, f"{float(total_loss(t.x_avg)):.5f}",
                     f"{float(jnp.linalg.norm(t.x_avg - x_star)):.4f}"])

    record("unquantized")
    for R in budgets:
        # naive comparator at the SAME budget: for R < 1 it must subsample
        # too (rand-(R·100)% + 1-bit dithered, unbiased), like App. E.2.
        if R < 1.0:
            naive = B.randk(R, quant_levels=2, unbiased=True)
            tag = f"naive rand-{int(R*100)}%+1b"
        else:
            naive = B.standard_dither(max(2, int(2 ** R)))
            tag = f"naive dithered R={R:g}"
        record(tag, compressor=naive.roundtrip)
        frame = F.make_frame("haar", jax.random.key(2), n, n)
        record(f"DSC R={R:g}", codec=Codec(frame, CodecConfig(
            bits_per_dim=R, dithered=True,
            embedding=EmbeddingSpec(kind="democratic"))))
        record(f"NDSC R={R:g}", codec=Codec(frame, CodecConfig(
            bits_per_dim=R, dithered=True)))
        # the production train-step codec on the same consensus protocol.
        # R < 1 is skipped here: the chunked codec subsamples at CHUNK
        # granularity, and n=30 fits one chunk — all-or-nothing dropping,
        # not the paper's coordinate-level sub-linear regime (which needs
        # model scale; see benchmarks/modelscale_ablation.py).
        if R >= 1.0:
            chunked_rt, r_eff = _dist_gradcomp_compressor(R)
            record(f"NDSC-chunked R={r_eff:g} (dist)", compressor=chunked_rt)

    print_table(
        f"Fig. 3a — multi-worker regression (m={workers}, n={n}, {steps} steps)",
        ["method", "final loss", "‖x̄−x*‖"], rows)

    # Fig. 5 protocol at larger n: heavy-tailed design is where the
    # democratic embedding's dimension-freeness shows (gap grows with n).
    rows2 = _heavy_tail_block(n=256, workers=workers, s=40, steps=600,
                              alpha=0.02, seed=seed + 1)
    return rows + rows2


def _heavy_tail_block(n, workers, s, steps, alpha, seed):
    key = jax.random.key(seed)
    a, b, x_star = synthetic_regression(key, workers * s, n,
                                        design="gauss3", model="gauss")
    col_scale = jnp.linalg.norm(a, axis=0, keepdims=True) / jnp.sqrt(
        workers * s)
    a = a / col_scale                      # normalize the cubed columns
    x_star = jnp.linalg.lstsq(a, b)[0]     # planted model after rescale
    b = a @ x_star
    a_w, b_w = a.reshape(workers, s, n), b.reshape(workers, s)

    def subgrad_i(i, k, x):
        idx = jax.random.randint(k, (8,), 0, s)
        ai, bi = a_w[i][idx], b_w[i][idx]
        return jnp.mean((ai @ x - bi)[:, None] * ai, axis=0)

    x0 = jnp.zeros((n,))
    rows = []

    def record(name, codec=None, compressor=None):
        t = O.dq_psgd_multiworker(subgrad_i, workers, x0, codec, alpha,
                                  steps, key=jax.random.key(1),
                                  compressor_roundtrip=compressor)
        rel = float(jnp.linalg.norm(t.x_avg - x_star)
                    / jnp.linalg.norm(x_star))
        rows.append([name, "-", f"{rel:.4f}"])

    record("unquantized")
    naive = B.standard_dither(2)
    record("naive dithered R=1", compressor=naive.roundtrip)
    frame = F.make_frame("haar", jax.random.key(2), n, n)
    record("NDSC R=1", codec=Codec(frame, CodecConfig(bits_per_dim=1.0,
                                                      dithered=True)))
    print_table(
        f"Fig. 5 — heavy-tailed design, n={n} (relative ‖x̄−x*‖/‖x*‖)",
        ["method", "final loss", "rel dist"], rows)
    return rows


if __name__ == "__main__":
    run()
