"""Paper App. N: why λ = N/n should be as close to 1 as possible.

Two curves per embedding kind, sweeping the embedding dimension N at fixed
n and a FIXED total bit budget nR:
  * ‖x‖∞·√N/‖y‖₂   — the flatness gain from a larger subspace (decreases),
  * ‖y − Q(y)‖/‖y‖ — the end-to-end quantization error (the budget dilution
    R → nR/N wins: error grows with N, so pick N ≈ n).

Reproduces Figs. 8–12 of the paper's App. N numerically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import gaussian_cubed, print_table
from repro.core.coding import Codec, CodecConfig
from repro.core import frames as F


def run(n: int = 96, R: float = 4.0, trials: int = 10, seed: int = 0,
        embed_dims=(128, 256, 512, 1024, 2048)):
    rows = []
    for N in embed_dims:
        lam_eff = N / n
        flat, err = [], []
        for t in range(trials):
            key = jax.random.key(seed + t)
            frame = F.hadamard_frame(key, n, N)
            y = gaussian_cubed(jax.random.fold_in(key, 1), (n,))
            x = frame.apply_t(y)
            flat.append(float(jnp.max(jnp.abs(x))) * (N ** 0.5)
                        / float(jnp.linalg.norm(y)))
            codec = Codec(frame, CodecConfig(bits_per_dim=R))
            y_hat = codec.roundtrip(y, jax.random.fold_in(key, 2))
            err.append(float(jnp.linalg.norm(y_hat - y)
                             / jnp.linalg.norm(y)))
        rows.append([f"{lam_eff:.2f}", N, f"{sum(flat)/trials:.3f}",
                     f"{R/lam_eff:.2f}", f"{sum(err)/trials:.4f}"])
    print_table(
        f"App. N — aspect-ratio trade-off (n={n}, budget nR = {n*R:.0f} bits)",
        ["λ=N/n", "N", "‖x‖∞√N/‖y‖", "bits/emb-dim", "roundtrip err"], rows)
    return rows


if __name__ == "__main__":
    run()
