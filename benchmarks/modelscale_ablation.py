"""Model-scale budget ablation: loss-vs-R for compressed LM training.

The paper's experiments stop at convex problems and a small CNN; this table
carries the claim to the transformer training stack: fixed-batch fitting of
a llama-family smoke model under the full compressed consensus (NDSC chunked
codec + EF + AdamW) at R ∈ {uncompressed, 8, 4, 2, 1, 0.5} bits/dim.
Expected: R ≥ 2 indistinguishable from uncompressed; R = 0.5 (sub-linear
chunk subsampling) trains but slower — mirroring Fig. 1b/Thm. 3 behaviour.
"""
from __future__ import annotations

import jax

from benchmarks.common import print_table
from repro import configs
from repro.data import batch_for_shape
from repro.dist import step as step_lib
from repro.dist.gradcomp import GradCompConfig, wire_bytes_tree
from repro.launch.mesh import make_host_mesh
from repro.optimizer import adamw


def run(steps: int = 20, seed: int = 0):
    mesh = make_host_mesh(1, 1)
    cfg = configs.get_reduced("llama3.2-3b")
    batch = batch_for_shape(cfg, 8, 32, 0, seed)
    settings = [
        ("uncompressed (psum)", GradCompConfig(strategy="psum")),
        ("R=8", GradCompConfig(bits=8, chunk=256)),
        ("R=4", GradCompConfig(bits=4, chunk=256)),
        ("R=2", GradCompConfig(bits=2, chunk=256)),
        ("R=1", GradCompConfig(bits=1, chunk=256)),
        ("R=0.5 (sub-linear)", GradCompConfig(bits=1, chunk=256,
                                              keep_fraction=0.5)),
    ]
    rows = []
    for name, gc in settings:
        opt = adamw(3e-3)
        tstep = step_lib.make_train_step(cfg, opt, gc, mesh, clip_norm=1.0)
        params, opt_state, ef = step_lib.init_train_state(
            cfg, opt, gc, mesh, jax.random.key(seed))
        losses = []
        for _ in range(steps):
            params, opt_state, ef, m = tstep(params, opt_state, ef, batch)
            losses.append(float(m["loss"]))
        if gc.strategy == "psum":
            wire = "1.00× (f32)"
        else:
            audit = wire_bytes_tree(params, gc, 1)
            wire = f"{audit['f32_bytes']/audit['payload_bytes']:.1f}× less"
        rows.append([name, f"{losses[0]:.3f}", f"{losses[-1]:.3f}", wire])
    print_table(
        f"Model-scale ablation — fixed-batch loss after {steps} steps "
        f"({cfg.name})",
        ["budget", "loss@0", f"loss@{steps}", "wire bytes"], rows)
    return rows


if __name__ == "__main__":
    run()
