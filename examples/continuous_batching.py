"""Continuous-batching serving demo: a request queue through fixed slots,
with a shared prefix amortized through the quantized-KV prefix cache.

    PYTHONPATH=src python examples/continuous_batching.py

8 requests flow through 2 decode slots; the engine prefills each cold
prompt in isolation and scatters its caches into a freed slot mid-flight,
while requests carrying `prefix_id="system"` reuse the cached prefill of
the shared system prompt (bit-exact with prefilling it on the spot —
verified in tests/test_engine.py). The batched decode_step keeps both
slots busy throughout.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import model as model_lib
from repro.serve import Engine, Request, ServeConfig


def main():
    # 8-bit NDSC-quantized KV cache: cached prefix entries store packed
    # int32 words + scales, bits/32 of the f32 bytes
    cfg = dataclasses.replace(configs.get_reduced("yi-6b"), kv_quant_bits=8)
    params = model_lib.init_params(jax.random.key(0), cfg)
    eng = Engine(cfg, params, ServeConfig(slots=2, max_seq=64))

    key = jax.random.key(1)
    system = jax.random.randint(jax.random.fold_in(key, 99), (16,), 0,
                                cfg.vocab_size, jnp.int32)
    eng.register_prefix("system", system, prefill=True)

    for i in range(8):
        prompt = jax.random.randint(jax.random.fold_in(key, i),
                                    (4 + 2 * i,), 0, cfg.vocab_size,
                                    jnp.int32)
        # every other request rides the cached system prefix
        eng.submit(Request(rid=i, prompt=prompt,
                           max_new_tokens=4 + (i % 3) * 3,
                           prefix_id="system" if i % 2 else None))

    t0 = time.time()
    finished = eng.run_to_completion()
    dt = time.time() - t0
    total_tokens = sum(len(r.tokens_out) for r in finished)
    stats = eng.prefix_cache.stats()
    print(f"{len(finished)} requests, {total_tokens} tokens through 2 slots "
          f"in {dt:.1f}s; prefix cache: {stats['hits']} hits, "
          f"{stats['bytes']} bytes cached")
    for r in sorted(finished, key=lambda r: r.rid):
        print(f"  req {r.rid} [{r.admission:>10}]: prompt[{len(r.prompt)}] "
              f"→ {r.tokens_out}")


if __name__ == "__main__":
    main()
