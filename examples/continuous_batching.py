"""Continuous-batching serving demo: a request queue through fixed slots.

    PYTHONPATH=src python examples/continuous_batching.py

8 requests with different prompt lengths and generation budgets flow through
2 decode slots; the scheduler prefills each prompt in isolation, scatters its
caches into a freed slot mid-flight, and the batched decode_step keeps both
slots busy. Outputs are token-exact vs generating each request alone
(verified in tests/test_scheduler.py).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import model as model_lib
from repro.serve import BatchScheduler, Request


def main():
    cfg = configs.get_reduced("yi-6b")
    params = model_lib.init_params(jax.random.key(0), cfg)
    sched = BatchScheduler(cfg, params, slots=2, max_seq=64)

    key = jax.random.key(1)
    for i in range(8):
        prompt = jax.random.randint(jax.random.fold_in(key, i),
                                    (4 + 2 * i,), 0, cfg.vocab_size,
                                    jnp.int32)
        sched.submit(Request(rid=i, prompt=prompt,
                             max_new_tokens=4 + (i % 3) * 3))

    t0 = time.time()
    finished = sched.run_to_completion()
    dt = time.time() - t0
    total_tokens = sum(len(r.tokens_out) for r in finished)
    print(f"{len(finished)} requests, {total_tokens} tokens through 2 slots "
          f"in {dt:.1f}s")
    for r in sorted(finished, key=lambda r: r.rid):
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] → {r.tokens_out}")


if __name__ == "__main__":
    main()
