"""Federated quickstart: 4 clients, compressed deltas, a bytes ledger.

    PYTHONPATH=src python examples/fed_quickstart.py

Each client fits a shared least-squares model on its own shard, ships its
params-delta through the chunked NDSC codec at 2 bits/dim (error feedback
on), and the server FedAvgs the decoded deltas. The history carries a
per-round wire-bytes ledger that matches the analytic audit to the byte.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.data import synthetic_regression
from repro.fed import (ClientConfig, FedConfig, Federation, ServerConfig)
from repro import codecs as registry


def main():
    m, dim, per = 4, 64, 96
    a, b, x_star = synthetic_regression(jax.random.key(0), m * per, dim,
                                        design="gauss", model="gauss")
    shards = [{"a": a[i * per:(i + 1) * per], "b": b[i * per:(i + 1) * per]}
              for i in range(m)]

    def loss_fn(params, batch):
        r = batch["a"] @ params["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r)

    def global_loss(params):
        r = a @ params["x"] - b
        return 0.5 * jnp.mean(r * r)

    params = {"x": jnp.zeros(dim)}
    codec = registry.make("ndsc", budget=2.0, chunk=32)
    fed = Federation(loss_fn, params, shards, codec,
                     ClientConfig(local_steps=2, lr=0.5),
                     ServerConfig(aggregator="fedavg"))
    hist = fed.run(FedConfig(num_rounds=30), eval_fn=global_loss)

    f32 = 4 * dim * m
    print(f"== fed quickstart: {m} clients, dim={dim}, NDSC R=2 bits/dim ==")
    for t in range(0, 30, 5):
        print(f"   round {t:2d}: loss {hist['loss'][t]:.4e}   "
              f"wire {hist['wire_bytes'][t]:.0f} B "
              f"(f32 would be {f32} B)")
    print(f"   final loss {hist['loss'][-1]:.4e}, "
          f"total {hist['cum_bytes'][-1] / 1e3:.1f} kB on the wire, "
          f"ledger ≡ audit: "
          f"{all(r == a_ for r, a_ in zip(hist['wire_bytes'], hist['analytic_bytes']))}")
    print(f"   ‖x − x*‖ = "
          f"{float(jnp.linalg.norm(fed.server.params['x'] - x_star)):.3f}")


if __name__ == "__main__":
    main()
