"""End-to-end driver: train an LM with democratically-compressed gradients.

    PYTHONPATH=src python examples/train_lm.py            # ~25M, 300 steps
    PYTHONPATH=src python examples/train_lm.py --full     # ~110M (slower)

This is the deliverable (b) end-to-end run: synthetic Markov token stream →
blockwise-attention transformer → shard_map train step whose gradient
consensus goes through the NDSC codec (FWHT embed → 4-bit pack → all-gather
of PACKED payloads → decode → mean → AdamW), with per-worker error feedback.
On the CPU container the mesh is 1×1; the identical code drives the 16×16 /
2×16×16 production meshes (see repro/launch/dryrun.py).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.dist.gradcomp import GradCompConfig
from repro.launch.train import train
from repro.models.model import ModelConfig, param_count


def small_lm() -> ModelConfig:
    """~25M params: CPU-friendly a-few-minutes run."""
    return ModelConfig(
        name="lm-25m", num_layers=6, d_model=384, num_heads=6,
        num_kv_heads=2, d_ff=1536, vocab_size=2048, block="attn_mlp",
        rope_theta=10000.0, remat=False)


def full_lm() -> ModelConfig:
    """~110M params: the deliverable-scale run (use on real hardware)."""
    return ModelConfig(
        name="lm-110m", num_layers=12, d_model=640, num_heads=10,
        num_kv_heads=2, d_ff=2560, vocab_size=50304, block="attn_mlp",
        rope_theta=10000.0, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    cfg = full_lm() if args.full else small_lm()
    print(f"{cfg.name}: {param_count(cfg)/1e6:.1f}M params")
    gc = GradCompConfig(bits=args.bits, strategy="allgather_packed")
    _, losses = train(cfg, steps=args.steps, batch_size=args.batch,
                      seq_len=args.seq, gc=gc, lr=3e-3, log_every=10,
                      ckpt_dir=args.ckpt_dir)
    print(f"\nloss: {losses[0]:.3f} → {losses[-1]:.3f} "
          f"over {len(losses)} steps (R={args.bits} bits/dim on the wire)")


if __name__ == "__main__":
    main()
