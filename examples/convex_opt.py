"""The paper's algorithms on their own turf: convex problems.

    PYTHONPATH=src python examples/convex_opt.py

Runs (i) DGD-DEF on smooth+strongly-convex least squares across budgets,
(ii) DQ-PSGD on a non-smooth SVM at a sub-linear budget R = 0.5, and
(iii) the multi-worker consensus (Alg. 3) with 10 workers.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core.coding import Codec, CodecConfig
from repro.core import frames, optim
from repro.data import synthetic_regression, synthetic_two_class


def dgd_def_demo():
    print("== DGD-DEF: least squares, budgets R ∈ {1,2,4,8} ==")
    n = 116
    a, b, x_star_data = synthetic_regression(jax.random.key(0), 200, n,
                                             design="gauss3", model="gauss")
    a = a / jnp.sqrt(a.shape[0])
    h = a.T @ a
    x_star = jnp.linalg.solve(h, a.T @ (b / jnp.sqrt(200)))
    eigs = jnp.linalg.eigvalsh(h)
    alpha = optim.alpha_star(float(eigs[-1]), float(eigs[0]))
    sigma = optim.sigma_rate(float(eigs[-1]), float(eigs[0]))
    grad = lambda x: h @ x - a.T @ (b / jnp.sqrt(200))
    print(f"   unquantized rate σ = {sigma:.4f}")
    for R in (1, 2, 4, 8):
        frame = frames.hadamard_frame(jax.random.key(1), n)
        codec = Codec(frame, CodecConfig(bits_per_dim=float(R)))
        t = optim.dgd_def(grad, jnp.zeros(n), codec, alpha, 200,
                          x_star=x_star)
        print(f"   R={R}: ‖x_T − x*‖ = {float(t.dist_history[-1]):.3e}")


def dq_psgd_demo():
    print("\n== DQ-PSGD: SVM hinge loss at R = 0.5 bits/dim ==")
    n, m = 30, 100
    a, b = synthetic_two_class(jax.random.key(0), m // 2, n)
    loss = lambda x: float(jnp.mean(jnp.maximum(0, 1 - b * (a @ x))))

    def subgrad(k, x):
        idx = jax.random.randint(k, (20,), 0, m)
        ai, bi = a[idx], b[idx]
        return jnp.mean(-(bi[:, None] * ai) * ((bi * (ai @ x)) < 1)[:, None],
                        axis=0)

    frame = frames.haar_frame(jax.random.key(1), n, n)
    codec = Codec(frame, CodecConfig(bits_per_dim=0.5, dithered=True))
    x0 = jnp.zeros(n)
    t = optim.dq_psgd(subgrad, x0, codec, 0.05, 600, key=jax.random.key(2))
    print(f"   hinge loss: {loss(x0):.3f} → {loss(t.x_avg):.3f} "
          f"(15 bits total per iteration for a 30-dim gradient)")


def multiworker_demo():
    print("\n== Alg. 3: 10 workers, private data, consensus at the PS ==")
    n, workers, s = 30, 10, 10
    a, b, x_star = synthetic_regression(jax.random.key(0), workers * s, n,
                                        design="gauss", model="student_t")
    a_w, b_w = a.reshape(workers, s, n), b.reshape(workers, s)

    def subgrad_i(i, k, x):
        idx = jax.random.randint(k, (4,), 0, s)
        ai, bi = a_w[i][idx], b_w[i][idx]
        return jnp.mean((ai @ x - bi)[:, None] * ai, axis=0)

    frame = frames.haar_frame(jax.random.key(1), n, n)
    codec = Codec(frame, CodecConfig(bits_per_dim=1.0, dithered=True))
    t = optim.dq_psgd_multiworker(subgrad_i, workers, jnp.zeros(n), codec,
                                  0.05, 500, key=jax.random.key(2))
    print(f"   ‖x̄ − x*‖ = {float(jnp.linalg.norm(t.x_avg - x_star)):.3f} "
          f"(R = 1 bit/dim/worker)")


if __name__ == "__main__":
    dgd_def_demo()
    dq_psgd_demo()
    multiworker_demo()
