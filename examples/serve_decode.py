"""Batched serving demo: prefill + greedy decode against explicit caches.

    PYTHONPATH=src python examples/serve_decode.py

Drives the same decode_step that the decode_32k / long_500k dry-run shapes
lower on the production mesh — here at smoke scale on the host device, for
a MoE (mixtral-style, ring-buffered sliding window) and a recurrent (xLSTM)
architecture, demonstrating bounded cache memory past the window.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import configs
from repro.launch.serve import serve


if __name__ == "__main__":
    for arch in ("mixtral-8x22b", "xlstm-350m"):
        print(f"\n=== {arch} (reduced) ===")
        cfg = configs.get_reduced(arch)
        serve(cfg, batch=4, prompt_len=24, gen=12)
