"""Quickstart: the paper's source coding in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. Build a randomized Hadamard frame S = PDH.
2. Embed a heavy-tailed vector near-democratically (x = Sᵀy, one FWHT).
3. Quantize at R = 4 bits/dim, decode, check the Thm. 1 error bound.
4. Run DGD-DEF on a least-squares problem at R = 2 and watch it converge
   where naive quantized GD stalls.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import frames, optim
from repro.core.coding import Codec, CodecConfig


def main():
    n = 1024
    key = jax.random.key(0)
    y = jax.random.normal(key, (n,)) ** 3          # heavy-tailed gradient

    # --- 1–2: frame + near-democratic embedding --------------------------
    frame = frames.hadamard_frame(jax.random.key(1), n)
    x = frame.apply_t(y)                            # x = Sᵀy (FWHT)
    print(f"‖y‖∞ = {float(jnp.max(jnp.abs(y))):8.3f}   "
          f"‖x‖∞ = {float(jnp.max(jnp.abs(x))):6.3f}   "
          f"(information democratized: {float(jnp.max(jnp.abs(y)))/float(jnp.max(jnp.abs(x))):.0f}× flatter)")

    # --- 3: quantize at R = 4 bits/dim ------------------------------------
    codec = Codec(frame, CodecConfig(bits_per_dim=4.0))
    y_hat = codec.roundtrip(y)
    rel = float(jnp.linalg.norm(y_hat - y) / jnp.linalg.norm(y))
    print(f"R=4 bits/dim: ‖y − Q(y)‖/‖y‖ = {rel:.4f}  "
          f"(Thm. 1 bound: {codec.error_bound():.4f})")

    # --- 4: DGD-DEF vs naive quantized GD at R = 2 -------------------------
    m, d = 200, 64
    a = jax.random.normal(jax.random.key(2), (m, d)) ** 3 / jnp.sqrt(m)
    x_star = jax.random.normal(jax.random.key(3), (d,))
    h = a.T @ a
    eigs = jnp.linalg.eigvalsh(h)
    alpha = optim.alpha_star(float(eigs[-1]), float(eigs[0]))
    grad = lambda x: h @ (x - x_star)

    f2 = frames.hadamard_frame(jax.random.key(4), d)
    codec2 = Codec(f2, CodecConfig(bits_per_dim=2.0))
    t_def = optim.dgd_def(grad, jnp.zeros(d), codec2, alpha, 150,
                          x_star=x_star)
    t_naive = optim.dqgd_schedule(                 # DQGD of [6], same budget
        grad, jnp.zeros(d), levels=4, alpha=alpha, steps=150,
        L=float(eigs[-1]), mu=float(eigs[0]),
        D=float(jnp.linalg.norm(x_star)) * 1.5, n=d, x_star=x_star)
    print("\nleast squares, R=2 bits/dim, 150 steps:")
    print(f"  DGD-DEF   ‖x_T − x*‖ = {float(t_def.dist_history[-1]):.2e}")
    print(f"  DQGD [6]  ‖x_T − x*‖ = {float(t_naive.dist_history[-1]):.2e}")


if __name__ == "__main__":
    main()
