"""Prefix cache: scatter/extract round-trip identity + LRU behavior.

The round-trip `extract_slot` -> `scatter_slot` being bitwise the identity
is what makes a prefix-hit admission bit-exact with a cold one (the engine
contract in `repro.serve.verify_prefix_contract` reduces to it), so it is
property-tested here across every decode-capable block family: plain
attention, NDSC-quantized attention, recurrent (xlstm), and hybrid
(attention ring + SSM state).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import decode as decode_lib
from repro.models import model as model_lib
from repro.serve import PrefixCache

# family -> (arch, kv_quant_bits): attention, quantized attention,
# recurrent, hybrid — every decode cache taxonomy in models/decode.py
FAMILIES = {
    "attn": ("yi-6b", 0),
    "attn_quant8": ("yi-6b", 8),
    "recurrent": ("xlstm-350m", 0),
    "hybrid": ("hymba-1.5b", 0),
}

_CACHE = {}


def _model(family):
    if family not in _CACHE:
        arch, bits = FAMILIES[family]
        cfg = configs.get_reduced(arch)
        if bits:
            cfg = dataclasses.replace(cfg, kv_quant_bits=bits)
        params = model_lib.init_params(jax.random.key(0), cfg)
        _CACHE[family] = (cfg, params)
    return _CACHE[family]


def _leaves(state):
    return jax.tree.leaves((state.caches, state.pos))


def _assert_bitwise(a, b, msg):
    la, lb = _leaves(a), _leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y)), msg


@pytest.mark.parametrize("family", sorted(FAMILIES))
@given(st.integers(0, 10_000), st.integers(1, 14), st.integers(0, 2))
@settings(max_examples=10, deadline=None)
def test_extract_scatter_roundtrip_is_identity(family, seed, plen, slot):
    """extract(scatter(entry)) == entry, bitwise, for random prefill states
    of every cache family — including packed quantized words/scales."""
    cfg, params = _model(family)
    max_seq = 32
    prompt = jax.random.randint(jax.random.key(seed), (plen,), 0,
                                cfg.vocab_size, jnp.int32)
    _, st1 = decode_lib.prefill(cfg, params, prompt[None, :], max_seq)
    entry = decode_lib.extract_slot(st1, 0)          # trimmed to plen

    batched = decode_lib.init_decode_state(cfg, 3, max_seq)
    seated = decode_lib.scatter_slot(batched, entry, slot)
    back = decode_lib.extract_slot(seated, slot)
    _assert_bitwise(back, entry,
                    f"{family}: extract∘scatter is not the identity")
    # the other slots stay untouched (still all-zero / init values)
    for other in range(3):
        if other == slot:
            continue
        _assert_bitwise(decode_lib.extract_slot(seated, other, trim=False),
                        decode_lib.extract_slot(batched, other, trim=False),
                        f"{family}: scatter into slot {slot} leaked into "
                        f"slot {other}")


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_seated_entry_continues_like_fresh_prefill(family):
    """Seating the TRIMMED cache entry is indistinguishable from seating
    the full untrimmed slot (bitwise — trimming drops only dead positions),
    and the seated slot's continuation tracks the batch-1 continuation it
    came from. The cross-batch-shape comparison is numeric, not bitwise:
    XLA reduction order may differ between batch shapes, which is exactly
    why the engine contract compares equal-shape runs."""
    cfg, params = _model(family)
    max_seq = 32
    prompt = jax.random.randint(jax.random.key(7), (6,), 0,
                                cfg.vocab_size, jnp.int32)
    logits, st1 = decode_lib.prefill(cfg, params, prompt[None, :], max_seq)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]

    base = decode_lib.init_decode_state(cfg, 2, max_seq)
    seat_t = decode_lib.scatter_slot(
        base, decode_lib.extract_slot(st1, 0), 1)
    seat_f = decode_lib.scatter_slot(
        base, decode_lib.extract_slot(st1, 0, trim=False), 1)
    _assert_bitwise(seat_t, seat_f,
                    f"{family}: trimming the entry changed the seated state")

    toks2 = jnp.concatenate([jnp.zeros_like(tok), tok])    # slot 1 = tok
    l_new, st_new = decode_lib.decode_step(cfg, params, seat_t, toks2)
    l_ref, _ = decode_lib.decode_step(cfg, params, st1, tok)
    assert int(st_new.pos[1]) == int(prompt.shape[0]) + 1
    np.testing.assert_allclose(np.asarray(l_new[1]), np.asarray(l_ref[0]),
                               rtol=2e-5, atol=2e-5)


def test_expand_state_roundtrips_trimmed_entry():
    cfg, params = _model("attn_quant8")
    _, st1 = decode_lib.prefill(
        cfg, params, jnp.arange(5, dtype=jnp.int32)[None, :], 24)
    entry = decode_lib.extract_slot(st1, 0)
    full = decode_lib.expand_state(cfg, entry, 24)
    _assert_bitwise(decode_lib.extract_slot(full, 0), entry,
                    "expand_state lost entry content")
    assert int(full.pos[0]) == 5


# ---------------------------------------------------------------------------
# LRU cache behavior (host-side, no model needed beyond small states)
# ---------------------------------------------------------------------------
def _entry_state(cfg, tokens, max_seq=24):
    params = _model("attn")[1]
    _, st1 = decode_lib.prefill(cfg, params, jnp.asarray(tokens)[None, :],
                                max_seq)
    return decode_lib.extract_slot(st1, 0)


def test_lru_eviction_and_counters():
    cfg = _model("attn")[0]
    cache = PrefixCache(max_entries=2)
    for pid in ("a", "b", "c"):
        toks = np.arange(3, dtype=np.int32)
        cache.put(pid, toks, _entry_state(cfg, toks))
    assert len(cache) == 2 and cache.evictions == 1
    assert "a" not in cache and "b" in cache and "c" in cache

    assert cache.get("a") is None                 # miss counted
    assert cache.get("b") is not None             # hit counted, b now MRU
    toks = np.arange(3, dtype=np.int32)
    cache.put("d", toks, _entry_state(cfg, toks))  # evicts c, not b
    assert "b" in cache and "c" not in cache
    s = cache.stats()
    assert s == {"entries": 2, "bytes": cache.nbytes, "hits": 1,
                 "misses": 1, "evictions": 2}
    # peek touches neither the LRU order nor the counters
    assert cache.peek("nope") is None and cache.peek("d") is not None
    assert cache.stats()["hits"] == 1 and cache.stats()["misses"] == 1


def test_bytes_accounting_and_quantized_entries_are_smaller():
    """Entry bytes sum to the cache total, and an 8-bit NDSC entry for the
    same prefix costs a fraction of the f32 one — the serve-time HBM story."""
    cfg_f32 = _model("attn")[0]
    cfg_q8, params_q8 = _model("attn_quant8")
    toks = np.arange(8, dtype=np.int32)

    cache = PrefixCache(max_entries=4)
    e32 = cache.put("f32", toks, _entry_state(cfg_f32, toks))
    _, st_q = decode_lib.prefill(cfg_q8, params_q8,
                                 jnp.asarray(toks)[None, :], 24)
    eq8 = cache.put("q8", toks, decode_lib.extract_slot(st_q, 0))
    assert cache.nbytes == e32.nbytes + eq8.nbytes
    assert e32.nbytes > 0 and eq8.nbytes > 0
    assert eq8.nbytes < e32.nbytes / 2
    assert e32.length == eq8.length == 8


def test_rejects_zero_entry_budget():
    with pytest.raises(ValueError):
        PrefixCache(max_entries=0)
