import os
import sys

# The mesh-backend tests place cohort lanes on devices, so the suite runs
# with a few VIRTUAL host devices — forced here, before any jax import, via
# the only mechanism XLA offers (the dry-run sets its own 512 in a
# subprocess the same way). Any inherited XLA_FLAGS are dropped first: tests
# must see a deterministic device count, not whatever the shell had.
# REPRO_TEST_DEVICES=1 restores the historical single-device behavior (the
# mesh-parametrized fixtures then skip cleanly, as on single-device
# runners).
_DEVICES = os.environ.get("REPRO_TEST_DEVICES", "4")
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_DEVICES}")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests use hypothesis; the offline container has no wheel for it,
# so fall back to the deterministic mini-stub in tests/_stubs. A real
# installed hypothesis (CI: `pip install .[test]`) always takes precedence.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_stubs"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

import pytest  # noqa: E402

from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.obs import core as _obs_core  # noqa: E402


@pytest.fixture(autouse=True)
def _obs_isolation():
    """No obs session may leak between tests: the module-level stack is
    process-global, so a test that enables without disabling would silently
    instrument (and slow) every test after it."""
    yield
    _obs_core.reset()


@pytest.fixture(scope="session")
def mesh():
    """The shared 1×1 ("data","model") host mesh every dist test runs on."""
    return make_host_mesh(data=1, model=1)


@pytest.fixture(scope="session", params=[2, 4],
                ids=lambda n: f"{n}dev")
def data_mesh(request):
    """Host mesh with `param` devices on the data axis, parametrized over 2
    and 4 so mesh-backend tests exercise both even and UNEVEN lane splits
    (a 6-lane cohort pads to 8 on 4 devices but not on 2, etc.). Skips
    cleanly when the process has fewer devices — single-device runners, or
    REPRO_TEST_DEVICES=1."""
    if jax.device_count() < request.param:
        pytest.skip(f"needs {request.param} devices, "
                    f"have {jax.device_count()}")
    return make_host_mesh(data=request.param, model=1)
