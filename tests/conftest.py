import os
import sys

# tests must see exactly ONE device (the dry-run sets 512 in its own process)
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests use hypothesis; the offline container has no wheel for it,
# so fall back to the deterministic mini-stub in tests/_stubs. A real
# installed hypothesis (CI: `pip install .[test]`) always takes precedence.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_stubs"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

import pytest  # noqa: E402

from repro.launch.mesh import make_host_mesh  # noqa: E402


@pytest.fixture(scope="session")
def mesh():
    """The shared 1×1 ("data","model") host mesh every dist test runs on."""
    return make_host_mesh(data=1, model=1)
