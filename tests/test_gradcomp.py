"""Distributed gradient compression: codec, EF, wire audit, strategies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.dist import gradcomp as G


def _tree(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w": jax.random.normal(k1, (37, 19)),
            "b": jax.random.normal(k2, (64,)),
            "nested": {"v": jax.random.normal(k3, (3, 5, 7))}}


@given(bits=st.sampled_from([2, 4, 8]), seed=st.integers(0, 200))
@settings(max_examples=15, deadline=None)
def test_tree_roundtrip_error(bits, seed):
    cfg = G.GradCompConfig(bits=bits, chunk=128)
    tree = _tree(jax.random.key(seed))
    payloads, meta = G.compress_tree(tree, cfg)
    out = G.decode_payload(payloads, meta, cfg)
    for k in jax.tree.leaves(tree):
        pass
    flat_in, flat_out = jax.tree.leaves(tree), jax.tree.leaves(out)
    for a, b in zip(flat_in, flat_out):
        assert a.shape == b.shape
        rel = float(jnp.linalg.norm(b - a) / (jnp.linalg.norm(a) + 1e-9))
        # chunked NDSC bound with padding slack
        assert rel <= 2.0 ** (2 - bits) * np.sqrt(np.log(2 * 128)) + 1e-6


def test_deterministic_frames():
    """Same seed + leaf index → identical payloads (shared randomness)."""
    cfg = G.GradCompConfig(bits=4, chunk=64)
    x = jax.random.normal(jax.random.key(0), (100,))
    p1 = G.encode_leaf(x, 3, cfg)
    p2 = G.encode_leaf(x, 3, cfg)
    np.testing.assert_array_equal(p1["words"], p2["words"])
    p3 = G.encode_leaf(x, 4, cfg)          # different leaf → different frame
    assert not np.array_equal(np.asarray(p1["words"]),
                              np.asarray(p3["words"]))


def test_wire_bytes_audit():
    cfg = G.GradCompConfig(bits=4, chunk=64)
    tree = {"w": jnp.zeros((64, 64))}
    audit = G.wire_bytes_tree(tree, cfg, num_workers=8)
    assert audit["f32_bytes"] == 64 * 64 * 4
    assert audit["payload_bytes"] == 64 * 64 * 4 // 8 + 64 * 4
    assert audit["compression_x"] == pytest.approx(
        audit["f32_bytes"] / audit["payload_bytes"])


def test_stacked_decode():
    """extra_lead=1: decode m gathered payloads at once (consensus path)."""
    cfg = G.GradCompConfig(bits=8, chunk=64)
    xs = [jax.random.normal(jax.random.key(i), (50,)) for i in range(4)]
    payloads = [G.encode_leaf(x, 0, cfg) for x in xs]
    stacked = {"words": jnp.stack([p["words"] for p in payloads]),
               "scale": jnp.stack([p["scale"] for p in payloads])}
    tree = {"x": xs[0]}
    _, treedef = jax.tree.flatten(tree)
    meta = (treedef, [(50, (50,), jnp.float32)])
    out = G.decode_payload(jax.tree.unflatten(treedef, [stacked]), meta, cfg,
                           extra_lead=1)
    for i, x in enumerate(xs):
        rel = float(jnp.linalg.norm(out["x"][i] - x) / jnp.linalg.norm(x))
        assert rel < 0.05


def test_error_feedback_contracts():
    """EF: repeated compression of a FIXED gradient with error feedback makes
    the running descent direction mean → exact gradient (EF-SGD property)."""
    cfg = G.GradCompConfig(bits=2, chunk=64)
    g = jax.random.normal(jax.random.key(0), (200,)) ** 3
    e = jnp.zeros_like(g)
    decoded_sum = jnp.zeros_like(g)
    for t in range(30):
        u = g + e
        p = G.encode_leaf(u, 0, cfg)
        d = G.decode_leaf(p, 0, u.size, u.shape, u.dtype, cfg)
        e = u - d
        decoded_sum = decoded_sum + d
    mean_dir = decoded_sum / 30
    rel = float(jnp.linalg.norm(mean_dir - g) / jnp.linalg.norm(g))
    assert rel < 0.05          # without EF, 2-bit error plateaus ≈ β ≈ 0.9


def test_dithered_codec_unbiased_over_rounds():
    """§Perf it.10: non-subtractive uniform dither makes the chunked codec
    unbiased (in the quantizer interior) — the Alg.-2 property that lets
    training drop the params-sized EF state."""
    cfg = G.GradCompConfig(bits=4, chunk=128, dithered=True,
                           error_feedback=False)
    x = jax.random.normal(jax.random.key(0), (300,)) ** 3
    outs = [G.decode_leaf(G.encode_leaf(x, 0, cfg, round_idx=r), 0,
                          x.size, x.shape, x.dtype, cfg)
            for r in range(300)]
    mean = jnp.mean(jnp.stack(outs), 0)
    rel = float(jnp.linalg.norm(mean - x) / jnp.linalg.norm(x))
    det = G.GradCompConfig(bits=4, chunk=128)
    d = G.decode_leaf(G.encode_leaf(x, 0, det), 0, x.size, x.shape,
                      x.dtype, det)
    rel_det = float(jnp.linalg.norm(d - x) / jnp.linalg.norm(x))
    assert rel < rel_det / 3          # bias ≪ single-shot NN error


def test_dithered_training_without_ef(mesh):
    """Dithered codec + NO error feedback still fits a fixed batch."""
    from repro import configs
    from repro.data import batch_for_shape
    from repro.dist import step as step_lib
    from repro.optimizer import adamw
    cfg = configs.get_reduced("llama3.2-3b")
    gc = G.GradCompConfig(bits=4, chunk=256, dithered=True,
                          error_feedback=False)
    opt = adamw(3e-3)
    tstep = step_lib.make_train_step(cfg, opt, gc, mesh, clip_norm=1.0)
    params, opt_state, ef = step_lib.init_train_state(cfg, opt, gc, mesh)
    assert ef == {}                    # no EF state allocated
    batch = batch_for_shape(cfg, 8, 32, 0)
    losses = []
    for _ in range(20):
        params, opt_state, ef, metrics = tstep(params, opt_state, ef, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 2.0


def test_strategy_validation():
    with pytest.raises(ValueError):
        G.GradCompConfig(bits=3)
    with pytest.raises(ValueError):
        G.GradCompConfig(chunk=100)


@given(keep=st.sampled_from([0.25, 0.4, 0.5, 0.75]),
       n=st.integers(100, 3000), seed=st.integers(0, 100))
@settings(max_examples=15, deadline=None)
def test_exact_keep_deterministic_count_and_audit(keep, n, seed):
    """exact_keep: the realized kept-chunk count is deterministic and the
    realized bytes-on-wire equal the analytic audit exactly, every round."""
    cfg = G.GradCompConfig(bits=2, chunk=64, keep_fraction=keep,
                           exact_keep=True)
    x = jax.random.normal(jax.random.key(seed), (n,))
    c = -(-n // 64)
    tree = {"x": x}
    for r in (0, 1, 7):
        payloads, _ = G.compress_tree(tree, cfg, round_idx=r)
        assert int(payloads["x"]["mask"].sum()) == cfg.kept_chunks(c)
        assert (G.wire_bytes_payload(payloads, cfg)
                == G.wire_bytes_tree(tree, cfg)["payload_bytes"])


def test_exact_keep_roundtrip_decodes():
    cfg = G.GradCompConfig(bits=4, chunk=64, keep_fraction=0.5,
                           exact_keep=True)
    tree = {"x": jax.random.normal(jax.random.key(0), (400,))}
    payloads, meta = G.compress_tree(tree, cfg)
    out = G.decode_payload(payloads, meta, cfg)
    assert out["x"].shape == (400,)
    # kept chunks decode to something, dropped chunks to zero
    assert float(jnp.linalg.norm(out["x"])) > 0


def test_keep_mask_drawn_at_logical_chunks():
    """ROADMAP item: the ZeRO-1 owned layout (chunk count padded to a
    multiple of m) must produce the SAME payload as the un-padded all-gather
    encode on the real chunks when the keep mask / dither are in play —
    the mask is drawn at the pre-pad chunk count in both paths."""
    from repro.dist import zero as zero_lib
    x = jax.random.normal(jax.random.key(1), (500,))
    c = -(-500 // 64)                                   # 8 logical chunks
    for kwargs in ({"keep_fraction": 0.5},
                   {"keep_fraction": 0.5, "exact_keep": True},
                   {"dithered": True, "error_feedback": False},
                   {"dithered": True, "error_feedback": False,
                    "keep_fraction": 0.3}):
        cfg = G.GradCompConfig(bits=2, chunk=64, **kwargs)
        direct = G.encode_leaf(x, 3, cfg, round_idx=5)
        u = zero_lib.to_owned(x, 64, 3)                 # pads 8 → 9 chunks
        assert u.shape[0] != c                          # padding is real
        padded = G.encode_leaf(u, 3, cfg, round_idx=5, logical_chunks=c)
        for k in direct:
            np.testing.assert_array_equal(np.asarray(direct[k]),
                                          np.asarray(padded[k][:c]), err_msg=k)
        if "mask" in padded:
            assert not np.asarray(padded["mask"][c:]).any()


@given(bits=st.sampled_from([1, 2]),
       keep=st.sampled_from([0.25, 0.5, 0.75]),
       n=st.integers(100, 5000))
@settings(max_examples=20, deadline=None)
def test_wire_audit_sublinear_matches_analytic(bits, keep, n):
    """Sub-linear budget (R_eff = bits·keep < 2): the audited bytes-on-wire
    must equal the analytic formula — expected kept chunks × (packed words +
    f32 chunk scale) + the 1-bit-per-chunk keep mask. The chunk-level scale
    overhead is exactly what makes R_eff fractional."""
    cfg = G.GradCompConfig(bits=bits, chunk=64, keep_fraction=keep)
    assert cfg.effective_bits == pytest.approx(bits * keep)
    tree = {"w": jnp.zeros((n,))}
    audit = G.wire_bytes_tree(tree, cfg, num_workers=4)
    chunks = -(-n // 64)
    expect = keep * chunks * (64 * bits // 8 + 4) + (chunks + 7) // 8
    assert audit["f32_bytes"] == n * 4
    assert audit["payload_bytes"] == pytest.approx(expect)
    assert audit["compression_x"] == pytest.approx(n * 4 / expect)
    assert audit["allgather_rx_bytes"] == pytest.approx(3 * expect)


# ---------------------------------------------------------------------------
# exact_keep tie handling (the `draw <= thresh` bug kept >k chunks on ties)
# ---------------------------------------------------------------------------
@given(k=st.integers(0, 12),
       draws=st.lists(st.sampled_from([0.1, 0.3, 0.3, 0.3, 0.7]),
                      min_size=1, max_size=12))
@settings(max_examples=40, deadline=None)
def test_exact_keep_mask_exact_count_under_ties(k, draws):
    """_exact_keep_mask keeps EXACTLY min(k, c) chunks no matter how many
    draws tie — the k-th order statistic threshold would keep every tied
    chunk and blow the fixed wire budget."""
    k = min(k, len(draws))
    draw = jnp.asarray(draws, jnp.float32)[:, None]
    keep = G._exact_keep_mask(draw, k)
    assert keep.shape == draw.shape
    assert int(keep.sum()) == k


def test_exact_keep_all_ties_end_to_end(monkeypatch):
    """Worst case — EVERY keep-draw identical: the payload must still carry
    exactly kept_chunks(c) chunks and the realized bytes must equal the
    analytic audit (ties broken by chunk index, same on every worker)."""
    real_uniform = jax.random.uniform

    def tied_uniform(key, shape=(), *args, **kwargs):
        if tuple(shape)[-1:] == (1,):       # the (c, 1) keep draw
            return jnp.full(shape, 0.5, jnp.float32)
        return real_uniform(key, shape, *args, **kwargs)

    monkeypatch.setattr(jax.random, "uniform", tied_uniform)
    cfg = G.GradCompConfig(bits=2, chunk=64, keep_fraction=0.4,
                           exact_keep=True)
    x = jax.random.normal(jax.random.key(0), (700,))
    c = -(-700 // 64)
    tree = {"x": x}
    payloads, _ = G.compress_tree(tree, cfg)
    mask = np.asarray(payloads["x"]["mask"])[:, 0]
    k = cfg.kept_chunks(c)
    assert int(mask.sum()) == k
    # stable argsort rank ⇒ ties resolve to the lowest chunk indices
    np.testing.assert_array_equal(mask, ([1.0] * k + [0.0] * (c - k)))
    assert (G.wire_bytes_payload(payloads, cfg)
            == G.wire_bytes_tree(tree, cfg)["payload_bytes"])


def test_exact_keep_matches_threshold_when_no_ties():
    """With all-distinct draws the argsort-rank fix selects the same chunks
    the old k-th-order-statistic threshold did (regression guard)."""
    draw = jax.random.uniform(jax.random.key(3), (50, 1))
    k = 20
    keep = G._exact_keep_mask(draw, k)
    thresh = jnp.sort(draw[:, 0])[k - 1]
    np.testing.assert_array_equal(np.asarray(keep),
                                  np.asarray(draw <= thresh))


# ---------------------------------------------------------------------------
# fused encode+EF entry point
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kwargs", [
    {},
    {"dithered": True, "error_feedback": False},
    {"keep_fraction": 0.5, "exact_keep": True},
    {"dithered": True, "error_feedback": False, "keep_fraction": 0.3},
])
def test_encode_leaf_ef_matches_composed(kwargs):
    """encode_leaf_ef: the payload is IDENTICAL to encode_leaf under the
    same key/round, and the residual matches the composed eager
    u − decode_leaf(encode_leaf(u)) to a few ulp of the embedding scale."""
    cfg = G.GradCompConfig(bits=2, chunk=64, **kwargs)
    x = jax.random.normal(jax.random.key(9), (500,))
    payload, resid = G.encode_leaf_ef(x, 3, cfg, round_idx=5)
    direct = G.encode_leaf(x, 3, cfg, round_idx=5)
    assert set(payload) == set(direct)
    for k in direct:
        np.testing.assert_array_equal(payload[k], direct[k])
    decoded = G.decode_leaf(direct, 3, x.size, x.shape, x.dtype, cfg)
    assert resid.shape == x.shape and resid.dtype == x.dtype
    np.testing.assert_allclose(resid, x - decoded, atol=5e-6, rtol=0)
