"""Scalar quantizers (paper §3 Eq. (11), App. E Eq. (20))."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import quantizers as q


@given(st.integers(min_value=1, max_value=8), st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_uniform_quantizer_max_error(bits, seed):
    """Per-coordinate error ≤ Δ/2 = 1/levels on B∞(1) (Eq. (11))."""
    levels = 2 ** bits
    x = jax.random.uniform(jax.random.key(seed), (64,), minval=-1, maxval=1)
    err = jnp.abs(q.uniform_quantize(x, levels) - x)
    assert float(jnp.max(err)) <= 1.0 / levels + 1e-6


def test_quantize_dequantize_indices_roundtrip():
    x = jnp.linspace(-1, 1, 101)
    for levels in (2, 3, 4, 16, 256):
        idx = q.quantize_indices(x, levels)
        assert int(idx.min()) >= 0 and int(idx.max()) <= levels - 1
        np.testing.assert_allclose(q.dequantize_indices(idx, levels),
                                   q.uniform_quantize(x, levels), atol=1e-6)


def test_dithered_quantizer_unbiased():
    """E[Q(v)] = v (App. E: unbiasedness is what removes error feedback)."""
    v = jnp.array([0.3, -0.7, 0.123, 0.99])
    keys = jax.random.split(jax.random.key(0), 4000)
    samples = jax.vmap(lambda k: q.dithered_quantize(k, v, levels=5))(keys)
    np.testing.assert_allclose(jnp.mean(samples, axis=0), v, atol=0.02)


def test_dithered_indices_consistent():
    key = jax.random.key(3)
    x = jax.random.uniform(key, (256,), minval=-1, maxval=1)
    idx = q.dithered_quantize_indices(key, x, 7)
    vals = q.dithered_dequantize_indices(idx, 7)
    np.testing.assert_allclose(vals, q.dithered_quantize(key, x, 7), atol=1e-6)


def test_gain_quantizer_unbiased_in_range():
    v = jnp.array([0.0, 1.7, 3.2])
    keys = jax.random.split(jax.random.key(1), 3000)
    samples = jax.vmap(lambda k: q.gain_quantize(k, v, dynamic_range=4.0,
                                                 bits=3))(keys)
    np.testing.assert_allclose(jnp.mean(samples, axis=0), v, atol=0.05)


def test_subsample_mask_rate():
    mask = q.subsample_mask(jax.random.key(0), (100_000,), 0.3)
    assert abs(float(jnp.mean(mask)) - 0.3) < 0.01


def test_levels_for_budget():
    assert q.levels_for_budget(1) == 2
    assert q.levels_for_budget(4) == 16
    assert q.levels_for_budget(0.5) == 2      # sub-linear floor
    assert q.levels_for_budget(2.5) == 5
