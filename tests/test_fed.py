"""repro.fed: engine equivalences, wire ledger, error-feedback contraction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import optim as core_optim
from repro.fed import (ClientConfig, FedConfig, Federation, ServerConfig,
                       clients as clients_lib, server as server_lib)
from repro import codecs as registry
from repro.optimizer import sgd


def _quadratic(dim=48, n=96, seed=0):
    """Shared least-squares problem: (data dict, loss_fn, grad_fn, x*)."""
    ka, kx = jax.random.split(jax.random.key(seed))
    a = jax.random.normal(ka, (n, dim)) / jnp.sqrt(n)
    x_true = jax.random.normal(kx, (dim,))
    b = a @ x_true

    def loss_fn(params, batch):
        r = batch["a"] @ params["x"] - batch["b"]
        return 0.5 * jnp.sum(r * r)

    grad_fn = lambda x: a.T @ (a @ x - b)
    return {"a": a, "b": b}, loss_fn, grad_fn, x_true


def test_fedavg_identity_matches_gd():
    """(a) FedAvg + identity codec + shared quadratic + 1 local step is
    plain gradient descent — must match core.optim.gd."""
    data, loss_fn, grad_fn, _ = _quadratic()
    dim, lr, rounds = 48, 0.4, 25
    params = {"x": jnp.zeros(dim)}
    codec = registry.make("identity")
    fed = Federation(loss_fn, params, [data] * 4, codec,
                     ClientConfig(local_steps=1, lr=lr),
                     ServerConfig(aggregator="fedavg", server_lr=1.0))
    fed.run(FedConfig(num_rounds=rounds))
    ref = core_optim.gd(grad_fn, jnp.zeros(dim), lr, rounds)
    np.testing.assert_allclose(np.asarray(fed.server.params["x"]),
                               np.asarray(ref.x_final), atol=1e-5)


def test_identity_no_error_feedback_state():
    data, loss_fn, _, _ = _quadratic()
    params = {"x": jnp.zeros(48)}
    fed = Federation(loss_fn, params, [data] * 2, registry.make("identity"),
                     ClientConfig(error_feedback=False))
    fed.run(FedConfig(num_rounds=2))
    assert fed.states[0].ef == {}
    assert int(fed.states[0].rounds_seen) == 2


@pytest.mark.parametrize("budgets", [[2.0, 2.0, 2.0], [0.5, 1.5, 4.0]])
def test_ledger_matches_analytic_audit(budgets):
    """(b) realized per-round wire bytes == analytic audit, to the byte,
    homogeneous and heterogeneous, under partial participation."""
    data, loss_fn, _, _ = _quadratic()
    params = {"x": jnp.zeros(48)}
    codecs = [registry.make("ndsc", budget=b, chunk=32) for b in budgets]
    fed = Federation(loss_fn, params, [data] * 3, codecs,
                     ClientConfig(local_steps=2, lr=0.1), seed=5)
    hist = fed.run(FedConfig(num_rounds=6, participation=0.7, dropout=0.3,
                             seed=11))
    assert any(hist["stragglers"]) or all(hist["participants"])
    for real, ana, parts in zip(hist["wire_bytes"], hist["analytic_bytes"],
                                hist["participants"]):
        assert real == ana
        if not parts:
            assert real == 0.0
    # analytic per-client: ndsc payload for 48 dims @ chunk 32 → 2 chunks
    per_client = {
        i: codecs[i].wire_bits(params) / 8.0 for i in range(3)}
    for real, parts in zip(hist["wire_bytes"], hist["participants"]):
        assert real == sum(per_client[i] for i in parts)


def test_error_feedback_contracts_fixed_point():
    """(c) fixed gradient ⇒ per-round delta is constant; with EF the running
    mean of applied updates converges to the true delta (EF-SGD fixed point)
    and the EF memory stays bounded."""
    dim = 96
    g = jax.random.normal(jax.random.key(3), (dim,)) ** 3
    data = {"g": g[None]}            # one "sample" carrying the gradient

    def loss_fn(params, batch):
        return jnp.sum(batch["g"][0] * params["x"])   # ∇ = g, constant

    lr, rounds = 0.1, 40
    params = {"x": jnp.zeros(dim)}
    codec = registry.make("ndsc", budget=2.0, chunk=32)
    fed = Federation(loss_fn, params, [data], codec,
                     ClientConfig(local_steps=1, lr=lr),
                     ServerConfig(server_lr=1.0))
    ef_norms = []
    for t in range(rounds):
        fed.run_round(FedConfig(num_rounds=rounds), t)
        ef_norms.append(float(jnp.linalg.norm(fed.states[0].ef["x"])))
    # server walked x ← x + Σ decoded; with EF, Σ decoded → −rounds·lr·g
    target = -rounds * lr * g
    got = np.asarray(fed.server.params["x"])
    rel = np.linalg.norm(got - target) / np.linalg.norm(target)
    assert rel < 0.05, rel
    # EF memory is bounded (β/(1−β)·‖u‖-style), not growing
    assert ef_norms[-1] < 5.0 * lr * float(jnp.linalg.norm(g))
    assert max(ef_norms) == pytest.approx(max(ef_norms[:10]), rel=1.0)


def test_heterogeneous_chunk_layouts_reconcile():
    """Clients on different chunk sizes AND budgets decode to dense deltas
    the server can average — the layout reconciliation path."""
    data, loss_fn, _, _ = _quadratic()
    params = {"x": jnp.zeros(48)}
    codecs = [registry.make("ndsc", budget=1.0, chunk=32),
              registry.make("ndsc", budget=4.0, chunk=64),
              registry.make("identity")]
    fed = Federation(loss_fn, params, [data] * 3, codecs,
                     ClientConfig(local_steps=1, lr=0.3))
    hist = fed.run(FedConfig(num_rounds=8),
                   eval_fn=lambda p: loss_fn(p, data))
    assert hist["loss"][-1] < hist["loss"][0]


def test_cohort_round_matches_sequential():
    """vmapped cohort round == running the same clients one by one."""
    data, loss_fn, _, _ = _quadratic()
    m, dim = 3, 48
    params = {"x": jnp.zeros(dim)}
    codec = registry.make("ndsc", budget=2.0, chunk=32)
    ccfg = ClientConfig(local_steps=1, lr=0.2)
    key = jax.random.key(7)
    states = [clients_lib.init_client_state(params, jax.random.fold_in(key, i),
                                            ccfg) for i in range(m)]
    datas = [jax.tree.map(lambda a, i=i: a * (1.0 + 0.1 * i), data)
             for i in range(m)]
    single = clients_lib.make_client_round(loss_fn, codec, ccfg, params)
    seq = [single(params, datas[i], states[i], 0) for i in range(m)]

    cohort = clients_lib.make_cohort_round(loss_fn, codec, ccfg, params)
    stacked_data = jax.tree.map(lambda *xs: jnp.stack(xs), *datas)
    stacked_state = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
    wires, new_states = cohort(params, stacked_data, stacked_state, 0)
    for i in range(m):
        for k in ("words", "scale"):
            np.testing.assert_array_equal(np.asarray(seq[i][0]["x"][k]),
                                          np.asarray(wires["x"][k][i]))
        np.testing.assert_allclose(np.asarray(seq[i][1].ef["x"]),
                                   np.asarray(new_states.ef["x"][i]),
                                   rtol=1e-6, atol=1e-7)


def test_fedopt_server_optimizer():
    """Delta-compressed FedOpt via repro.optimizer converges on the shared
    quadratic and keeps optimizer state on the server."""
    data, loss_fn, _, _ = _quadratic()
    params = {"x": jnp.zeros(48)}
    fed = Federation(loss_fn, params, [data] * 2,
                     registry.make("ndsc", budget=4.0, chunk=32),
                     ClientConfig(local_steps=1, lr=0.3),
                     ServerConfig(aggregator="fedopt",
                                  optimizer=sgd(1.0, momentum=0.5)))
    hist = fed.run(FedConfig(num_rounds=15),
                   eval_fn=lambda p: loss_fn(p, data))
    assert hist["loss"][-1] < 0.2 * hist["loss"][0]
    assert int(fed.server.opt_state["step"]) == 15


def test_fedmem_full_participation_matches_fedavg():
    """With full participation every memory slot is refreshed each round, so
    the EF21-style fedmem step equals plain FedAvg."""
    data, loss_fn, _, _ = _quadratic()
    params = {"x": jnp.zeros(48)}
    codec = registry.make("ndsc", budget=4.0, chunk=32)
    ccfg = ClientConfig(local_steps=1, lr=0.3)
    runs = {}
    for agg in ("fedavg", "fedmem"):
        fed = Federation(loss_fn, params, [data] * 3, codec, ccfg,
                         ServerConfig(aggregator=agg), seed=2)
        fed.run(FedConfig(num_rounds=5))
        runs[agg] = np.asarray(fed.server.params["x"])
    np.testing.assert_allclose(runs["fedavg"], runs["fedmem"],
                               rtol=1e-5, atol=1e-6)


def test_fedmem_partial_participation_uses_stale_slots():
    data, loss_fn, _, _ = _quadratic()
    params = {"x": jnp.zeros(48)}
    fed = Federation(loss_fn, params, [data] * 4,
                     registry.make("ndsc", budget=4.0, chunk=32),
                     ClientConfig(local_steps=1, lr=0.2),
                     ServerConfig(aggregator="fedmem"), seed=3)
    hist = fed.run(FedConfig(num_rounds=10, participation=0.5, seed=9),
                   eval_fn=lambda p: loss_fn(p, data))
    assert all(len(p) == 2 for p in hist["participants"])
    assert hist["loss"][-1] < hist["loss"][0]
    mem_norm = float(jnp.linalg.norm(fed.server.memory["x"]))
    assert mem_norm > 0.0


def test_fedmem_data_size_weighting_reaches_slots():
    """weighting='data_size' must change the fedmem direction (slots are
    averaged with per-client weights, not uniformly)."""
    data, loss_fn, _, _ = _quadratic()
    small = jax.tree.map(lambda a: a[:24], data)
    params = {"x": jnp.zeros(48)}
    outs = {}
    for weighting in ("uniform", "data_size"):
        fed = Federation(loss_fn, params, [data, small],
                         registry.make("identity"),
                         ClientConfig(local_steps=1, lr=0.3),
                         ServerConfig(aggregator="fedmem"), seed=4)
        fed.run(FedConfig(num_rounds=3, weighting=weighting))
        outs[weighting] = np.asarray(fed.server.params["x"])
    assert not np.allclose(outs["uniform"], outs["data_size"])


def test_server_config_validation():
    with pytest.raises(ValueError):
        ServerConfig(aggregator="bogus")
    with pytest.raises(ValueError):
        ServerConfig(aggregator="fedopt")          # optimizer missing
    with pytest.raises(ValueError):
        FedConfig(participation=0.0)
    with pytest.raises(ValueError):
        FedConfig(dropout=1.0)


def test_empty_round_skips_update():
    """A round where every sampled client straggles leaves params unchanged
    and ledgers zero bytes."""
    data, loss_fn, _, _ = _quadratic()
    params = {"x": jnp.ones(48)}
    fed = Federation(loss_fn, params, [data] * 2, registry.make("identity"))
    before = np.asarray(fed.server.params["x"]).copy()
    # force the empty-participants path directly
    fed.server = server_lib.aggregate(fed.server, fed.server_cfg, [], [])
    np.testing.assert_array_equal(np.asarray(fed.server.params["x"]), before)
