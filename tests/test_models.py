"""Per-architecture smoke tests (reduced configs, one fwd/train step on CPU)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import batch_for_shape
from repro.models import model as model_lib


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get_reduced(arch)
    params = model_lib.init_params(jax.random.key(0), cfg)
    batch = batch_for_shape(cfg, 2, 32)
    loss = jax.jit(lambda p, b: model_lib.loss_fn(cfg, p, b))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_one_train_step_reduces_loss_direction(arch):
    """One plain SGD step along the gradient must not blow up (finite grads,
    loss moves); catches NaN/∞ gradients per block family."""
    cfg = configs.get_reduced(arch)
    params = model_lib.init_params(jax.random.key(0), cfg)
    batch = batch_for_shape(cfg, 2, 32)
    loss_fn = lambda p: model_lib.loss_fn(cfg, p, batch)
    loss0, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    params2 = jax.tree.map(lambda p, g: p - 0.05 * g / (gnorm + 1e-9),
                           params, grads)
    loss1 = jax.jit(loss_fn)(params2)
    assert bool(jnp.isfinite(loss1))
    assert float(loss1) < float(loss0) + 0.05   # descent (tolerant)


def test_logits_shape_dense():
    cfg = configs.get_reduced("phi3-mini-3.8b")
    params = model_lib.init_params(jax.random.key(0), cfg)
    batch = batch_for_shape(cfg, 2, 16)
    logits = model_lib.logits_fn(cfg, params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)


def test_vlm_loss_masks_image_positions():
    """pixtral: image-prefix positions must not contribute to the CE loss."""
    cfg = configs.get_reduced("pixtral-12b")
    params = model_lib.init_params(jax.random.key(0), cfg)
    batch = batch_for_shape(cfg, 2, 32)
    h, positions, targets = model_lib._embed_inputs(cfg, params, batch)
    assert h.shape[1] == 32                       # patches + text
    assert int(jnp.sum(targets[:, :cfg.num_patches] == -1)) \
        == 2 * cfg.num_patches


def test_param_counts_match_assignments():
    expected = {
        "hymba-1.5b": 1.5, "phi3-mini-3.8b": 3.8, "yi-6b": 6.0,
        "arctic-480b": 480.0, "pixtral-12b": 12.0, "llama3.2-3b": 3.0,
        "mixtral-8x22b": 141.0, "mistral-large-123b": 123.0,
        "xlstm-350m": 0.35, "hubert-xlarge": 0.96,
    }
    for arch, target_b in expected.items():
        n = model_lib.param_count(configs.get(arch)) / 1e9
        assert 0.6 * target_b <= n <= 1.45 * target_b, (arch, n)


def test_moe_capacity_and_aux():
    from repro.models import moe as moe_lib
    key = jax.random.key(0)
    d, e, f, t = 16, 4, 32, 64
    x = jax.random.normal(key, (2, t // 2, d))
    ks = jax.random.split(key, 4)
    router = jax.random.normal(ks[0], (d, e)) * 0.1
    wg = jax.random.normal(ks[1], (e, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (e, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (e, f, d)) * 0.1
    out, aux = moe_lib.moe_ffn(x, router, wg, wu, wd, top_k=2,
                               capacity_factor=8.0, return_aux=True)
    assert out.shape == x.shape
    assert float(aux["drop_fraction"]) == 0.0     # cf=8 → nothing dropped
    assert float(aux["load_balance_loss"]) >= 1.0 - 1e-3  # ≥ 1 at optimum


def test_sliding_window_attention_masks_past():
    """A token must not attend beyond `window` positions back."""
    from repro.models import layers as L
    b, s, h, dh, w = 1, 64, 2, 8, 16
    key = jax.random.key(0)
    q = jax.random.normal(key, (b, s, h, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, dh))
    out_w = L.blockwise_attention(q, k, v, causal=True, window=w,
                                  block_q=16, block_kv=16)
    # perturb kv far in the past of the last query: output must not change
    k2 = k.at[:, : s - w - 1].set(jax.random.normal(jax.random.fold_in(key, 3),
                                                    (b, s - w - 1, h, dh)))
    v2 = v.at[:, : s - w - 1].set(jax.random.normal(jax.random.fold_in(key, 4),
                                                    (b, s - w - 1, h, dh)))
    out_w2 = L.blockwise_attention(q, k2, v2, causal=True, window=w,
                                   block_q=16, block_kv=16)
    np.testing.assert_allclose(out_w[:, -1], out_w2[:, -1], atol=1e-5)


def test_blockwise_attention_matches_naive():
    from repro.models import layers as L
    b, s, h, dh = 2, 48, 3, 16
    key = jax.random.key(5)
    q = jax.random.normal(key, (b, s, h, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, dh))
    got = L.blockwise_attention(q, k, v, causal=True, block_q=16,
                                block_kv=16)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / dh ** 0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask, scores, -1e30)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_mamba_assoc_scan_matches_sequential():
    """ssm_scan="associative" must be numerically identical (§Perf it.9)."""
    from repro.models import ssm
    p = ssm.init_mamba(jax.random.key(0), 32, 32, 8)
    x = jax.random.normal(jax.random.key(1), (2, 16, 32))
    y1, h1 = ssm.mamba_scan(p, x)
    y2, h2 = ssm.mamba_assoc_scan(p, x)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(h1, h2, rtol=1e-4, atol=1e-5)


def test_hybrid_forward_assoc_scan_config():
    cfg = dataclasses.replace(configs.get_reduced("hymba-1.5b"),
                              ssm_scan="associative")
    params = model_lib.init_params(jax.random.key(0), cfg)
    batch = batch_for_shape(cfg, 2, 32)
    loss = jax.jit(lambda p, b: model_lib.loss_fn(cfg, p, b))(params, batch)
    assert bool(jnp.isfinite(loss))
