"""Tiny deterministic stand-in for `hypothesis` (offline test container).

Only used when the real hypothesis is not installed — tests/conftest.py adds
this directory to sys.path as a fallback, so `pip install .[test]` (CI, dev
machines) always wins. Implements exactly what this repo's property tests
use: @given with positional/keyword strategies, @settings(max_examples,
deadline), st.integers / st.sampled_from / st.floats / st.booleans /
st.lists.

Draws are deterministic per test (seeded by the test's qualified name), so a
failing example reproduces on re-run. No shrinking — the drawn kwargs appear
in the assertion traceback instead.
"""
from __future__ import annotations

import functools
import inspect
import random

__version__ = "0.0.stub"


class SearchStrategy:
    def __init__(self, draw, label: str):
        self._draw = draw
        self.label = label

    def example_from(self, rng: random.Random):
        return self._draw(rng)

    def __repr__(self):
        return f"st.{self.label}"


class strategies:  # noqa: N801 — mirrors `hypothesis.strategies` module use
    @staticmethod
    def integers(min_value: int = 0, max_value: int = 2**31 - 1):
        return SearchStrategy(lambda r: r.randint(min_value, max_value),
                              f"integers({min_value}, {max_value})")

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return SearchStrategy(lambda r: r.choice(elements),
                              f"sampled_from({elements})")

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_):
        return SearchStrategy(lambda r: r.uniform(min_value, max_value),
                              f"floats({min_value}, {max_value})")

    @staticmethod
    def booleans():
        return SearchStrategy(lambda r: r.random() < 0.5, "booleans()")

    @staticmethod
    def lists(elements, min_size: int = 0, max_size: int = 10):
        return SearchStrategy(
            lambda r: [elements.example_from(r)
                       for _ in range(r.randint(min_size, max_size))],
            f"lists({elements.label}, {min_size}..{max_size})")


st = strategies


def settings(max_examples: int = 10, deadline=None, **_ignored):
    """Attach settings; must sit between @given and the test function."""

    def deco(fn):
        fn._stub_settings = {"max_examples": max_examples}
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    """Run the test once per drawn example (max_examples, default 10)."""

    def deco(fn):
        n = getattr(fn, "_stub_settings", {}).get("max_examples", 10)
        sig = inspect.signature(fn)
        # real hypothesis assigns positional strategies to the RIGHTMOST
        # parameters (leading params stay free for pytest fixtures)
        free = [p for p in sig.parameters if p not in kw_strategies]
        pos_names = free[len(free) - len(arg_strategies):]
        drawn_names = set(pos_names) | set(kw_strategies)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = random.Random(fn.__qualname__)
            for _ in range(n):
                drawn = {name: s.example_from(rng)
                         for name, s in zip(pos_names, arg_strategies)}
                drawn.update({k: s.example_from(rng)
                              for k, s in kw_strategies.items()})
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (hypothesis stub): {drawn}"
                    ) from e

        # pytest must only see the NON-drawn parameters (fixtures), else it
        # tries to resolve the strategy-bound names as fixtures.
        del wrapper.__wrapped__
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in drawn_names])
        return wrapper

    return deco


def assume(condition) -> bool:
    """No-op acceptance (the stub has no example rejection machinery)."""
    return bool(condition)
