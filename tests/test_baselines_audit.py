"""Property tests: every baseline Compressor's wire_bits audit is honest.

For each registered scheme, check that the analytic `wire_bits(n)` matches
the bits actually needed to describe the roundtrip output:

  * level-grid schemes — the output values land on the advertised grid, so
    log2(levels) bits per coordinate (+32 for the f32 scale) suffice;
  * sign/ternary — the output alphabet really has 2 / 3 symbols;
  * top-k / rand-k — at most k coordinates survive, and the audit charges
    the index cost log2(C(n, k)) for naming them plus the per-value payload.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import baselines as B


def _y(seed, n):
    return jax.random.normal(jax.random.key(seed), (n,)) ** 3


def _grid_positions(y_hat, scale, levels):
    """Quantizer level index of each output value on the [-scale, scale]
    uniform grid; valid iff every position is a near-integer in range."""
    delta = 2.0 / levels
    pos = (np.asarray(y_hat) / np.asarray(scale) + 1.0 - delta / 2.0) / delta
    return pos


@given(levels=st.sampled_from([4, 8, 16, 64]), n=st.integers(8, 600),
       seed=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_uniform_grid_schemes_fit_audit(levels, n, seed):
    y = _y(seed, n)
    scale = float(jnp.max(jnp.abs(y)))
    # naive: midpoint grid −1 + (2i+1)Δ/2; dither: endpoint grid −1 + jΔ'
    for comp, pos_of in (
            (B.naive_uniform(levels),
             lambda v: _grid_positions(v, scale, levels)),
            (B.standard_dither(levels),
             lambda v: (np.asarray(v) / scale + 1.0) * (levels - 1) / 2.0)):
        y_hat = comp.roundtrip(jax.random.key(seed + 1), y)
        pos = pos_of(y_hat)
        assert np.all(pos > -0.5) and np.all(pos < levels - 0.5), comp.name
        np.testing.assert_allclose(pos, np.round(pos), atol=1e-3)
        # n grid indices + one f32 scale — exactly the audit
        assert comp.wire_bits(n) == pytest.approx(
            n * math.log2(levels) + 32)


@given(s=st.sampled_from([1, 4, 15]), n=st.integers(8, 600),
       seed=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_qsgd_levels_fit_audit(s, n, seed):
    """QSGD output is sign · (ℓ/s) · ‖y‖₂ with ℓ ∈ {0..s}: 1 sign bit +
    log2(s+1) level bits per coordinate + 32 for the norm."""
    y = _y(seed, n)
    comp = B.qsgd(s)
    y_hat = comp.roundtrip(jax.random.key(seed + 1), y)
    norm = float(jnp.linalg.norm(y))
    lev = np.abs(np.asarray(y_hat)) / norm * s
    np.testing.assert_allclose(lev, np.round(lev), atol=1e-3)
    assert np.all(lev <= s + 0.5)
    assert comp.wire_bits(n) == pytest.approx(
        n * (1 + math.log2(s + 1)) + 32)


@given(n=st.integers(8, 600), seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_sign_and_ternary_alphabets(n, seed):
    y = _y(seed, n)
    comp = B.sign_compressor()
    y_hat = np.asarray(comp.roundtrip(jax.random.key(0), y))
    assert len(np.unique(np.round(y_hat, 6))) <= 2
    assert comp.wire_bits(n) == n + 32

    tern = B.ternary()
    t_hat = np.asarray(tern.roundtrip(jax.random.key(seed + 1), y))
    assert len(np.unique(np.round(t_hat, 6))) <= 3
    assert tern.wire_bits(n) == pytest.approx(n * math.log2(3) + 32)


@given(kf=st.sampled_from([0.05, 0.125, 0.5]), n=st.integers(16, 600),
       seed=st.integers(0, 50),
       quant=st.sampled_from([None, 16, 256]))
@settings(max_examples=25, deadline=None)
def test_topk_randk_sparsity_and_index_cost(kf, n, seed, quant):
    """Sparsifiers: ≤ k survivors; the audit charges k payload values plus
    the log2(C(n,k)) bits needed to name the surviving index set."""
    y = _y(seed, n)
    k = max(1, int(round(kf * n)))
    payload = 32 if quant is None else math.log2(quant)
    expect = k * payload + math.log2(math.comb(n, k)) + 32
    for comp in (B.topk(kf, quant), B.randk(kf, quant)):
        y_hat = np.asarray(comp.roundtrip(jax.random.key(seed + 1), y))
        nnz = int(np.sum(y_hat != 0.0))
        assert nnz <= k + 1, comp.name      # +1: magnitude ties at the cut
        assert comp.wire_bits(n) == pytest.approx(expect), comp.name
    # the index cost is real: audit must exceed the pure-payload cost
    assert B.topk(kf, quant).wire_bits(n) > k * payload


def test_randk_unbiased_rescale_uses_realized_keep_rate():
    """unbiased=True must divide by the EXACT keep probability k/n of the
    fixed-size mask, not the requested fraction k was rounded from."""
    n = 30
    y = jnp.ones((n,))
    comp = B.randk(0.05, unbiased=True)          # k = round(1.5) = 2, not n/20
    keys = jax.random.split(jax.random.key(0), 4000)
    mean = jnp.mean(jax.vmap(lambda k: comp.roundtrip(k, y))(keys), axis=0)
    np.testing.assert_allclose(np.asarray(mean), 1.0, atol=0.15)


def test_index_cost_grows_with_n_at_fixed_k():
    """Naming k survivors out of n costs more bits as n grows — the audit
    must reflect the log2(C(n,k)) term, not just k payload values."""
    b1 = B.topk(0.5, 256).wire_bits(64)      # k = 32 of 64
    k = 32
    b2 = B.topk(k / 1024, 256).wire_bits(1024)   # k = 32 of 1024
    assert b2 > b1
    assert b2 - b1 == pytest.approx(
        math.log2(math.comb(1024, 32)) - math.log2(math.comb(64, 32)))


def test_quantized_topk_values_on_grid():
    y = _y(3, 128)
    comp = B.topk(0.25, quant_levels=16)
    y_hat = np.asarray(comp.roundtrip(jax.random.key(0), y))
    kept = y_hat[y_hat != 0.0]
    # top-k keeps the max coordinate, so the quantizer scale is max|y|
    scale = float(jnp.max(jnp.abs(y)))
    pos = _grid_positions(kept, scale, 16)
    np.testing.assert_allclose(pos, np.round(pos), atol=1e-3)
