"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import fwht as fwht_kernel
from repro.kernels import ops as kernel_ops
from repro.kernels import quantencode as qe_kernel
from repro.kernels import quantpack as qp_kernel
from repro.kernels import ref


# ---------------------------------------------------------------------------
# FWHT
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [2, 8, 64, 128, 1024])
@pytest.mark.parametrize("lead", [(), (1,), (5,), (3, 4)])
def test_fwht_pallas_matches_ref(n, lead):
    x = jax.random.normal(jax.random.key(0), lead + (n,))
    got = fwht_kernel.fwht_pallas(x, interpret=True)
    np.testing.assert_allclose(got, ref.fwht(x), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_dtypes(dtype):
    x = jax.random.normal(jax.random.key(1), (4, 256)).astype(dtype)
    got = fwht_kernel.fwht_pallas(x, interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref.fwht(x), np.float32),
                               rtol=tol, atol=tol)


def test_fwht_orthonormal_involution():
    """H·H = I (normalized Hadamard is its own inverse)."""
    x = jax.random.normal(jax.random.key(2), (3, 512))
    np.testing.assert_allclose(ref.fwht(ref.fwht(x)), x, atol=1e-4)
    np.testing.assert_allclose(
        fwht_kernel.fwht_pallas(fwht_kernel.fwht_pallas(x, interpret=True),
                                interpret=True), x, atol=1e-4)


def test_fwht_matches_hadamard_matrix():
    n = 16
    h = np.array([[1.0]])
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    h /= np.sqrt(n)
    x = np.random.RandomState(0).randn(4, n).astype(np.float32)
    np.testing.assert_allclose(ref.fwht(jnp.asarray(x)), x @ h, atol=1e-5)


def test_fwht_rejects_non_pow2():
    with pytest.raises(ValueError):
        fwht_kernel.fwht_pallas(jnp.zeros((2, 48)), interpret=True)


# ---------------------------------------------------------------------------
# quantize-pack / unpack-dequant
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits", [1, 2, 4, 8])
@pytest.mark.parametrize("rows,n", [(1, 32), (7, 128), (16, 1024)])
def test_quantpack_pallas_matches_ref(bits, rows, n):
    x = jax.random.normal(jax.random.key(3), (rows, n))
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    got = qp_kernel.quantize_pack_pallas(x, scale, bits, interpret=True)
    want = ref.quantize_pack(x, scale, bits)
    np.testing.assert_array_equal(got, want)
    back = qp_kernel.unpack_dequant_pallas(got, scale, bits, n,
                                           interpret=True)
    np.testing.assert_allclose(back, ref.unpack_dequant(want, scale, bits, n),
                               atol=1e-6)


@given(bits=st.sampled_from([1, 2, 4, 8]), seed=st.integers(0, 500))
@settings(max_examples=20, deadline=None)
def test_quantpack_roundtrip_error_property(bits, seed):
    """|x − unpack(pack(x))| ≤ scale/2^bits per coordinate."""
    n = 128
    x = jax.random.normal(jax.random.key(seed), (4, n))
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    words = ref.quantize_pack(x, scale, bits)
    back = ref.unpack_dequant(words, scale, bits, n)
    max_err = float(jnp.max(jnp.abs(back - x) / scale))
    assert max_err <= 1.0 / (2 ** bits) + 1e-6


def test_quantpack_rejects_bad_bits():
    x = jnp.zeros((2, 32))
    s = jnp.ones((2, 1))
    with pytest.raises(ValueError):
        ref.quantize_pack(x, s, 3)
    with pytest.raises(ValueError):
        qp_kernel.quantize_pack_pallas(x, s, 5, interpret=True)


@given(bits=st.sampled_from([2, 4, 8]), rows=st.integers(1, 21),
       seed=st.integers(0, 500))
@settings(max_examples=15, deadline=None)
def test_quantpack_pallas_odd_shapes_match_ref(bits, rows, seed):
    """Row counts off the 8-row tile grid and odd (non-power-of-two) lengths:
    the Pallas encode→decode roundtrip must match the jnp reference exactly
    (these are the ragged tail shapes the gradient codec produces)."""
    n = (32 // bits) * 13                   # divisible by the packing factor
    x = jax.random.normal(jax.random.key(seed), (rows, n))
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) + 1e-6
    words = qp_kernel.quantize_pack_pallas(x, scale, bits, interpret=True)
    np.testing.assert_array_equal(words, ref.quantize_pack(x, scale, bits))
    back = qp_kernel.unpack_dequant_pallas(words, scale, bits, n,
                                           interpret=True)
    np.testing.assert_allclose(back, ref.unpack_dequant(words, scale, bits, n),
                               atol=1e-6)
    assert float(jnp.max(jnp.abs(back - x) / scale)) <= 1.0 / 2 ** bits + 1e-6


def test_packed_size():
    """Wire-format audit: 4-bit pack is exactly 8 values per int32 word."""
    x = jnp.ones((2, 64))
    s = jnp.ones((2, 1))
    assert ref.quantize_pack(x, s, 4).shape == (2, 8)
    assert ref.quantize_pack(x, s, 1).shape == (2, 2)
    assert ref.quantize_pack(x, s, 8).shape == (2, 16)


# ---------------------------------------------------------------------------
# fused encode (sign-flip → FWHT → scale → quantize → pack) vs composed ref
# ---------------------------------------------------------------------------
def _signs(n, seed=7):
    b = jax.random.bernoulli(jax.random.key(seed), 0.5, (n,))
    return jnp.where(b, 1.0, -1.0).astype(jnp.float32)


def _draws(rows, n, bits, seed=11):
    kd, km = jax.random.split(jax.random.key(seed))
    delta = 2.0 / (2 ** bits)
    dither = jax.random.uniform(kd, (rows, n), jnp.float32,
                                -delta / 2, delta / 2)
    mask = (jax.random.uniform(km, (rows, 1)) < 0.6).astype(jnp.float32)
    return dither, mask


@pytest.mark.parametrize("bits", [1, 2, 4, 8])
@pytest.mark.parametrize("rows,n", [(1, 32), (8, 128), (13, 256)])
@pytest.mark.parametrize("mode", ["det", "dither", "mask", "dither_mask"])
def test_fused_encode_payload_bitexact(bits, rows, n, mode):
    """The PAYLOAD contract: fused-kernel (words, scale) == composed ref,
    bit for bit — deterministically and with shared pre-drawn draws."""
    x = jax.random.normal(jax.random.key(bits * 100 + rows), (rows, n))
    signs = _signs(n)
    dither, mask = _draws(rows, n, bits)
    dth = dither if "dither" in mode else None
    msk = mask if "mask" in mode else None
    kw, ks = qe_kernel.encode_pallas(x, signs, bits, dither=dth, mask=msk,
                                     interpret=True)
    rw, rs = ref.encode(x, signs, bits, dither=dth, mask=msk)
    np.testing.assert_array_equal(kw, rw)
    np.testing.assert_array_equal(np.asarray(ks).view(np.int32),
                                  np.asarray(rs).view(np.int32))


@pytest.mark.parametrize("bits", [1, 4])
@pytest.mark.parametrize("rows,n", [(5, 128), (13, 64)])
@pytest.mark.parametrize("mode", ["det", "dither_mask", "rescale"])
def test_fused_encode_ef_residual(bits, rows, n, mode):
    """The EF contract: payload stays bitwise; the in-tile residual matches
    the composed eager reference u − D(E(u)) to a few f32 ulp of the
    embedding scale (fma contraction in the in-tile decode is allowed)."""
    x = jax.random.normal(jax.random.key(bits * 10 + rows), (rows, n))
    signs = _signs(n)
    dither, mask = _draws(rows, n, bits)
    dth = dither if mode != "det" else None
    msk = mask if mode != "det" else None
    rescale = 0.6 if mode == "rescale" else None
    kw, ks, kr = qe_kernel.encode_ef_pallas(
        x, signs, bits, dither=dth, mask=msk, rescale=rescale,
        interpret=True)
    rw, rs, rr = ref.encode_ef(x, signs, bits, dither=dth, mask=msk,
                               rescale=rescale)
    np.testing.assert_array_equal(kw, rw)
    np.testing.assert_array_equal(np.asarray(ks).view(np.int32),
                                  np.asarray(rs).view(np.int32))
    np.testing.assert_allclose(kr, rr, atol=4e-6, rtol=0)
    # composed end-to-end: residual really is u − decode(encode(u))
    y_hat = ref.decode_embedded(rw, rs, signs, bits, n, mask=msk,
                                rescale=rescale)
    np.testing.assert_allclose(kr, x - y_hat, atol=4e-6, rtol=0)


def test_fused_encode_ef_residual_dtype_rounding():
    """residual_dtype=bf16 rounds ŷ where a bf16 tree decode would; the
    residual then matches the reference to bf16 resolution."""
    rows, n, bits = 6, 128, 4
    x = jax.random.normal(jax.random.key(3), (rows, n))
    signs = _signs(n)
    _, _, kr = qe_kernel.encode_ef_pallas(
        x, signs, bits, residual_dtype=jnp.bfloat16, interpret=True)
    _, _, rr = ref.encode_ef(x, signs, bits, residual_dtype=jnp.bfloat16)
    np.testing.assert_allclose(kr, rr, atol=4e-3, rtol=0)


def test_fused_encode_interpret_inferred_on_cpu():
    """interpret=None must infer interpreter mode off-TPU (satellite #2):
    the call below would crash trying to compile a TPU kernel otherwise."""
    x = jax.random.normal(jax.random.key(4), (4, 64))
    kw, ks = qe_kernel.encode_pallas(x, _signs(64), 2)
    rw, rs = ref.encode(x, _signs(64), 2)
    np.testing.assert_array_equal(kw, rw)
    got = fwht_kernel.fwht_pallas(x)
    np.testing.assert_allclose(got, ref.fwht(x), rtol=1e-5, atol=1e-5)


def test_forced_pallas_refuses_silent_fallback(monkeypatch):
    """REPRO_FORCE_PALLAS=1 + N over the VMEM budget must raise, not
    silently hand back the jnp reference (satellite #1)."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    big = fwht_kernel.MAX_VMEM_N * 2
    x = jnp.zeros((2, big))
    with pytest.raises(ValueError, match="VMEM"):
        kernel_ops.fwht(x)
    with pytest.raises(ValueError, match="VMEM"):
        kernel_ops.encode(x, jnp.ones((big,)), 2)
    with pytest.raises(ValueError, match="VMEM"):
        kernel_ops.encode_ef(x, jnp.ones((big,)), 2)
    # under the budget the forced path still dispatches to the kernel
    small = jax.random.normal(jax.random.key(5), (3, 64))
    kw, _ = kernel_ops.encode(small, _signs(64), 4)
    rw, _ = ref.encode(small, _signs(64), 4)
    np.testing.assert_array_equal(kw, rw)


def test_unforced_large_n_falls_back_to_ref(monkeypatch):
    """Without the force flag, over-budget N quietly uses the reference."""
    monkeypatch.delenv("REPRO_FORCE_PALLAS", raising=False)
    x = jax.random.normal(jax.random.key(6), (1, fwht_kernel.MAX_VMEM_N * 2))
    np.testing.assert_allclose(kernel_ops.fwht(x), ref.fwht(x),
                               rtol=1e-5, atol=1e-5)
