"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import fwht as fwht_kernel
from repro.kernels import quantpack as qp_kernel
from repro.kernels import ref


# ---------------------------------------------------------------------------
# FWHT
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [2, 8, 64, 128, 1024])
@pytest.mark.parametrize("lead", [(), (1,), (5,), (3, 4)])
def test_fwht_pallas_matches_ref(n, lead):
    x = jax.random.normal(jax.random.key(0), lead + (n,))
    got = fwht_kernel.fwht_pallas(x, interpret=True)
    np.testing.assert_allclose(got, ref.fwht(x), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_dtypes(dtype):
    x = jax.random.normal(jax.random.key(1), (4, 256)).astype(dtype)
    got = fwht_kernel.fwht_pallas(x, interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref.fwht(x), np.float32),
                               rtol=tol, atol=tol)


def test_fwht_orthonormal_involution():
    """H·H = I (normalized Hadamard is its own inverse)."""
    x = jax.random.normal(jax.random.key(2), (3, 512))
    np.testing.assert_allclose(ref.fwht(ref.fwht(x)), x, atol=1e-4)
    np.testing.assert_allclose(
        fwht_kernel.fwht_pallas(fwht_kernel.fwht_pallas(x, interpret=True),
                                interpret=True), x, atol=1e-4)


def test_fwht_matches_hadamard_matrix():
    n = 16
    h = np.array([[1.0]])
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    h /= np.sqrt(n)
    x = np.random.RandomState(0).randn(4, n).astype(np.float32)
    np.testing.assert_allclose(ref.fwht(jnp.asarray(x)), x @ h, atol=1e-5)


def test_fwht_rejects_non_pow2():
    with pytest.raises(ValueError):
        fwht_kernel.fwht_pallas(jnp.zeros((2, 48)), interpret=True)


# ---------------------------------------------------------------------------
# quantize-pack / unpack-dequant
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits", [1, 2, 4, 8])
@pytest.mark.parametrize("rows,n", [(1, 32), (7, 128), (16, 1024)])
def test_quantpack_pallas_matches_ref(bits, rows, n):
    x = jax.random.normal(jax.random.key(3), (rows, n))
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    got = qp_kernel.quantize_pack_pallas(x, scale, bits, interpret=True)
    want = ref.quantize_pack(x, scale, bits)
    np.testing.assert_array_equal(got, want)
    back = qp_kernel.unpack_dequant_pallas(got, scale, bits, n,
                                           interpret=True)
    np.testing.assert_allclose(back, ref.unpack_dequant(want, scale, bits, n),
                               atol=1e-6)


@given(bits=st.sampled_from([1, 2, 4, 8]), seed=st.integers(0, 500))
@settings(max_examples=20, deadline=None)
def test_quantpack_roundtrip_error_property(bits, seed):
    """|x − unpack(pack(x))| ≤ scale/2^bits per coordinate."""
    n = 128
    x = jax.random.normal(jax.random.key(seed), (4, n))
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    words = ref.quantize_pack(x, scale, bits)
    back = ref.unpack_dequant(words, scale, bits, n)
    max_err = float(jnp.max(jnp.abs(back - x) / scale))
    assert max_err <= 1.0 / (2 ** bits) + 1e-6


def test_quantpack_rejects_bad_bits():
    x = jnp.zeros((2, 32))
    s = jnp.ones((2, 1))
    with pytest.raises(ValueError):
        ref.quantize_pack(x, s, 3)
    with pytest.raises(ValueError):
        qp_kernel.quantize_pack_pallas(x, s, 5, interpret=True)


@given(bits=st.sampled_from([2, 4, 8]), rows=st.integers(1, 21),
       seed=st.integers(0, 500))
@settings(max_examples=15, deadline=None)
def test_quantpack_pallas_odd_shapes_match_ref(bits, rows, seed):
    """Row counts off the 8-row tile grid and odd (non-power-of-two) lengths:
    the Pallas encode→decode roundtrip must match the jnp reference exactly
    (these are the ragged tail shapes the gradient codec produces)."""
    n = (32 // bits) * 13                   # divisible by the packing factor
    x = jax.random.normal(jax.random.key(seed), (rows, n))
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) + 1e-6
    words = qp_kernel.quantize_pack_pallas(x, scale, bits, interpret=True)
    np.testing.assert_array_equal(words, ref.quantize_pack(x, scale, bits))
    back = qp_kernel.unpack_dequant_pallas(words, scale, bits, n,
                                           interpret=True)
    np.testing.assert_allclose(back, ref.unpack_dequant(words, scale, bits, n),
                               atol=1e-6)
    assert float(jnp.max(jnp.abs(back - x) / scale)) <= 1.0 / 2 ** bits + 1e-6


def test_packed_size():
    """Wire-format audit: 4-bit pack is exactly 8 values per int32 word."""
    x = jnp.ones((2, 64))
    s = jnp.ones((2, 1))
    assert ref.quantize_pack(x, s, 4).shape == (2, 8)
    assert ref.quantize_pack(x, s, 1).shape == (2, 2)
    assert ref.quantize_pack(x, s, 8).shape == (2, 16)
