"""Decode/serving path: stepwise decode must match the parallel forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import decode as decode_lib
from repro.models import model as model_lib


def _stepwise_logits(cfg, params, tokens, max_seq):
    """Feed tokens one at a time through decode_step; stack the logits."""
    b, s = tokens.shape
    state = decode_lib.init_decode_state(cfg, b, max_seq)
    outs = []
    step = jax.jit(lambda p, st, t: decode_lib.decode_step(cfg, p, st, t))
    for i in range(s):
        logits, state = step(params, state, tokens[:, i][:, None])
        outs.append(logits)
    return jnp.stack(outs, axis=1), state     # (B, S, V)


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "yi-6b", "hymba-1.5b",
                                  "xlstm-350m"])
def test_decode_matches_forward(arch):
    """Teacher-forced stepwise decode logits == training forward logits.

    This is the strongest single correctness check of the serving path: it
    exercises RoPE offsets, cache insert/validity masks, and every recurrent
    state update against the parallel (scan) implementation.
    """
    cfg = configs.get_reduced(arch)
    params = model_lib.init_params(jax.random.key(0), cfg)
    b, s = 2, 24
    tokens = jax.random.randint(jax.random.key(1), (b, s + 1), 0,
                                cfg.vocab_size, jnp.int32)
    fwd = model_lib.logits_fn(cfg, params, {"tokens": tokens})  # (B, S, V)
    got, _ = _stepwise_logits(cfg, params, tokens[:, :-1], max_seq=s + 4)
    np.testing.assert_allclose(got, fwd, rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_moe_high_capacity():
    """MoE decode parity needs capacity high enough that nothing drops."""
    cfg = dataclasses.replace(configs.get_reduced("mixtral-8x22b"),
                              capacity_factor=8.0)
    params = model_lib.init_params(jax.random.key(0), cfg)
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.key(1), (b, s + 1), 0,
                                cfg.vocab_size, jnp.int32)
    fwd = model_lib.logits_fn(cfg, params, {"tokens": tokens})
    got, _ = _stepwise_logits(cfg, params, tokens[:, :-1], max_seq=s + 4)
    np.testing.assert_allclose(got, fwd, rtol=2e-3, atol=2e-3)


def test_ring_cache_equals_full_recompute():
    """Sliding-window ring cache: decode past the window must equal a fresh
    forward over the (windowed) suffix."""
    cfg = configs.get_reduced("phi3-mini-3.8b")
    cfg = dataclasses.replace(cfg, attention_kind="sliding", window=8)
    params = model_lib.init_params(jax.random.key(0), cfg)
    b, s = 1, 20                                   # > 2× window
    tokens = jax.random.randint(jax.random.key(1), (b, s + 1), 0,
                                cfg.vocab_size, jnp.int32)
    fwd = model_lib.logits_fn(cfg, params, {"tokens": tokens})
    got, state = _stepwise_logits(cfg, params, tokens[:, :-1],
                                  max_seq=s + 4)
    assert state.caches["k"].shape[2] == 8         # ring is window-sized
    np.testing.assert_allclose(got[:, -1], fwd[:, -1], rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["yi-6b", "xlstm-350m"])
def test_prefill_then_decode(arch):
    """prefill(prompt) + decode steps ≡ stepwise decode from scratch."""
    cfg = configs.get_reduced(arch)
    params = model_lib.init_params(jax.random.key(0), cfg)
    b, s = 2, 12
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0,
                                cfg.vocab_size, jnp.int32)
    logits_p, state_p = decode_lib.prefill(cfg, params, tokens, max_seq=s + 8)
    step_logits, state_s = _stepwise_logits(cfg, params, tokens,
                                            max_seq=s + 8)
    np.testing.assert_allclose(logits_p, step_logits[:, -1],
                               rtol=2e-3, atol=2e-3)
    assert int(state_p.pos[0]) == int(state_s.pos[0]) == s
    # continue one decode step from both states: identical next logits
    nxt = jnp.zeros((b, 1), jnp.int32)
    l1, _ = decode_lib.decode_step(cfg, params, state_p, nxt)
    l2, _ = decode_lib.decode_step(cfg, params, state_s, nxt)
    np.testing.assert_allclose(l1, l2, rtol=2e-3, atol=2e-3)


def test_encoder_has_no_decode():
    cfg = configs.get_reduced("hubert-xlarge")
    with pytest.raises(ValueError):
        decode_lib.init_decode_state(cfg, 2, 16)


def test_greedy_token_shape():
    logits = jnp.zeros((3, 100)).at[:, 7].set(1.0)
    tok = decode_lib.greedy_token(logits)
    assert tok.shape == (3, 1)
    assert tok.tolist() == [[7], [7], [7]]
