"""Benchmark history + the regression sentinel.

The acceptance case: a synthetic ~2x slowdown against a healthy baseline
must produce a finding, and `benchmarks.run --check-regressions` must turn
it into exit code 2 (and back to 0 under --regress-report-only).
"""
import copy
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import run as bench_run               # noqa: E402
from repro.obs import history, regress                # noqa: E402


def _payload(seconds=1.0, *, tiny=True, tput=None, repeat=None,
             ok=True, directions=None):
    rec = {"name": "fed", "ok": ok, "seconds": seconds,
           "headline": {"rate_bits": 4.0, "note": "text", "flag": True},
           "repeat_seconds": repeat, "directions": directions}
    if tput is not None:
        rec["headline"]["tput"] = tput
    return {"schema_version": 3, "tiny": tiny,
            "env": {"python": "3.11.8", "jax": "0.4.37", "jaxlib": "0.4.36",
                    "backend": "cpu", "device_kind": "cpu",
                    "device_count": 8, "repro_force_pallas": None,
                    "git_sha": "abc123", "git_dirty": False},
            "failed": [] if ok else ["fed"], "benchmarks": [rec]}


def _history_rows(values, **kw):
    rows = []
    for v in values:
        rows.extend(history.records_from_payload(_payload(v, **kw)))
    return rows


# ---------------------------------------------------------------------------
# records_from_payload
# ---------------------------------------------------------------------------
def test_records_flatten_seconds_and_numeric_headlines():
    recs = history.records_from_payload(_payload(1.5, repeat=[1.4, 1.5, 1.6]))
    by_metric = {r["metric"]: r for r in recs}
    # numeric headline fields flatten; strings and bools don't
    assert set(by_metric) == {"seconds", "headline.rate_bits"}
    sec = by_metric["seconds"]
    assert sec["value"] == 1.5 and sec["direction"] == "lower"
    assert sec["repeat_values"] == [1.4, 1.5, 1.6]
    assert sec["git_sha"] == "abc123" and sec["git_dirty"] is False
    assert sec["blessed"] is False and sec["payload_schema_version"] == 3
    # headline metrics record but stay ungated without a hint
    assert by_metric["headline.rate_bits"]["direction"] is None
    assert by_metric["headline.rate_bits"]["repeat_values"] is None


def test_directions_hint_gates_headline_metric():
    recs = history.records_from_payload(
        _payload(1.0, tput=120.0, directions={"tput": "higher"}))
    tput = next(r for r in recs if r["metric"] == "headline.tput")
    assert tput["direction"] == "higher"


def test_v2_payload_still_flattens():
    p = _payload(2.0)
    p["schema_version"] = 2
    for k in ("git_sha", "git_dirty"):
        del p["env"][k]
    recs = history.records_from_payload(p)
    sec = next(r for r in recs if r["metric"] == "seconds")
    assert sec["value"] == 2.0 and sec["git_sha"] is None
    assert sec["payload_schema_version"] == 2


# ---------------------------------------------------------------------------
# fingerprint
# ---------------------------------------------------------------------------
def test_fingerprint_sensitivity():
    env = _payload()["env"]
    base = history.env_fingerprint(env, tiny=True)
    assert history.env_fingerprint(env, tiny=True) == base
    assert history.env_fingerprint(env, tiny=False) != base
    bumped = dict(env, jax="0.5.0")
    assert history.env_fingerprint(bumped, tiny=True) != base
    # non-comparability keys (hostname-ish noise) don't split the baseline
    noisy = dict(env, platform="Linux-whatever", hostname="runner-42")
    assert history.env_fingerprint(noisy, tiny=True) == base


# ---------------------------------------------------------------------------
# append / load
# ---------------------------------------------------------------------------
def test_append_load_roundtrip(tmp_path):
    path = str(tmp_path / "hist.jsonl")
    assert history.load(path) == []                  # missing file: empty
    rows = _history_rows([1.0, 1.1])
    assert history.append(path, rows) == len(rows)
    assert history.append(path, []) == 0
    loaded = history.load(path)
    assert [r["value"] for r in loaded if r["metric"] == "seconds"] == \
        [1.0, 1.1]
    assert loaded.truncated is False


def test_load_tolerates_truncated_final_line(tmp_path):
    path = str(tmp_path / "hist.jsonl")
    history.append(path, _history_rows([1.0]))
    with open(path, "a") as f:
        f.write('{"schema_version": 1, "benchmark": "fed", "metr')
    loaded = history.load(path)
    assert loaded.truncated is True
    assert [r["value"] for r in loaded if r["metric"] == "seconds"] == [1.0]


def test_load_skips_future_schema_and_junk(tmp_path):
    path = str(tmp_path / "hist.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps({"schema_version": history.HISTORY_SCHEMA_VERSION
                            + 1, "benchmark": "fed", "metric": "seconds",
                            "value": 9.9}) + "\n")
        f.write(json.dumps({"benchmark": "fed"}) + "\n")   # missing keys
        f.write(json.dumps(["not", "a", "dict"]) + "\n")
        f.write(json.dumps({"schema_version": 1, "benchmark": "fed",
                            "metric": "seconds", "value": 1.0}) + "\n")
    loaded = history.load(path)
    assert [r["value"] for r in loaded] == [1.0]


# ---------------------------------------------------------------------------
# the sentinel
# ---------------------------------------------------------------------------
def test_sentinel_detects_2x_slowdown():
    hist = _history_rows([1.0, 0.98, 1.02, 1.01, 0.99])
    result = regress.check(hist, history.records_from_payload(_payload(2.0)))
    assert result["checked"] == 1
    assert len(result["findings"]) == 1
    f = result["findings"][0]
    assert f["benchmark"] == "fed" and f["metric"] == "seconds"
    assert f["ratio"] == pytest.approx(2.0, rel=0.05)
    assert "fed/seconds" in regress.render(result)
    assert regress.worst(result) is f


def test_sentinel_quiet_on_small_drift():
    hist = _history_rows([1.0, 0.98, 1.02, 1.01, 0.99])
    result = regress.check(hist, history.records_from_payload(_payload(1.05)))
    assert result["checked"] == 1 and result["findings"] == []
    assert regress.worst(result) is None


def test_sentinel_direction_higher():
    hints = {"directions": {"tput": "higher"}}
    hist = _history_rows([1.0] * 4, tput=100.0, **hints)
    drop = history.records_from_payload(_payload(1.0, tput=40.0, **hints))
    gain = history.records_from_payload(_payload(1.0, tput=200.0, **hints))
    found = regress.check(hist, drop)["findings"]
    assert [f["metric"] for f in found] == ["headline.tput"]
    assert regress.check(hist, gain)["findings"] == []


def test_sentinel_noise_floor_suppresses():
    hist = _history_rows([1.0, 1.0, 1.0, 1.0])
    noisy = history.records_from_payload(
        _payload(1.5, repeat=[0.7, 1.5, 2.2]))     # sigma ~0.75 → huge floor
    result = regress.check(hist, noisy)
    assert result["findings"] == []
    calm = history.records_from_payload(
        _payload(1.5, repeat=[1.49, 1.5, 1.51]))
    assert len(regress.check(hist, calm)["findings"]) == 1


def test_bless_restarts_baseline_window():
    fast = _history_rows([1.0] * 5)
    slow = _history_rows([2.0] * 3)
    current = history.records_from_payload(_payload(2.0))
    # unblessed, the old fast rows poison the baseline: 2.0 alarms
    assert regress.check(fast + slow, current)["findings"]
    # blessing the first slow run restarts the window there: 2.0 is normal
    blessed = copy.deepcopy(slow)
    for r in blessed[:2]:                 # first run's records (2 metrics)
        r["blessed"] = True
    assert regress.check(fast + blessed, current)["findings"] == []


def test_sentinel_skips_thin_history_failed_and_ungated():
    thin = _history_rows([1.0, 1.0])                 # < min_baseline
    result = regress.check(thin, history.records_from_payload(_payload(9.0)))
    assert result["findings"] == [] and result["checked"] == 0
    why = dict(result["skipped"])
    assert "insufficient history" in why["fed/seconds"]
    assert "no direction" in why["fed/headline.rate_bits"]
    # failed runs are never gated (CI already fails them)
    hist = _history_rows([1.0] * 5)
    bad = history.records_from_payload(_payload(9.0, ok=False))
    assert regress.check(hist, bad)["findings"] == []


def test_trimmed_mean_drops_outliers():
    assert regress.trimmed_mean([1.0, 1.0, 1.0, 1.0, 50.0]) == 1.0
    assert regress.trimmed_mean([3.0]) == 3.0
    with pytest.raises(ValueError):
        regress.trimmed_mean([])


def test_failed_history_rows_excluded_from_baseline():
    ok_rows = _history_rows([1.0] * 3)
    bad_rows = _history_rows([50.0] * 3, ok=False)
    result = regress.check(ok_rows + bad_rows,
                           history.records_from_payload(_payload(1.0)))
    assert result["checked"] == 1 and result["findings"] == []


# ---------------------------------------------------------------------------
# CLI integration: benchmarks.run --from-json --check-regressions
# ---------------------------------------------------------------------------
def _write_cli_fixture(tmp_path, seconds):
    hist_path = str(tmp_path / "BENCH_history.jsonl")
    history.append(hist_path, _history_rows([1.0, 0.99, 1.01, 1.0]))
    payload_path = str(tmp_path / "payload.json")
    with open(payload_path, "w") as f:
        json.dump(_payload(seconds), f)
    return payload_path, hist_path


def test_cli_regression_exits_2(tmp_path, capsys):
    payload_path, hist_path = _write_cli_fixture(tmp_path, 2.0)
    with pytest.raises(SystemExit) as exc:
        bench_run.main(["--from-json", payload_path, "--check-regressions",
                        "--history", hist_path])
    assert exc.value.code == 2
    assert "1 regression(s)" in capsys.readouterr().out


def test_cli_report_only_and_append(tmp_path, capsys):
    payload_path, hist_path = _write_cli_fixture(tmp_path, 2.0)
    before = len(history.load(hist_path))
    bench_run.main(["--from-json", payload_path, "--check-regressions",
                    "--regress-report-only", "--append-history",
                    "--history", hist_path])          # no SystemExit
    out = capsys.readouterr().out
    assert "1 regression(s)" in out and "appended" in out
    after = history.load(hist_path)
    assert len(after) == before + 2                  # seconds + rate_bits


def test_cli_clean_run_checks_quietly(tmp_path, capsys):
    payload_path, hist_path = _write_cli_fixture(tmp_path, 1.0)
    bench_run.main(["--from-json", payload_path, "--check-regressions",
                    "--history", hist_path])
    assert "0 regression(s)" in capsys.readouterr().out


def test_cli_bless_appends_blessed_records(tmp_path):
    payload_path, hist_path = _write_cli_fixture(tmp_path, 2.0)
    bench_run.main(["--from-json", payload_path, "--bless",
                    "--history", hist_path])
    rows = history.load(hist_path)
    assert [r["blessed"] for r in rows[-2:]] == [True, True]
    # next identical run gates against the blessed baseline... which is
    # too thin (1 run) to alarm — bless really does restart the window
    result = regress.check(rows, history.records_from_payload(_payload(2.0)))
    assert result["findings"] == []
