"""Frame constructions: Parseval property, adjoint consistency (paper §2)."""
import jax
import numpy as np
import pytest

from repro.core import frames as F


@pytest.mark.parametrize("kind,n,N", [
    ("haar", 16, 16), ("haar", 16, 32), ("haar", 24, 37),
    ("hadamard", 16, 16), ("hadamard", 16, 32), ("hadamard", 24, 32),
])
def test_parseval(kind, n, N):
    """S Sᵀ = I_n for Haar and PDH frames (paper: Parseval ⇒ K_l = 1)."""
    f = F.make_frame(kind, jax.random.key(0), n, N)
    S = F.dense_matrix(f)
    np.testing.assert_allclose(S @ S.T, np.eye(n), atol=1e-5)


def test_subgaussian_approx_parseval():
    f = F.subgaussian_frame(jax.random.key(1), 64, 256)
    S = F.dense_matrix(f)
    gram = S @ S.T
    # approximate frame bounds A=1−ξ, B=1+ξ (paper App. J.1)
    eigs = np.linalg.eigvalsh(gram)
    # Marchenko–Pastur: eigenvalues of S Sᵀ concentrate in
    # [(1−√(n/N))², (1+√(n/N))²] = [0.25, 2.25] for λ = 4
    assert 0.15 < eigs.min() < eigs.max() < 2.4


@pytest.mark.parametrize("kind", ["haar", "hadamard"])
def test_apply_matches_dense(kind):
    f = F.make_frame(kind, jax.random.key(2), 24, 32)
    S = F.dense_matrix(f)
    y = jax.random.normal(jax.random.key(3), (5, 24))
    x = jax.random.normal(jax.random.key(4), (5, 32))
    np.testing.assert_allclose(f.apply(x), x @ np.asarray(S).T, atol=1e-5)
    np.testing.assert_allclose(f.apply_t(y), y @ np.asarray(S), atol=1e-5)


def test_hadamard_requires_pow2():
    with pytest.raises(ValueError):
        F.hadamard_frame(jax.random.key(0), 10, 24)


def test_next_pow2():
    assert [F.next_pow2(k) for k in (1, 2, 3, 9, 1024, 1025)] == \
        [1, 2, 4, 16, 1024, 2048]
