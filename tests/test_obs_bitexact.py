"""The obs hard contract: enabling observability changes NOTHING.

Params, client EF states, the wire ledger/history and the compiled-program
cache sizes must be identical between an instrumented and an
uninstrumented run — obs is observe-only, host-side, outside jit. Checked
on both Federation backends (vmap cohorts and mesh lane placement) and on
the dist consensus train step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import batch_for_shape
from repro.dist import step as step_lib
from repro.dist.gradcomp import GradCompConfig
from repro.fed import (ClientConfig, FedConfig, Federation, ServerConfig)
from repro import codecs as registry
from repro.models import model as model_lib
from repro.obs import core as obs
from repro.obs import recompile
from repro.obs.sinks import MemorySink
from repro.optimizer import sgd
from repro.serve import Engine, Request, ServeConfig


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def _problem(m=4, dim=24, n=16, seed=3):
    ka, kx = jax.random.split(jax.random.key(seed))
    a = jax.random.normal(ka, (m, n, dim)) / jnp.sqrt(n)
    x_true = jax.random.normal(kx, (dim,))
    shards = [{"a": a[i], "b": a[i] @ x_true} for i in range(m)]

    def loss_fn(p, batch):
        r = batch["a"] @ p["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r)

    return shards, loss_fn, {"x": jnp.zeros(dim)}


@pytest.mark.parametrize("backend", ["vmap", "mesh"])
def test_federation_bit_exact_and_no_extra_recompiles(backend):
    shards, loss_fn, params = _problem()

    def build():
        return Federation(loss_fn, params, shards,
                          registry.make("ndsc", 4.0, chunk=32),
                          ClientConfig(local_steps=2, lr=0.2),
                          ServerConfig(aggregator="fedavg"), seed=5,
                          backend=backend)

    cfg = FedConfig(num_rounds=4, participation=0.9, dropout=0.2, seed=11)

    # warm the process-wide lru-cached programs (the server aggregate folds,
    # keyed on participant-lane count): they compile once per process, so
    # whichever arm ran first would otherwise be charged for them — an order
    # artifact, not an obs effect. Same cfg ⇒ same participant draws ⇒ same
    # lane counts as both measured arms.
    build().run(cfg)

    base = recompile.counts()
    fed_off = build()
    hist_off = fed_off.run(cfg)
    compiles_off = recompile.delta(base, recompile.counts())

    base = recompile.counts()
    o = obs.enable()
    fed_on = build()
    hist_on = fed_on.run(cfg)
    obs.disable()
    compiles_on = recompile.delta(base, recompile.counts())

    assert _tree_equal(fed_off.server.params, fed_on.server.params)
    assert _tree_equal([s.ef for s in fed_off.states],
                       [s.ef for s in fed_on.states])
    assert hist_off == hist_on                    # ledger + history exact
    # same programs, same number of compiled specializations: obs added none
    assert compiles_on == compiles_off
    # and the session actually observed the run
    s = o.summary()
    assert s["counters"]["fed.rounds"]["total"] == 4.0
    assert s["counters"]["fed.wire_bytes"]["total"] == sum(
        hist_off["wire_bytes"])
    assert "fed.round" in s["spans"]

    # PR-10 contract: the cost model captured the round program, and
    # reading the snapshot touches no jit cache (counts pinned around it)
    base = recompile.counts()
    snap = o.costs()
    assert recompile.counts() == base
    prog_name = "fed.round.cohort" if backend == "vmap" else "fed.round.mesh"
    prog = snap["programs"][prog_name]
    assert prog["calls"] > 0 and prog["wire_bytes"] > 0
    for spec in prog["specializations"]:      # cost analysis may degrade
        assert spec["available"] or spec["reason"]   # ... but never crash
    attrib = s["spans"]["fed.clients.compute"]["attrib"]
    assert attrib["calls_observed"] >= prog["calls"]
    assert attrib["wire_min_bytes"] >= prog["wire_bytes"]


def test_federation_run_obs_argument_scopes_session():
    """`Federation.run(obs=...)` instruments exactly that run, without a
    globally-enabled session."""
    shards, loss_fn, params = _problem()
    fed = Federation(loss_fn, params, shards,
                     registry.make("ndsc", 4.0, chunk=32),
                     ClientConfig(local_steps=1, lr=0.2),
                     ServerConfig(), seed=5)
    session = obs.Obs(sinks=(MemorySink(),))
    fed.run(FedConfig(num_rounds=2), obs=session)
    assert not obs.enabled()                      # run() released it
    session.close()
    s = session.summary()
    assert s["counters"]["fed.rounds"]["total"] == 2.0
    metas = [e for e in session.memory_events()
             if e["type"] == "meta" and e["name"] == "fed.run.summary"]
    assert len(metas) == 1 and metas[0]["data"]["rounds"] == 2


def test_serve_engine_bit_exact_and_no_extra_recompiles():
    """The serve engine under obs: token streams, admissions and the final
    decode state are bitwise identical with observability on or off, and
    obs adds zero compiled specializations (the engine's jitted programs
    are shared process-wide per (config, max_seq))."""
    cfg = configs.get_reduced("yi-6b")
    params = model_lib.init_params(jax.random.key(0), cfg)
    prefix = np.arange(9, dtype=np.int32) + 2
    prompts = [jnp.arange(3 + i, dtype=jnp.int32) for i in range(4)]

    def run():
        eng = Engine(cfg, params, ServeConfig(slots=2, max_seq=48))
        eng.register_prefix("sys", prefix, prefill=True)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=4,
                               prefix_id="sys" if i % 2 else None))
        finished = eng.run_to_completion()
        return ([(r.rid, r.admission, r.tokens_out) for r in finished],
                eng.state)

    run()    # warm the process-shared jitted programs + specializations

    base = recompile.counts()
    out_off, state_off = run()
    compiles_off = recompile.delta(base, recompile.counts())

    base = recompile.counts()
    o = obs.enable()
    out_on, state_on = run()
    obs.disable()
    compiles_on = recompile.delta(base, recompile.counts())

    assert out_off == out_on                      # streams + admissions
    assert _tree_equal((state_off.caches, state_off.pos),
                       (state_on.caches, state_on.pos))
    assert compiles_on == compiles_off
    s = o.summary()
    assert s["counters"]["serve.submitted"]["count"] == 4
    assert s["counters"]["serve.requests"]["count"] == 4
    assert s["counters"]["serve.prefix.hit"]["count"] == 2
    assert s["counters"]["serve.prefill_bytes_saved"]["total"] > 0
    assert s["hists"]["serve.ttft_s"]["count"] == 4
    assert "serve.decode_step" in s["spans"]
    assert "serve.admit_prefix" in s["spans"]

    base = recompile.counts()
    snap = o.costs()
    assert recompile.counts() == base
    decode = snap["programs"]["serve.decode_step"]
    assert decode["calls"] > 0
    for spec in decode["specializations"]:
        assert spec["available"] or spec["reason"]
    assert {"serve.prefill", "serve.admit_prefix",
            "serve.admit_cold"} <= set(snap["programs"])


def test_dist_step_bit_exact_and_no_extra_recompiles(mesh):
    cfg = configs.get_reduced("llama3.2-3b")
    gc = GradCompConfig(bits=4, chunk=256, strategy="allgather_packed")
    opt = sgd(1e-2, momentum=0.9)
    batch = batch_for_shape(cfg, 2, 16)

    def run_steps():
        tstep = step_lib.make_train_step(cfg, opt, gc, mesh)
        params, opt_state, ef = step_lib.init_train_state(cfg, opt, gc, mesh)
        for _ in range(2):
            params, opt_state, ef, metrics = tstep(params, opt_state, ef,
                                                   batch)
        # the caller holds tstep so recompile.counts() can still read its
        # cache size after this returns
        return params, ef, metrics, tstep

    base = recompile.counts()
    p_off, ef_off, m_off, step_off = run_steps()
    compiles_off = recompile.delta(base, recompile.counts())

    base = recompile.counts()
    o = obs.enable()
    p_on, ef_on, m_on, step_on = run_steps()
    obs.disable()
    compiles_on = recompile.delta(base, recompile.counts())

    assert _tree_equal(p_off, p_on)
    assert _tree_equal(ef_off, ef_on)
    assert float(m_off["loss"]) == float(m_on["loss"])
    assert compiles_on == compiles_off
    s = o.summary()
    assert s["counters"]["dist.steps"]["total"] == 2.0
    assert s["counters"]["dist.payload_bytes"]["total"] > 0
    assert "dist.step" in s["spans"]

    base = recompile.counts()
    snap = o.costs()
    assert recompile.counts() == base
    prog = snap["programs"]["dist.step"]
    assert prog["calls"] == 2 and prog["wire_bytes"] > 0
    for spec in prog["specializations"]:
        assert spec["available"] or spec["reason"]
    attrib = s["spans"]["dist.step"]["attrib"]
    assert attrib["calls_observed"] == 2
