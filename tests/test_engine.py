"""Serve engine v2: correctness, the prefix contract, exhaustion, the
deprecated v1 alias, and the load generator."""
import dataclasses
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import decode as decode_lib
from repro.models import model as model_lib
from repro.serve import (BatchScheduler, Engine, EngineExhausted, LoadConfig,
                         Request, ServeConfig, generate,
                         verify_prefix_contract)


def _model(arch="yi-6b", bits=0):
    cfg = configs.get_reduced(arch)
    if bits:
        cfg = dataclasses.replace(cfg, kv_quant_bits=bits)
    return cfg, model_lib.init_params(jax.random.key(0), cfg)


def _isolated_greedy(cfg, params, prompt, n_new, max_seq):
    logits, state = decode_lib.prefill(cfg, params, prompt[None, :], max_seq)
    toks = [int(jnp.argmax(logits[0]))]
    cur = jnp.array([[toks[-1]]], jnp.int32)
    for _ in range(n_new - 1):
        logits, state = decode_lib.decode_step(cfg, params, state, cur)
        toks.append(int(jnp.argmax(logits[0])))
        cur = jnp.array([[toks[-1]]], jnp.int32)
    return toks


@pytest.mark.parametrize("arch", ["yi-6b", "xlstm-350m"])
def test_engine_matches_isolated_generation(arch):
    """Cold requests through shared slots decode exactly what each gets in
    isolation — continuous batching must not leak state across refills."""
    cfg, params = _model(arch)
    max_seq, n_new = 48, 5
    prompts = [jax.random.randint(jax.random.key(20 + i), (4 + i,), 0,
                                  cfg.vocab_size, jnp.int32)
               for i in range(5)]
    eng = Engine(cfg, params, ServeConfig(slots=2, max_seq=max_seq))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=n_new))
    finished = eng.run_to_completion()
    assert len(finished) == 5
    by_rid = {r.rid: r for r in finished}
    for i, p in enumerate(prompts):
        want = _isolated_greedy(cfg, params, p, n_new, max_seq)
        assert by_rid[i].tokens_out == want, (i, by_rid[i].tokens_out, want)
        assert by_rid[i].admission == "cold"
        assert by_rid[i].ttft_s is not None and by_rid[i].ttft_s >= 0


@pytest.mark.parametrize("bits", [0, 8], ids=["f32", "quant8"])
def test_prefix_hit_bitexact_with_cold(bits):
    """THE contract: a prefix-hit admission's cached K/V (packed words +
    scales when quantized), positions and greedy tokens are bitwise
    identical to a cold admission prefilling the same prefix on the spot."""
    cfg, params = _model(bits=bits)
    rng = np.random.default_rng(3)
    evidence = verify_prefix_contract(
        cfg, params, ServeConfig(slots=2, max_seq=48),
        rng.integers(0, cfg.vocab_size, 12, dtype=np.int32),
        rng.integers(0, cfg.vocab_size, 5, dtype=np.int32))
    assert evidence["tokens"] == 4
    assert evidence["entry_bytes"] > 0


def test_prefix_and_cold_requests_interleave():
    """Prefixed and plain requests share slots; a prefixed request's output
    equals a cold request over prefix+suffix token-for-token is NOT required
    (different admission programs) — but its stream must match another
    engine admitting the same (prefix, suffix) pair the same way."""
    cfg, params = _model()
    scfg = ServeConfig(slots=2, max_seq=48)
    prefix = np.arange(10, dtype=np.int32) + 3
    suffix = np.arange(4, dtype=np.int32)

    def run(interleaved: bool):
        eng = Engine(cfg, params, scfg)
        eng.register_prefix("sys", prefix)
        reqs = [Request(rid=0, prompt=jnp.asarray(suffix),
                        max_new_tokens=4, prefix_id="sys")]
        if interleaved:
            reqs.append(Request(rid=1, prompt=jnp.arange(6, dtype=jnp.int32),
                                max_new_tokens=4))
        for r in reqs:
            eng.submit(r)
        out = eng.run_to_completion()
        return {r.rid: r for r in out}

    solo = run(interleaved=False)
    mixed = run(interleaved=True)
    assert solo[0].tokens_out == mixed[0].tokens_out
    assert solo[0].admission == "prefix_cold"     # first engine, lazy prefill
    assert mixed[1].admission == "cold"


def test_extend_prefix_append_only_equivalence():
    """`extend_prefix(p, more)` then a hit on suffix s ≡ a hit on the
    ORIGINAL prefix with prompt more+s: both decode the same tokens over
    the same positions, so the streams are identical."""
    cfg, params = _model(bits=8)
    scfg = ServeConfig(slots=1, max_seq=48)
    prefix = np.arange(8, dtype=np.int32) + 1
    more = np.asarray([5, 9, 2], np.int32)
    suffix = np.asarray([7, 4], np.int32)

    eng_a = Engine(cfg, params, scfg)
    eng_a.register_prefix("p", prefix, prefill=True)
    eng_a.extend_prefix("p", more)
    assert eng_a.prefix_cache.peek("p").length == len(prefix) + len(more)
    eng_a.submit(Request(rid=0, prompt=jnp.asarray(suffix),
                         max_new_tokens=4, prefix_id="p"))
    (ra,) = eng_a.run_to_completion()
    assert ra.admission == "prefix_hit"

    eng_b = Engine(cfg, params, scfg)
    eng_b.register_prefix("p", prefix, prefill=True)
    eng_b.submit(Request(rid=0,
                         prompt=jnp.asarray(np.concatenate([more, suffix])),
                         max_new_tokens=4, prefix_id="p"))
    (rb,) = eng_b.run_to_completion()
    assert rb.admission == "prefix_hit"
    assert ra.tokens_out == rb.tokens_out

    # growing an unknown or over-long prefix is refused loudly
    with pytest.raises(KeyError):
        eng_a.extend_prefix("nope", more)
    with pytest.raises(ValueError):
        eng_a.extend_prefix("p", np.zeros(scfg.max_seq, np.int32))


def test_run_to_completion_raises_exhausted():
    """The v1 scheduler silently returned partials when max_steps ran out;
    v2 raises `EngineExhausted` carrying the partial results instead."""
    cfg, params = _model()
    eng = Engine(cfg, params, ServeConfig(slots=1, max_seq=64))
    for i in range(2):
        eng.submit(Request(rid=i, prompt=jnp.arange(3, dtype=jnp.int32),
                           max_new_tokens=30))
    with pytest.raises(EngineExhausted) as exc:
        eng.run_to_completion(max_steps=3)
    assert exc.value.steps == 3
    assert exc.value.pending + exc.value.active >= 1
    assert isinstance(exc.value.finished, list)
    # a sane budget drains the same engine fine afterwards
    finished = eng.run_to_completion()
    assert len(finished) == 2 and all(r.done for r in finished)


def test_submit_and_register_validation():
    cfg, params = _model()
    eng = Engine(cfg, params, ServeConfig(slots=1, max_seq=16))
    with pytest.raises(KeyError):
        eng.submit(Request(rid=0, prompt=jnp.arange(2, dtype=jnp.int32),
                           prefix_id="unregistered"))
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=jnp.zeros((0,), jnp.int32)))
    with pytest.raises(ValueError):
        eng.register_prefix("big", np.zeros(16, np.int32))   # >= max_seq
    with pytest.raises(ValueError):
        ServeConfig(slots=0, max_seq=16)


def test_batchscheduler_alias_warns_and_matches_engine():
    """The v1 name still works — same results as Engine — but constructing
    it warns. Importing repro.serve must NOT warn (CI guards this too)."""
    cfg, params = _model()
    prompt = jnp.arange(5, dtype=jnp.int32)

    with pytest.warns(DeprecationWarning, match="BatchScheduler"):
        sched = BatchScheduler(cfg, params, slots=2, max_seq=32)
    assert isinstance(sched, Engine)
    sched.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
    (old,) = sched.run_to_completion()

    eng = Engine(cfg, params, ServeConfig(slots=2, max_seq=32))
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
    (new,) = eng.run_to_completion()
    assert old.tokens_out == new.tokens_out


def test_import_serve_emits_no_deprecation_warning():
    """`import repro.serve` stays warning-free — only *constructing* the
    deprecated alias warns. Run in a subprocess so this module's own
    imports can't mask a regression."""
    proc = subprocess.run(
        [sys.executable, "-W", "error::DeprecationWarning", "-c",
         "import repro.serve"],
        capture_output=True, text=True, timeout=240)
    assert proc.returncode == 0, proc.stderr


# ---------------------------------------------------------------------------
# Load generator
# ---------------------------------------------------------------------------
def test_loadgen_is_deterministic_and_open_loop():
    lc = LoadConfig(n_requests=20, base_rate=50.0, burst_rate=200.0,
                    prompt_len=(3, 6), max_new_tokens=(2, 5),
                    prefix_ratio=0.4, seed=9)
    a1 = generate(lc, vocab_size=100, prefix_id="p",
                  prefix_tokens=np.arange(6, dtype=np.int32))
    a2 = generate(lc, vocab_size=100, prefix_id="p",
                  prefix_tokens=np.arange(6, dtype=np.int32))
    assert len(a1) == 20
    assert [x.time for x in a1] == [x.time for x in a2]
    assert all(b.time >= a.time for a, b in zip(a1, a1[1:]))
    for x1, x2 in zip(a1, a2):
        assert np.array_equal(np.asarray(x1.request.prompt),
                              np.asarray(x2.request.prompt))
    hit = [x for x in a1 if x.request.prefix_id is not None]
    cold = [x for x in a1 if x.request.prefix_id is None]
    assert hit and cold
    # cold prompts carry the prefix inline: same token coverage either way
    assert all(len(x.request.prompt) >= 6 + lc.prompt_len[0] for x in cold)
    assert all(len(x.request.prompt) <= lc.prompt_len[1] for x in hit)
    # burst phases really modulate the rate
    assert lc.rate_at(0.1) == lc.burst_rate
    assert lc.rate_at(lc.burst_len_s + 0.1) == lc.base_rate
