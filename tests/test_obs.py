"""repro.obs: session stack, sinks, trace export, recompile tracking, and
the instrumentation hooks in kernels.ops and serve.scheduler."""
import json
import threading

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.kernels import fwht as fwht_kernel
from repro.kernels import ops
from repro.models import model as model_lib
from repro.obs import core as obs
from repro.obs import recompile, report, trace as trace_lib
from repro.obs.sinks import EventList, MemorySink, load_jsonl
from repro.serve import Engine, Request, ServeConfig


# ---------------------------------------------------------------------------
# disabled path
# ---------------------------------------------------------------------------
def test_disabled_is_noop():
    assert not obs.enabled()
    assert obs.get() is None
    assert obs.span("x") is obs.NOOP_SPAN        # shared singleton, no alloc
    with obs.span("x", k=1):
        pass
    obs.counter("c", 1, k=2)
    obs.gauge("g", 3.0)
    obs.histogram("h", 0.5)


def test_traced_decorator_passthrough_when_disabled():
    calls = []

    @obs.traced("my.fn", tag="t")
    def fn(a, b=2):
        calls.append((a, b))
        return a + b

    assert fn(1, b=3) == 4                        # disabled: plain call
    o = obs.enable()
    assert fn(5) == 7
    obs.disable()
    assert calls == [(1, 3), (5, 2)]
    spans = [e for e in o.memory_events() if e["type"] == "span"]
    assert [s["name"] for s in spans] == ["my.fn"]
    assert spans[0]["attrs"] == {"tag": "t"}


# ---------------------------------------------------------------------------
# sessions, sinks, summary
# ---------------------------------------------------------------------------
def test_enable_disable_stack_and_events():
    o1 = obs.enable()
    assert obs.get() is o1
    o2 = obs.enable()                             # nested: innermost wins
    assert obs.get() is o2
    obs.counter("inner", 1)
    obs.disable()
    assert obs.get() is o1
    obs.counter("outer", 1)
    obs.disable()
    assert not obs.enabled()
    assert [e["name"] for e in o2.memory_events()
            if e["type"] == "counter"] == ["inner"]
    assert [e["name"] for e in o1.memory_events()
            if e["type"] == "counter"] == ["outer"]


def test_use_and_suspended():
    session = obs.Obs(sinks=(MemorySink(),))
    with obs.use(session):
        obs.counter("a", 1)
        with obs.suspended():
            assert not obs.enabled()
            obs.counter("ghost", 1)               # must vanish
        obs.counter("b", 1)
    assert not obs.enabled()
    names = [e["name"] for e in session.memory_events()]
    assert names == ["a", "b"]
    session.close()


def test_span_nesting_depth_and_duration():
    o = obs.enable()
    with obs.span("outer"):
        with obs.span("inner"):
            pass
    obs.disable()
    spans = {e["name"]: e for e in o.memory_events() if e["type"] == "span"}
    assert spans["inner"]["depth"] == 2
    assert spans["outer"]["depth"] == 1
    assert spans["outer"]["dur"] >= spans["inner"]["dur"] >= 0.0
    tid = threading.get_ident() & 0x7FFFFFFF
    assert spans["outer"]["tid"] == tid


def test_jsonl_sink_roundtrip(tmp_path):
    path = str(tmp_path / "sub" / "events.jsonl")  # parent dir auto-created
    obs.enable(jsonl=path)
    obs.counter("c", 2, op="fwht")
    with obs.span("s", k=1):
        pass
    obs.disable()
    events = load_jsonl(path)
    assert [e["type"] for e in events] == ["counter", "span", "meta"]
    assert events[0]["value"] == 2.0 and events[0]["attrs"]["op"] == "fwht"
    assert events[-1]["name"] == "obs.summary"    # emitted by close()


def test_summary_aggregates_and_survives_disable():
    o = obs.enable()
    for v in (1.0, 3.0):
        obs.counter("c", v)
        obs.histogram("h", v)
    obs.gauge("g", 7.0)
    with obs.span("s"):
        pass
    obs.disable()
    s = o.summary()
    assert s["counters"]["c"] == {"total": 4.0, "count": 2}
    assert s["hists"]["h"]["count"] == 2 and s["hists"]["h"]["max"] == 3.0
    assert s["gauges"]["g"]["last"] == 7.0
    assert s["spans"]["s"]["count"] == 1
    assert s is o.summary()                       # frozen after close
    rendered = report.render(s)
    assert isinstance(rendered, str) and "s" in rendered


# ---------------------------------------------------------------------------
# Chrome trace export
# ---------------------------------------------------------------------------
def test_chrome_trace_sink_writes_valid_trace(tmp_path):
    path = str(tmp_path / "trace.json")
    obs.enable(trace=path)
    with obs.span("work", k=1):
        obs.counter("bytes", 10)
    obs.disable()
    n = trace_lib.validate_trace(path)
    doc = json.load(open(path))
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert {"M", "X", "C"} <= phases              # metadata, span, counter
    assert n == len(doc["traceEvents"]) >= 4
    span = [e for e in doc["traceEvents"] if e["ph"] == "X"][0]
    assert span["name"] == "work" and span["dur"] >= 0
    assert span["args"] == {"k": 1}


def test_validate_trace_rejects_malformed():
    with pytest.raises(ValueError, match="bad phase"):
        trace_lib.validate_trace([{"ph": "Z", "name": "x"}])
    with pytest.raises(ValueError, match="dur"):
        trace_lib.validate_trace(
            [{"ph": "X", "name": "x", "ts": 0.0, "pid": 0}])
    with pytest.raises(ValueError, match="traceEvents"):
        trace_lib.validate_trace({})


def test_jax_profiler_unavailable_is_recorded_not_raised(tmp_path, monkeypatch):
    """Satellite: a missing/broken jax.profiler must degrade to a no-op
    session with a recorded reason, never an exception."""
    import jax as jax_mod

    class Broken:
        def start_trace(self, d):
            raise RuntimeError("no profiler build")

        def stop_trace(self):
            raise RuntimeError("no profiler build")

    monkeypatch.setattr(jax_mod, "profiler", Broken())
    o = obs.enable(jax_trace_dir=str(tmp_path / "jaxtrace"))
    obs.counter("still.works", 1)
    obs.disable()
    s = o.summary()
    assert s["jax_trace"]["active"] is False
    assert "no profiler build" in s["jax_trace"]["error"]
    assert s["counters"]["still.works"]["total"] == 1.0


# ---------------------------------------------------------------------------
# recompile tracker
# ---------------------------------------------------------------------------
def test_recompile_registry_counts_and_delta():
    fn = recompile.register("t.obs.toy", jax.jit(lambda x: x * 2))
    before = recompile.counts()
    fn(jnp.ones(4))
    fn(jnp.ones(8))                               # new shape -> new compile
    fn(jnp.ones(8))                               # cached -> no compile
    after = recompile.counts()
    assert recompile.delta(before, after)["t.obs.toy"] == 2


def test_recompile_counts_survive_gc():
    """An active session pins programs registered during its window, so the
    summary still reports them after the owner (e.g. a benchmark's
    Federation) is garbage-collected."""
    o = obs.enable()
    fn = recompile.register("t.obs.dying", jax.jit(lambda x: x + 1))
    fn(jnp.ones(4))
    del fn
    import gc
    gc.collect()
    obs.disable()
    assert o.summary()["recompiles"]["t.obs.dying"] == 1


# ---------------------------------------------------------------------------
# kernels.ops dispatch counters
# ---------------------------------------------------------------------------
def test_kernel_dispatch_counter(monkeypatch):
    monkeypatch.delenv("REPRO_FORCE_PALLAS", raising=False)
    o = obs.enable()
    ops.fwht(jnp.ones((2, 64)))
    obs.disable()
    events = [e for e in o.memory_events()
              if e["name"] == "kernels.dispatch"]
    assert len(events) == 1
    attrs = events[0]["attrs"]
    assert attrs["op"] == "fwht" and attrs["n"] == 64
    assert attrs["path"] in ("pallas", "ref") and attrs["forced"] is False


def test_forced_dispatch_error_counts_and_raises(monkeypatch):
    """Satellite: the forced-pallas refusal must BOTH report through the obs
    counter and keep raising."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    n = fwht_kernel.MAX_VMEM_N * 2
    o = obs.enable()
    with pytest.raises(ValueError, match="REPRO_FORCE_PALLAS"):
        ops.fwht(jnp.ones((1, n)))
    obs.disable()
    errs = [e for e in o.memory_events()
            if e["name"] == "kernels.forced_error"]
    assert len(errs) == 1
    assert errs[0]["attrs"] == {"op": "fwht", "n": n}


# ---------------------------------------------------------------------------
# scheduler instrumentation
# ---------------------------------------------------------------------------
def test_scheduler_tokens_identical_and_metrics_present():
    cfg = configs.get_reduced("phi3-mini-3.8b")
    params = model_lib.init_params(jax.random.key(0), cfg)
    prompts = [jax.random.randint(jax.random.key(40 + i), (4 + i,), 0,
                                  cfg.vocab_size, jnp.int32)
               for i in range(3)]

    def generate():
        sched = Engine(cfg, params, ServeConfig(slots=2, max_seq=32))
        for i, p in enumerate(prompts):
            sched.submit(Request(rid=i, prompt=p, max_new_tokens=4))
        done = sched.run_to_completion()
        return {r.rid: r.tokens_out for r in done}, done

    ref, _ = generate()
    o = obs.enable()
    instrumented, done = generate()
    obs.disable()
    assert instrumented == ref                    # tokens identical with obs
    assert all(r.submit_time is not None and r.finish_time is not None
               and r.finish_time >= r.submit_time for r in done)
    s = o.summary()
    assert s["counters"]["serve.submitted"]["total"] == 3.0
    assert s["counters"]["serve.requests"]["total"] == 3.0
    assert s["hists"]["serve.request_latency_s"]["count"] == 3
    assert s["gauges"]["serve.queue_depth"]["last"] == 0.0
    assert {"serve.admit_cold", "serve.decode_step"} <= set(s["spans"])
    reasons = {e["attrs"]["reason"] for e in o.memory_events()
               if e["name"] == "serve.requests"}
    assert reasons <= {"eos", "budget", "max_seq"} and reasons


# ---------------------------------------------------------------------------
# truncated JSONL tolerance
# ---------------------------------------------------------------------------
def _write_events_jsonl(path, n=3):
    with open(path, "w") as f:
        for i in range(n):
            f.write(json.dumps({"type": "counter", "name": f"c{i}",
                                "ts": float(i), "value": 1.0}) + "\n")


def test_load_jsonl_truncated_final_line(tmp_path):
    """A writer that died mid-write leaves a torn last record: the parsed
    prefix comes back with truncated=True instead of an exception."""
    path = str(tmp_path / "torn.jsonl")
    _write_events_jsonl(path)
    with open(path, "a") as f:
        f.write('{"type": "counter", "name": "c3", "ts": 3.0, "val')
    events = load_jsonl(path)
    assert isinstance(events, EventList) and events.truncated is True
    assert [e["name"] for e in events] == ["c0", "c1", "c2"]
    with pytest.raises(json.JSONDecodeError):
        load_jsonl(path, strict=True)


def test_load_jsonl_midfile_corruption_still_raises(tmp_path):
    """A bad record with valid records AFTER it is corruption, not a torn
    tail — that must keep raising."""
    path = str(tmp_path / "corrupt.jsonl")
    _write_events_jsonl(path, n=1)
    with open(path, "a") as f:
        f.write('{"broken": \n')
        f.write(json.dumps({"type": "counter", "name": "after",
                            "ts": 9.0, "value": 1.0}) + "\n")
    with pytest.raises(json.JSONDecodeError):
        load_jsonl(path)


def test_load_jsonl_intact_file_not_truncated(tmp_path):
    path = str(tmp_path / "ok.jsonl")
    _write_events_jsonl(path)
    events = load_jsonl(path)
    assert events.truncated is False and len(events) == 3


# ---------------------------------------------------------------------------
# Chrome trace under interleaved spans + multiple counter tracks
# ---------------------------------------------------------------------------
def test_chrome_trace_interleaved_spans_and_counter_tracks(tmp_path):
    path = str(tmp_path / "trace.json")
    o = obs.enable(trace=path)
    with obs.span("outer"):
        # interleaved (not properly nested) spans: enter a, enter b,
        # exit a, exit b — the exporter must still produce a valid trace
        a = o.span("stream.a")
        b = o.span("stream.b")
        a.__enter__()
        b.__enter__()
        for i in range(4):
            obs.counter("track.bytes", 128 * (i + 1))
            obs.gauge("track.depth", i)
        a.__exit__(None, None, None)
        b.__exit__(None, None, None)
    obs.disable()

    assert trace_lib.validate_trace(path) > 0
    doc = json.load(open(path))
    events = doc["traceEvents"]
    span_names = {e["name"] for e in events if e["ph"] == "X"}
    assert {"outer", "stream.a", "stream.b"} <= span_names
    # each metric name is its own counter track; samples must be
    # monotonically timestamped within a track (Perfetto requirement)
    tracks: dict = {}
    for e in events:
        if e["ph"] == "C":
            tracks.setdefault(e["name"], []).append(e["ts"])
    assert {"track.bytes", "track.depth"} <= set(tracks)
    for name, tss in tracks.items():
        assert tss == sorted(tss), f"counter track {name} not monotonic"
    assert len(tracks["track.bytes"]) == 4


# ---------------------------------------------------------------------------
# summary: p99 + deterministic ordering
# ---------------------------------------------------------------------------
def test_hist_summary_includes_p99():
    o = obs.enable()
    for v in range(101):                         # 0..100: ranks land exactly
        obs.histogram("lat", float(v))
    obs.disable()
    h = o.summary()["hists"]["lat"]
    assert h["p50"] == 50.0
    assert h["p95"] == 95.0
    assert h["p99"] == 99.0
    assert h["max"] == 100.0


def test_summary_ordering_is_deterministic():
    """Every per-name table in the summary is key-sorted, so JSON payloads
    diff cleanly run to run regardless of emission order."""
    o = obs.enable()
    for name in ("zeta", "alpha", "mid"):
        obs.counter(name, 1)
        obs.gauge("g." + name, 1.0)
        obs.histogram("h." + name, 1.0)
        with obs.span("s." + name):
            pass
    obs.disable()
    s = o.summary()
    for table in ("counters", "gauges", "hists", "spans", "recompiles"):
        keys = list(s[table])
        assert keys == sorted(keys), f"{table} not sorted"
