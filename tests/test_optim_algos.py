"""The paper's optimization algorithms: Thm. 2 / Thm. 3 behaviour."""
import math

import jax
import jax.numpy as jnp
import pytest

from repro.core.coding import Codec, CodecConfig
from repro.core import frames as F
from repro.core import optim as O
from repro.core import baselines as B


def quadratic_problem(key, n=64, cond=10.0):
    """f(x) = ½(x−x*)ᵀ H (x−x*) with eigenvalues in [μ, L]."""
    k1, k2 = jax.random.split(key)
    eigs = jnp.linspace(1.0, cond, n)
    qmat = jnp.linalg.qr(jax.random.normal(k1, (n, n)))[0]
    h = (qmat * eigs) @ qmat.T
    x_star = jax.random.normal(k2, (n,))
    grad = lambda x: h @ (x - x_star)
    return grad, x_star, float(eigs[-1]), float(eigs[0])


def test_unquantized_gd_rate():
    grad, x_star, L, mu = quadratic_problem(jax.random.key(0))
    alpha = O.alpha_star(L, mu)
    trace = O.gd(grad, jnp.zeros_like(x_star), alpha, 200, x_star)
    sigma = O.sigma_rate(L, mu)
    d0 = float(jnp.linalg.norm(x_star))
    assert float(trace.dist_history[-1]) <= (sigma ** 200) * d0 * 1.3


@pytest.mark.parametrize("R", [4.0, 8.0])
def test_dgd_def_converges_linearly(R):
    """DGD-DEF at budget R: ‖x_T−x*‖ ≲ max{σ, 2^{−R}β}^T·D (Thm. 2)."""
    grad, x_star, L, mu = quadratic_problem(jax.random.key(1))
    n = x_star.shape[0]
    frame = F.make_frame("hadamard", jax.random.key(2), n, n)
    codec = Codec(frame, CodecConfig(bits_per_dim=R))
    alpha = O.alpha_star(L, mu)
    steps = 150
    trace = O.dgd_def(grad, jnp.zeros_like(x_star), codec, alpha, steps,
                      x_star=x_star)
    sigma = O.sigma_rate(L, mu)
    beta = codec.error_bound()
    rate = max(sigma, beta)
    assert rate < 1.0
    final = float(trace.dist_history[-1])
    d0 = float(jnp.linalg.norm(x_star))
    # allow the (1 + βαL/|β−ν|) constant in front
    assert final <= 20.0 * (rate ** steps) * d0 + 1e-6


def test_dgd_def_beats_naive_at_low_budget():
    """At R=2 the democratic codec converges where naive uniform stalls
    (paper Fig. 1b behaviour)."""
    grad, x_star, L, mu = quadratic_problem(jax.random.key(3), cond=30.0)
    n = x_star.shape[0]
    frame = F.make_frame("hadamard", jax.random.key(4), n, n)
    codec = Codec(frame, CodecConfig(bits_per_dim=2.0))
    alpha = O.alpha_star(L, mu)
    t_codec = O.dgd_def(grad, jnp.zeros_like(x_star), codec, alpha, 300,
                        x_star=x_star)
    naive = B.naive_uniform(levels=4)   # same 2 bits/dim
    t_naive = O.dqgd(grad, jnp.zeros_like(x_star), naive.roundtrip, alpha,
                     300, x_star=x_star)
    assert float(t_codec.dist_history[-1]) < 0.2 * float(
        t_naive.dist_history[-1]) + 1e-8


def _svm_problem(key, m=80, n=24):
    from repro.data import synthetic_two_class
    a, b = synthetic_two_class(key, m // 2, n)

    def subgrad(k, x):
        idx = jax.random.randint(k, (16,), 0, m)
        ai, bi = a[idx], b[idx]
        margin = bi * (ai @ x)
        g = -(bi[:, None] * ai) * (margin < 1.0)[:, None]
        return jnp.mean(g, axis=0)

    def full_loss(x):
        return jnp.mean(jnp.maximum(0.0, 1.0 - b * (a @ x)))

    return subgrad, full_loss


def test_dq_psgd_converges():
    """DQ-PSGD on the hinge loss decreases the objective (paper Fig. 2)."""
    subgrad, full_loss = _svm_problem(jax.random.key(0))
    n = 24
    frame = F.make_frame("haar", jax.random.key(1), n, n)
    codec = Codec(frame, CodecConfig(bits_per_dim=1.0, dithered=True))
    x0 = jnp.zeros((n,))
    trace = O.dq_psgd(subgrad, x0, codec, alpha=0.05, steps=400,
                      key=jax.random.key(2))
    assert float(full_loss(trace.x_avg)) < 0.5 * float(full_loss(x0))


def test_dq_psgd_multiworker_consensus():
    """Alg. 3: m workers with private data; consensus mean converges."""
    m_workers = 5
    probs = [_svm_problem(jax.random.key(10 + i)) for i in range(m_workers)]

    def subgrad_i(i, k, x):
        branches = [p[0] for p in probs]
        return jax.lax.switch(i, branches, k, x)

    n = 24
    frame = F.make_frame("haar", jax.random.key(1), n, n)
    codec = Codec(frame, CodecConfig(bits_per_dim=2.0, dithered=True))
    x0 = jnp.zeros((n,))
    trace = O.dq_psgd_multiworker(subgrad_i, m_workers, x0, codec,
                                  alpha=0.05, steps=300,
                                  key=jax.random.key(3))
    total = lambda x: sum(float(p[1](x)) for p in probs) / m_workers
    assert total(trace.x_avg) < 0.5 * total(x0)


def test_dqgd_schedule_threshold():
    """[6]'s fixed-range DQGD: diverges when √n/2^R > σ-headroom, converges
    at high budget — the √n penalty DGD-DEF removes (paper Fig. 1b)."""
    grad, x_star, L, mu = quadratic_problem(jax.random.key(7), n=64, cond=20)
    alpha = O.alpha_star(L, mu)
    d = float(jnp.linalg.norm(x_star)) * 1.5
    lo = O.dqgd_schedule(grad, jnp.zeros_like(x_star), 2 ** 2, alpha, 120,
                         L, mu, d, 64, x_star=x_star)
    hi = O.dqgd_schedule(grad, jnp.zeros_like(x_star), 2 ** 8, alpha, 120,
                         L, mu, d, 64, x_star=x_star)
    assert float(hi.dist_history[-1]) < 1e-2 * float(jnp.linalg.norm(x_star))
    assert float(lo.dist_history[-1]) > 10 * float(hi.dist_history[-1])


def test_step_size_helpers():
    assert O.alpha_star(10, 1) == pytest.approx(2 / 11)
    assert O.sigma_rate(10, 1) == pytest.approx(9 / 11)
    a = O.psgd_alpha(D=1.0, B=2.0, Ku=3.0, R=0.5, T=100)
    assert a == pytest.approx((1 / 6) * math.sqrt(0.5 / 100))
