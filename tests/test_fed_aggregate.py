"""Stacked server aggregation: bit-exactness with the list reference,
sum modes, weight guards, codec-spec canonicalization, audit caching."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fed import (AdaptiveConfig, ClientConfig, FedConfig, Federation,
                       ServerConfig, server as server_lib)
from repro import codecs as registry
from repro.optimizer import sgd


def _random_tree(key, lanes=None):
    ks = jax.random.split(key, 3)
    shape = lambda s: ((lanes,) + s) if lanes is not None else s
    return {"w": jax.random.normal(ks[0], shape((13, 5)), jnp.float32),
            "b": jax.random.normal(ks[1], shape((29,)), jnp.float32)}


def _server_cfgs(sum_mode="sequential"):
    return [
        ServerConfig(sum_mode=sum_mode),
        ServerConfig(aggregator="fedopt", optimizer=sgd(1.0, momentum=0.5),
                     sum_mode=sum_mode),
        ServerConfig(aggregator="fedmem", server_lr=0.7, sum_mode=sum_mode),
    ]


# ---------------------------------------------------------------------------
# aggregate_stacked vs the list reference, unit level
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("lanes", [1, 3, 6])
@pytest.mark.parametrize("slot_weighted", [False, True])
def test_stacked_sequential_bitwise_matches_list_reference(lanes,
                                                           slot_weighted):
    """Every aggregator, every piece of server state, bit for bit."""
    m_total = 8
    key = jax.random.key(0)
    params = _random_tree(jax.random.fold_in(key, 99))
    stacked = _random_tree(jax.random.fold_in(key, 1), lanes=lanes)
    deltas = [jax.tree.map(lambda x, i=i: x[i], stacked)
              for i in range(lanes)]
    rng = np.random.default_rng(3)
    weights = rng.uniform(0.5, 2.0, lanes)
    ids = sorted(rng.choice(m_total, size=lanes, replace=False).tolist())
    slot_w = rng.uniform(0.5, 2.0, m_total) if slot_weighted else None
    for cfg in _server_cfgs():
        state = server_lib.init_server(params, cfg, m_total)
        ref = server_lib.aggregate(
            state, cfg, deltas, weights, ids,
            slot_weights=slot_w if cfg.aggregator == "fedmem" else None)
        got = server_lib.aggregate_stacked(
            state, cfg, stacked, weights, ids,
            slot_weights=slot_w if cfg.aggregator == "fedmem" else None)
        for name, r, g in (("params", ref.params, got.params),
                           ("opt_state", ref.opt_state, got.opt_state),
                           ("memory", ref.memory, got.memory)):
            for rl, gl in zip(jax.tree.leaves(r), jax.tree.leaves(g)):
                np.testing.assert_array_equal(
                    np.asarray(rl), np.asarray(gl),
                    err_msg=f"{cfg.aggregator}/{name} diverged")


def test_stacked_pairwise_matches_to_tolerance():
    """sum_mode='pairwise' reduces in a different order: equal to the
    sequential reference only to float tolerance (and for 1-2 lanes, where
    the orders coincide, exactly)."""
    key = jax.random.key(7)
    params = _random_tree(jax.random.fold_in(key, 99))
    for lanes in (1, 2, 5, 9):
        stacked = _random_tree(jax.random.fold_in(key, lanes), lanes=lanes)
        weights = np.random.default_rng(lanes).uniform(0.5, 2.0, lanes)
        seq = server_lib.aggregate_stacked(
            server_lib.init_server(params, ServerConfig(), 4),
            ServerConfig(sum_mode="sequential"), stacked, weights)
        pw = server_lib.aggregate_stacked(
            server_lib.init_server(params, ServerConfig(), 4),
            ServerConfig(sum_mode="pairwise"), stacked, weights)
        for s, p in zip(jax.tree.leaves(seq.params),
                        jax.tree.leaves(pw.params)):
            np.testing.assert_allclose(np.asarray(s), np.asarray(p),
                                       rtol=1e-5, atol=1e-6)
        if lanes <= 2:
            for s, p in zip(jax.tree.leaves(seq.params),
                            jax.tree.leaves(pw.params)):
                np.testing.assert_array_equal(np.asarray(s), np.asarray(p))


def test_sum_mode_validation():
    with pytest.raises(ValueError, match="sum_mode"):
        ServerConfig(sum_mode="bogus")


def test_stacked_weight_arity_checked():
    params = _random_tree(jax.random.key(0))
    stacked = _random_tree(jax.random.key(1), lanes=3)
    state = server_lib.init_server(params, ServerConfig(), 3)
    with pytest.raises(ValueError, match="weights"):
        server_lib.aggregate_stacked(state, ServerConfig(), stacked,
                                     np.ones(2))


def test_stacked_norms_match_host_reference():
    """Device-side per-lane norms (what the decode programs emit) agree with
    the float64 host oracle to f32 precision."""
    stacked = _random_tree(jax.random.key(4), lanes=5)
    lanes = [jax.tree.map(lambda x, i=i: x[i], stacked) for i in range(5)]
    dev = np.asarray(server_lib.stacked_norms(stacked))
    host = server_lib.delta_norms(lanes)
    np.testing.assert_allclose(dev, host, rtol=1e-6)


# ---------------------------------------------------------------------------
# non-positive weight sums must fail loudly, not NaN-poison the params
# ---------------------------------------------------------------------------
def test_zero_weight_sum_raises():
    deltas = [{"x": jnp.ones(4)}, {"x": jnp.ones(4)}]
    with pytest.raises(ValueError, match="positive"):
        server_lib.weighted_mean(deltas, np.zeros(2))


def test_nan_inf_and_negative_weight_sums_raise():
    deltas = [{"x": jnp.ones(4)}]
    for bad in (np.array([np.nan]), np.array([-1.0]), np.array([np.inf])):
        with pytest.raises(ValueError, match="positive"):
            server_lib.weighted_mean(deltas, bad)


def test_stacked_and_fedmem_slot_weight_guards():
    params = {"x": jnp.ones(4)}
    stacked = {"x": jnp.ones((2, 4))}
    cfg = ServerConfig(aggregator="fedmem")
    state = server_lib.init_server(params, cfg, 3)
    avg = ServerConfig()
    with pytest.raises(ValueError, match="positive"):
        server_lib.aggregate_stacked(server_lib.init_server(params, avg, 3),
                                     avg, stacked, np.zeros(2))
    with pytest.raises(ValueError, match="slot_weights"):
        server_lib.aggregate_stacked(state, cfg, stacked, np.ones(2), [0, 1],
                                     slot_weights=np.zeros(3))
    deltas = [{"x": jnp.ones(4)}, {"x": jnp.ones(4)}]
    with pytest.raises(ValueError, match="slot_weights"):
        server_lib.aggregate(state, cfg, deltas, np.ones(2), [0, 1],
                             slot_weights=np.zeros(3))
    # fedmem NEVER reads the participant weights (its direction comes from
    # the slots) — both layouts must accept a zero weight sum there, like
    # the list reference always has
    ref = server_lib.aggregate(state, cfg, deltas, np.zeros(2), [0, 1])
    got = server_lib.aggregate_stacked(state, cfg, stacked, np.zeros(2),
                                       [0, 1])
    np.testing.assert_array_equal(np.asarray(ref.params["x"]),
                                  np.asarray(got.params["x"]))


# ---------------------------------------------------------------------------
# the full driver: stacked pipeline ≡ PR-2 sequential reference, bit for bit
# ---------------------------------------------------------------------------
def _mixed_population(seed=0):
    """m=6: three ndsc R=2 clients with equal specs, two sub-linear ndsc
    R=0.75, one identity; one client has a different shard shape."""
    ka, kx = jax.random.split(jax.random.key(seed))
    m, dim, n = 6, 48, 64
    a = jax.random.normal(ka, (m, n, dim)) / jnp.sqrt(n)
    x_true = jax.random.normal(kx, (dim,))
    shards = [{"a": a[i], "b": a[i] @ x_true} for i in range(m)]
    shards[5] = {"a": a[5][:32], "b": (a[5] @ x_true)[:32]}

    def loss_fn(p, batch):
        r = batch["a"] @ p["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r)

    codecs = ([registry.make("ndsc", budget=2.0, chunk=32) for _ in range(3)]
              + [registry.make("ndsc", budget=0.75, chunk=32)
                 for _ in range(2)]
              + [registry.make("identity")])
    return loss_fn, {"x": jnp.zeros(dim)}, shards, codecs


@pytest.mark.parametrize("agg", ["fedavg", "fedopt", "fedmem"])
def test_driver_stacked_bit_exact_with_sequential_reference(agg):
    """The stacked on-device pipeline (cohort decode → concat →
    aggregate_stacked, sum_mode='sequential') reproduces the PR-2 list-
    reference driver bit for bit — params, fedmem memory, fedopt optimizer
    state — on a mixed population with partial participation, stragglers
    and data_size weighting."""
    loss_fn, params, shards, codecs = _mixed_population()
    scfg = {"fedavg": ServerConfig(),
            "fedopt": ServerConfig(aggregator="fedopt",
                                   optimizer=sgd(1.0, momentum=0.5)),
            "fedmem": ServerConfig(aggregator="fedmem")}[agg]
    ccfg = ClientConfig(local_steps=2, lr=0.3)
    out = {}
    for use_cohorts in (True, False):
        fed = Federation(loss_fn, params, shards, list(codecs), ccfg, scfg,
                         seed=3, use_cohorts=use_cohorts)
        hist = fed.run(FedConfig(num_rounds=6, participation=0.8, dropout=0.2,
                                 seed=9, weighting="data_size"))
        out[use_cohorts] = (fed, hist)
    fed_c, hist_c = out[True]
    fed_s, hist_s = out[False]
    assert hist_c["participants"] == hist_s["participants"]
    assert hist_c["wire_bytes"] == hist_s["wire_bytes"]
    np.testing.assert_array_equal(np.asarray(fed_c.server.params["x"]),
                                  np.asarray(fed_s.server.params["x"]))
    for c, s in zip(jax.tree.leaves(fed_c.server.opt_state),
                    jax.tree.leaves(fed_s.server.opt_state)):
        np.testing.assert_array_equal(np.asarray(c), np.asarray(s))
    for c, s in zip(jax.tree.leaves(fed_c.server.memory),
                    jax.tree.leaves(fed_s.server.memory)):
        np.testing.assert_array_equal(np.asarray(c), np.asarray(s))


def test_driver_ema_norms_bit_exact_across_paths():
    """The decode-program-emitted norms feed the allocator EMA identically
    on the stacked and reference paths (the adaptive trajectories can only
    be regression-tested if the EMA state matches bitwise)."""
    loss_fn, params, shards, _ = _mixed_population()
    factory = lambda r: registry.make("ndsc", budget=float(r), chunk=32)
    acfg = AdaptiveConfig(total_rate=8.0, realloc_every=2, grid=0.25,
                          hysteresis=0.25, min_rate=0.25)
    ema, rates = {}, {}
    for use_cohorts in (True, False):
        fed = Federation(loss_fn, params, shards[:4], [factory(2.0)] * 4,
                         ClientConfig(local_steps=1, lr=0.3), ServerConfig(),
                         seed=1, use_cohorts=use_cohorts, adaptive=acfg,
                         codec_factory=factory)
        hist = fed.run(FedConfig(num_rounds=6, participation=0.8, seed=5))
        ema[use_cohorts] = fed._ema.norms.copy()
        rates[use_cohorts] = hist["rates"]
    np.testing.assert_array_equal(ema[True], ema[False])
    assert rates[True] == rates[False]


# ---------------------------------------------------------------------------
# codec_spec canonicalization: factory defaults must not split cohorts
# ---------------------------------------------------------------------------
def test_codec_spec_binds_factory_defaults():
    """make('ndsc', 1.5) and make('ndsc', 1.5, chunk=128) build identical
    codecs — their specs must compare equal (chunk=128 IS the default)."""
    a = registry.make("ndsc", budget=1.5)
    b = registry.make("ndsc", budget=1.5, chunk=128)
    c = registry.make("ndsc", budget=1.5, chunk=128, exact_keep=True, seed=0)
    d = registry.make("ndsc", budget=1.5, chunk=64)
    assert a.spec == b.spec == c.spec
    assert a.spec != d.spec
    # kwarg ORDER never mattered; defaults now don't either, across backends
    assert (registry.make("dsc", budget=2.0).spec
            == registry.make("dsc", budget=2.0, dithered=False).spec)
    assert (registry.make("topk", budget=2.0).spec
            == registry.make("topk", budget=2.0, quant_levels=256).spec)


def test_codec_spec_unknown_name_raises():
    with pytest.raises(ValueError, match="unknown"):
        registry.codec_spec("nope", 2.0, {})


def test_make_accepts_spec_tuple():
    """make(spec) rebuilds a codec from its canonical identity —
    make(c.spec).spec == c.spec — so checkpoints and benchmarks can
    round-trip codecs without re-plumbing the original kwargs."""
    for args in (("ndsc", 1.5, {"chunk": 64}),
                 ("ndsc", [1.0, 2.0], {"chunk": 32}),   # per-leaf budgets
                 ("dsc", 2.0, {"dithered": True}),
                 ("qsgd", 4.0, {}),
                 ("topk", 2.0, {"quant_levels": 64})):
        name, budget, kwargs = args
        direct = registry.make(name, budget, **kwargs)
        rebuilt = registry.make(direct.spec)
        assert rebuilt.spec == direct.spec, args
        assert rebuilt.name == direct.name
        # and the spec constructor alone agrees with codec_spec
        assert registry.make(
            registry.codec_spec(name, budget, kwargs)).spec == direct.spec
    # spec-form rejects extra arguments and malformed tuples
    c = registry.make("ndsc", 1.5)
    with pytest.raises(ValueError, match="no extra"):
        registry.make(c.spec, 2.0)
    with pytest.raises(ValueError, match="no extra"):
        registry.make(c.spec, chunk=32)
    with pytest.raises(ValueError, match="malformed"):
        registry.make(("ndsc", 1.5))
    # a spec-rebuilt codec encodes/decodes identically to the original
    key = jax.random.key(0)
    tree = {"w": jax.random.normal(jax.random.key(1), (96,))}
    wire_a = c.encode(key, tree)
    wire_b = registry.make(c.spec).encode(key, tree)
    for xa, xb in zip(jax.tree.leaves(wire_a), jax.tree.leaves(wire_b)):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


def test_equivalent_make_calls_share_one_cohort_and_compile():
    """Clients built with and without the factory-default kwargs land in ONE
    cohort: a single vmapped round/decode program is compiled, not two."""
    loss_fn, params, shards, _ = _mixed_population()
    codecs = [registry.make("ndsc", budget=1.5),
              registry.make("ndsc", budget=1.5, chunk=128),
              registry.make("ndsc", budget=1.5, chunk=128, seed=0)]
    fed = Federation(loss_fn, params, shards[:3], codecs,
                     ClientConfig(local_steps=1, lr=0.2), ServerConfig(),
                     seed=0)
    fed.run(FedConfig(num_rounds=2))
    assert len(fed._cohort_fns) == 1
    assert len(fed._cohort_decode_fns) == 1
    assert len(fed._round_fns) == 1


# ---------------------------------------------------------------------------
# analytic-audit caching: computed once per spec, ledger unchanged
# ---------------------------------------------------------------------------
def test_audit_cache_one_entry_per_spec_and_ledger_unchanged():
    loss_fn, params, shards, codecs = _mixed_population()
    fed = Federation(loss_fn, params, shards, list(codecs),
                     ClientConfig(local_steps=1, lr=0.2), ServerConfig(),
                     seed=0)
    # 3 distinct specs (ndsc R=2, ndsc R=0.75, identity) → 3 cached audits
    assert len(fed._audit_bits) == 3
    hist = fed.run(FedConfig(num_rounds=3, participation=0.8, seed=2))
    for ana, parts in zip(hist["analytic_bytes"], hist["participants"]):
        direct = sum(codecs[i].wire_bits(params) / 8.0 for i in parts)
        assert ana == direct
    assert hist["wire_bytes"] == hist["analytic_bytes"]


def test_audit_cache_survives_rate_reallocation():
    """set_rates reuses cached audits for previously seen specs and the
    ledger stays byte-exact across the rebuild."""
    loss_fn, params, shards, _ = _mixed_population()
    factory = lambda r: registry.make("ndsc", budget=float(r), chunk=32)
    acfg = AdaptiveConfig(total_rate=8.0, realloc_every=2, hysteresis=0.0,
                          grid=0.25, min_rate=0.25)
    fed = Federation(loss_fn, params, shards[:4], [factory(2.0)] * 4,
                     ClientConfig(local_steps=1, lr=0.3), ServerConfig(),
                     seed=0, adaptive=acfg, codec_factory=factory)
    hist = fed.run(FedConfig(num_rounds=8, seed=1))
    assert any(hist["realloc"])
    assert hist["wire_bytes"] == hist["analytic_bytes"]
    # one audit entry per distinct spec ever installed
    specs = {registry.make("ndsc", budget=float(r), chunk=32).spec
             for rates in hist["rates"] for r in rates}
    assert len(fed._audit_bits) == len(specs)


# ---------------------------------------------------------------------------
# spec-less codecs still work end to end (object-keyed caches)
# ---------------------------------------------------------------------------
def test_specless_codec_round_trip():
    loss_fn, params, shards, _ = _mixed_population()
    bare = dataclasses.replace(registry.make("ndsc", budget=2.0, chunk=32),
                               spec=None)
    fed = Federation(loss_fn, params, shards[:2], bare,
                     ClientConfig(local_steps=1, lr=0.2), ServerConfig(),
                     seed=0)
    hist = fed.run(FedConfig(num_rounds=2))
    assert hist["wire_bytes"] == hist["analytic_bytes"]
    assert len(fed._audit_bits) == 1       # keyed by the codec object


# ---------------------------------------------------------------------------
# the mesh padding contract: zero-weight lanes are admitted and inert
# ---------------------------------------------------------------------------
def test_zero_weight_padding_lanes_are_inert():
    """aggregate_stacked with trailing zero-weight lanes (the mesh backend's
    padding layout) passes the weight guard and produces the SAME result as
    the unpadded stack — sequential mode bitwise, pairwise to tolerance."""
    key = jax.random.key(4)
    lanes, pads = 5, 3
    real = _random_tree(key, lanes=lanes)
    junk = _random_tree(jax.random.fold_in(key, 1), lanes=pads)
    padded = jax.tree.map(lambda a, b: jnp.concatenate([a, b]), real, junk)
    w = np.random.default_rng(7).uniform(0.5, 2.0, lanes)
    w_padded = np.concatenate([w, np.zeros(pads)])
    params = _random_tree(jax.random.fold_in(key, 2))
    for sum_mode in ("sequential", "pairwise"):
        cfg = ServerConfig(sum_mode=sum_mode)
        state = server_lib.init_server(params, cfg, lanes + pads)
        ref = server_lib.aggregate_stacked(state, cfg, real, w)
        got = server_lib.aggregate_stacked(state, cfg, padded, w_padded)
        for rl, gl in zip(jax.tree.leaves(ref.params),
                          jax.tree.leaves(got.params)):
            if sum_mode == "sequential":
                np.testing.assert_array_equal(np.asarray(rl), np.asarray(gl))
            else:
                np.testing.assert_allclose(np.asarray(rl), np.asarray(gl),
                                           rtol=1e-6)


def test_weight_guard_rejects_negative_and_nonfinite_entries():
    """Exact zeros pass (padding lanes); anything negative or non-finite is
    poison even when the SUM still looks positive."""
    deltas = [{"x": jnp.ones(4)}, {"x": jnp.ones(4)}]
    server_lib._check_weights(np.array([1.0, 0.0]))            # zeros OK
    for bad in (np.array([2.0, -1.0]),       # positive sum, negative entry
                np.array([1.0, np.nan]),
                np.array([1.0, np.inf])):
        with pytest.raises(ValueError, match="non-negative|positive"):
            server_lib.weighted_mean(deltas, bad)


def test_concat_stacks_perm_drops_padded_lanes():
    """concat_stacks' gather permutation can SELECT lanes, not just reorder
    them: stacks with trailing padding join into a real-lanes-only result.
    (The driver's mesh join slices padding off before concat — this pins
    down that the perm itself is also a safe way to drop lanes, so zero
    lanes can never leak into an aggregate through it.)"""
    import repro.fed.clients as clients_lib

    def tree(v, lanes):
        return {"x": jnp.full((lanes, 3), float(v))}

    # cohort A: lanes 0..2 real (clients 4,1,2), one pad; cohort B: lanes
    # 0..1 real (clients 3,0), two pads
    a = jax.tree.map(lambda *xs: jnp.concatenate(xs),
                     tree(4, 1), tree(1, 1), tree(2, 1), tree(-99, 1))
    b = jax.tree.map(lambda *xs: jnp.concatenate(xs),
                     tree(3, 1), tree(0, 1), tree(-77, 2))
    # participant order 0..4; global lane layout [A(4 lanes), B(4 lanes)]
    perm = [5, 1, 2, 4, 0]     # client i at global lane perm[i]
    joined = clients_lib.concat_stacks([a, b], perm)
    np.testing.assert_array_equal(np.asarray(joined["x"][:, 0]),
                                  [0.0, 1.0, 2.0, 3.0, 4.0])
    assert joined["x"].shape[0] == 5       # pads dropped by the gather
