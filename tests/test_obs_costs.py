"""The device cost model: capture, compile-free extraction, attribution.

The load-bearing guarantee: `costs.snapshot()` NEVER triggers an XLA
backend compile and never touches any program's jit cache — proven here by
monkeypatching the compiler entry point to raise, not just by counting.
"""
import functools

import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops
from repro.obs import core as obs
from repro.obs import costs, recompile, report


def _toy():
    return recompile.register("t.costs.toy", jax.jit(lambda x, y: x @ y))


# ---------------------------------------------------------------------------
# capture
# ---------------------------------------------------------------------------
def test_capture_dedupes_specializations_and_accumulates():
    fn = _toy()
    store = {}
    x = jnp.ones((8, 8))
    costs.record_call(store, "t.costs.toy", fn, (x, x), wire_bytes=10.0)
    costs.record_call(store, "t.costs.toy", fn, (x, x), wire_bytes=10.0)
    y = jnp.ones((16, 16))
    costs.record_call(store, "t.costs.toy", fn, (y, y))
    assert len(store) == 2                      # one record per signature
    rec = next(r for r in store.values() if r["args"][0].shape == (8, 8))
    assert rec["calls"] == 2 and rec["wire_bytes"] == 20.0
    # captured args are abstract — no live arrays (or tracers) retained
    assert all(isinstance(a, jax.ShapeDtypeStruct) for a in rec["args"])


def test_python_scalars_do_not_mint_specializations():
    """A jitted program traced once covers every value of a dynamic python
    int (e.g. the round index) — the capture must key by type, not value."""
    fn = jax.jit(lambda x, i: x + i)
    store = {}
    x = jnp.ones(4)
    for i in range(5):
        costs.record_call(store, "t.costs.scalar", fn, (x, i))
    assert len(store) == 1
    assert next(iter(store.values()))["calls"] == 5


def test_static_tag_separates_closures():
    store = {}
    x = jnp.ones(8)
    for bits in (1, 4):
        fn = functools.partial(lambda v, bits: v * bits, bits=bits)
        costs.record_call(store, "t.costs.bits", fn, (x,), jit_wrap=True,
                          static=("bits", bits))
    assert len(store) == 2


# ---------------------------------------------------------------------------
# extraction: compile-free by construction
# ---------------------------------------------------------------------------
def test_snapshot_never_backend_compiles(monkeypatch):
    """The hard proof: with the XLA compile entry point booby-trapped,
    the default snapshot still extracts FLOPs/bytes."""
    fn = _toy()
    x = jnp.ones((32, 32))
    fn(x, x)                                    # the real compile, up front
    store = {}
    costs.record_call(store, "t.costs.toy", fn, (x, x))

    import jax._src.compiler as compiler

    def boom(*a, **k):
        raise AssertionError("cost extraction triggered a backend compile")

    monkeypatch.setattr(compiler, "backend_compile", boom)
    snap = costs.snapshot(store)
    spec = snap["programs"]["t.costs.toy"]["specializations"][0]
    assert spec["available"] and spec["source"] == "lowered"
    assert spec["flops"] and spec["flops"] > 0
    assert spec["bytes_accessed"] and spec["bytes_accessed"] > 0
    assert spec["argument_bytes"] == 2 * 32 * 32 * 4


def test_snapshot_leaves_jit_cache_and_registry_untouched():
    fn = _toy()
    x = jnp.ones((8, 8))
    fn(x, x)
    store = {}
    costs.record_call(store, "t.costs.toy", fn, (x, x))
    before_cache = fn._cache_size()
    before_counts = recompile.counts()
    costs.snapshot(store)
    costs.snapshot(store, compile_ok=True)      # AOT path: also outside jit
    assert fn._cache_size() == before_cache
    assert recompile.counts() == before_counts


def test_compile_ok_adds_memory_analysis():
    fn = _toy()
    x = jnp.ones((16, 16))
    fn(x, x)
    store = {}
    costs.record_call(store, "t.costs.toy", fn, (x, x))
    spec = costs.snapshot(store, compile_ok=True)[
        "programs"]["t.costs.toy"]["specializations"][0]
    assert spec["source"] == "compiled" and spec["available"]
    assert spec["peak_bytes"] and spec["peak_bytes"] > 0
    assert spec["output_bytes"] == 16 * 16 * 4


def test_unavailable_backend_degrades_with_reason():
    """A program that refuses to re-lower must yield available=False with
    the reason recorded — never an exception out of snapshot()."""
    def broken(*args):
        raise RuntimeError("this backend has no cost analysis")

    store = {}
    costs.record_call(store, "t.costs.broken", broken, (jnp.ones(4),),
                      jit_wrap=True)
    # force the failure through the real lower() path
    snap = costs.snapshot(store)
    spec = snap["programs"]["t.costs.broken"]["specializations"][0]
    assert spec["available"] is False
    assert "no cost analysis" in spec["reason"]
    assert spec["flops"] is None and spec["bytes_accessed"] is None
    assert snap["programs"]["t.costs.broken"]["cost_coverage"] == 0.0


def test_jit_wrap_capture_never_registers_or_compiles():
    """Kernel-style capture: snapshot jits a FRESH wrapper for lowering
    only — the recompile registry must not grow a new program for it."""
    store = {}
    costs.record_call(store, "t.costs.plain", lambda x: x * 2.0,
                      (jnp.ones(16),), jit_wrap=True)
    names_before = set(recompile.counts())
    spec = costs.snapshot(store)["programs"]["t.costs.plain"][
        "specializations"][0]
    assert spec["available"] and spec["flops"] is not None
    assert set(recompile.counts()) == names_before


# ---------------------------------------------------------------------------
# peaks + attribution
# ---------------------------------------------------------------------------
def test_peaks_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_PEAK_FLOPS", "2e12")
    monkeypatch.setenv("REPRO_PEAK_BYTES", "1e11")
    pk = costs.peaks()
    assert pk == {"flops_per_s": 2e12, "bytes_per_s": 1e11,
                  "backend": pk["backend"], "device_kind": pk["device_kind"],
                  "source": "env"}


def test_peaks_device_table_prefix_match():
    pk = costs.peaks(backend="tpu", device_kind="TPU v4 (chip)")
    assert pk["source"] == "device_table"
    assert pk["flops_per_s"] == 275e12


def test_attach_attrib_roofline_math():
    summary = {"spans": {"work": {"count": 1, "total_s": 2.0, "mean_s": 2.0,
                                  "max_s": 2.0}}}
    snap = {"peaks": {"flops_per_s": 100.0, "bytes_per_s": 10.0},
            "programs": {"prog": {"span": "work", "calls": 4,
                                  "wire_bytes": 40.0, "flops_total": 100.0,
                                  "bytes_total": 5.0, "cost_coverage": 1.0,
                                  "specializations": []}}}
    costs.attach_attrib(summary, snap)
    at = summary["spans"]["work"]["attrib"]
    assert at["t_flops_s"] == 1.0                # 100 FLOP / 100 FLOP/s
    assert at["t_bytes_s"] == 0.5
    assert at["t_model_s"] == 1.0 and at["bound"] == "flops"
    assert at["roofline_frac"] == 0.5            # 1.0 model / 2.0 measured
    assert at["wire_min_bytes_per_s"] == 20.0
    assert at["flops_per_s_achieved"] == 50.0


def test_attrib_skips_spans_without_programs():
    summary = {"spans": {"lonely": {"count": 1, "total_s": 1.0}}}
    costs.attach_attrib(summary, {"peaks": costs.peaks(), "programs": {}})
    assert "attrib" not in summary["spans"]["lonely"]


# ---------------------------------------------------------------------------
# session integration
# ---------------------------------------------------------------------------
def test_session_costs_and_summary_attrib():
    fn = _toy()
    x = jnp.ones((8, 8))
    fn(x, x)                                    # compile outside the session
    o = obs.enable()
    with obs.span("t.costs.work"):
        obs.observe_program_call("t.costs.toy", fn, (x, x),
                                 span="t.costs.work", wire_bytes=64.0)
        fn(x, x)
    obs.disable()
    s = o.summary()
    prog = s["costs"]["programs"]["t.costs.toy"]
    assert prog["calls"] == 1 and prog["wire_bytes"] == 64.0
    at = s["spans"]["t.costs.work"]["attrib"]
    assert at["roofline_frac"] is not None and at["cost_coverage"] == 1.0
    rendered = report.render(s)
    assert "attrib (roofline)" in rendered and "t.costs.toy" in rendered
    # attribution surfaces as counter tracks for the Chrome trace
    gauge_names = {e["name"] for e in o.memory_events()
                   if e["type"] == "gauge"}
    assert "attrib.t.costs.work.roofline_frac" in gauge_names


def test_costs_false_disables_capture():
    fn = _toy()
    x = jnp.ones((4, 4))
    o = obs.enable(costs=False)
    obs.observe_program_call("t.costs.toy", fn, (x, x))
    obs.disable()
    s = o.summary()
    assert "costs" not in s
    assert o._cost_captures == {}


def test_kernel_dispatch_is_captured(monkeypatch):
    monkeypatch.delenv("REPRO_FORCE_PALLAS", raising=False)
    o = obs.enable()
    ops.encode(jnp.ones((2, 64)), jnp.ones((2, 64)), 4)
    obs.disable()
    snap = o.costs()
    names = [n for n in snap["programs"] if n.startswith("kernels.encode")]
    assert len(names) == 1
    prog = snap["programs"][names[0]]
    spec = prog["specializations"][0]
    assert "static=('bits', 4)" in spec["sig"]
    assert spec["available"] or spec["reason"]   # degrade allowed, crash not


def test_disabled_observe_is_noop():
    assert not obs.enabled()
    obs.observe_program_call("t.costs.toy", _toy(), (jnp.ones(4),))


@pytest.mark.parametrize("bad", [object(), {"weird": object()}])
def test_capture_never_raises_from_odd_args(bad):
    o = obs.enable()
    try:
        o.observe_call("t.costs.odd", lambda x: x, (bad,))
    finally:
        obs.disable()
