"""Quantized-KV-cache decode path: parity with the exact f32 cache."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import decode as decode_lib
from repro.models import model as model_lib


@pytest.mark.parametrize("bits,tol", [(8, 0.06)])
def test_quant_cache_decode_close_to_exact(bits, tol):
    base = configs.get_reduced("yi-6b")
    qcfg = dataclasses.replace(base, kv_quant_bits=bits)
    params = model_lib.init_params(jax.random.key(0), base)
    b, s = 2, 20
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0,
                                base.vocab_size, jnp.int32)

    def run(cfg):
        state = decode_lib.init_decode_state(cfg, b, s + 4)
        step = jax.jit(lambda p, st, t: decode_lib.decode_step(cfg, p, st, t))
        outs = []
        for i in range(s):
            logits, state = step(params, state, tokens[:, i][:, None])
            outs.append(logits)
        return jnp.stack(outs, 1)

    exact = run(base)
    quant = run(qcfg)
    # logits agreement in probability space (softmax dampens the 8-bit noise)
    pe = jax.nn.softmax(exact, -1)
    pq = jax.nn.softmax(quant, -1)
    tv = float(jnp.mean(jnp.sum(jnp.abs(pe - pq), -1) / 2))
    assert tv < tol, tv
    # greedy tokens rarely flip
    agree = float(jnp.mean((jnp.argmax(exact, -1) ==
                            jnp.argmax(quant, -1)).astype(jnp.float32)))
    assert agree > 0.9, agree


def test_quant_cache_state_is_packed():
    cfg = dataclasses.replace(configs.get_reduced("yi-6b"), kv_quant_bits=4)
    state = decode_lib.init_decode_state(cfg, 2, 32)
    assert "k_words" in state.caches and "k" not in state.caches
    f32 = 2 * cfg.num_layers * 2 * 32 * cfg.num_kv_heads * cfg.dh * 4
    packed = (state.caches["k_words"].size
              + state.caches["v_words"].size) * 4
    assert packed == f32 // 8                    # 4-bit → 8× smaller cache
