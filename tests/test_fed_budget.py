"""Budget allocation policies + the compressor registry's budget mapping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.fed import budget
from repro import codecs as registry
from repro.fed.registry import gradcomp_config_for_budget


# ---------------------------------------------------------------------------
# allocation policies
# ---------------------------------------------------------------------------
@given(avg=st.floats(0.2, 7.5), m=st.integers(2, 12),
       seed=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_policies_conserve_total(avg, m, seed):
    total = avg * m
    norms = np.abs(np.random.default_rng(seed).standard_normal(m)) + 0.01
    for policy in budget.POLICIES:
        rates = budget.allocate(policy, total, m, norms=norms,
                                min_rate=0.125, max_rate=8.0)
        assert rates.shape == (m,)
        assert rates.sum() == pytest.approx(total, rel=1e-6)
        assert (rates >= 0.125 - 1e-9).all()
        assert (rates <= 8.0 + 1e-9).all()


def test_uniform_is_flat():
    rates = budget.allocate("uniform", 8.0, 4)
    np.testing.assert_allclose(rates, 2.0)


def test_norm_proportional_orders_with_norms():
    rates = budget.allocate("norm_proportional", 8.0, 4,
                            norms=[1.0, 2.0, 4.0, 8.0])
    assert (np.diff(rates) > 0).all()


def test_waterfill_beats_uniform_distortion():
    """Water-filling minimizes Σ n_i²·4^{−R_i}: strictly better than uniform
    whenever the norms are heterogeneous."""
    norms = np.array([0.1, 1.0, 3.0, 10.0])
    total, m = 8.0, 4
    uni = budget.allocate("uniform", total, m)
    wf = budget.allocate("waterfill", total, m, norms=norms)
    prop = budget.allocate("norm_proportional", total, m, norms=norms)
    d_uni = budget.expected_distortion(norms, uni)
    d_wf = budget.expected_distortion(norms, wf)
    assert d_wf < 0.5 * d_uni
    assert d_wf <= budget.expected_distortion(norms, prop) + 1e-12


def test_waterfill_equalizes_marginals():
    """At the optimum the marginals n_i²·4^{−R_i} agree for every client
    strictly inside the [min, max] bounds."""
    norms = np.array([0.5, 1.0, 2.0, 4.0])
    rates = budget.allocate("waterfill", 10.0, 4, norms=norms)
    marg = norms ** 2 * 4.0 ** (-rates)
    interior = (rates > 0.125 + 1e-6) & (rates < 8.0 - 1e-6)
    assert interior.sum() >= 2
    mi = marg[interior]
    assert mi.max() / mi.min() < 1.1


def test_allocate_validation():
    with pytest.raises(ValueError):
        budget.allocate("bogus", 4.0, 4)
    with pytest.raises(ValueError):
        budget.allocate("uniform", 100.0, 2, max_rate=8.0)   # infeasible
    with pytest.raises(ValueError):
        budget.allocate("waterfill", 4.0, 4)                 # norms missing


def test_waterfill_respects_bounds_off_lattice():
    """min_rate not a multiple of the greedy quantum: rates must still stay
    inside [min, max] with the total conserved (increments are clamped)."""
    rates = budget.allocate("waterfill", 15.9, 2, norms=[10.0, 1.0],
                            min_rate=0.07, max_rate=8.0)
    assert rates.sum() == pytest.approx(15.9, abs=1e-6)
    assert (rates <= 8.0 + 1e-9).all()
    assert (rates >= 0.07 - 1e-9).all()


def test_split_leaf_budgets_conserves_bits():
    tree = {"w": jnp.zeros((64, 8)), "b": jnp.zeros((32,))}
    sizes = np.array([32.0, 512.0])      # flatten order: b, w
    norms = [0.1, 5.0]
    rates = budget.split_leaf_budgets(tree, 2.0, norms=norms)
    total = (np.asarray(rates) * sizes).sum()
    assert total == pytest.approx(2.0 * sizes.sum(), rel=1e-3)
    assert rates[1] > rates[0]           # the high-norm leaf gets more
    with pytest.raises(ValueError):      # rate below the per-leaf floor
        budget.split_leaf_budgets(tree, 0.1, norms=norms, min_rate=0.125)


@given(m=st.integers(2, 12), seed=st.integers(0, 200))
@settings(max_examples=30, deadline=None)
def test_quantize_rates_deficit_branch(m, seed):
    """Raw rates ABOVE the target total exercise the deficit branch
    (units < 0): grid steps must be taken away, never handed out, and the
    result stays a feasible lattice allocation conserving the total."""
    rng = np.random.default_rng(seed)
    grid, lo, hi = 0.25, 0.25, 8.0
    raw = rng.uniform(4.0, hi, m)                 # deliberately rich
    total = float(np.clip(raw.sum() - rng.uniform(1.0, 2.0 * m),
                          lo * m, hi * m))        # poorer target → deficit
    q = budget.quantize_rates(raw, grid, total, lo, hi)
    units = int(round(total / grid)) - int(np.floor(raw / grid + 1e-9).sum())
    if units < 0:                                 # the branch under test
        assert (q <= raw + grid + 1e-9).all()
    assert q.sum() == pytest.approx(total, abs=grid)
    assert all(lo - 1e-9 <= r <= hi + 1e-9 for r in q)
    assert all(abs(r / grid - round(r / grid)) < 1e-9 for r in q)


def test_quantize_rates_deficit_example():
    """Everyone floor-snapped at the cap, target far below: whole steps are
    removed by smallest fractional remainder, bounded at the lattice floor."""
    q = budget.quantize_rates([8.0, 8.0, 8.0], 0.25, 6.0, 0.25, 8.0)
    assert q.sum() == pytest.approx(6.0, abs=0.25)
    assert (q >= 0.25 - 1e-9).all() and (q <= 8.0 + 1e-9).all()


@given(m=st.integers(2, 12), avg=st.floats(0.5, 7.5),
       seed=st.integers(0, 200))
@settings(max_examples=30, deadline=None)
def test_clip_renormalize_conserves_budget(m, seed, avg):
    """_clip_renormalize: for any feasible total and any raw proportional
    split, the output respects the [lo, hi] box and conserves Σ R_i —
    including when clamping pushes mass BOTH ways."""
    rng = np.random.default_rng(seed)
    lo, hi = 0.125, 8.0
    total = avg * m
    raw = rng.uniform(0.0, 3.0, m)
    raw = total * raw / raw.sum()                 # Σ raw == total, may violate box
    out = budget._clip_renormalize(raw.copy(), total, lo, hi)
    assert (out >= lo - 1e-9).all()
    assert (out <= hi + 1e-9).all()
    assert out.sum() == pytest.approx(total, rel=1e-9, abs=1e-9)


def test_clip_renormalize_deficit_redistribution():
    """A rate clamped DOWN at the cap frees budget that must flow to the
    unclamped clients (and vice versa for the floor)."""
    out = budget._clip_renormalize(np.array([10.0, 1.0, 1.0]), 12.0,
                                   0.125, 8.0)
    assert out[0] == pytest.approx(8.0)
    assert out[1:].sum() == pytest.approx(4.0)
    out2 = budget._clip_renormalize(np.array([0.01, 0.01, 7.98]), 8.0,
                                    0.125, 8.0)
    assert (out2[:2] >= 0.125 - 1e-9).all()
    assert out2.sum() == pytest.approx(8.0)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_lists_all_conventions():
    names = registry.available()
    for required in ("identity", "ndsc", "dsc", "sign", "qsgd", "topk",
                     "randk", "ternary", "naive", "dither"):
        assert required in names
    with pytest.raises(ValueError):
        registry.make("nope")


@given(b=st.floats(0.1, 8.0))
@settings(max_examples=25, deadline=None)
def test_budget_maps_to_effective_bits(b):
    """GradCompConfig.effective_bits is the audit unit: the mapped config
    realizes the requested budget exactly."""
    cfg = gradcomp_config_for_budget(b, chunk=64)
    assert cfg.effective_bits == pytest.approx(b)
    assert cfg.exact_keep or cfg.keep_fraction == 1.0


def test_roundtrip_all_backends():
    """Every registered compressor encodes+decodes a tree back to its
    structure with finite error and a positive bit audit."""
    tree = {"w": jax.random.normal(jax.random.key(0), (20, 7)),
            "b": jax.random.normal(jax.random.key(1), (33,))}
    key = jax.random.key(2)
    for name in registry.available():
        codec = registry.make(name, budget=4.0)
        meta = codec.meta(tree)
        wire, bits = codec.compress(key, tree, round_idx=1)
        out = codec.decode(wire, meta)
        assert jax.tree.structure(out) == jax.tree.structure(tree), name
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            assert a.shape == b.shape
            assert bool(jnp.isfinite(b).all())
        assert bits > 0, name
        assert codec.wire_bytes(wire, meta) > 0, name


def test_ndsc_per_leaf_budgets():
    tree = {"w": jnp.ones((64, 4)), "b": jnp.ones((40,))}
    leaf_budgets = [1.0, 4.0]            # flatten order: b, w
    codec = registry.make("ndsc", budget=leaf_budgets, chunk=32)
    meta = codec.meta(tree)
    wire = codec.encode(jax.random.key(0), tree)
    out = codec.decode(wire, meta)
    assert out["w"].shape == (64, 4)
    cfg_b, cfg_w = meta.extra
    assert cfg_b.effective_bits == pytest.approx(1.0)
    assert cfg_w.effective_bits == pytest.approx(4.0)
    with pytest.raises(ValueError):
        registry.make("ndsc", budget=[1.0], chunk=32).meta(tree)


def test_ndsc_realized_equals_analytic_bytes():
    tree = {"w": jax.random.normal(jax.random.key(0), (100,))}
    for b in (0.25, 1.0, 3.0, 8.0):
        codec = registry.make("ndsc", budget=b, chunk=32)
        meta = codec.meta(tree)
        wire = codec.encode(jax.random.key(1), tree, round_idx=2)
        assert codec.wire_bytes(wire, meta) == codec.wire_bits(tree) / 8.0


def test_dsc_sublinear_realized_bytes_sane():
    """Sub-linear dsc payloads carry a Bernoulli keep mask: the realized
    bytes track the analytic audit (same units, binomial fluctuation)."""
    tree = {"w": jax.random.normal(jax.random.key(0), (200,))}
    codec = registry.make("dsc", budget=0.5)
    meta = codec.meta(tree)
    wire = codec.encode(jax.random.key(1), tree)
    real = codec.wire_bytes(wire, meta)
    analytic = codec.wire_bits(tree) / 8.0
    assert 0.4 * analytic < real < 2.5 * analytic


def test_identity_codec_is_exact():
    tree = {"w": jax.random.normal(jax.random.key(0), (11, 3))}
    codec = registry.make("identity")
    meta = codec.meta(tree)
    out = codec.decode(codec.encode(jax.random.key(1), tree), meta)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    assert codec.wire_bits(tree) == 32 * 33
