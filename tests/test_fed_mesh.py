"""Mesh federation backend: lane placement, padding, bit-exactness with the
vmap cohort engine, collective folds, straggler-dropout ledger accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.sharding import lane_pspec, padded_lanes
from repro.fed import (ClientConfig, FedConfig, Federation, ServerConfig,
                       clients as clients_lib, mesh as mesh_lib, server as server_lib)
from repro import codecs as registry
from repro.optimizer import sgd


def _mixed_population(seed=0):
    """m=6: a 3-lane ndsc cohort, a 2-lane sub-linear cohort and an identity
    singleton with a different shard shape — cohort sizes 3 and 2 never
    divide a 2- or 4-device axis, so every mesh round exercises padding."""
    ka, kx = jax.random.split(jax.random.key(seed))
    m, dim, n = 6, 48, 64
    a = jax.random.normal(ka, (m, n, dim)) / jnp.sqrt(n)
    x_true = jax.random.normal(kx, (dim,))
    shards = [{"a": a[i], "b": a[i] @ x_true} for i in range(m)]
    shards[5] = {"a": a[5][:32], "b": (a[5] @ x_true)[:32]}

    def loss_fn(p, batch):
        r = batch["a"] @ p["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r)

    codecs = ([registry.make("ndsc", budget=2.0, chunk=32) for _ in range(3)]
              + [registry.make("ndsc", budget=0.75, chunk=32)
                 for _ in range(2)]
              + [registry.make("identity")])
    return loss_fn, {"x": jnp.zeros(dim)}, shards, codecs


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _run_pair(data_mesh, server_cfg, num_rounds=4, participation=0.8,
              dropout=0.2, ccfg=None, seed=3):
    loss_fn, params, shards, codecs = _mixed_population()
    ccfg = ccfg or ClientConfig(local_steps=2, lr=0.3)
    out = {}
    for backend in ("vmap", "mesh"):
        fed = Federation(loss_fn, params, shards, list(codecs), ccfg,
                         server_cfg, seed=seed, backend=backend,
                         mesh=data_mesh if backend == "mesh" else None)
        hist = fed.run(FedConfig(num_rounds=num_rounds,
                                 participation=participation,
                                 dropout=dropout, seed=9))
        out[backend] = (fed, hist)
    return out


# ---------------------------------------------------------------------------
# padding / placement units
# ---------------------------------------------------------------------------
def test_padded_lanes_contract():
    # divisibility AND ≥2 lanes per device (the batch-1 vmap hazard)
    assert padded_lanes(6, 4) == 8
    assert padded_lanes(8, 4) == 8
    assert padded_lanes(2, 4) == 8      # 2 real lanes still give 2/device
    assert padded_lanes(4, 4) == 8      # 1/device would lower differently
    assert padded_lanes(5, 2) == 6
    assert padded_lanes(2, 2) == 4
    # a 1-device mesh IS the vmap layout: no padding at all
    assert padded_lanes(3, 1) == 3
    assert padded_lanes(1, 1) == 1
    with pytest.raises(ValueError, match="positive"):
        padded_lanes(3, 0)


def test_stack_padded_repeats_first_lane():
    trees = [{"x": jnp.full((3,), float(i))} for i in range(3)]
    stacked = clients_lib.stack_padded(trees, 5)
    got = np.asarray(stacked["x"])
    np.testing.assert_array_equal(got[:3, 0], [0.0, 1.0, 2.0])
    np.testing.assert_array_equal(got[3:, 0], [0.0, 0.0])  # lane-0 copies
    with pytest.raises(ValueError, match="pad"):
        clients_lib.stack_padded(trees, 2)


def test_lane_pspec_covers_data_axes(data_mesh):
    spec = lane_pspec(data_mesh)
    assert spec == jax.sharding.PartitionSpec("data")


# ---------------------------------------------------------------------------
# collective folds vs the single-device reference
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("lanes", [2, 3, 6, 8])
def test_mesh_weighted_mean_sequential_bitwise(data_mesh, lanes):
    """The mesh fold (all_gather + the reference's sequential fold) is
    bit-exact with server._stacked_mean_fn for every lane count, divisible
    by the axis size or not."""
    key = jax.random.key(1)
    stacked = {"w": jax.random.normal(key, (lanes, 13, 5), jnp.float32),
               "b": jax.random.normal(jax.random.fold_in(key, 1),
                                      (lanes, 29), jnp.float32)}
    w = np.random.default_rng(0).uniform(0.5, 2.0, lanes)
    ref = server_lib._stacked_mean_fn("sequential")(
        stacked, jnp.asarray(w, jnp.float32))
    got = mesh_lib.mesh_weighted_mean(stacked, w, data_mesh, "sequential")
    _assert_trees_equal(ref, got)


def test_mesh_weighted_mean_pairwise_tolerance(data_mesh):
    lanes = 6
    key = jax.random.key(2)
    stacked = {"w": jax.random.normal(key, (lanes, 31), jnp.float32)}
    w = np.random.default_rng(1).uniform(0.5, 2.0, lanes)
    ref = server_lib._stacked_mean_fn("sequential")(
        stacked, jnp.asarray(w, jnp.float32))
    got = mesh_lib.mesh_weighted_mean(stacked, w, data_mesh, "pairwise")
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(ref["w"]),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# the full driver: mesh backend ≡ vmap cohort engine, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("agg", ["fedavg", "fedopt", "fedmem"])
def test_mesh_backend_bit_exact_with_vmap(data_mesh, agg):
    """Params, fedopt opt_state, fedmem memory, EF memories, participation
    counters and the byte ledger all match bitwise between the vmap cohort
    engine and the mesh backend, on a mixed population with cohort sizes
    that don't divide the device axis, under partial participation AND
    straggler dropout."""
    cfg = (ServerConfig(aggregator="fedopt", optimizer=sgd(1.0, momentum=0.5))
           if agg == "fedopt" else ServerConfig(aggregator=agg))
    out = _run_pair(data_mesh, cfg)
    fv, hv = out["vmap"]
    fm, hm = out["mesh"]
    assert hv["participants"] == hm["participants"]
    assert hv["stragglers"] == hm["stragglers"]
    assert hv["wire_bytes"] == hm["wire_bytes"]          # to the byte
    assert hv["analytic_bytes"] == hm["analytic_bytes"]
    _assert_trees_equal(fv.server.params, fm.server.params)
    _assert_trees_equal(fv.server.opt_state, fm.server.opt_state)
    _assert_trees_equal(fv.server.memory, fm.server.memory)
    for sv, sm in zip(fv.states, fm.states):
        _assert_trees_equal(sv.ef, sm.ef)
        assert int(sv.rounds_seen) == int(sm.rounds_seen)
        np.testing.assert_array_equal(jax.random.key_data(sv.key),
                                      jax.random.key_data(sm.key))


def test_mesh_backend_pairwise_close_to_vmap(data_mesh):
    out = _run_pair(data_mesh, ServerConfig(sum_mode="pairwise"),
                    num_rounds=3, participation=1.0, dropout=0.0)
    pv = np.asarray(out["vmap"][0].server.params["x"])
    pm = np.asarray(out["mesh"][0].server.params["x"])
    np.testing.assert_allclose(pm, pv, rtol=2e-5)


def test_mesh_backend_compiles_one_program_per_cohort(data_mesh):
    loss_fn, params, shards, codecs = _mixed_population()
    fed = Federation(loss_fn, params, shards, codecs,
                     ClientConfig(local_steps=1, lr=0.2), ServerConfig(),
                     seed=0, backend="mesh", mesh=data_mesh)
    fed.run(FedConfig(num_rounds=2))
    assert len(fed._mesh_fns) == 2        # two multi-client cohorts
    assert len(fed._cohort_fns) == 0      # vmap cohort path never used
    assert len(fed._decode_fns) == 1      # identity singleton → scalar path


def test_mesh_backend_requires_cohorts():
    loss_fn, params, shards, codecs = _mixed_population()
    with pytest.raises(ValueError, match="use_cohorts"):
        Federation(loss_fn, params, shards, codecs, backend="mesh",
                   use_cohorts=False)
    with pytest.raises(ValueError, match="backend"):
        Federation(loss_fn, params, shards, codecs, backend="pmap")


# ---------------------------------------------------------------------------
# sub-linear budgets (R < 1, exact-keep chunk drop) on the mesh backend
# ---------------------------------------------------------------------------
def test_mesh_sublinear_budgets_ledger_and_bitexact(data_mesh):
    """An all-sub-linear population (every codec R < 1 with exact_keep):
    the realized byte ledger equals the analytic audit EVERY round on the
    mesh backend — exact-keep makes the kept-chunk count deterministic, so
    sharding lanes over 2 or 4 devices must not perturb a single mask —
    and the whole run stays bit-exact with the vmap cohort engine."""
    ka, kx = jax.random.split(jax.random.key(7))
    m, dim, n = 5, 96, 24
    a = jax.random.normal(ka, (m, n, dim)) / jnp.sqrt(n)
    x_true = jax.random.normal(kx, (dim,))
    shards = [{"a": a[i], "b": a[i] @ x_true} for i in range(m)]

    def loss_fn(p, batch):
        r = batch["a"] @ p["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r)

    params = {"x": jnp.zeros(dim)}
    codecs_ = ([registry.make("ndsc", budget=0.25, chunk=32)
                for _ in range(3)]
               + [registry.make("ndsc", budget=0.5, chunk=32)
                  for _ in range(2)])
    for c in codecs_:
        assert c.rate < 1.0                      # genuinely sub-linear
    analytic_of = {i: codecs_[i].wire_bits(params) / 8.0 for i in range(m)}

    runs = {}
    for backend in ("vmap", "mesh"):
        fed = Federation(loss_fn, params, shards, list(codecs_),
                         ClientConfig(local_steps=2, lr=0.3), ServerConfig(),
                         seed=5, backend=backend,
                         mesh=data_mesh if backend == "mesh" else None)
        hist = fed.run(FedConfig(num_rounds=4, seed=13))
        assert hist["wire_bytes"] == hist["analytic_bytes"]
        for t, participants in enumerate(hist["participants"]):
            expect = sum(analytic_of[i] for i in participants)
            assert hist["wire_bytes"][t] == expect, (
                f"round {t} ({backend}): sub-linear ledger "
                f"{hist['wire_bytes'][t]} ≠ analytic {expect}")
        runs[backend] = (fed, hist)
    assert runs["vmap"][1]["wire_bytes"] == runs["mesh"][1]["wire_bytes"]
    _assert_trees_equal(runs["vmap"][0].server.params,
                        runs["mesh"][0].server.params)
    for sv, sm in zip(runs["vmap"][0].states, runs["mesh"][0].states):
        _assert_trees_equal(sv.ef, sm.ef)


# ---------------------------------------------------------------------------
# straggler dropout: a dropped lane contributes ZERO wire bytes
# ---------------------------------------------------------------------------
def test_dropout_ledger_matches_analytic_audit_both_backends(data_mesh):
    """With straggler dropout on, the per-round ledger must equal the
    analytic audit summed over the SURVIVING participants only — on both
    backends: dropped lanes (and mesh padding lanes) never transmit, so
    they must never be charged."""
    loss_fn, params, shards, codecs = _mixed_population()
    analytic_of = {i: codecs[i].wire_bits(params) / 8.0
                   for i in range(len(shards))}
    for backend in ("vmap", "mesh"):
        fed = Federation(loss_fn, params, shards, list(codecs),
                         ClientConfig(local_steps=1, lr=0.2), ServerConfig(),
                         seed=1, backend=backend,
                         mesh=data_mesh if backend == "mesh" else None)
        hist = fed.run(FedConfig(num_rounds=6, participation=0.9,
                                 dropout=0.4, seed=11))
        assert any(hist["stragglers"]), "dropout never fired — weak test"
        assert hist["wire_bytes"] == hist["analytic_bytes"]
        for t, participants in enumerate(hist["participants"]):
            expect = sum(analytic_of[i] for i in participants)
            assert hist["wire_bytes"][t] == expect, (
                f"round {t} ({backend}): ledger {hist['wire_bytes'][t]} ≠ "
                f"Σ analytic over survivors {expect} — a dropped or padded "
                f"lane leaked into the ledger")
            for s in hist["stragglers"][t]:
                assert s not in participants
