"""Continuous-batching scheduler: slot refill correctness and throughput."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import decode as decode_lib
from repro.models import model as model_lib
from repro.serve import BatchScheduler, Request


def _isolated_greedy(cfg, params, prompt, n_new, max_seq):
    """Reference: batch-1 prefill + greedy decode."""
    logits, state = decode_lib.prefill(cfg, params, prompt[None, :], max_seq)
    toks = [int(jnp.argmax(logits[0]))]
    cur = jnp.array([[toks[-1]]], jnp.int32)
    for _ in range(n_new - 1):
        logits, state = decode_lib.decode_step(cfg, params, state, cur)
        toks.append(int(jnp.argmax(logits[0])))
        cur = jnp.array([[toks[-1]]], jnp.int32)
    return toks


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "xlstm-350m"])
def test_scheduler_matches_isolated_generation(arch):
    """6 requests through 2 slots must produce EXACTLY the tokens each
    request gets in isolation — the refill must not leak state between
    requests sharing a slot."""
    cfg = configs.get_reduced(arch)
    params = model_lib.init_params(jax.random.key(0), cfg)
    max_seq = 48
    n_new = 6
    prompts = [jax.random.randint(jax.random.key(10 + i), (5 + i,), 0,
                                  cfg.vocab_size, jnp.int32)
               for i in range(6)]

    sched = BatchScheduler(cfg, params, slots=2, max_seq=max_seq)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new_tokens=n_new))
    finished = sched.run_to_completion()
    assert len(finished) == 6
    by_rid = {r.rid: r for r in finished}
    for i, p in enumerate(prompts):
        want = _isolated_greedy(cfg, params, p, n_new, max_seq)
        assert by_rid[i].tokens_out == want, (i, by_rid[i].tokens_out, want)


def test_scheduler_eos_and_budget():
    cfg = configs.get_reduced("yi-6b")
    params = model_lib.init_params(jax.random.key(0), cfg)
    sched = BatchScheduler(cfg, params, slots=3, max_seq=32)
    for i in range(4):
        sched.submit(Request(rid=i,
                             prompt=jnp.arange(4, dtype=jnp.int32) + i,
                             max_new_tokens=3))
    finished = sched.run_to_completion()
    assert len(finished) == 4
    assert all(len(r.tokens_out) <= 3 for r in finished)
    assert all(r.done for r in finished)


def test_scheduler_rejects_encoder():
    cfg = configs.get_reduced("hubert-xlarge")
    params = model_lib.init_params(jax.random.key(0), cfg)
    with pytest.raises(ValueError):
        BatchScheduler(cfg, params, slots=2, max_seq=16)
