"""NDSC-quantized KV cache + fused dequant flash-decode kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import quantdecode as qd
from repro.kernels import ref
from repro.models import kvquant
from repro.models import layers as L


def _setup(b=2, c=64, kh=2, g=4, dh=64, bits=8, seed=0):
    key = jax.random.key(seed)
    ks_ = jax.random.split(key, 4)
    q = jax.random.normal(ks_[0], (b, 1, kh * g, dh))
    k = jax.random.normal(ks_[1], (b, c, kh, dh))
    v = jax.random.normal(ks_[2], (b, c, kh, dh))
    return q, k, v


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("dh,block_c", [(64, 16), (128, 32)])
def test_kernel_matches_ref(bits, dh, block_c):
    b, c, kh, g = 2, 64, 2, 2
    key = jax.random.key(1)
    q = jax.random.normal(key, (b, kh, g, dh))
    kw = jax.random.randint(jax.random.fold_in(key, 1),
                            (b, c, kh, dh * bits // 32), -2**31, 2**31 - 1,
                            jnp.int32)
    ks = jax.random.uniform(jax.random.fold_in(key, 2), (b, c, kh)) + 0.1
    vw = jax.random.randint(jax.random.fold_in(key, 3),
                            (b, c, kh, dh * bits // 32), -2**31, 2**31 - 1,
                            jnp.int32)
    vs = jax.random.uniform(jax.random.fold_in(key, 4), (b, c, kh)) + 0.1
    kv_len = jnp.array([c, c // 2], jnp.int32)
    got = qd.quant_decode_attention_pallas(q, kw, ks, vw, vs, kv_len,
                                           bits=bits, block_c=block_c,
                                           interpret=True)
    want = ref.quant_decode_attention(q, kw, ks, vw, vs, kv_len, bits=bits)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("bits,tol", [(8, 0.03), (4, 0.15)])
def test_quantized_cache_approximates_exact_attention(bits, tol):
    """End-to-end: encode K/V into the packed rotated cache, decode-attend,
    compare against exact f32 decode attention."""
    b, c, kh, g, dh = 2, 64, 2, 4, 64
    q, k, v = _setup(b, c, kh, g, dh)
    signs = kvquant.head_signs(0, 3, kh, dh)

    kw, ks = kvquant.encode_entry(k, signs, bits)
    vw, vs = kvquant.encode_entry(v, signs, bits)
    kv_len = jnp.full((b,), c, jnp.int32)

    got = kvquant.quant_decode_attention(
        q, (kw, ks, vw, vs), kv_len, signs, bits)
    want = L.decode_attention(q, k, v, kv_len=kv_len)
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < tol, rel


def test_rotation_preserves_inner_products():
    """⟨q, k⟩ = ⟨Dq·H, Dk·H⟩ — attention in the rotated basis is exact."""
    kh, dh = 2, 64
    signs = kvquant.head_signs(0, 0, kh, dh)
    q = jax.random.normal(jax.random.key(0), (kh, dh))
    k = jax.random.normal(jax.random.key(1), (kh, dh))
    qr = kvquant.rotate(q, signs)
    kr = kvquant.rotate(k, signs)
    np.testing.assert_allclose(jnp.sum(q * k, -1), jnp.sum(qr * kr, -1),
                               rtol=1e-4)


def test_rotated_scale_flatter_for_outliers():
    """The democratic effect: rotation shrinks ‖·‖∞ of outlier-heavy
    vectors, so the per-vector quantization scale is tighter."""
    kh, dh = 1, 128
    signs = kvquant.head_signs(0, 0, kh, dh)
    x = jnp.zeros((kh, dh)).at[0, 7].set(10.0).at[0, 80].set(-6.0) \
        + 0.1 * jax.random.normal(jax.random.key(2), (kh, dh))
    xr = kvquant.rotate(x, signs)
    assert float(jnp.max(jnp.abs(xr))) < 0.5 * float(jnp.max(jnp.abs(x)))


def test_cache_memory_footprint():
    cache = kvquant.init_cache(num_layers=4, batch=2, cache_len=128,
                               num_kv=2, dh=64, bits=4)
    f32_bytes = 2 * 4 * 2 * 128 * 2 * 64 * 4       # k+v f32
    packed = sum(x.size * 4 for x in (cache.k_words, cache.v_words))
    scales = sum(x.size * 4 for x in (cache.k_scale, cache.v_scale))
    assert packed == f32_bytes // 8                 # 4-bit = 8× smaller
    assert scales == f32_bytes // 64                # one f32 per dh=64 vector
