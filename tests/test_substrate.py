"""Substrate: optimizer, data pipeline, checkpointing, sharding rules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import TokenStream, synthetic_regression, synthetic_two_class
from repro.dist.sharding import (data_axes_for, param_spec, param_specs,
                                 shardable)
from repro.optimizer import (adamw, clip_by_global_norm, cosine_schedule,
                             global_norm, sgd, warmup_cosine)
from repro.optimizer.optim import apply_updates


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------
def _rosenbrock_ish(params):
    return jnp.sum((params["x"] - 3.0) ** 2) + 2 * jnp.sum(
        (params["y"] + 1.0) ** 2)


@pytest.mark.parametrize("make_opt", [
    lambda: adamw(0.1), lambda: sgd(0.1, momentum=0.9),
    lambda: sgd(0.2), lambda: sgd(0.1, momentum=0.9, nesterov=True),
])
def test_optimizers_converge_quadratic(make_opt):
    opt = make_opt()
    params = {"x": jnp.zeros((4,)), "y": jnp.zeros((3,))}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(_rosenbrock_ish)(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert float(_rosenbrock_ish(params)) < 1e-3


def test_adamw_weight_decay_shrinks():
    opt = adamw(0.1, weight_decay=1.0)
    params = {"w": jnp.ones((8,)) * 5}
    state = opt.init(params)
    zero_grads = {"w": jnp.zeros((8,))}
    for _ in range(50):
        updates, state = opt.update(zero_grads, state, params)
        params = apply_updates(params, updates)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1.0


def test_schedules():
    cos = cosine_schedule(1.0, 100)
    assert float(cos(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(cos(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)
    wc = warmup_cosine(1.0, 10, 110)
    assert float(wc(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(wc(jnp.asarray(10))) == pytest.approx(1.0)


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((4,)) * 10}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


# ---------------------------------------------------------------------------
# Data
# ---------------------------------------------------------------------------
def test_token_stream_deterministic_and_shaped():
    s = TokenStream(vocab_size=100, seq_len=16, batch_size=4, seed=3)
    b0 = s.batch(0)
    b0_again = s.batch(0)
    b1 = s.batch(1)
    np.testing.assert_array_equal(b0["tokens"], b0_again["tokens"])
    assert not np.array_equal(np.asarray(b0["tokens"]),
                              np.asarray(b1["tokens"]))
    assert b0["tokens"].shape == (4, 17)
    assert int(b0["tokens"].max()) < 100


def test_token_stream_learnable_structure():
    """Markov stream: bigram MI must be far above the iid baseline."""
    s = TokenStream(vocab_size=32, seq_len=512, batch_size=8, seed=0)
    toks = np.asarray(s.batch(0)["tokens"])
    pairs = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            pairs[(a, b)] = pairs.get((a, b), 0) + 1
    # concentration: top-32 bigrams should cover far more than 32/1024 mass
    top = sorted(pairs.values(), reverse=True)[:32]
    assert sum(top) / (toks.size - toks.shape[0]) > 0.15


def test_regression_generators():
    a, b, x_star = synthetic_regression(jax.random.key(0), 50, 10)
    assert a.shape == (50, 10) and b.shape == (50,)
    np.testing.assert_allclose(a @ x_star, b, rtol=1e-5)
    x, y = synthetic_two_class(jax.random.key(1), 20, 5)
    assert x.shape == (40, 5)
    assert set(np.unique(np.asarray(y))) == {-1.0, 1.0}


# ---------------------------------------------------------------------------
# Checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "step": jnp.asarray(7, jnp.int32)}
    save_checkpoint(str(tmp_path), 7, tree)
    save_checkpoint(str(tmp_path), 12, tree)
    assert latest_step(str(tmp_path)) == 12
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 12
    np.testing.assert_array_equal(restored["params"]["w"],
                                  tree["params"]["w"])


def test_checkpoint_structure_mismatch(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"b": jnp.zeros(3)})


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------
def test_param_spec_rules():
    P = jax.sharding.PartitionSpec
    assert param_spec(".embed", (1024, 64), 16, False) == P("model", None)
    assert param_spec(".head", (64, 1024), 16, False) == P(None, "model")
    assert param_spec(".blocks.wq", (2, 64, 1600), 16, True) \
        == P(None, None, "model")
    # 25 heads × 64 dh = 1600 divides 16 even though 25 doesn't
    assert param_spec(".blocks.e_gate", (2, 128, 64, 256), 16, True) \
        == P(None, "model", None, None)       # expert-parallel (128 % 16 = 0)
    assert param_spec(".blocks.e_gate", (2, 8, 64, 256), 16, True) \
        == P(None, None, None, "model")       # d_ff fallback (8 % 16 ≠ 0)
    assert param_spec(".blocks.attn_norm", (2, 64), 16, True) == P(None, None)


def test_param_specs_all_archs_valid():
    """Every spec must be dimension-consistent with its leaf (divisibility)."""
    from repro import configs
    from repro.models import model as model_lib
    for arch in configs.ARCH_NAMES:
        cfg = configs.get(arch)
        shapes = jax.eval_shape(
            lambda: model_lib.init_params(jax.random.key(0), cfg))
        specs = param_specs(shapes, 16)
        for leaf, spec in zip(jax.tree.leaves(shapes),
                              jax.tree.leaves(
                                  specs, is_leaf=lambda x: isinstance(
                                      x, jax.sharding.PartitionSpec))):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax == "model":
                    assert dim % 16 == 0, (arch, leaf.shape, spec)


def test_data_axes_for():
    import numpy as np
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    assert data_axes_for(8, mesh) == ("data",)
    assert shardable(32, 16) and not shardable(33, 16)
