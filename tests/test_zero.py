"""ZeRO-1 + all-to-all compressed reduce-scatter (repro/dist/zero.py).

The multi-worker equivalence test runs in a subprocess because it needs
XLA_FLAGS=--xla_force_host_platform_device_count set before jax init.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import zero as zero_lib
from repro.dist.gradcomp import GradCompConfig


def test_leaf_layout():
    assert zero_lib.leaf_layout((100,), 64, 4) == (4, 1)      # 2 chunks → pad 4
    assert zero_lib.leaf_layout((64, 64), 64, 4) == (64, 16)
    assert zero_lib.leaf_layout((1,), 64, 8) == (8, 1)


def test_owned_reconstruction_roundtrip():
    """pad→chunk→slice-per-owner→gather reproduces the leaf exactly."""
    cfg = GradCompConfig(bits=4, chunk=64)
    x = jnp.arange(1000, dtype=jnp.float32).reshape(25, 40)
    m = 4
    padded, rows_per = zero_lib.leaf_layout(x.shape, cfg.chunk, m)
    flat = jnp.pad(x.reshape(-1), (0, padded * cfg.chunk - x.size))
    owned = flat.reshape(m, rows_per, cfg.chunk)
    recon = owned.reshape(-1)[: x.size].reshape(x.shape)
    np.testing.assert_array_equal(recon, x)


@pytest.mark.slow
def test_multiworker_equivalence_subprocess():
    """m=4 data shards: ZeRO-1 all-to-all schedule must produce EXACTLY the
    same updated parameters as the paper-faithful all-gather consensus —
    including the sub-linear keep_fraction < 1 regime, where the chunk
    keep-mask is drawn at the pre-pad chunk count in both paths."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, %r)
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.data import batch_for_shape
        from repro.dist import step as step_lib, zero as zero_lib
        from repro.dist.gradcomp import GradCompConfig
        from repro.optimizer import sgd

        mesh = jax.sharding.Mesh(
            np.array(jax.devices()).reshape(4, 2), ("data", "model"))
        cfg = configs.get_reduced("phi3-mini-3.8b")
        opt = sgd(1.0)
        batch = batch_for_shape(cfg, 8, 32)

        def run_pair(tag, **gc_kwargs):
            gc_z = GradCompConfig(strategy="alltoall_zero1", **gc_kwargs)
            zstep = step_lib.make_zero_train_step(cfg, opt, gc_z, mesh)
            state = step_lib.init_zero_state(cfg, opt, gc_z, mesh)
            o1, _, _, mz = zstep(*state, batch)
            gc_a = GradCompConfig(strategy="allgather_packed", **gc_kwargs)
            tstep = step_lib.make_train_step(cfg, opt, gc_a, mesh)
            st2 = step_lib.init_train_state(cfg, opt, gc_a, mesh)
            p1, _, _, mr = tstep(*st2, batch)
            assert abs(float(mz["loss"]) - float(mr["loss"])) < 1e-6
            pmeta = zero_lib.params_meta(jax.eval_shape(lambda: p1), gc_z, 4)
            treedef, infos = pmeta
            flat_owned = treedef.flatten_up_to(
                jax.tree.map(lambda x: np.asarray(x), o1))
            recon = [x.reshape(-1)[:i[0]].reshape(i[1])
                     for x, i in zip(flat_owned, infos)]
            flat_ref = [np.asarray(x) for x in jax.tree.leaves(p1)]
            err = max(float(np.max(np.abs(a - b)))
                      for a, b in zip(recon, flat_ref))
            assert err < 1e-5, (tag, err)
            print("EXACT", tag, err)

        run_pair("dense", bits=8, chunk=256)
        run_pair("sublinear", bits=8, chunk=256, keep_fraction=0.5)
        run_pair("sublinear_exact", bits=8, chunk=256, keep_fraction=0.5,
                 exact_keep=True)
    """) % os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert out.stdout.count("EXACT") == 3
