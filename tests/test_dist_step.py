"""Distributed train/serve step factories on the host mesh (1 device)."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.data import batch_for_shape
from repro.dist import step as step_lib
from repro.dist.gradcomp import GradCompConfig
from repro.optimizer import adamw, sgd

# the `mesh` fixture (shared 1×1 host mesh) comes from tests/conftest.py


@pytest.mark.parametrize("strategy", ["psum", "psum_decoded",
                                      "allgather_packed"])
def test_train_step_runs(mesh, strategy):
    cfg = configs.get_reduced("llama3.2-3b")
    gc = GradCompConfig(bits=4, chunk=256, strategy=strategy)
    opt = sgd(1e-2, momentum=0.9)
    tstep = step_lib.make_train_step(cfg, opt, gc, mesh)
    params, opt_state, ef = step_lib.init_train_state(cfg, opt, gc, mesh)
    batch = batch_for_shape(cfg, 4, 32)
    params, opt_state, ef, metrics = tstep(params, opt_state, ef, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))


def test_compressed_training_loss_decreases(mesh):
    """20 steps of compressed-consensus training must fit a fixed batch
    (end-to-end integration: codec → consensus → EF → AdamW)."""
    cfg = configs.get_reduced("llama3.2-3b")
    gc = GradCompConfig(bits=4, chunk=256, strategy="allgather_packed")
    opt = adamw(3e-3)
    tstep = step_lib.make_train_step(cfg, opt, gc, mesh, clip_norm=1.0)
    params, opt_state, ef = step_lib.init_train_state(cfg, opt, gc, mesh)
    batch = batch_for_shape(cfg, 8, 32, 0)
    losses = []
    for _ in range(20):
        params, opt_state, ef, metrics = tstep(params, opt_state, ef, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 2.0


def test_compressed_matches_psum_direction(mesh):
    """With 8 bits the compressed consensus must stay close to the exact
    all-reduce direction (single step, same init)."""
    cfg = configs.get_reduced("phi3-mini-3.8b")
    opt = sgd(1.0)  # updates = −grads
    batch = batch_for_shape(cfg, 4, 32)

    results = {}
    for strategy in ("psum", "allgather_packed"):
        gc = GradCompConfig(bits=8, chunk=256, strategy=strategy,
                            error_feedback=False)
        tstep = step_lib.make_train_step(cfg, opt, gc, mesh)
        params, opt_state, ef = step_lib.init_train_state(cfg, opt, gc, mesh)
        p1, _, _, _ = tstep(params, opt_state, ef, batch)
        results[strategy] = p1

    flat_a = jnp.concatenate([x.ravel() for x in
                              jax.tree.leaves(results["psum"])])
    flat_b = jnp.concatenate([x.ravel() for x in
                              jax.tree.leaves(results["allgather_packed"])])
    cos = float(jnp.dot(flat_a, flat_b)
                / (jnp.linalg.norm(flat_a) * jnp.linalg.norm(flat_b)))
    assert cos > 0.999


def test_sublinear_budget_training(mesh):
    """R_eff = 0.5 bits/dim (1-bit × keep 50% of chunks): training still
    fits a fixed batch through error feedback (paper's R < 1 regime at
    model scale)."""
    cfg = configs.get_reduced("llama3.2-3b")
    gc = GradCompConfig(bits=1, chunk=256, keep_fraction=0.5)
    assert gc.effective_bits == 0.5
    opt = adamw(3e-3)
    tstep = step_lib.make_train_step(cfg, opt, gc, mesh, clip_norm=1.0)
    params, opt_state, ef = step_lib.init_train_state(cfg, opt, gc, mesh)
    batch = batch_for_shape(cfg, 8, 32, 0)
    losses = []
    for _ in range(20):
        params, opt_state, ef, metrics = tstep(params, opt_state, ef, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 1.5


def test_serve_step_runs(mesh):
    cfg = configs.get_reduced("mixtral-8x22b")
    from repro.models import decode as decode_lib
    from repro.models import model as model_lib
    params = model_lib.init_params(jax.random.key(0), cfg)
    state = decode_lib.init_decode_state(cfg, 2, 64)
    sstep = step_lib.make_serve_step(cfg, mesh)
    logits, state = sstep(params, state, jnp.zeros((2, 1), jnp.int32))
    assert logits.shape == (2, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_train_state_specs_match_init(mesh):
    cfg = configs.get_reduced("yi-6b")
    gc = GradCompConfig(bits=4, chunk=256)
    opt = adamw(1e-3)
    p_spec, o_spec, e_spec = step_lib.train_state_specs(cfg, opt, gc, mesh)
    p, o, e = step_lib.init_train_state(cfg, opt, gc, mesh)
    for spec_leaf, real_leaf in zip(jax.tree.leaves(p_spec),
                                    jax.tree.leaves(p)):
        assert spec_leaf.shape == real_leaf.shape
        assert spec_leaf.dtype == real_leaf.dtype
    assert jax.tree.structure(o_spec) == jax.tree.structure(o)
    for spec_leaf, real_leaf in zip(jax.tree.leaves(e_spec),
                                    jax.tree.leaves(e)):
        assert spec_leaf.shape == real_leaf.shape
