"""Democratic & near-democratic embeddings: Lemmas 1–3 of the paper."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import embeddings as E
from repro.core import frames as F


def _heavy_tailed(key, n):
    return jax.random.normal(key, (n,)) ** 3          # paper §5 protocol


@pytest.mark.parametrize("kind,n,N", [
    ("haar", 64, 64), ("haar", 64, 96), ("hadamard", 64, 64),
    ("hadamard", 100, 128),
])
def test_nde_exact_representation(kind, n, N):
    """y = S x_nd exactly (Parseval closed form, Eq. (8))."""
    f = F.make_frame(kind, jax.random.key(0), n, N)
    y = _heavy_tailed(jax.random.key(1), n)
    x = E.near_democratic(f, y)
    np.testing.assert_allclose(E.inverse(f, x), y, atol=1e-4)


@pytest.mark.parametrize("kind", ["haar", "hadamard"])
def test_nde_linf_bound(kind):
    """Lemmas 2/3: ‖x_nd‖∞ ≤ 2√(λ log(2N)/N)·‖y‖₂ w.p. ≥ 1 − 1/2N."""
    n = N = 256
    failures = 0
    trials = 40
    for t in range(trials):
        f = F.make_frame(kind, jax.random.key(t), n, N)
        y = _heavy_tailed(jax.random.key(1000 + t), n)
        x = E.near_democratic(f, y)
        bound = 2 * math.sqrt(math.log(2 * N) / N) * float(jnp.linalg.norm(y))
        if float(jnp.max(jnp.abs(x))) > bound:
            failures += 1
    assert failures <= 2, f"ℓ∞ bound violated in {failures}/{trials} trials"


def test_democratic_exact_and_flat():
    """LV iterative truncation: y = Sx and ‖x‖∞ ≤ K_u‖y‖₂/√N (Lemma 1)."""
    n, N = 64, 128
    f = F.haar_frame(jax.random.key(0), n, N)
    y = _heavy_tailed(jax.random.key(1), n)
    x = E.democratic(f, y)
    np.testing.assert_allclose(E.inverse(f, x), y, atol=1e-4)
    ku = E.kashin_constant_upper()
    bound = ku / math.sqrt(N) * float(jnp.linalg.norm(y))
    assert float(jnp.max(jnp.abs(x))) <= bound * 1.05


def test_democratic_flatter_than_nde():
    """DE should have ≤ ℓ∞ than NDE (it minimizes ℓ∞; NDE minimizes ℓ2)."""
    n, N = 64, 128
    f = F.haar_frame(jax.random.key(0), n, N)
    y = _heavy_tailed(jax.random.key(1), n)
    x_d = E.democratic(f, y)
    x_nd = E.near_democratic(f, y)
    assert float(jnp.max(jnp.abs(x_d))) <= float(jnp.max(jnp.abs(x_nd))) + 1e-5


def test_embedding_spec_dispatch():
    f = F.haar_frame(jax.random.key(0), 16, 32)
    y = jax.random.normal(jax.random.key(1), (16,))
    for kind in ("near_democratic", "democratic"):
        x = E.EmbeddingSpec(kind=kind).embed(f, y)
        np.testing.assert_allclose(E.inverse(f, x), y, atol=1e-4)
    with pytest.raises(ValueError):
        E.EmbeddingSpec(kind="nope").embed(f, y)
