"""Cohort engine: partition properties, vmapped/sequential bit-exactness,
adaptive budget re-allocation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.fed import (AdaptiveConfig, ClientConfig, FedConfig, Federation,
                       NormEMA, ServerConfig, budget, clients as clients_lib,
                       rounds as rounds_lib)
from repro import codecs as registry


# ---------------------------------------------------------------------------
# partitioner properties
# ---------------------------------------------------------------------------
@given(m=st.integers(1, 40), n_specs=st.integers(1, 5),
       seed=st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_partition_is_exact_disjoint_cover(m, n_specs, seed):
    """Any participant set splits into cohorts whose union is exactly the
    input (no loss, no duplication) and whose members share a key; None keys
    always land in singleton cohorts."""
    rng = np.random.default_rng(seed)
    keys = [None] + [("spec", i) for i in range(n_specs)]
    assignment = [(i, keys[rng.integers(len(keys))]) for i in range(m)]
    parts = rounds_lib.partition_cohorts(assignment)
    all_members = [i for _, members in parts for i in members]
    assert sorted(all_members) == list(range(m))          # exact cover
    assert len(all_members) == len(set(all_members))      # disjoint
    key_of = dict(assignment)
    for key, members in parts:
        if key is None:
            assert len(members) == 1
            assert key_of[members[0]] is None
        else:
            assert all(key_of[i] == key for i in members)


def test_partition_preserves_order():
    parts = rounds_lib.partition_cohorts(
        [(3, "a"), (1, "b"), (4, "a"), (0, None), (2, "b")])
    assert parts == [("a", [3, 4]), ("b", [1, 2]), (None, [0])]


def test_cohort_key_requires_registry_spec():
    """Codecs built outside registry.make carry no spec → never cohorted."""
    params = {"x": jnp.zeros(8)}
    data = {"g": jnp.zeros((2, 8))}
    cfg = ClientConfig()
    made = registry.make("identity")
    assert rounds_lib.cohort_key(made, cfg, data) is not None
    import dataclasses
    bare = dataclasses.replace(made, spec=None)
    assert rounds_lib.cohort_key(bare, cfg, data) is None


def test_equal_make_calls_share_cohort_key():
    """registry.make with equal args gives DISTINCT objects with EQUAL specs
    — the property the cohort partitioner builds on."""
    a = registry.make("ndsc", budget=2.0, chunk=32)
    b = registry.make("ndsc", budget=2.0, chunk=32)
    c = registry.make("ndsc", budget=2.0, chunk=64)
    assert a is not b and a.spec == b.spec
    assert a.spec != c.spec
    data = {"g": jnp.zeros((4, 8))}
    cfg = ClientConfig()
    assert (rounds_lib.cohort_key(a, cfg, data)
            == rounds_lib.cohort_key(b, cfg, data))
    # different data SHAPES must split the cohort (stacking needs rectangles)
    other = {"g": jnp.zeros((5, 8))}
    assert (rounds_lib.cohort_key(a, cfg, data)
            != rounds_lib.cohort_key(a, cfg, other))


# ---------------------------------------------------------------------------
# vmapped driver ≡ sequential driver, bit for bit
# ---------------------------------------------------------------------------
def _mixed_population(seed=0):
    """m=6: three ndsc R=2 clients (distinct codec objects, equal specs),
    two sub-linear ndsc R=0.75 (masked payloads), one identity; one client
    has a different shard shape."""
    ka, kx = jax.random.split(jax.random.key(seed))
    m, dim, n = 6, 48, 64
    a = jax.random.normal(ka, (m, n, dim)) / jnp.sqrt(n)
    x_true = jax.random.normal(kx, (dim,))
    shards = [{"a": a[i], "b": a[i] @ x_true} for i in range(m)]
    shards[5] = {"a": a[5][:32], "b": (a[5] @ x_true)[:32]}

    def loss_fn(p, batch):
        r = batch["a"] @ p["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r)

    codecs = ([registry.make("ndsc", budget=2.0, chunk=32) for _ in range(3)]
              + [registry.make("ndsc", budget=0.75, chunk=32)
                 for _ in range(2)]
              + [registry.make("identity")])
    return loss_fn, {"x": jnp.zeros(dim)}, shards, codecs


def test_cohort_driver_bit_exact_with_sequential():
    """Decoded global delta (≡ server params trajectory), per-round ledger
    bytes, EF memories and PRNG-driven participation all match bit-for-bit
    between the vmapped cohort driver and the scalar sequential one, on a
    mixed homogeneous/heterogeneous population with partial participation."""
    loss_fn, params, shards, codecs = _mixed_population()
    ccfg = ClientConfig(local_steps=2, lr=0.3)
    out = {}
    for use_cohorts in (True, False):
        fed = Federation(loss_fn, params, shards, list(codecs), ccfg,
                         ServerConfig(), seed=3, use_cohorts=use_cohorts)
        hist = fed.run(FedConfig(num_rounds=6, participation=0.8, dropout=0.2,
                                 seed=9))
        out[use_cohorts] = (fed, hist)
    fed_c, hist_c = out[True]
    fed_s, hist_s = out[False]
    assert hist_c["participants"] == hist_s["participants"]
    assert hist_c["wire_bytes"] == hist_s["wire_bytes"]        # to the byte
    assert hist_c["analytic_bytes"] == hist_s["analytic_bytes"]
    assert hist_c["wire_bytes"] == hist_c["analytic_bytes"]    # audit holds
    np.testing.assert_array_equal(np.asarray(fed_c.server.params["x"]),
                                  np.asarray(fed_s.server.params["x"]))
    for sc, ss in zip(fed_c.states, fed_s.states):
        np.testing.assert_array_equal(np.asarray(sc.ef["x"]),
                                      np.asarray(ss.ef["x"]))
        assert int(sc.rounds_seen) == int(ss.rounds_seen)


def test_cohort_driver_compiles_once_per_cohort():
    """3 equal-spec clients + 2 equal-spec clients + 1 singleton → exactly
    2 cohort programs and 1 scalar program are built."""
    loss_fn, params, shards, codecs = _mixed_population()
    fed = Federation(loss_fn, params, shards, codecs,
                     ClientConfig(local_steps=1, lr=0.2), ServerConfig(),
                     seed=0)
    fed.run(FedConfig(num_rounds=2))
    assert len(fed._cohort_fns) == 2
    assert len(fed._cohort_decode_fns) == 2
    # scalar fns exist for all three distinct (spec, cfg) pairs (built in
    # __init__ as the singleton fallback), but cohorts used the vmapped path
    assert len(fed._round_fns) == 3


def test_stack_unstack_roundtrip():
    states = [clients_lib.init_client_state(
        {"x": jnp.zeros(5)}, jax.random.key(i)) for i in range(3)]
    stacked = clients_lib.stack_trees(states)
    back = clients_lib.unstack_tree(stacked, 3)
    for orig, rt in zip(states, back):
        np.testing.assert_array_equal(np.asarray(orig.ef["x"]),
                                      np.asarray(rt.ef["x"]))
        assert jax.random.key_data(orig.key).tolist() == \
            jax.random.key_data(rt.key).tolist()


# ---------------------------------------------------------------------------
# adaptive budget re-allocation
# ---------------------------------------------------------------------------
def _adaptive_fed(realloc_every=2, hysteresis=0.25, seed=0, rounds=None):
    ka, kx = jax.random.split(jax.random.key(seed))
    m, dim, n = 4, 48, 32
    a = jax.random.normal(ka, (m, n, dim)) / jnp.sqrt(n)
    x_true = jax.random.normal(kx, (dim,))
    scales = np.logspace(-1, 1, m)
    shards = [{"a": scales[i] * a[i], "b": scales[i] * (a[i] @ x_true)}
              for i in range(m)]

    def loss_fn(p, batch):
        r = batch["a"] @ p["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r)

    factory = lambda r: registry.make("ndsc", budget=float(r), chunk=32)
    acfg = AdaptiveConfig(total_rate=8.0, realloc_every=realloc_every,
                          hysteresis=hysteresis, grid=0.25, min_rate=0.25)
    fed = Federation(loss_fn, {"x": jnp.zeros(dim)}, shards,
                     [factory(2.0) for _ in range(m)],
                     ClientConfig(local_steps=1, lr=0.3), ServerConfig(),
                     seed=seed, adaptive=acfg, codec_factory=factory)
    return fed, acfg


def test_adaptive_reallocates_and_keeps_ledger_exact():
    fed, acfg = _adaptive_fed(realloc_every=2)
    hist = fed.run(FedConfig(num_rounds=8, seed=1))
    assert any(hist["realloc"]), "allocator never adapted"
    # re-allocation only at realloc_every boundaries, never at round 0
    for t, flag in enumerate(hist["realloc"]):
        if flag:
            assert t > 0 and t % acfg.realloc_every == 0
    # total budget conserved on the lattice, rates within bounds
    for rates in hist["rates"]:
        assert rates is not None
        assert sum(rates) == pytest.approx(acfg.total_rate, abs=acfg.grid)
        assert all(acfg.min_rate - 1e-9 <= r <= acfg.max_rate + 1e-9
                   for r in rates)
        assert all(abs(r / acfg.grid - round(r / acfg.grid)) < 1e-9
                   for r in rates)
    # the ledger stays byte-exact across codec rebuilds
    assert hist["wire_bytes"] == hist["analytic_bytes"]


def test_adaptive_requires_factory_and_rates():
    data = {"a": jnp.zeros((4, 8)), "b": jnp.zeros(4)}
    loss = lambda p, b: jnp.sum(p["x"])
    acfg = AdaptiveConfig(total_rate=4.0)
    with pytest.raises(ValueError, match="codec_factory"):
        Federation(loss, {"x": jnp.zeros(8)}, [data],
                   registry.make("ndsc", budget=2.0, chunk=32),
                   adaptive=acfg)
    # baseline codecs without a .rate can't seed the allocation state
    import dataclasses
    bare = dataclasses.replace(registry.make("ndsc", budget=2.0, chunk=32),
                               rate=None)
    with pytest.raises(ValueError, match="rate"):
        Federation(loss, {"x": jnp.zeros(8)}, [data], bare,
                   adaptive=acfg,
                   codec_factory=lambda r: registry.make("ndsc", budget=r))


def test_hysteresis_suppresses_churn():
    """With an enormous hysteresis the allocation never moves (and no new
    programs compile); with zero hysteresis it adapts."""
    frozen, _ = _adaptive_fed(realloc_every=2, hysteresis=100.0)
    hist = frozen.run(FedConfig(num_rounds=6, seed=1))
    assert not any(hist["realloc"])
    assert all(r == hist["rates"][0] for r in hist["rates"])
    moving, _ = _adaptive_fed(realloc_every=2, hysteresis=0.0)
    hist2 = moving.run(FedConfig(num_rounds=6, seed=1))
    assert any(hist2["realloc"])


def test_ema_tracks_and_fills_unseen():
    ema = NormEMA(3, beta=0.5)
    assert np.allclose(ema.snapshot(), 1.0)      # no observations yet
    ema.update([0], [4.0])
    snap = ema.snapshot()
    assert snap[0] == 4.0                        # first obs initializes
    assert snap[1] == snap[2] == 4.0             # unseen filled with mean
    ema.update([0], [0.0])
    assert ema.snapshot()[0] == pytest.approx(2.0)   # 0.5·4 + 0.5·0


@given(avg=st.floats(0.5, 7.5), m=st.integers(2, 10),
       seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_quantize_rates_lattice_and_conservation(avg, m, seed):
    rng = np.random.default_rng(seed)
    total = avg * m
    raw = budget.allocate("waterfill", total, m,
                          norms=rng.uniform(0.1, 10.0, m), min_rate=0.25)
    grid = 0.25
    q = budget.quantize_rates(raw, grid, total, 0.25, 8.0)
    assert q.sum() == pytest.approx(total, abs=grid)
    assert all(0.25 - 1e-9 <= r <= 8.0 + 1e-9 for r in q)
    assert all(abs(r / grid - round(r / grid)) < 1e-9 for r in q)


def test_delta_norms_matches_tree_norm():
    from repro.fed import delta_norms
    trees = [{"a": jnp.array([3.0, 4.0]), "b": jnp.zeros(2)},
             {"a": jnp.array([0.0, 0.0]), "b": jnp.array([5.0, 12.0])}]
    assert delta_norms(trees) == pytest.approx([5.0, 13.0])
