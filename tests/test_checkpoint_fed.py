"""Federation checkpointing: a resumed run is bit-exact with an
uninterrupted one — params, EF, fedopt opt_state, NormEMA, round counter."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (federation_state, restore_federation,
                              save_federation)
from repro.fed import (AdaptiveConfig, ClientConfig, FedConfig, Federation,
                       ServerConfig)
from repro import codecs as registry
from repro.optimizer import sgd


def _problem(seed=2):
    ka, kx = jax.random.split(jax.random.key(seed))
    m, dim, n = 4, 48, 32
    a = jax.random.normal(ka, (m, n, dim)) / jnp.sqrt(n)
    x_true = jax.random.normal(kx, (dim,))
    scales = np.logspace(-1, 1, m)
    shards = [{"a": scales[i] * a[i], "b": scales[i] * (a[i] @ x_true)}
              for i in range(m)]

    def loss_fn(p, batch):
        r = batch["a"] @ p["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r)

    return loss_fn, {"x": jnp.zeros(dim)}, shards


def _build(loss_fn, params, shards, adaptive=True):
    m = len(shards)
    factory = lambda r: registry.make("ndsc", budget=float(r), chunk=32)
    acfg = (AdaptiveConfig(total_rate=8.0, realloc_every=2, grid=0.25,
                           hysteresis=0.25, min_rate=0.25)
            if adaptive else None)
    return Federation(loss_fn, params, shards,
                      [factory(2.0) for _ in range(m)],
                      ClientConfig(local_steps=2, lr=0.3),
                      ServerConfig(aggregator="fedopt",
                                   optimizer=sgd(1.0, momentum=0.5)),
                      seed=7, adaptive=acfg,
                      codec_factory=factory if acfg else None)


@pytest.mark.parametrize("adaptive", [False, True],
                         ids=["static", "adaptive"])
def test_resumed_run_bit_exact_with_uninterrupted(tmp_path, adaptive):
    """Run 10 rounds straight vs 5 rounds → save → fresh federation →
    restore → 5 more rounds: every piece of state and the round-5..9
    history must match bit for bit (same round indices ⇒ same participant
    draws, codec salts and re-allocation boundaries)."""
    loss_fn, params, shards = _problem()
    cfg5 = FedConfig(num_rounds=5, participation=0.9, dropout=0.1, seed=4)

    ref = _build(loss_fn, params, shards, adaptive)
    h_ref = ref.run(FedConfig(num_rounds=10, participation=0.9, dropout=0.1,
                              seed=4))

    half = _build(loss_fn, params, shards, adaptive)
    half.run(cfg5)
    save_federation(str(tmp_path), half)

    resumed = _build(loss_fn, params, shards, adaptive)
    step = restore_federation(str(tmp_path), resumed)
    assert step == 5 and resumed.rounds_done == 5
    h_resumed = resumed.run(cfg5)

    # history tail: identical participation, ledger, rates
    assert h_ref["participants"][5:] == h_resumed["participants"]
    assert h_ref["stragglers"][5:] == h_resumed["stragglers"]
    assert h_ref["wire_bytes"][5:] == h_resumed["wire_bytes"]
    assert h_ref["rates"][5:] == h_resumed["rates"]
    assert h_ref["realloc"][5:] == h_resumed["realloc"]
    # full state, bitwise
    for name in ("params", "opt_state", "memory"):
        for a, b in zip(jax.tree.leaves(getattr(ref.server, name)),
                        jax.tree.leaves(getattr(resumed.server, name))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for s_ref, s_res in zip(ref.states, resumed.states):
        np.testing.assert_array_equal(np.asarray(s_ref.ef["x"]),
                                      np.asarray(s_res.ef["x"]))
        np.testing.assert_array_equal(jax.random.key_data(s_ref.key),
                                      jax.random.key_data(s_res.key))
        assert int(s_ref.rounds_seen) == int(s_res.rounds_seen)
    if adaptive:
        np.testing.assert_array_equal(ref._ema.norms, resumed._ema.norms)
        np.testing.assert_array_equal(ref._ema.seen, resumed._ema.seen)
        np.testing.assert_array_equal(ref._rates, resumed._rates)


def test_federation_state_covers_round_counter_and_keys(tmp_path):
    loss_fn, params, shards = _problem()
    fed = _build(loss_fn, params, shards, adaptive=False)
    fed.run(FedConfig(num_rounds=3, seed=1))
    tree = federation_state(fed)
    assert int(tree["round"]) == 3
    assert len(tree["clients"]["key_data"]) == fed.num_clients
    # key data round-trips losslessly through the npz format
    save_federation(str(tmp_path), fed, step=3)
    other = _build(loss_fn, params, shards, adaptive=False)
    restore_federation(str(tmp_path), other, step=3)
    for a, b in zip(fed.states, other.states):
        np.testing.assert_array_equal(jax.random.key_data(a.key),
                                      jax.random.key_data(b.key))


def test_restore_rejects_mismatched_structure(tmp_path):
    loss_fn, params, shards = _problem()
    fed = _build(loss_fn, params, shards, adaptive=False)
    fed.run(FedConfig(num_rounds=1))
    save_federation(str(tmp_path), fed)
    smaller = _build(loss_fn, params, shards[:3], adaptive=False)
    with pytest.raises(ValueError, match="structure mismatch"):
        restore_federation(str(tmp_path), smaller)
