"""Trip-count-aware HLO static analyzer: validated against unrolled loops."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_static


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_equal_unroll():
    w = jnp.ones((128, 128))
    x = jnp.ones((8, 128))
    trips = 12

    def body(c, _):
        return jnp.tanh(c @ w), None

    def scanned(x):
        return jax.lax.scan(body, x, None, length=trips)[0]

    def unrolled(x):
        for _ in range(trips):
            x, _ = body(x, None)
        return x

    f_scan = hlo_static.analyze(_compile_text(scanned, x)).flops
    f_unroll = hlo_static.analyze(_compile_text(unrolled, x)).flops
    assert f_scan == pytest.approx(f_unroll, rel=0.02)
    # and both ≈ trips × 2·8·128·128 matmul flops
    assert f_scan == pytest.approx(trips * 2 * 8 * 128 * 128, rel=0.05)


def test_nested_scan_multiplies():
    w = jnp.ones((32, 32))
    x = jnp.ones((4, 32))

    def inner(c, _):
        return c @ w, None

    def outer(c, _):
        return jax.lax.scan(inner, c, None, length=5)[0], None

    def fn(x):
        return jax.lax.scan(outer, x, None, length=7)[0]

    flops = hlo_static.analyze(_compile_text(fn, x)).flops
    assert flops == pytest.approx(7 * 5 * 2 * 4 * 32 * 32, rel=0.05)


def test_scan_bytes_not_inflated_by_stacked_xs():
    """Scan xs of shape (T, …) must be charged one pass, not T passes."""
    t, d = 64, 256
    xs = jnp.ones((t, d))

    def body(c, x):
        return c + x, None

    def fn(xs):
        return jax.lax.scan(body, jnp.zeros((d,)), xs)[0]

    b = hlo_static.analyze(_compile_text(fn, xs)).bytes_accessed
    full = t * d * 4
    assert b < 8 * full          # one-pass-ish, NOT t× = 64×


def test_collective_census_with_multiplier():
    if len(jax.devices()) < 1:
        pytest.skip("needs a device")
    import numpy as np
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    from jax.sharding import PartitionSpec as P

    def local(x):
        def body(c, _):
            return jax.lax.psum(c, "data"), None
        return jax.lax.scan(body, x, None, length=3)[0]

    from repro.compat import shard_map
    sm = shard_map(local, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                   axis_names={"data"}, check=False)
    txt = jax.jit(sm).lower(jnp.ones((4, 8))).compile().as_text()
    costs = hlo_static.analyze(txt)
    # 1-device meshes lower psum to no-op; just assert the parse runs
    assert costs.flops >= 0


def test_shape_parsing():
    elems, bts = hlo_static._shape_elems_bytes("f32[8,16]{1,0}")
    assert (elems, bts) == (128, 512)
    elems, bts = hlo_static._shape_elems_bytes(
        "(s32[], f32[4,4]{1,0}, /*index=2*/bf16[10])")
    assert elems == 1 + 16 + 10
    assert bts == 4 + 64 + 20
