"""DSC/NDSC codecs: Theorem 1 error bounds as property tests (hypothesis)."""
import math

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.coding import Codec, CodecConfig, compress_in_embedded_space
from repro.core.embeddings import EmbeddingSpec
from repro.core import frames as F
from repro.core import quantizers as q


def _codec(kind, n, N, R, dithered=False, embedding="near_democratic"):
    frame = F.make_frame(kind, jax.random.key(0), n, N)
    return Codec(frame, CodecConfig(bits_per_dim=R, dithered=dithered,
                                    embedding=EmbeddingSpec(kind=embedding)))


@given(R=st.sampled_from([1.0, 2.0, 4.0, 8.0]),
       kind=st.sampled_from(["haar", "hadamard"]),
       seed=st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_ndsc_thm1_bound(R, kind, seed):
    """‖y − Q_nd(y)‖₂ ≤ 2^(2−R/λ)·√log(2N)·‖y‖₂ (Thm. 1 Eq. (14))."""
    n = N = 128
    codec = _codec(kind, n, N, R)
    y = jax.random.normal(jax.random.key(seed), (n,)) ** 3
    y_hat = codec.roundtrip(y, jax.random.key(seed + 1))
    rel = float(jnp.linalg.norm(y_hat - y) / jnp.linalg.norm(y))
    assert rel <= codec.error_bound() + 1e-6


def test_dsc_thm1_bound_democratic():
    """DSC with Haar frame: ‖y − Q_d(y)‖₂ ≤ 2^(1−R/λ)·K_u·‖y‖₂ (Eq. (13))."""
    n, N, R = 64, 128, 4.0
    codec = _codec("haar", n, N, R, embedding="democratic")
    for seed in range(5):
        y = jax.random.normal(jax.random.key(seed), (n,)) ** 3
        y_hat = codec.roundtrip(y, jax.random.key(100 + seed))
        rel = float(jnp.linalg.norm(y_hat - y) / jnp.linalg.norm(y))
        assert rel <= codec.error_bound() + 1e-6


def test_error_decays_with_budget():
    """More bits → strictly better error (covering-efficiency sanity)."""
    n = N = 256
    y = jax.random.normal(jax.random.key(7), (n,)) ** 3
    errs = []
    for R in (1, 2, 4, 8):
        codec = _codec("hadamard", n, N, float(R))
        y_hat = codec.roundtrip(y, jax.random.key(8))
        errs.append(float(jnp.linalg.norm(y_hat - y)))
    assert errs == sorted(errs, reverse=True)
    assert errs[-1] < 0.02 * errs[0] + 1e-9


def test_sublinear_budget_runs():
    """R < 1: subsample + 1-bit path (App. E.2); unbiased when dithered."""
    n = N = 512
    codec = _codec("hadamard", n, N, R=0.5, dithered=True)
    assert codec.sublinear
    y = jax.random.normal(jax.random.key(1), (n,))
    keys = jax.random.split(jax.random.key(2), 600)
    outs = jax.vmap(lambda k: codec.roundtrip(y, k))(keys)
    mean = jnp.mean(outs, axis=0)
    # unbiasedness of the sub-linear dithered codec (consensus relies on it)
    corr = float(jnp.dot(mean, y) / (jnp.linalg.norm(mean) * jnp.linalg.norm(y)))
    assert corr > 0.9


def test_wire_bits_budget():
    """Fixed-length budget audit: nR bits (+O(1) scale, excluded here)."""
    codec = _codec("hadamard", 128, 128, R=4.0)
    assert codec.wire_bits() == 128 * 4
    codec = _codec("hadamard", 100, 128, R=4.0)   # λ = 1.28
    assert codec.wire_bits() <= 100 * 4 + 1e-9    # nR budget respected


def test_dithered_codec_unbiased():
    n = N = 128
    codec = _codec("hadamard", n, N, R=2.0, dithered=True)
    y = jax.random.normal(jax.random.key(3), (n,))
    keys = jax.random.split(jax.random.key(4), 800)
    outs = jax.vmap(lambda k: codec.roundtrip(y, k))(keys)
    err = float(jnp.linalg.norm(jnp.mean(outs, axis=0) - y)
                / jnp.linalg.norm(y))
    assert err < 0.1


def test_thm4_compress_in_embedded_space():
    """App. H: rand-k in the embedded space ≤ γ‖y‖₂ uniformly (Thm. 4)."""
    n = N = 256
    frame = F.make_frame("hadamard", jax.random.key(0), n, N)
    y = jax.random.normal(jax.random.key(1), (n,)) ** 3

    def randk_half(key, x):
        mask = q.subsample_mask(key, x.shape, 0.5)
        return x * mask  # biased variant: uniform bound applies

    y_hat = compress_in_embedded_space(frame, randk_half, y,
                                       jax.random.key(2))
    gamma = 2 * math.sqrt(math.log(2 * N))
    rel = float(jnp.linalg.norm(y_hat - y) / jnp.linalg.norm(y))
    assert rel <= gamma
