"""The multi-pod dry-run launcher, exercised end-to-end in a subprocess
(it must own the 512-device XLA flag before jax init)."""
import json
import os
import subprocess
import sys

import pytest


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.slow
def test_dryrun_single_combo(tmp_path):
    out_json = tmp_path / "rec.json"
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = SRC
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "xlstm-350m", "--shape", "decode_32k", "--multi-pod",
         "--json-out", str(out_json)],
        env=env, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-2000:]
    recs = json.loads(out_json.read_text())
    assert len(recs) == 1
    rec = recs[0]
    assert rec["status"] == "OK"
    assert rec["mesh"] == "2x16x16"
    assert rec["num_devices"] == 512
    roof = rec["roofline"]
    assert roof["flops_per_device"] > 0
    assert roof["hbm_bytes_per_device"] > 0
    assert roof["dominant"] in ("compute", "memory", "collective")


@pytest.mark.slow
def test_dryrun_documented_skip(tmp_path):
    out_json = tmp_path / "rec.json"
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = SRC
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "phi3-mini-3.8b", "--shape", "long_500k",
         "--json-out", str(out_json)],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    rec = json.loads(out_json.read_text())[0]
    assert rec["status"] == "SKIP"
    assert "full attention" in rec["reason"]
