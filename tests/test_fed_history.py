"""Federation.run history: documented keys, byte accounting, resume indices.

The history dict is the interface the benchmarks and the paper figures
read; these tests pin its documented shape (run()'s docstring: round, loss,
wire_bytes, analytic_bytes, cum_bytes, participants, stragglers, realloc,
rates) and the cumulative-bytes invariant the communication-budget plots
depend on.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_federation, save_federation
from repro.fed import (ClientConfig, FedConfig, Federation, ServerConfig)
from repro import codecs as registry

DOCUMENTED_KEYS = {"round", "loss", "wire_bytes", "analytic_bytes",
                   "cum_bytes", "participants", "stragglers", "realloc",
                   "rates"}


def _problem(m=4, dim=32, n=24, seed=6):
    ka, kx = jax.random.split(jax.random.key(seed))
    a = jax.random.normal(ka, (m, n, dim)) / jnp.sqrt(n)
    x_true = jax.random.normal(kx, (dim,))
    shards = [{"a": a[i], "b": a[i] @ x_true} for i in range(m)]

    def loss_fn(p, batch):
        r = batch["a"] @ p["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r)

    return shards, loss_fn, {"x": jnp.zeros(dim)}


def _build(loss_fn, params, shards):
    return Federation(loss_fn, params, shards,
                      registry.make("ndsc", 4.0, chunk=32),
                      ClientConfig(local_steps=2, lr=0.25),
                      ServerConfig(aggregator="fedavg"), seed=8)


def test_history_documented_keys_and_lengths():
    shards, loss_fn, params = _problem()
    fed = _build(loss_fn, params, shards)
    rounds = 5
    hist = fed.run(FedConfig(num_rounds=rounds, participation=0.8,
                             dropout=0.2, seed=2),
                   eval_fn=lambda p: loss_fn(p, {
                       "a": jnp.concatenate([s["a"] for s in shards]),
                       "b": jnp.concatenate([s["b"] for s in shards])}))
    assert set(hist) == DOCUMENTED_KEYS
    for key in DOCUMENTED_KEYS:
        assert len(hist[key]) == rounds, key       # incl. loss with eval_fn
    assert hist["round"] == list(range(rounds))
    for t in range(rounds):
        assert set(hist["participants"][t]).isdisjoint(
            hist["stragglers"][t])
        assert hist["wire_bytes"][t] >= 0.0
        assert hist["analytic_bytes"][t] >= 0.0


def test_history_loss_empty_without_eval_fn():
    shards, loss_fn, params = _problem()
    hist = _build(loss_fn, params, shards).run(FedConfig(num_rounds=2))
    assert hist["loss"] == []
    assert len(hist["round"]) == 2


def test_cum_bytes_is_monotone_running_sum():
    shards, loss_fn, params = _problem()
    fed = _build(loss_fn, params, shards)
    hist = fed.run(FedConfig(num_rounds=6, participation=0.7, dropout=0.3,
                             seed=13))
    running = np.cumsum(hist["wire_bytes"])
    np.testing.assert_array_equal(np.asarray(hist["cum_bytes"]), running)
    assert all(b1 >= b0 for b0, b1 in zip(hist["cum_bytes"],
                                          hist["cum_bytes"][1:]))


def test_round_indices_continue_across_checkpoint_restore(tmp_path):
    """Resume must pick up at the saved round counter: the restored run's
    history rounds continue where the first run stopped, and match the
    tail of an uninterrupted run exactly."""
    shards, loss_fn, params = _problem()
    cfg = FedConfig(num_rounds=3, participation=0.9, dropout=0.1, seed=4)

    ref = _build(loss_fn, params, shards)
    h_full = ref.run(FedConfig(num_rounds=6, participation=0.9, dropout=0.1,
                               seed=4))

    first = _build(loss_fn, params, shards)
    h_first = first.run(cfg)
    save_federation(str(tmp_path), first)

    resumed = _build(loss_fn, params, shards)
    restore_federation(str(tmp_path), resumed)
    assert resumed.rounds_done == 3
    h_resumed = resumed.run(cfg)

    assert h_first["round"] == [0, 1, 2]
    assert h_resumed["round"] == [3, 4, 5]
    stitched = {k: h_first[k] + h_resumed[k] for k in h_full}
    # cum_bytes restarts per run() call; everything else stitches exactly
    assert {k: v for k, v in stitched.items() if k != "cum_bytes"} == \
        {k: v for k, v in h_full.items() if k != "cum_bytes"}
    np.testing.assert_allclose(
        np.asarray(h_resumed["cum_bytes"]) + h_first["cum_bytes"][-1],
        np.asarray(h_full["cum_bytes"][3:]))
