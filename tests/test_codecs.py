"""repro.codecs package: stage pipelines, NDSC bit-exactness with the
gradcomp path, the new ratq / sparsify_then_embed codecs, registry
diagnostics, and the fed.registry / benchmarks.roofline deprecation shims."""
import importlib
import os
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import codecs
from repro.codecs import stages
from repro.dist import gradcomp as G

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _heavy(key, shape):
    return jax.random.normal(key, shape) ** 3


def _bitwise_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return (a.dtype == b.dtype and a.shape == b.shape
            and np.array_equal(a.view(np.uint8), b.view(np.uint8)))


# ---------------------------------------------------------------------------
# NDSC through repro.codecs is BIT-EXACT with the direct gradcomp path
# ---------------------------------------------------------------------------
def _assert_ndsc_bitexact(bits, keep, dithered, n=256, chunk=32,
                          round_idx=3):
    key = jax.random.key(11)
    tree = {"w": _heavy(jax.random.fold_in(key, 0), (n,)),
            "b": _heavy(jax.random.fold_in(key, 1), (5, 9))}
    leaves, _ = jax.tree.flatten(tree)
    drop = keep < 1.0
    cfg = G.GradCompConfig(bits=bits, chunk=chunk, keep_fraction=keep,
                           exact_keep=drop, dithered=dithered,
                           error_feedback=True, seed=0)
    pipeline = stages.Pipeline(
        transform=stages.Transform("hadamard", seed=0),
        sparsify=(stages.Sparsify("chunk_drop", fraction=keep)
                  if drop else stages.Sparsify()),
        quantize=stages.Quantize("dithered" if dithered else "uniform",
                                 bits=bits),
        chunk=chunk)
    codec = pipeline.tree_codec("under-test")
    meta = codec.meta(tree)
    ekey = jax.random.fold_in(key, 7)

    wire = codec.encode(ekey, tree, round_idx)
    plist = meta.treedef.flatten_up_to(wire)
    direct = [G.encode_leaf(x, i, cfg, round_idx,
                            key=jax.random.fold_in(ekey, i))
              for i, x in enumerate(leaves)]
    for p, d in zip(plist, direct):
        assert set(p) == set(d)
        for field in p:
            assert _bitwise_equal(p[field], d[field]), field

    dec = jax.tree.leaves(codec.decode(wire, meta))
    for i, (d, (size, shape, dtype)) in enumerate(zip(direct, meta.infos)):
        assert _bitwise_equal(dec[i],
                              G.decode_leaf(d, i, size, shape, dtype, cfg))

    wire_ef, resid = codec.encode_ef(ekey, tree, meta, round_idx)
    for i, (x, p, r, info) in enumerate(zip(
            leaves, meta.treedef.flatten_up_to(wire_ef),
            jax.tree.leaves(resid), meta.infos)):
        dp, dr = G.encode_leaf_ef(x, i, cfg, round_idx,
                                  key=jax.random.fold_in(ekey, i),
                                  residual_dtype=info[2])
        for field in p:
            assert _bitwise_equal(p[field], dp[field]), f"EF {field}"
        assert _bitwise_equal(r, dr)

    assert abs(codec.wire_bytes(wire, meta)
               - sum(G.wire_bytes_payload(d, cfg) for d in direct)) < 1e-9
    assert abs(codec.wire_bits(tree)
               - G.wire_bytes_tree(leaves, cfg)["payload_bytes"] * 8.0) < 1e-6


@pytest.mark.parametrize("bits", [1, 2, 4, 8])
@pytest.mark.parametrize("keep", [0.25, 1.0])
@pytest.mark.parametrize("dithered", [False, True])
def test_ndsc_pipeline_bitexact_with_gradcomp(bits, keep, dithered):
    _assert_ndsc_bitexact(bits, keep, dithered)


@pytest.mark.parametrize("bits,keep", [(1, 1.0), (4, 0.25), (8, 1.0)])
def test_ndsc_pipeline_bitexact_forced_pallas(monkeypatch, bits, keep):
    """Same contract with the (interpret-mode) Pallas kernels forced: the
    dispatch layer may never change a wire payload. Reduced grid — the
    interpreter is slow; CI sweeps the full grid via codec_frontier under
    REPRO_FORCE_PALLAS=1."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    _assert_ndsc_bitexact(bits, keep, dithered=False, n=128, chunk=32)


def test_make_ndsc_matches_explicit_pipeline():
    tree = {"w": _heavy(jax.random.key(0), (200,))}
    made = codecs.make("ndsc", budget=4.0, chunk=32)
    cfg = codecs.gradcomp_config_for_budget(4.0, 32)
    assert made.rate == cfg.effective_bits
    key = jax.random.key(5)
    wire = made.encode(key, tree, 0)
    direct = G.encode_leaf(tree["w"], 0, cfg, 0,
                           key=jax.random.fold_in(key, 0))
    for field in wire["w"]:
        assert _bitwise_equal(wire["w"][field], direct[field])


# ---------------------------------------------------------------------------
# ratq: roundtrip quality, audit == ledger, static shapes across rounds
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("budget", [0.5, 1.0, 4.0])
def test_ratq_roundtrip_and_ledger(budget):
    n = 256
    tree = {"y": _heavy(jax.random.key(3), (n,))}
    codec = codecs.make("ratq", budget=budget, chunk=32)
    meta = codec.meta(tree)
    wire = codec.encode(jax.random.key(4), tree, 0)
    assert ("mask" in wire["y"]) == (budget < 1.0)
    out = codec.decode(wire, meta)["y"]
    assert out.shape == (n,) and out.dtype == jnp.float32
    err = float(jnp.linalg.norm(out - tree["y"])
                / jnp.linalg.norm(tree["y"]))
    assert err < (1.05 if budget < 4 else 0.3)
    # fixed-length wire: realized ledger equals the analytic audit exactly
    assert abs(codec.wire_bytes(wire, meta)
               - codec.wire_bits(tree) / 8.0) < 1e-6
    # the rung index is the cheap side channel: ⌈log2 16⌉ = 4 bits/chunk
    # beats ndsc's 32-bit f32 scale at every budget
    ndsc = codecs.make("ndsc", budget=budget, chunk=32)
    assert codec.wire_bits(tree) < ndsc.wire_bits(tree)


def test_ratq_no_recompile_across_rounds():
    n = 256
    y = _heavy(jax.random.key(6), (n,))
    for budget in (0.5, 2.0):
        codec = codecs.make("ratq", budget=budget, chunk=32)
        meta = codec.meta({"y": y})
        fn = jax.jit(lambda k, t, r: codec.decode(codec.encode(k, t, r),
                                                  meta))
        for r in range(4):
            jax.block_until_ready(
                fn(jax.random.fold_in(jax.random.key(0), r), {"y": y},
                   jnp.uint32(r)))
        assert fn._cache_size() == 1, \
            f"ratq(R={budget}) recompiled across rounds"


def test_ratq_ladder_scales_cover_dynamic_range():
    """Chunks with very different norms land on different rungs, and every
    chunk's chosen scale bounds its own ℓ∞ norm (no clipping)."""
    n, chunk = 128, 32
    y = jnp.concatenate([100.0 * _heavy(jax.random.key(1), (chunk,)),
                         _heavy(jax.random.key(2), (n - chunk,)) * 0.01])
    codec = codecs.make("ratq", budget=4.0, chunk=chunk, ladder=16)
    wire = codec.encode(jax.random.key(0), {"y": y}, 0)
    ridx = np.asarray(wire["y"]["ridx"]).reshape(-1)
    assert ridx.max() > ridx.min()           # the ladder is actually used
    leaf = codec.meta({"y": y}).extra[0]
    scales = np.asarray(leaf._scales(wire["y"]["ridx"], wire["y"]["gain"]))
    import repro.kernels.ops as kernel_ops
    rot = np.asarray(kernel_ops.rotate(
        G._to_chunks(y, chunk), G._frame_signs(0, leaf.cfg).astype(
            jnp.float32)))
    assert (np.abs(rot).max(axis=-1, keepdims=True)
            <= scales + 1e-6).all()


# ---------------------------------------------------------------------------
# sparsify_then_embed: selection, reconstruction support, audit == ledger
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["topk", "randk"])
def test_sparsify_then_embed_roundtrip(mode):
    n, k = 300, 60
    y = _heavy(jax.random.key(8), (n,))
    codec = codecs.make("sparsify_then_embed", budget=1.0, mode=mode,
                        bits=8, chunk=32, k_fraction=k / n)
    meta = codec.meta({"y": y})
    wire = codec.encode(jax.random.key(9), {"y": y}, 0)
    idx = np.asarray(wire["y"]["indices"])
    assert idx.shape == (k,) and (np.diff(idx) > 0).all()
    if mode == "topk":
        expect = np.sort(np.argsort(-np.abs(np.asarray(y)))[:k])
        np.testing.assert_array_equal(idx, expect)
    out = np.asarray(codec.decode(wire, meta)["y"])
    # reconstruction lives exactly on the selected support
    assert (out[np.setdiff1d(np.arange(n), idx)] == 0.0).all()
    kept = np.asarray(y)[idx]
    err = np.linalg.norm(out[idx] - kept) / np.linalg.norm(kept)
    assert err < 0.05                        # 8-bit embedded quantization
    assert abs(codec.wire_bytes(wire, meta)
               - codec.wire_bits({"y": y}) / 8.0) < 1e-9


def test_sparsify_then_embed_audit_charges_indices():
    """The audit is C·(chunk·bits + 32) + log2 C(n,k) — the identical
    index-cost convention as the plain topk/randk baselines."""
    import math
    n, k, bits, chunk = 512, 64, 4, 32
    codec = codecs.make("sparsify_then_embed", budget=1.0, bits=bits,
                        chunk=chunk, k_fraction=k / n)
    tmpl = {"y": jax.ShapeDtypeStruct((n,), jnp.float32)}
    c = -(-k // chunk)
    expect = c * (chunk * bits + 32) + math.log2(math.comb(n, k))
    assert abs(codec.wire_bits(tmpl) - expect) < 1e-9


def test_randk_selection_is_key_deterministic():
    n = 200
    y = _heavy(jax.random.key(1), (n,))
    codec = codecs.make("sparsify_then_embed", budget=1.0, mode="randk",
                        bits=4, chunk=32, k_fraction=0.2)
    w1 = codec.encode(jax.random.key(2), {"y": y}, 0)
    w2 = codec.encode(jax.random.key(2), {"y": y}, 0)
    w3 = codec.encode(jax.random.key(3), {"y": y}, 0)
    np.testing.assert_array_equal(np.asarray(w1["y"]["indices"]),
                                  np.asarray(w2["y"]["indices"]))
    assert not np.array_equal(np.asarray(w1["y"]["indices"]),
                              np.asarray(w3["y"]["indices"]))


# ---------------------------------------------------------------------------
# stage validation + registry diagnostics
# ---------------------------------------------------------------------------
def test_stage_validation_errors():
    with pytest.raises(ValueError, match="transform"):
        stages.Transform("fourier")
    with pytest.raises(ValueError, match="sparsify"):
        stages.Sparsify("bottomk")
    with pytest.raises(ValueError, match="fraction"):
        stages.Sparsify("chunk_drop", fraction=0.0)
    with pytest.raises(ValueError, match="bits"):
        stages.Quantize(bits=3)
    with pytest.raises(ValueError, match="ladder"):
        stages.Quantize("ratq", ladder=1)
    with pytest.raises(ValueError, match="pack"):
        stages.Pack("zip")
    # unsupported stage combination: ratq after topk selection
    with pytest.raises(ValueError, match="topk/randk"):
        stages.Pipeline(sparsify=stages.Sparsify("topk", fraction=0.1),
                        quantize=stages.Quantize("ratq")).leaf()
    with pytest.raises(ValueError, match="hadamard"):
        stages.Pipeline(transform=stages.Transform("identity")).leaf()


def test_equal_pipelines_share_a_leaf_codec():
    a = stages.Pipeline(quantize=stages.Quantize(bits=4), chunk=64)
    b = stages.Pipeline(quantize=stages.Quantize(bits=4), chunk=64)
    assert a == b and hash(a) == hash(b)
    assert a.leaf() is b.leaf()              # lru-cached dispatch


def test_registry_unknown_name_suggests_nearest():
    with pytest.raises(ValueError) as e:
        codecs.make("ndcs", budget=1.0)
    msg = str(e.value)
    assert "unknown codec 'ndcs'" in msg
    assert "did you mean 'ndsc'?" in msg
    assert "available:" in msg
    with pytest.raises(ValueError, match="available:"):
        codecs.make("no_such_codec_at_all")


def test_registry_lists_new_codecs():
    names = codecs.available()
    assert "ratq" in names and "sparsify_then_embed" in names
    assert "ndsc" in names and "identity" in names


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------
def test_fed_registry_shim_import_is_warning_free():
    """`import repro.fed.registry` must NOT warn (CI imports it with
    -W error::DeprecationWarning); only calling make() through it warns."""
    sys.modules.pop("repro.fed.registry", None)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("error", DeprecationWarning)
        shim = importlib.import_module("repro.fed.registry")
    assert not caught
    for name in ("TreeCodec", "available", "codec_spec",
                 "gradcomp_config_for_budget", "register"):
        assert getattr(shim, name) is getattr(codecs, name)


def test_fed_registry_shim_make_warns_and_forwards():
    from repro.fed import registry as shim
    with pytest.warns(DeprecationWarning, match="repro.codecs"):
        codec = shim.make("identity")
    assert codec.name == codecs.make("identity").name


def test_roofline_shim_warns_and_forwards():
    sys.modules.pop("benchmarks.roofline", None)
    with pytest.warns(DeprecationWarning, match="hlo_report"):
        roofline = importlib.import_module("benchmarks.roofline")
    hlo_report = importlib.import_module("benchmarks.hlo_report")
    assert roofline.main is hlo_report.main
    assert roofline.table_rows is hlo_report.table_rows
    assert roofline.markdown is hlo_report.markdown
