"""Pytree optimizers: AdamW, SGD(+momentum), LR schedules, grad clipping.

Same (init, update) contract as optax, but self-contained:

    opt = adamw(lr=schedule, weight_decay=0.1)
    opt_state = opt.init(params)
    updates, opt_state = opt.update(grads, opt_state, params)
    params = apply_updates(params, updates)

States are plain pytrees (dicts of arrays + a scalar step), so they thread
through jit/shard_map/checkpointing unchanged and inherit param shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Union

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]
ScalarOrSchedule = Union[float, Schedule]


def _lr_at(lr: ScalarOrSchedule, step: jax.Array) -> jax.Array:
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------
def constant_schedule(value: float) -> Schedule:
    return lambda step: jnp.asarray(value, jnp.float32)


def cosine_schedule(peak: float, total_steps: int, floor: float = 0.0) -> Schedule:
    def fn(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * frac))
    return fn


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.0) -> Schedule:
    cos = cosine_schedule(peak, max(total_steps - warmup_steps, 1), floor)
    def fn(step):
        warm = peak * step / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))
    return fn


# ---------------------------------------------------------------------------
# Gradient clipping
# ---------------------------------------------------------------------------
def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), norm


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def adamw(lr: ScalarOrSchedule, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda: jax.tree.map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return {"mu": zeros(), "nu": zeros(),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v +
                          (1 - b2) * jnp.square(g.astype(jnp.float32)),
                          state["nu"], grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            m_hat, v_hat = m / c1, v / c2
            u = -lr_t * (m_hat / (jnp.sqrt(v_hat) + eps)
                         + weight_decay * p.astype(jnp.float32))
            return u.astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, {"mu": mu, "nu": nu, "step": step}

    return Optimizer(init, update)


def sgd(lr: ScalarOrSchedule, momentum: float = 0.0,
        nesterov: bool = False) -> Optimizer:
    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["vel"] = jax.tree.map(
                lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return state

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        if not momentum:
            updates = jax.tree.map(
                lambda g, p: (-lr_t * g.astype(jnp.float32)).astype(p.dtype),
                grads, params)
            return updates, {"step": step}
        vel = jax.tree.map(
            lambda v, g: momentum * v + g.astype(jnp.float32),
            state["vel"], grads)
        if nesterov:
            updates = jax.tree.map(
                lambda v, g, p: (-lr_t * (momentum * v + g.astype(jnp.float32))
                                 ).astype(p.dtype), vel, grads, params)
        else:
            updates = jax.tree.map(
                lambda v, p: (-lr_t * v).astype(p.dtype), vel, params)
        return updates, {"step": step, "vel": vel}

    return Optimizer(init, update)
