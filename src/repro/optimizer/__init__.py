"""Pure-JAX pytree optimizers (optax is not available offline)."""
from repro.optimizer.optim import (Optimizer, adamw, sgd, cosine_schedule,
                                   constant_schedule, warmup_cosine,
                                   global_norm, clip_by_global_norm)
