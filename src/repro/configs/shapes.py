"""The four assigned input shapes + ShapeDtypeStruct input_specs per mode.

  train_4k     seq=4096     global_batch=256   (training: train_step)
  prefill_32k  seq=32768    global_batch=32    (inference prefill: forward)
  decode_32k   seq=32768    global_batch=128   (decode: serve_step, 1 token
                                                against a 32k cache)
  long_500k    seq=524288   global_batch=1     (long-context decode; only
                                                sub-quadratic archs)

Applicability rules (DESIGN.md §4):
  * encoder-only (hubert): no decode → decode_32k / long_500k skipped;
    prefill_32k is the encoder forward.
  * long_500k requires sub-quadratic sequence mixing: runs for sliding-window
    attention (hymba, mixtral) and recurrent state (xlstm); skipped for pure
    full-attention archs (phi3, yi, arctic, pixtral, llama3.2, mistral-large).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                   # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k":    InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k":   InputShape("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg, shape: InputShape) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape.mode == "decode":
        if not cfg.decode_supported:
            return False, "encoder-only: no autoregressive decode"
        if shape.name == "long_500k" and not cfg.subquadratic:
            return False, ("pure full attention: O(s²) at 524k infeasible; "
                           "needs sliding-window/recurrent mixing")
    return True, ""


def input_specs(cfg, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for train/prefill batches (no allocation).

    Decode shapes use repro.dist.step.serve_state_specs (the state IS the
    input there).
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.frontend == "audio":
        return {
            "embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.float32),
            "targets": jax.ShapeDtypeStruct((b, s), i32),
        }
    if cfg.frontend == "vision":
        text = s - cfg.num_patches
        if text <= 0:
            raise ValueError(f"seq {s} shorter than the {cfg.num_patches}"
                             " image patches")
        return {
            "image_embeds": jax.ShapeDtypeStruct(
                (b, cfg.num_patches, cfg.d_model), jnp.float32),
            "tokens": jax.ShapeDtypeStruct((b, text + 1), i32),
        }
    return {"tokens": jax.ShapeDtypeStruct((b, s + 1), i32)}
