"""llama3.2-3b [dense] — small llama3 [hf:meta-llama/Llama-3.2-1B card family].

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.
"""
from repro.models.model import ModelConfig

SOURCE = "hf:meta-llama/Llama-3.2-3B"


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b", num_layers=28, d_model=3072, num_heads=24,
        num_kv_heads=8, d_ff=8192, vocab_size=128256,
        block="attn_mlp", rope_theta=500000.0, source=SOURCE)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=256, vocab_size=512,
        block="attn_mlp", rope_theta=10000.0, remat=False, source=SOURCE)
