"""hubert-xlarge [audio] — encoder-only, same arch as wav2vec2 [arXiv:2106.07447].

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (cluster targets).
The conv/mel frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, S, d_model); this module is the bidirectional
transformer encoder with the masked-cluster prediction head. Encoder-only →
no decode shapes (DESIGN.md §4).
"""
from repro.models.model import ModelConfig

SOURCE = "arXiv:2106.07447 (HuBERT)"


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", num_layers=48, d_model=1280, num_heads=16,
        num_kv_heads=16, d_ff=5120, vocab_size=504,
        block="encoder", causal=False, frontend="audio",
        rope_theta=10000.0, source=SOURCE)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="hubert-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, d_ff=256, vocab_size=64,
        block="encoder", causal=False, frontend="audio",
        rope_theta=10000.0, remat=False, source=SOURCE)
