"""hymba-1.5b [hybrid] — parallel attention + Mamba heads [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Hymba runs sliding-window attention in all but three layers; we model the
SWA configuration uniformly (window 1024 per the paper's global-local split),
which is what makes long_500k decode feasible for this arch.
"""
from repro.models.model import ModelConfig

SOURCE = "arXiv:2411.13676 (Hymba)"


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", num_layers=32, d_model=1600, num_heads=25,
        num_kv_heads=5, d_ff=5504, vocab_size=32001, head_dim=64,
        block="hybrid", attention_kind="sliding", window=1024,
        ssm_state=16, d_inner=1600, rope_theta=10000.0, source=SOURCE)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=256, vocab_size=512, head_dim=32,
        block="hybrid", attention_kind="sliding", window=64,
        ssm_state=8, d_inner=128, rope_theta=10000.0, remat=False,
        source=SOURCE)
