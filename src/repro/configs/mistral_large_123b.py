"""mistral-large-123b [dense] — [hf:mistralai/Mistral-Large-Instruct-2407].

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.
"""
from repro.models.model import ModelConfig

SOURCE = "hf:mistralai/Mistral-Large-Instruct-2407"


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b", num_layers=88, d_model=12288,
        num_heads=96, num_kv_heads=8, d_ff=28672, vocab_size=32768,
        block="attn_mlp", rope_theta=1_000_000.0, source=SOURCE)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=256, vocab_size=512,
        block="attn_mlp", rope_theta=10000.0, remat=False, source=SOURCE)
