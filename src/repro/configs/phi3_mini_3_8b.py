"""phi3-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2404.14219].

32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.
"""
from repro.models.model import ModelConfig

SOURCE = "arXiv:2404.14219 (Phi-3)"


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b", num_layers=32, d_model=3072, num_heads=32,
        num_kv_heads=32, d_ff=8192, vocab_size=32064,
        block="attn_mlp", rope_theta=10000.0, source=SOURCE)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="phi3-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, d_ff=256, vocab_size=512,
        block="attn_mlp", rope_theta=10000.0, remat=False, source=SOURCE)
