"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention
[arXiv:2401.04088].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8e top-2,
SWA window 4096 — the window is what lets long_500k decode run with a
bounded ring cache.
"""
from repro.models.model import ModelConfig

SOURCE = "arXiv:2401.04088 (Mixtral)"


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", num_layers=56, d_model=6144, num_heads=48,
        num_kv_heads=8, d_ff=16384, vocab_size=32768,
        block="attn_moe", num_experts=8, top_k=2,
        attention_kind="sliding", window=4096,
        rope_theta=1_000_000.0, source=SOURCE)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=512,
        block="attn_moe", num_experts=4, top_k=2,
        attention_kind="sliding", window=64,
        rope_theta=10000.0, remat=False, source=SOURCE)
