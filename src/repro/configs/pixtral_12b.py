"""pixtral-12b [vlm] — pixtral-ViT + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128.
The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (B, 1024, d_model); this module is the language
decoder that consumes them (image prefix + text suffix, loss on text only).
"""
from repro.models.model import ModelConfig

SOURCE = "hf:mistralai/Pixtral-12B-2409"


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", num_layers=40, d_model=5120, num_heads=32,
        num_kv_heads=8, d_ff=14336, vocab_size=131072, head_dim=128,
        block="attn_mlp", frontend="vision", num_patches=1024,
        rope_theta=1_000_000.0, source=SOURCE)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="pixtral-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=256, vocab_size=512, head_dim=32,
        block="attn_mlp", frontend="vision", num_patches=16,
        rope_theta=10000.0, remat=False, source=SOURCE)
