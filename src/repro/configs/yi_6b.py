"""yi-6b [dense] — llama-arch GQA [arXiv:2403.04652].

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.models.model import ModelConfig

SOURCE = "arXiv:2403.04652 (Yi)"


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b", num_layers=32, d_model=4096, num_heads=32,
        num_kv_heads=4, d_ff=11008, vocab_size=64000,
        block="attn_mlp", rope_theta=5_000_000.0, source=SOURCE)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="yi-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=256, vocab_size=512,
        block="attn_mlp", rope_theta=10000.0, remat=False, source=SOURCE)
