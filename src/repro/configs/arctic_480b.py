"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2.
Arctic is a dense-MoE hybrid: every layer has a dense residual MLP in
parallel with the 128-expert top-2 MoE FFN (block="attn_moe_dense").
"""
from repro.models.model import ModelConfig

SOURCE = "hf:Snowflake/snowflake-arctic-base"


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", num_layers=35, d_model=7168, num_heads=56,
        num_kv_heads=8, d_ff=4864, vocab_size=32000,
        block="attn_moe_dense", num_experts=128, top_k=2,
        rope_theta=10000.0, source=SOURCE)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="arctic-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=512,
        block="attn_moe_dense", num_experts=4, top_k=2,
        rope_theta=10000.0, remat=False, source=SOURCE)
