"""Architecture registry: the 10 assigned configs + the paper's own setups.

Every entry cites its source paper / model card; `get(name)` returns the full
ModelConfig, `get_reduced(name)` the ≤2-layer smoke variant exercised by the
CPU tests (the full configs are touched only via the ShapeDtypeStruct dry-run).
"""
from __future__ import annotations

from repro.configs import (arctic_480b, hubert_xlarge, hymba_1_5b,
                           llama3_2_3b, mistral_large_123b, mixtral_8x22b,
                           phi3_mini_3_8b, pixtral_12b, xlstm_350m, yi_6b)
from repro.configs.shapes import SHAPES, InputShape, applicable, input_specs

_MODULES = {
    "hymba-1.5b": hymba_1_5b,
    "phi3-mini-3.8b": phi3_mini_3_8b,
    "yi-6b": yi_6b,
    "arctic-480b": arctic_480b,
    "pixtral-12b": pixtral_12b,
    "hubert-xlarge": hubert_xlarge,
    "llama3.2-3b": llama3_2_3b,
    "mixtral-8x22b": mixtral_8x22b,
    "mistral-large-123b": mistral_large_123b,
    "xlstm-350m": xlstm_350m,
}

ARCH_NAMES = tuple(_MODULES)


def get(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return _MODULES[name].config()


def get_reduced(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return _MODULES[name].reduced()
