"""xlstm-350m [ssm] — alternating sLSTM + mLSTM blocks [arXiv:2405.04517].

24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304. The scanned unit is an
(mLSTM, sLSTM) pair — 12 pairs for 24 layers; d_ff=0 (no FFN in the xLSTM
block recipe). Fully recurrent (O(1) state/token) → long_500k runs natively.
"""
from repro.models.model import ModelConfig

SOURCE = "arXiv:2405.04517 (xLSTM)"


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", num_layers=24, d_model=1024, num_heads=4,
        num_kv_heads=4, d_ff=0, vocab_size=50304,
        block="xlstm_pair", source=SOURCE)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, d_ff=0, vocab_size=512,
        block="xlstm_pair", remat=False, source=SOURCE)
