"""Regression sentinel: gate current benchmark numbers against history.

`check()` compares each current record (from
`history.records_from_payload`) against the trailing window of COMPARABLE
history — same benchmark, same metric, same env fingerprint, same
direction, `ok` runs only, truncated at the most recent blessed record
(how an intentional perf change resets its baseline). The baseline is a
trimmed mean over that window, so one historical outlier can't poison the
gate; the tolerance is a relative threshold plus a noise floor of
`noise_sigmas`× the within-run repeat standard deviation, so benchmarks
too noisy to measure never alarm on noise alone. Direction-aware: a
"lower"-is-better metric regresses only above `baseline * (1 + rel)`, a
"higher"-is-better one only below `baseline * (1 - rel)`; direction-less
metrics are recorded in history but never gated.

Exposed as `python -m benchmarks.run --check-regressions` — report-only on
PRs (`--regress-report-only`), enforcing (exit code 2) nightly.
"""
from __future__ import annotations

import math
from typing import Optional


def trimmed_mean(values, trim: float = 0.2) -> float:
    """Mean with `trim` fraction dropped from EACH end (rounded down, and
    only once there are enough samples that trimming leaves some)."""
    vals = sorted(float(v) for v in values)
    if not vals:
        raise ValueError("trimmed_mean of no values")
    k = int(len(vals) * trim)
    if len(vals) - 2 * k >= 1:
        vals = vals[k:len(vals) - k] if k else vals
    return sum(vals) / len(vals)


def _stdev(values) -> float:
    vals = [float(v) for v in values]
    if len(vals) < 2:
        return 0.0
    mean = sum(vals) / len(vals)
    return math.sqrt(sum((v - mean) ** 2 for v in vals) / (len(vals) - 1))


def _comparable(history, cur) -> list:
    """History rows this record can be judged against, oldest first,
    restarted at the most recent blessed row."""
    rows = [h for h in history
            if h.get("benchmark") == cur.get("benchmark")
            and h.get("metric") == cur.get("metric")
            and h.get("fingerprint") == cur.get("fingerprint")
            and h.get("direction") == cur.get("direction")
            and h.get("ok", True)]
    for i in range(len(rows) - 1, -1, -1):
        if rows[i].get("blessed"):
            return rows[i:]
    return rows


def check(history, current, *, window: int = 8, rel_threshold: float = 0.35,
          min_baseline: int = 3, noise_sigmas: float = 3.0,
          trim: float = 0.2) -> dict:
    """Gate `current` records against `history`.

    Returns {"findings": [...], "checked": n, "skipped": [(key, why)]}.
    A finding means: the current value is past the relative threshold AND
    past the repeat-noise floor, against the trimmed mean of the last
    `window` comparable runs (needing at least `min_baseline` of them —
    young histories never alarm).
    """
    findings = []
    skipped = []
    checked = 0
    for cur in current:
        key = f"{cur.get('benchmark')}/{cur.get('metric')}"
        direction = cur.get("direction")
        if direction not in ("lower", "higher"):
            skipped.append((key, "no direction (recorded, not gated)"))
            continue
        if not cur.get("ok", True):
            skipped.append((key, "benchmark failed (gated by CI already)"))
            continue
        base_rows = _comparable(history, cur)[-window:]
        if len(base_rows) < min_baseline:
            skipped.append((key, f"insufficient history "
                                 f"({len(base_rows)}/{min_baseline})"))
            continue
        baseline = trimmed_mean([h["value"] for h in base_rows], trim=trim)
        noise = noise_sigmas * _stdev(cur.get("repeat_values") or [])
        value = float(cur["value"])
        checked += 1
        if direction == "lower":
            limit = baseline * (1.0 + rel_threshold) + noise
            regressed = value > limit
        else:
            limit = baseline * (1.0 - rel_threshold) - noise
            regressed = value < limit
        if regressed:
            findings.append({
                "benchmark": cur.get("benchmark"),
                "metric": cur.get("metric"),
                "value": value, "baseline": baseline, "limit": limit,
                "ratio": (value / baseline if baseline else math.inf),
                "direction": direction, "n_baseline": len(base_rows),
                "noise_floor": noise,
                "fingerprint": cur.get("fingerprint"),
            })
    findings.sort(key=lambda f: (f["benchmark"], f["metric"]))
    return {"findings": findings, "checked": checked, "skipped": skipped}


def render(result: dict, title: str = "regression sentinel") -> str:
    """Human-readable report of a `check()` result."""
    findings = result.get("findings", [])
    lines = [f"== {title}: {len(findings)} regression(s), "
             f"{result.get('checked', 0)} metric(s) checked =="]
    if findings:
        lines.append(f"  {'benchmark/metric':<36} {'value':>12} "
                     f"{'baseline':>12} {'limit':>12} {'ratio':>7}")
        for f in findings:
            lines.append(f"  {f['benchmark'] + '/' + f['metric']:<36} "
                         f"{f['value']:>12.4g} {f['baseline']:>12.4g} "
                         f"{f['limit']:>12.4g} {f['ratio']:>7.2f}")
    for key, why in result.get("skipped", []):
        lines.append(f"  skipped {key}: {why}")
    return "\n".join(lines)


def worst(result: dict) -> Optional[dict]:
    """The finding with the largest relative excursion, or None."""
    findings = result.get("findings", [])
    if not findings:
        return None
    return max(findings, key=lambda f: (f["ratio"] if f["direction"] ==
                                        "lower" else 1.0 / max(f["ratio"],
                                                               1e-30)))
