"""Per-program device cost model + roofline-fraction attribution.

The paper's claims are *cost* claims — O(n²) multiplications for the exact
embedding, O(n log n) additions for the Hadamard relaxation, R bits per
dimension on the wire — so a measured span is only half a result; this
module supplies the analytic half. Every named jitted program (the ones
`repro.obs.recompile` tracks: fed.round.*, fed.aggregate.*,
dist.step{,.zero1}, serve.{prefill,decode_step}, the kernel dispatch
wrappers) can be asked, per compiled specialization it was actually called
with, what the compiler says it does: FLOPs and bytes accessed from XLA's
HLO cost analysis, argument/output byte footprints, plus the analytic
wire-bytes the codec audit charges per call. A per-backend peak table then
turns (measured seconds, modeled FLOPs/bytes) into a roofline fraction per
instrumented span.

THE HARD CONSTRAINT, inherited from the PR-7 obs contract: cost extraction
must never trigger a compile. Two mechanisms enforce it:

  * Capture observes calls the instrumented layers already make — it
    records an abstract (shape/dtype/sharding) signature per distinct
    specialization, one cheap dict hit per call, only while an obs session
    with `costs=True` is active. Nothing is ever re-executed.
  * Extraction uses `fn.lower(*abstract_args).cost_analysis()` — a trace +
    HLO analysis with NO backend compile and NO effect on the program's
    jit cache (`_cache_size()` pinned before/after `snapshot()` in the
    regression tests; `tests/test_obs_costs.py` additionally monkeypatches
    the XLA compile entry point to raise). `memory_analysis()` (peak /
    temp device bytes) genuinely needs a compiled executable, so it is
    behind an explicit `snapshot(compile_ok=True)` opt-in that performs an
    AOT compile OUTSIDE every jit cache — never on by default.

Backends whose cost analysis is unavailable (or whose programs refuse to
re-lower) degrade per specialization to `available: False` with the
recorded reason — a cost model must never crash a benchmark.
"""
from __future__ import annotations

import os
from typing import Optional

# ---------------------------------------------------------------------------
# Per-backend peak table (device_kind prefix match first, backend fallback).
# Dense-compute peaks in FLOP/s and HBM/DRAM stream bandwidth in bytes/s —
# deliberately round numbers: the roofline fraction is an attribution aid
# ("this span reaches 3% of peak"), not a measurement. Override with
# REPRO_PEAK_FLOPS / REPRO_PEAK_BYTES (floats) for calibrated hardware.
# ---------------------------------------------------------------------------
DEVICE_PEAKS = (
    ("TPU v5p", 459e12, 2.77e12),
    ("TPU v5e", 197e12, 8.2e11),
    ("TPU v4", 275e12, 1.2e12),
    ("TPU v3", 123e12, 9.0e11),
    ("TPU v2", 46e12, 7.0e11),
)
BACKEND_PEAKS = {
    "tpu": (275e12, 1.2e12),
    "gpu": (1.0e14, 2.0e12),
    "cpu": (1.0e11, 5.0e10),   # one AVX-ish core complex + DDR stream
}


def peaks(backend: Optional[str] = None,
          device_kind: Optional[str] = None) -> dict:
    """{"flops_per_s", "bytes_per_s", "backend", "device_kind", "source"}.

    Resolution order: env override → device-kind prefix in DEVICE_PEAKS →
    backend default → cpu default. Never raises (jax probing is guarded):
    a missing accelerator yields the cpu row, with the source recorded.
    """
    if backend is None or device_kind is None:
        try:
            import jax                                  # noqa: PLC0415
            backend = backend or jax.default_backend()
            if device_kind is None:
                devs = jax.devices()
                device_kind = devs[0].device_kind if devs else None
        except Exception:
            pass
    env_f = os.environ.get("REPRO_PEAK_FLOPS")
    env_b = os.environ.get("REPRO_PEAK_BYTES")
    if env_f is not None and env_b is not None:
        return {"flops_per_s": float(env_f), "bytes_per_s": float(env_b),
                "backend": backend, "device_kind": device_kind,
                "source": "env"}
    if device_kind:
        for prefix, fl, by in DEVICE_PEAKS:
            if str(device_kind).startswith(prefix):
                return {"flops_per_s": fl, "bytes_per_s": by,
                        "backend": backend, "device_kind": device_kind,
                        "source": "device_table"}
    fl, by = BACKEND_PEAKS.get(backend or "cpu", BACKEND_PEAKS["cpu"])
    return {"flops_per_s": fl, "bytes_per_s": by, "backend": backend,
            "device_kind": device_kind, "source": "backend_default"}


# ---------------------------------------------------------------------------
# Call capture: one record per (program name, abstract signature, statics)
# ---------------------------------------------------------------------------
def _leaf_sig(x):
    """Hashable per-leaf signature component. Arrays (incl. tracers) key by
    shape/dtype; python scalars key by TYPE only — jit traces them as weak
    dynamic scalars, so e.g. a round index must not mint a new
    specialization per value."""
    shape = getattr(x, "shape", None)
    dtype = getattr(x, "dtype", None)
    if shape is not None and dtype is not None:
        return ("a", tuple(shape), str(dtype))
    if isinstance(x, (bool, int, float)):
        return (type(x).__name__,)
    return ("other", type(x).__qualname__)


def _abstractify(x):
    """Array-likes → ShapeDtypeStruct (keeping a NamedSharding so the
    re-lowered program matches the sharded one that actually ran); python
    scalars pass through to `lower()` unchanged. Tracers are reduced to
    their shape/dtype — capture never retains a live tracer."""
    shape = getattr(x, "shape", None)
    dtype = getattr(x, "dtype", None)
    if shape is None or dtype is None:
        return x
    import jax                                          # noqa: PLC0415
    from jax.sharding import NamedSharding              # noqa: PLC0415
    try:
        sharding = getattr(x, "sharding", None)
    except Exception:
        sharding = None
    if isinstance(sharding, NamedSharding):
        return jax.ShapeDtypeStruct(tuple(shape), dtype, sharding=sharding)
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def record_call(store: dict, name: str, fn, args, kwargs=None, *,
                static=None, jit_wrap: bool = False,
                span: Optional[str] = None, wire_bytes=None) -> None:
    """Observe one call of `fn` (a jitted program, or with `jit_wrap=True`
    a plain traceable callable) under program `name`.

    `store` is the owning Obs session's capture dict. First sighting of a
    signature abstracts and stores the args; every sighting bumps the call
    count and accumulates `wire_bytes` (the analytic minimum-traffic bytes
    this call puts on the wire, from the codec audit). `static` is a
    hashable tag for compile-time parameters closed over by `fn` (e.g.
    quantizer bits) so differently-specialized closures don't collide.
    `span` names the host-side obs span whose measured time this program
    should be attributed to (default: the program name itself).
    """
    import jax                                          # noqa: PLC0415
    kwargs = kwargs or {}
    leaves, treedef = jax.tree.flatten((args, kwargs))
    sig = (name, treedef, tuple(_leaf_sig(x) for x in leaves), static)
    rec = store.get(sig)
    if rec is None:
        a_args, a_kwargs = jax.tree.map(_abstractify, (args, kwargs))
        store[sig] = rec = {
            "name": name, "fn": fn, "args": a_args, "kwargs": a_kwargs,
            "static": static, "jit_wrap": jit_wrap, "span": span,
            "calls": 0, "wire_bytes": 0.0, "cost": None, "cost_mem": None,
        }
    rec["calls"] += 1
    if wire_bytes:
        rec["wire_bytes"] += float(wire_bytes)


# ---------------------------------------------------------------------------
# Extraction (cached per capture record)
# ---------------------------------------------------------------------------
def _normalize_cost(ca) -> dict:
    """XLA returns a dict (Lowered) or a per-partition list (Compiled)."""
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


def _leaf_bytes(tree) -> float:
    import jax                                          # noqa: PLC0415
    import numpy as np                                  # noqa: PLC0415
    total = 0.0
    for leaf in jax.tree.leaves(tree):
        shape = getattr(leaf, "shape", None)
        dtype = getattr(leaf, "dtype", None)
        if shape is None or dtype is None:
            continue
        try:
            itemsize = np.dtype(dtype).itemsize
        except Exception:
            # extended dtypes (typed PRNG keys: 'key<fry>') aren't numpy
            # dtypes; their itemsize attribute covers the wire footprint
            itemsize = getattr(dtype, "itemsize", None)
            if itemsize is None:
                continue
        total += float(np.prod(shape, dtype=np.float64) * itemsize)
    return total


def _extract(rec: dict, compile_ok: bool) -> dict:
    """Cost-analyze one captured specialization. `lower()` only (trace +
    HLO analysis; no backend compile, no jit-cache effect) unless
    `compile_ok`, which additionally AOT-compiles for `memory_analysis()`.
    Any failure degrades to available=False with the reason recorded."""
    cached = rec["cost_mem"] if compile_ok else rec["cost"]
    if cached is not None:
        return cached
    out = {"sig": _sig_str(rec), "calls": 0, "available": False,
           "reason": None, "source": None, "flops": None,
           "bytes_accessed": None, "argument_bytes": None,
           "output_bytes": None, "temp_bytes": None, "peak_bytes": None}
    try:
        import jax                                      # noqa: PLC0415
        fn = jax.jit(rec["fn"]) if rec["jit_wrap"] else rec["fn"]
        lowered = fn.lower(*rec["args"], **rec["kwargs"])
        out["argument_bytes"] = _leaf_bytes((rec["args"], rec["kwargs"]))
        if compile_ok:
            compiled = lowered.compile()
            ca = _normalize_cost(compiled.cost_analysis())
            out["source"] = "compiled"
            try:
                mem = compiled.memory_analysis()
                arg = float(mem.argument_size_in_bytes)
                outb = float(mem.output_size_in_bytes)
                tmp = float(mem.temp_size_in_bytes)
                out.update(argument_bytes=arg, output_bytes=outb,
                           temp_bytes=tmp, peak_bytes=arg + outb + tmp)
            except Exception as e:                      # pragma: no cover
                out["reason"] = f"memory_analysis: {type(e).__name__}: {e}"
        else:
            ca = _normalize_cost(lowered.cost_analysis())
            out["source"] = "lowered"
        flops = ca.get("flops")
        accessed = ca.get("bytes accessed")
        out["flops"] = float(flops) if flops is not None else None
        out["bytes_accessed"] = (float(accessed)
                                 if accessed is not None else None)
        if out["flops"] is None and out["bytes_accessed"] is None:
            out["reason"] = ("cost analysis reported neither flops nor "
                             "bytes accessed on this backend")
        else:
            out["available"] = True
    except Exception as e:
        out["reason"] = f"{type(e).__name__}: {e}"
    if compile_ok:
        rec["cost_mem"] = out
    else:
        rec["cost"] = out
    return out


def _sig_str(rec: dict) -> str:
    import jax                                          # noqa: PLC0415
    parts = []
    for leaf in jax.tree.leaves((rec["args"], rec["kwargs"])):
        shape = getattr(leaf, "shape", None)
        dtype = getattr(leaf, "dtype", None)
        if shape is not None and dtype is not None:
            parts.append(f"{dtype}[{','.join(map(str, shape))}]")
        else:
            parts.append(type(leaf).__name__)
    tail = f" static={rec['static']!r}" if rec["static"] is not None else ""
    return f"({', '.join(parts)}){tail}"


def snapshot(captures: dict, *, compile_ok: bool = False,
             peak_info: Optional[dict] = None) -> dict:
    """Fold a session's captures into the per-program cost table.

    {"peaks": {...}, "programs": {name: {"span", "calls", "wire_bytes",
    "flops_total", "bytes_total", "cost_coverage", "specializations":
    [...]}}}. Totals weight each specialization's analysis by its observed
    call count; `cost_coverage` is the fraction of observed calls whose
    specialization produced an analysis (1.0 = fully modeled). Extraction
    is cached per specialization, so repeated snapshots are cheap.
    """
    from repro.obs import recompile as recompile_lib    # noqa: PLC0415
    annotations = recompile_lib.annotations_by_name()
    programs: dict = {}
    for rec in captures.values():
        name = rec["name"]
        ann = annotations.get(name, {})
        prog = programs.setdefault(name, {
            "span": rec["span"] or ann.get("span") or name,
            "calls": 0, "wire_bytes": 0.0, "flops_total": 0.0,
            "bytes_total": 0.0, "covered_calls": 0,
            "annotations": {k: v for k, v in ann.items() if k != "span"},
            "specializations": []})
        spec = dict(_extract(rec, compile_ok))
        spec["calls"] = rec["calls"]
        prog["specializations"].append(spec)
        prog["calls"] += rec["calls"]
        prog["wire_bytes"] += rec["wire_bytes"]
        if spec["available"]:
            prog["covered_calls"] += rec["calls"]
            if spec["flops"] is not None:
                prog["flops_total"] += spec["flops"] * rec["calls"]
            if spec["bytes_accessed"] is not None:
                prog["bytes_total"] += spec["bytes_accessed"] * rec["calls"]
    for prog in programs.values():
        prog["specializations"].sort(key=lambda s: s["sig"])
        prog["cost_coverage"] = (prog.pop("covered_calls") / prog["calls"]
                                 if prog["calls"] else 0.0)
    return {"peaks": peak_info or peaks(),
            "programs": {k: programs[k] for k in sorted(programs)}}


# ---------------------------------------------------------------------------
# Roofline-fraction attribution onto measured spans
# ---------------------------------------------------------------------------
def attach_attrib(summary: dict, snap: dict) -> dict:
    """Mutate `summary` (a `report.summarize` result): every span that a
    cost-modeled program attributes to gains an `attrib` block — measured
    seconds vs the model-predicted FLOP time and byte time from the peak
    table, the achieved roofline fraction, which roof binds, and achieved
    wire-bytes/s against the analytic R·n minimum-traffic bytes."""
    spans = summary.get("spans", {})
    pk = snap.get("peaks", {})
    by_span: dict = {}
    for name, prog in snap.get("programs", {}).items():
        by_span.setdefault(prog.get("span") or name, []).append((name, prog))
    for span_name in sorted(by_span):
        sp = spans.get(span_name)
        if sp is None:
            continue
        group = by_span[span_name]
        flops = sum(p["flops_total"] for _, p in group)
        nbytes = sum(p["bytes_total"] for _, p in group)
        wire = sum(p["wire_bytes"] for _, p in group)
        calls = sum(p["calls"] for _, p in group)
        covered = sum(p["cost_coverage"] * p["calls"] for _, p in group)
        measured = sp.get("total_s", 0.0)
        t_flops = flops / pk["flops_per_s"] if pk.get("flops_per_s") else None
        t_bytes = nbytes / pk["bytes_per_s"] if pk.get("bytes_per_s") else None
        t_model = max(t_flops or 0.0, t_bytes or 0.0) or None
        attrib = {
            "programs": sorted(n for n, _ in group),
            "calls_observed": calls,
            "cost_coverage": (covered / calls) if calls else 0.0,
            "flops_total": flops or None,
            "bytes_total": nbytes or None,
            "measured_s": measured,
            "t_flops_s": t_flops if flops else None,
            "t_bytes_s": t_bytes if nbytes else None,
            "t_model_s": t_model if (flops or nbytes) else None,
            "roofline_frac": None, "bound": None,
            "flops_per_s_achieved": (flops / measured
                                     if flops and measured > 0 else None),
            "bytes_per_s_achieved": (nbytes / measured
                                     if nbytes and measured > 0 else None),
            "wire_min_bytes": wire or None,
            "wire_min_bytes_per_s": (wire / measured
                                     if wire and measured > 0 else None),
        }
        if attrib["t_model_s"] and measured > 0:
            attrib["roofline_frac"] = attrib["t_model_s"] / measured
            attrib["bound"] = ("flops" if (t_flops or 0.0) >= (t_bytes or 0.0)
                               else "bytes")
        sp["attrib"] = attrib
    return summary
