"""Spans, counters, gauges, histograms — zero overhead when disabled.

The module-level API (`span`, `counter`, `gauge`, `histogram`, `traced`)
reads one global: the currently active `Obs` session. Disabled (the
default) every call is a global load + an early return — `span` hands back
a shared no-op context manager, the metric calls return before touching
their arguments — so instrumentation can live permanently on the host-side
hot paths. None of it ever runs inside jit-compiled code: spans time the
host's view of a dispatch (`time.perf_counter`), which includes device
work only insofar as the call blocks; pair with the `jax.profiler`
passthrough (`enable(jax_trace_dir=...)`) for device timelines.

The hard contract the fed/dist regression tests pin: enabling obs changes
no numerics (params/EF/ledger/history bit-exact with disabled) and causes
no extra compiles (`recompile.counts()` deltas identical) — everything
here is observe-only, on the host, outside compiled code.

Sessions nest as a stack: `enable()` pushes a new session (innermost
wins), `disable()` pops and closes it (flushing JSONL, writing
trace.json); `use(obs)` activates an existing session for a scope without
owning its lifetime; `suspended()` blanks the stack for a scope — how the
overhead benchmark keeps its disabled arm clean inside an obs-enabled
benchmark runner.
"""
from __future__ import annotations

import contextlib
import functools
import os
import threading
import time
from typing import Optional

from repro.obs import costs as costs_lib
from repro.obs import recompile
from repro.obs import report as report_lib
from repro.obs import sinks as sinks_lib
from repro.obs import trace as trace_lib

_STACK: list["Obs"] = []          # innermost active session last
_ACTIVE: Optional["Obs"] = None   # == _STACK[-1] (None: disabled)


class _NoopSpan:
    """The shared disabled-path span: enter/exit do nothing."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NOOP_SPAN = _NoopSpan()


class Span:
    """A wall-clock span; emits one event on exit. Use via `obs.span(...)`."""
    __slots__ = ("_obs", "name", "attrs", "_t0")

    def __init__(self, obs: "Obs", name: str, attrs: dict):
        self._obs = obs
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        tls = self._obs._tls
        tls.depth = getattr(tls, "depth", 0) + 1
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        o = self._obs
        depth = o._tls.depth
        o._tls.depth = depth - 1
        o.emit({"type": "span", "name": self.name,
                "ts": self._t0 - o._epoch, "dur": t1 - self._t0,
                "pid": o._pid, "tid": threading.get_ident() & 0x7FFFFFFF,
                "depth": depth, "attrs": self.attrs})
        return False


class Obs:
    """One telemetry session: an event clock, a sink list, and a recompile
    baseline. Construct directly for tests, or via `enable()`."""

    def __init__(self, sinks=(), jax_trace_dir: Optional[str] = None,
                 costs: bool = True):
        self.sinks = list(sinks)
        self.costs_enabled = costs
        self._cost_captures: dict = {}   # sig -> capture record (costs.py)
        self._epoch = time.perf_counter()
        self._pid = os.getpid()
        self._tls = threading.local()
        self._pinned: list = []       # programs registered while active
        self._baseline = recompile.counts()
        self._summary: Optional[dict] = None
        self.closed = False
        self.jax_trace_active = False
        self.jax_trace_error: Optional[str] = None
        if jax_trace_dir is not None:
            ok, why = trace_lib.start_jax_trace(jax_trace_dir)
            self.jax_trace_active = ok
            self.jax_trace_error = why
        recompile.add_callback(self._on_register)

    # -- recompile pinning ---------------------------------------------------
    def _on_register(self, name: str, fn) -> None:
        # keep programs registered during this session alive until the
        # summary reads their final cache size (a benchmark's Federation may
        # be garbage before the summary is built)
        self._pinned.append(fn)

    # -- emission ------------------------------------------------------------
    def now(self) -> float:
        return time.perf_counter() - self._epoch

    def emit(self, event: dict) -> None:
        for s in self.sinks:
            s.emit(event)

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs)

    def _metric(self, etype: str, name: str, value, attrs: dict) -> None:
        self.emit({"type": etype, "name": name, "ts": self.now(),
                   "value": float(value), "pid": self._pid,
                   "tid": threading.get_ident() & 0x7FFFFFFF,
                   "attrs": attrs})

    def counter(self, name: str, value=1, **attrs) -> None:
        self._metric("counter", name, value, attrs)

    def gauge(self, name: str, value, **attrs) -> None:
        self._metric("gauge", name, value, attrs)

    def histogram(self, name: str, value, **attrs) -> None:
        self._metric("hist", name, value, attrs)

    def meta(self, name: str, **data) -> None:
        self.emit({"type": "meta", "name": name, "ts": self.now(),
                   "pid": self._pid, "tid": 0, "data": data})

    # -- cost capture --------------------------------------------------------
    def observe_call(self, name: str, fn, args, kwargs=None, *,
                     static=None, jit_wrap: bool = False,
                     span: Optional[str] = None, wire_bytes=None) -> None:
        """Record one observed call of a named program for the cost model
        (abstract signature + call count + analytic wire bytes). Never
        executes or compiles anything; never raises."""
        if not self.costs_enabled:
            return
        try:
            costs_lib.record_call(self._cost_captures, name, fn, args,
                                  kwargs, static=static, jit_wrap=jit_wrap,
                                  span=span, wire_bytes=wire_bytes)
        except Exception:
            pass                          # the cost model must never crash

    def costs(self, *, compile_ok: bool = False) -> dict:
        """Per-program cost snapshot of every specialization observed while
        this session was active (see `repro.obs.costs.snapshot`). Default is
        compile-free (`Lowered.cost_analysis`); `compile_ok=True` adds
        `memory_analysis` via an AOT compile outside every jit cache."""
        return costs_lib.snapshot(self._cost_captures, compile_ok=compile_ok)

    # -- readback ------------------------------------------------------------
    def memory_events(self) -> list:
        for s in self.sinks:
            if isinstance(s, sinks_lib.MemorySink):
                return s.events
        return []

    def recompiles(self) -> dict:
        """Per-program compiles since this session was enabled."""
        return recompile.delta(self._baseline, recompile.counts())

    def summary(self) -> dict:
        """Aggregate view (spans/metrics from the memory sink, recompile
        deltas, jax-trace status). Cached at close time."""
        if self._summary is not None:
            return self._summary
        s = report_lib.summarize(self.memory_events(),
                                 recompiles=self.recompiles())
        s["jax_trace"] = {"active": self.jax_trace_active,
                          "error": self.jax_trace_error}
        if self.costs_enabled:
            try:
                snap = self.costs()
                s["costs"] = snap
                costs_lib.attach_attrib(s, snap)
            except Exception as e:        # degrade, never crash a summary
                s["costs"] = {"error": f"{type(e).__name__}: {e}",
                              "programs": {}}
        if self.closed:
            self._summary = s
        return s

    def close(self) -> dict:
        """Stop the jax trace, freeze the summary, flush/close every sink,
        release pinned programs. Idempotent; returns the summary."""
        if self.closed:
            return self.summary()
        if self.jax_trace_active:
            trace_lib.stop_jax_trace()
            self.jax_trace_active = False
        recompile.remove_callback(self._on_register)
        self.closed = True
        s = self.summary()          # caches (pins still alive here)
        # surface attribution as counter tracks in the Chrome trace: one
        # final sample per attributed span (after the cached summary, so
        # these synthetic events never pollute the aggregates)
        for span_name, sp in s["spans"].items():
            at = sp.get("attrib") or {}
            for key in ("roofline_frac", "flops_per_s_achieved",
                        "wire_min_bytes_per_s"):
                if at.get(key) is not None:
                    self._metric("gauge", f"attrib.{span_name}.{key}",
                                 at[key], {})
        self.meta("obs.summary", **{"spans": len(s["spans"]),
                                    "events": s["events"]})
        for sink in self.sinks:
            sink.close()
        self._pinned.clear()
        return s


# ---------------------------------------------------------------------------
# The module-global session stack
# ---------------------------------------------------------------------------
def enabled() -> bool:
    return _ACTIVE is not None


def get() -> Optional[Obs]:
    """The innermost active session, or None when disabled."""
    return _ACTIVE


def _set_active(obs: Optional[Obs]) -> None:
    global _ACTIVE
    _ACTIVE = obs


def enable(*, memory: bool = True, jsonl: Optional[str] = None,
           trace: Optional[str] = None,
           jax_trace_dir: Optional[str] = None, sinks=(),
           costs: bool = True) -> Obs:
    """Activate a new session. `memory=True` keeps events in-process for
    `summary()`; `jsonl=`/`trace=` add file sinks (the trace file is
    written at `disable()`); `jax_trace_dir=` starts the optional
    `jax.profiler` passthrough (no-op with a recorded reason when the
    profiler is unavailable); `costs=True` (default) captures per-program
    call signatures for the device cost model (`session.costs()`, and the
    `costs`/`attrib` blocks of the summary). Returns the session (keep it:
    `summary()` stays readable after `disable()`)."""
    built = list(sinks)
    if memory:
        built.append(sinks_lib.MemorySink())
    if jsonl is not None:
        built.append(sinks_lib.JsonlSink(jsonl))
    if trace is not None:
        built.append(trace_lib.ChromeTraceSink(trace))
    obs = Obs(built, jax_trace_dir=jax_trace_dir, costs=costs)
    _STACK.append(obs)
    _set_active(obs)
    return obs


def disable() -> Optional[Obs]:
    """Close and pop the innermost session; returns it (summary intact)."""
    if not _STACK:
        return None
    obs = _STACK.pop()
    _set_active(_STACK[-1] if _STACK else None)
    obs.close()
    return obs


@contextlib.contextmanager
def use(obs: Obs):
    """Activate an existing session for a scope (does NOT close it)."""
    _STACK.append(obs)
    _set_active(obs)
    try:
        yield obs
    finally:
        if _STACK and _STACK[-1] is obs:
            _STACK.pop()
        elif obs in _STACK:          # exception unwound past inner enables
            _STACK.remove(obs)
        _set_active(_STACK[-1] if _STACK else None)


@contextlib.contextmanager
def suspended():
    """Disable observability for a scope without closing any session."""
    global _STACK
    saved, _STACK = _STACK, []
    _set_active(None)
    try:
        yield
    finally:
        _STACK = saved
        _set_active(_STACK[-1] if _STACK else None)


def reset() -> None:
    """Close every active session (test teardown hygiene)."""
    while _STACK:
        disable()


# -- the disabled-fast-path module API --------------------------------------
def span(name: str, **attrs):
    o = _ACTIVE
    if o is None:
        return NOOP_SPAN
    return o.span(name, **attrs)


def counter(name: str, value=1, **attrs) -> None:
    o = _ACTIVE
    if o is not None:
        o._metric("counter", name, value, attrs)


def gauge(name: str, value, **attrs) -> None:
    o = _ACTIVE
    if o is not None:
        o._metric("gauge", name, value, attrs)


def histogram(name: str, value, **attrs) -> None:
    o = _ACTIVE
    if o is not None:
        o._metric("hist", name, value, attrs)


def observe_program_call(name: str, fn, args, kwargs=None, *,
                         static=None, jit_wrap: bool = False,
                         span: Optional[str] = None, wire_bytes=None) -> None:
    """Cost-model capture hook for instrumented call sites: record that the
    named program is about to run with these arguments. Disabled sessions
    (and sessions with `costs=False`) cost one global load + early return;
    active capture is one dict probe per call (no execution, no compile)."""
    o = _ACTIVE
    if o is None:
        return
    o.observe_call(name, fn, args, kwargs, static=static, jit_wrap=jit_wrap,
                   span=span, wire_bytes=wire_bytes)


def traced(name: Optional[str] = None, **attrs):
    """Decorator form of `span`: times every call of the wrapped function
    under `name` (default: its qualname). Disabled sessions cost one global
    load per call."""
    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            o = _ACTIVE
            if o is None:
                return fn(*args, **kwargs)
            with o.span(label, **attrs):
                return fn(*args, **kwargs)
        return wrapper
    return deco
