"""repro.obs — zero-overhead-when-disabled telemetry for the whole stack.

Spans (context manager + decorator on monotonic `perf_counter`), typed
counters/gauges/histograms, a JSONL event sink + an in-memory sink, a
Chrome/Perfetto trace exporter (open `trace.json` in ui.perfetto.dev),
an optional `jax.profiler.trace` passthrough, and a recompile tracker
that attributes compilation-cache growth to named jitted programs.

    from repro import obs

    session = obs.enable(jsonl="events.jsonl", trace="trace.json")
    with obs.span("fed.round", round=0):
        obs.counter("fed.wire_bytes", 1234)
    obs.disable()                       # flushes JSONL, writes trace.json
    print(obs.report.render(session.summary()))

v2 adds the cost-attributed layer: sessions capture the abstract call
signatures of every registered jitted program that runs while they are
active, and `session.costs()` / the summary's `costs` + per-span `attrib`
blocks report compiler-modeled FLOPs / bytes per specialization, roofline
fractions against a per-backend peak table (`obs.costs.peaks`), and
achieved wire-bytes/s against the analytic R·n minimum-traffic model —
all extracted via compile-free lowering, preserving the obs contract.
`obs.history` + `obs.regress` persist benchmark runs to an append-only
`BENCH_history.jsonl` and gate new runs against the trailing baseline
(`python -m benchmarks.run --check-regressions`).

Disabled (the default), every instrumentation call is a global load + an
early return, and the instrumented layers (`repro.fed.rounds`,
`repro.dist.step`, `repro.kernels.ops`, `repro.serve.scheduler`) are
regression-tested bit-exact and recompile-free against their
uninstrumented behavior: everything here observes from the host side,
outside compiled code. The package imports without jax; the profiler
passthrough degrades to a recorded no-op when `jax.profiler` tracing is
unavailable (CPU CI).
"""
from repro.obs import costs, history, recompile, regress, report, sinks, trace
from repro.obs.core import (NOOP_SPAN, Obs, Span, counter, disable, enable,
                            enabled, gauge, get, histogram,
                            observe_program_call, reset, span, suspended,
                            traced, use)
from repro.obs.sinks import EventList, JsonlSink, MemorySink, load_jsonl
from repro.obs.trace import ChromeTraceSink, build_trace, validate_trace

__all__ = [
    "ChromeTraceSink", "EventList", "JsonlSink", "MemorySink", "NOOP_SPAN",
    "Obs", "Span", "build_trace", "costs", "counter", "disable", "enable",
    "enabled", "gauge", "get", "histogram", "history", "load_jsonl",
    "observe_program_call", "recompile", "regress", "report", "reset",
    "sinks", "span", "suspended", "trace", "traced", "use",
    "validate_trace",
]
