"""repro.obs — zero-overhead-when-disabled telemetry for the whole stack.

Spans (context manager + decorator on monotonic `perf_counter`), typed
counters/gauges/histograms, a JSONL event sink + an in-memory sink, a
Chrome/Perfetto trace exporter (open `trace.json` in ui.perfetto.dev),
an optional `jax.profiler.trace` passthrough, and a recompile tracker
that attributes compilation-cache growth to named jitted programs.

    from repro import obs

    session = obs.enable(jsonl="events.jsonl", trace="trace.json")
    with obs.span("fed.round", round=0):
        obs.counter("fed.wire_bytes", 1234)
    obs.disable()                       # flushes JSONL, writes trace.json
    print(obs.report.render(session.summary()))

Disabled (the default), every instrumentation call is a global load + an
early return, and the instrumented layers (`repro.fed.rounds`,
`repro.dist.step`, `repro.kernels.ops`, `repro.serve.scheduler`) are
regression-tested bit-exact and recompile-free against their
uninstrumented behavior: everything here observes from the host side,
outside compiled code. The package imports without jax; the profiler
passthrough degrades to a recorded no-op when `jax.profiler` tracing is
unavailable (CPU CI).
"""
from repro.obs import recompile, report, sinks, trace
from repro.obs.core import (NOOP_SPAN, Obs, Span, counter, disable, enable,
                            enabled, gauge, get, histogram, reset, span,
                            suspended, traced, use)
from repro.obs.sinks import JsonlSink, MemorySink, load_jsonl
from repro.obs.trace import ChromeTraceSink, build_trace, validate_trace

__all__ = [
    "ChromeTraceSink", "JsonlSink", "MemorySink", "NOOP_SPAN", "Obs",
    "Span", "build_trace", "counter", "disable", "enable", "enabled",
    "gauge", "get", "histogram", "load_jsonl", "recompile", "report",
    "reset", "sinks", "span", "suspended", "trace", "traced", "use",
    "validate_trace",
]
