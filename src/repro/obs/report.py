"""Aggregate obs events into a per-run summary (dict + rendered table).

`summarize` folds a flat event list (from a MemorySink or a JSONL file)
into per-name statistics; `render` formats the result as the text table
`benchmarks/run.py` prints per benchmark. The dict is JSON-able as-is —
it is what lands under each benchmark's `"obs"` key in `BENCH_*.json`.
"""
from __future__ import annotations


def _percentile(sorted_vals: list, q: float) -> float:
    """Nearest-rank percentile of an already-sorted list."""
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, max(0, round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


def summarize(events, recompiles=None) -> dict:
    """Fold events into {"spans", "counters", "gauges", "hists",
    "recompiles", "events"}.

    spans:    per name — count, total_s, mean_s, max_s
    counters: per name — total (sum of values), count
    gauges:   per name — last, min, max
    hists:    per name — count, mean, p50, p95, min, max
    """
    spans: dict = {}
    counters: dict = {}
    gauges: dict = {}
    hists: dict = {}
    for e in events:
        etype, name = e.get("type"), e.get("name")
        if etype == "span":
            s = spans.setdefault(name, {"count": 0, "total_s": 0.0,
                                        "max_s": 0.0})
            dur = float(e.get("dur", 0.0))
            s["count"] += 1
            s["total_s"] += dur
            s["max_s"] = max(s["max_s"], dur)
        elif etype == "counter":
            c = counters.setdefault(name, {"total": 0.0, "count": 0})
            c["total"] += float(e.get("value", 0.0))
            c["count"] += 1
        elif etype == "gauge":
            v = float(e.get("value", 0.0))
            g = gauges.setdefault(name, {"last": v, "min": v, "max": v})
            g["last"] = v
            g["min"] = min(g["min"], v)
            g["max"] = max(g["max"], v)
        elif etype == "hist":
            hists.setdefault(name, []).append(float(e.get("value", 0.0)))
    for s in spans.values():
        s["mean_s"] = s["total_s"] / s["count"] if s["count"] else 0.0
    hstats = {}
    for name, vals in hists.items():
        vals.sort()
        hstats[name] = {"count": len(vals),
                        "mean": sum(vals) / len(vals),
                        "p50": _percentile(vals, 0.50),
                        "p95": _percentile(vals, 0.95),
                        "min": vals[0], "max": vals[-1]}
    return {"events": len(events), "spans": spans, "counters": counters,
            "gauges": gauges, "hists": hstats,
            "recompiles": dict(recompiles or {})}


def render(summary: dict, title: str = "obs summary") -> str:
    """Human-readable table of a `summarize` result."""
    lines = [f"== {title} ({summary.get('events', 0)} events) =="]
    spans = summary.get("spans", {})
    if spans:
        lines.append(f"  {'span':<28} {'count':>7} {'total ms':>10} "
                     f"{'mean ms':>10} {'max ms':>10}")
        for name in sorted(spans):
            s = spans[name]
            lines.append(f"  {name:<28} {s['count']:>7} "
                         f"{s['total_s'] * 1e3:>10.2f} "
                         f"{s['mean_s'] * 1e3:>10.3f} "
                         f"{s['max_s'] * 1e3:>10.2f}")
    counters = summary.get("counters", {})
    if counters:
        lines.append(f"  {'counter':<38} {'total':>14} {'events':>8}")
        for name in sorted(counters):
            c = counters[name]
            lines.append(f"  {name:<38} {c['total']:>14g} {c['count']:>8}")
    gauges = summary.get("gauges", {})
    if gauges:
        lines.append(f"  {'gauge':<38} {'last':>10} {'min':>10} {'max':>10}")
        for name in sorted(gauges):
            g = gauges[name]
            lines.append(f"  {name:<38} {g['last']:>10g} {g['min']:>10g} "
                         f"{g['max']:>10g}")
    hists = summary.get("hists", {})
    if hists:
        lines.append(f"  {'histogram':<30} {'count':>7} {'mean':>10} "
                     f"{'p50':>10} {'p95':>10}")
        for name in sorted(hists):
            h = hists[name]
            lines.append(f"  {name:<30} {h['count']:>7} {h['mean']:>10.4g} "
                         f"{h['p50']:>10.4g} {h['p95']:>10.4g}")
    recompiles = summary.get("recompiles", {})
    if recompiles:
        lines.append(f"  {'program (compiles this session)':<44} {'n':>5}")
        for name in sorted(recompiles):
            lines.append(f"  {name:<44} {recompiles[name]:>5}")
    return "\n".join(lines)
