"""Aggregate obs events into a per-run summary (dict + rendered table).

`summarize` folds a flat event list (from a MemorySink or a JSONL file)
into per-name statistics; `render` formats the result as the text table
`benchmarks/run.py` prints per benchmark. The dict is JSON-able as-is —
it is what lands under each benchmark's `"obs"` key in `BENCH_*.json`.
"""
from __future__ import annotations


def _percentile(sorted_vals: list, q: float) -> float:
    """Nearest-rank percentile of an already-sorted list."""
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, max(0, round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


def summarize(events, recompiles=None) -> dict:
    """Fold events into {"spans", "counters", "gauges", "hists",
    "recompiles", "events"}.

    spans:    per name — count, total_s, mean_s, max_s
    counters: per name — total (sum of values), count
    gauges:   per name — last, min, max
    hists:    per name — count, mean, p50, p95, p99, min, max

    Every per-name dict is key-sorted so summaries (and their JSON dumps)
    diff cleanly across runs.
    """
    spans: dict = {}
    counters: dict = {}
    gauges: dict = {}
    hists: dict = {}
    for e in events:
        etype, name = e.get("type"), e.get("name")
        if etype == "span":
            s = spans.setdefault(name, {"count": 0, "total_s": 0.0,
                                        "max_s": 0.0})
            dur = float(e.get("dur", 0.0))
            s["count"] += 1
            s["total_s"] += dur
            s["max_s"] = max(s["max_s"], dur)
        elif etype == "counter":
            c = counters.setdefault(name, {"total": 0.0, "count": 0})
            c["total"] += float(e.get("value", 0.0))
            c["count"] += 1
        elif etype == "gauge":
            v = float(e.get("value", 0.0))
            g = gauges.setdefault(name, {"last": v, "min": v, "max": v})
            g["last"] = v
            g["min"] = min(g["min"], v)
            g["max"] = max(g["max"], v)
        elif etype == "hist":
            hists.setdefault(name, []).append(float(e.get("value", 0.0)))
    for s in spans.values():
        s["mean_s"] = s["total_s"] / s["count"] if s["count"] else 0.0
    hstats = {}
    for name, vals in hists.items():
        vals.sort()
        hstats[name] = {"count": len(vals),
                        "mean": sum(vals) / len(vals),
                        "p50": _percentile(vals, 0.50),
                        "p95": _percentile(vals, 0.95),
                        "p99": _percentile(vals, 0.99),
                        "min": vals[0], "max": vals[-1]}

    def _sorted(d):
        return {k: d[k] for k in sorted(d)}

    rec = dict(recompiles or {})
    return {"events": len(events), "spans": _sorted(spans),
            "counters": _sorted(counters), "gauges": _sorted(gauges),
            "hists": _sorted(hstats), "recompiles": _sorted(rec)}


def render(summary: dict, title: str = "obs summary") -> str:
    """Human-readable table of a `summarize` result."""
    lines = [f"== {title} ({summary.get('events', 0)} events) =="]
    spans = summary.get("spans", {})
    if spans:
        lines.append(f"  {'span':<28} {'count':>7} {'total ms':>10} "
                     f"{'mean ms':>10} {'max ms':>10}")
        for name in sorted(spans):
            s = spans[name]
            lines.append(f"  {name:<28} {s['count']:>7} "
                         f"{s['total_s'] * 1e3:>10.2f} "
                         f"{s['mean_s'] * 1e3:>10.3f} "
                         f"{s['max_s'] * 1e3:>10.2f}")
    counters = summary.get("counters", {})
    if counters:
        lines.append(f"  {'counter':<38} {'total':>14} {'events':>8}")
        for name in sorted(counters):
            c = counters[name]
            lines.append(f"  {name:<38} {c['total']:>14g} {c['count']:>8}")
    gauges = summary.get("gauges", {})
    if gauges:
        lines.append(f"  {'gauge':<38} {'last':>10} {'min':>10} {'max':>10}")
        for name in sorted(gauges):
            g = gauges[name]
            lines.append(f"  {name:<38} {g['last']:>10g} {g['min']:>10g} "
                         f"{g['max']:>10g}")
    hists = summary.get("hists", {})
    if hists:
        lines.append(f"  {'histogram':<30} {'count':>7} {'mean':>10} "
                     f"{'p50':>10} {'p95':>10} {'p99':>10}")
        for name in sorted(hists):
            h = hists[name]
            lines.append(f"  {name:<30} {h['count']:>7} {h['mean']:>10.4g} "
                         f"{h['p50']:>10.4g} {h['p95']:>10.4g} "
                         f"{h.get('p99', h['max']):>10.4g}")
    attrib = {name: sp["attrib"] for name, sp in spans.items()
              if isinstance(sp, dict) and sp.get("attrib")}
    if attrib:
        lines.append(f"  {'attrib (roofline)':<24} {'meas ms':>9} "
                     f"{'model ms':>9} {'frac':>7} {'bound':>6} "
                     f"{'GF/s':>8} {'wire B/s':>10} {'cov':>5}")
        for name in sorted(attrib):
            a = attrib[name]

            def g(key, scale=1.0, fmt="{:.3g}", a=a):
                v = a.get(key)
                return fmt.format(v * scale) if v is not None else "-"

            lines.append(
                f"  {name:<24} {a['measured_s'] * 1e3:>9.2f} "
                f"{g('t_model_s', 1e3, '{:.3f}'):>9} "
                f"{g('roofline_frac', 1.0, '{:.3g}'):>7} "
                f"{(a.get('bound') or '-'):>6} "
                f"{g('flops_per_s_achieved', 1e-9):>8} "
                f"{g('wire_min_bytes_per_s'):>10} "
                f"{a.get('cost_coverage', 0.0):>5.2f}")
    costs = summary.get("costs", {})
    programs = costs.get("programs", {}) if isinstance(costs, dict) else {}
    if programs:
        pk = costs.get("peaks", {})
        lines.append(f"  costs (peaks: {pk.get('source', '?')} "
                     f"{pk.get('flops_per_s', 0):.3g} FLOP/s, "
                     f"{pk.get('bytes_per_s', 0):.3g} B/s)")
        lines.append(f"  {'program':<28} {'calls':>7} {'specs':>6} "
                     f"{'GFLOP':>9} {'GB acc':>9} {'wire MB':>9}")
        for name in sorted(programs):
            p = programs[name]
            lines.append(
                f"  {name:<28} {p['calls']:>7} "
                f"{len(p['specializations']):>6} "
                f"{p['flops_total'] / 1e9:>9.4g} "
                f"{p['bytes_total'] / 1e9:>9.4g} "
                f"{p['wire_bytes'] / 1e6:>9.4g}")
        degraded = sorted({f"{name}: {s['reason']}"
                           for name, p in programs.items()
                           for s in p["specializations"]
                           if not s["available"] and s.get("reason")})
        for msg in degraded:
            lines.append(f"    (cost unavailable) {msg}")
    recompiles = summary.get("recompiles", {})
    if recompiles:
        lines.append(f"  {'program (compiles this session)':<44} {'n':>5}")
        for name in sorted(recompiles):
            lines.append(f"  {name:<44} {recompiles[name]:>5}")
    return "\n".join(lines)
