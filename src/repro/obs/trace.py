"""Chrome trace-event exporter: obs events → `trace.json` for Perfetto.

`ChromeTraceSink` converts each obs event to the Trace Event Format
(https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU)
and writes `{"traceEvents": [...]}` on close — drag the file into
https://ui.perfetto.dev (or chrome://tracing) to browse the span tree.
Spans become complete ("X") events with microsecond ts/dur; counters,
gauges and histogram samples become counter ("C") tracks. `validate_trace`
is the schema check the unit tests and `benchmarks/obs_overhead.py` gate
the emitted file on, so "loads in Perfetto" is asserted structurally, not
by eyeball.

`start_jax_trace` / `stop_jax_trace` wrap the optional `jax.profiler.trace`
passthrough (device-level timelines next to the host-side spans). They
degrade to a no-op with a recorded reason whenever the profiler is missing
or refuses to start — CPU CI runs without profiler support must not crash
(regression-tested via tests/test_obs.py).
"""
from __future__ import annotations

import json
import os
from typing import Union

_US = 1e6                     # seconds -> microseconds
_PHASES = {"B", "E", "X", "C", "M", "I", "i", "b", "e", "n", "s", "t", "f"}


def to_trace_event(event: dict) -> Union[dict, None]:
    """One obs event → one Chrome trace event (None: not representable)."""
    etype = event.get("type")
    pid = int(event.get("pid", 0))
    tid = int(event.get("tid", 0))
    ts = float(event.get("ts", 0.0)) * _US
    if etype == "span":
        return {"name": event["name"], "ph": "X", "ts": ts,
                "dur": float(event.get("dur", 0.0)) * _US,
                "pid": pid, "tid": tid,
                "cat": "span", "args": dict(event.get("attrs") or {})}
    if etype in ("counter", "gauge", "hist"):
        return {"name": event["name"], "ph": "C", "ts": ts,
                "pid": pid, "tid": tid, "cat": etype,
                "args": {"value": float(event.get("value", 0.0))}}
    if etype == "meta":
        return {"name": event["name"], "ph": "i", "ts": ts,
                "pid": pid, "tid": tid, "s": "g",
                "cat": "meta", "args": dict(event.get("data") or {})}
    return None


def build_trace(events, process_name: str = "repro") -> dict:
    """Full Chrome trace document from a list of obs events."""
    pids = sorted({int(e.get("pid", 0)) for e in events}) or [os.getpid()]
    trace_events = [{"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                     "args": {"name": process_name}} for pid in pids]
    for e in events:
        te = to_trace_event(e)
        if te is not None:
            trace_events.append(te)
    return {"traceEvents": trace_events, "displayTimeUnit": "ms"}


class ChromeTraceSink:
    """Accumulates converted events; writes the trace document on close."""

    def __init__(self, path: str, process_name: str = "repro"):
        self.path = path
        self.process_name = process_name
        self._events: list[dict] = []

    def emit(self, event: dict) -> None:
        te = to_trace_event(event)
        if te is not None:
            self._events.append(te)

    def close(self) -> None:
        pids = sorted({e["pid"] for e in self._events}) or [os.getpid()]
        doc = {"traceEvents":
               [{"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                 "args": {"name": self.process_name}} for pid in pids]
               + self._events,
               "displayTimeUnit": "ms"}
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(self.path, "w") as f:
            json.dump(doc, f)


def validate_trace(trace: Union[str, dict, list]) -> int:
    """Assert `trace` (a path, document dict, or bare event list) is valid
    Chrome trace-event JSON; returns the event count. Raises ValueError
    with every violation found — the structural stand-in for "opens in
    ui.perfetto.dev"."""
    if isinstance(trace, str):
        with open(trace) as f:
            trace = json.load(f)
    if isinstance(trace, dict):
        events = trace.get("traceEvents")
        if not isinstance(events, list):
            raise ValueError("trace document must carry a 'traceEvents' list")
    elif isinstance(trace, list):
        events = trace
    else:
        raise ValueError(f"not a trace document: {type(trace)}")
    problems = []
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = e.get("ph")
        if ph not in _PHASES:
            problems.append(f"event {i}: bad phase {ph!r}")
            continue
        if "name" not in e:
            problems.append(f"event {i}: missing name")
        if ph in ("X", "B", "E", "C", "I", "i"):
            if not isinstance(e.get("ts"), (int, float)):
                problems.append(f"event {i} ({ph}): missing numeric ts")
            if "pid" not in e:
                problems.append(f"event {i} ({ph}): missing pid")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: X event needs dur >= 0")
        if ph == "C" and not isinstance(e.get("args"), dict):
            problems.append(f"event {i}: C event needs numeric args")
    if problems:
        raise ValueError("invalid Chrome trace: " + "; ".join(problems[:10]))
    return len(events)


def start_jax_trace(trace_dir: str) -> tuple:
    """Best-effort `jax.profiler.start_trace`; (ok, reason-if-not)."""
    try:
        from jax import profiler                       # noqa: PLC0415
        profiler.start_trace(trace_dir)
        return True, None
    except Exception as e:                             # pragma: no cover -
        # exact failure depends on the runtime (no profiler build, TSL
        # session already active, missing module); they all mean "no
        # device trace", never "crash the run"
        return False, f"{type(e).__name__}: {e}"


def stop_jax_trace() -> tuple:
    """Best-effort `jax.profiler.stop_trace`; (ok, reason-if-not)."""
    try:
        from jax import profiler                       # noqa: PLC0415
        profiler.stop_trace()
        return True, None
    except Exception as e:
        return False, f"{type(e).__name__}: {e}"
