"""Event sinks: in-memory (tests / summaries) and JSONL (artifacts).

Every sink consumes the flat event dicts `repro.obs.core` emits:

  {"type": "span",    "name": ..., "ts": t0, "dur": s, "pid", "tid",
   "depth", "attrs": {...}}
  {"type": "counter" | "gauge" | "hist", "name": ..., "ts": ...,
   "value": v, "pid", "tid", "attrs": {...}}
  {"type": "meta",    "name": ..., "ts": ..., "data": {...}}

`ts` is seconds since the owning Obs session's epoch (a `perf_counter`
origin captured at enable time); durations are seconds. The Chrome-trace
sink lives in `repro.obs.trace` (it rescales to microseconds).
"""
from __future__ import annotations

import json
import os


class MemorySink:
    """Keeps every event in a list — the sink tests and `Obs.summary()`
    read back."""

    def __init__(self):
        self.events: list[dict] = []

    def emit(self, event: dict) -> None:
        self.events.append(event)

    def close(self) -> None:
        pass


class JsonlSink:
    """One JSON object per line, append-on-emit. The file handle stays open
    (and buffered) for the session; `close()` flushes it."""

    def __init__(self, path: str):
        self.path = path
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._f = open(path, "w")

    def emit(self, event: dict) -> None:
        self._f.write(json.dumps(event, separators=(",", ":"),
                                 default=str) + "\n")

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()


class EventList(list):
    """`load_jsonl`'s return type: a list of event dicts plus a `truncated`
    flag — True when the file ended mid-record (a crashed writer) and the
    parsed prefix is everything that survived."""
    truncated: bool = False


def load_jsonl(path: str, *, strict: bool = False) -> EventList:
    """Read a JSONL event file back into a list of event dicts.

    A malformed FINAL record — the signature of a writer that died
    mid-`write` — is tolerated: the parsed prefix is returned with
    `.truncated == True`. Malformed records with valid ones after them are
    real corruption and still raise (as does any bad record under
    `strict=True`)."""
    events = EventList()
    with open(path) as f:
        lines = f.readlines()
    for i, line in enumerate(lines):
        stripped = line.strip()
        if not stripped:
            continue
        try:
            events.append(json.loads(stripped))
        except json.JSONDecodeError:
            if strict or any(rest.strip() for rest in lines[i + 1:]):
                raise
            events.truncated = True
            break
    return events
