"""Event sinks: in-memory (tests / summaries) and JSONL (artifacts).

Every sink consumes the flat event dicts `repro.obs.core` emits:

  {"type": "span",    "name": ..., "ts": t0, "dur": s, "pid", "tid",
   "depth", "attrs": {...}}
  {"type": "counter" | "gauge" | "hist", "name": ..., "ts": ...,
   "value": v, "pid", "tid", "attrs": {...}}
  {"type": "meta",    "name": ..., "ts": ..., "data": {...}}

`ts` is seconds since the owning Obs session's epoch (a `perf_counter`
origin captured at enable time); durations are seconds. The Chrome-trace
sink lives in `repro.obs.trace` (it rescales to microseconds).
"""
from __future__ import annotations

import json
import os


class MemorySink:
    """Keeps every event in a list — the sink tests and `Obs.summary()`
    read back."""

    def __init__(self):
        self.events: list[dict] = []

    def emit(self, event: dict) -> None:
        self.events.append(event)

    def close(self) -> None:
        pass


class JsonlSink:
    """One JSON object per line, append-on-emit. The file handle stays open
    (and buffered) for the session; `close()` flushes it."""

    def __init__(self, path: str):
        self.path = path
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._f = open(path, "w")

    def emit(self, event: dict) -> None:
        self._f.write(json.dumps(event, separators=(",", ":"),
                                 default=str) + "\n")

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()


def load_jsonl(path: str) -> list[dict]:
    """Read a JSONL event file back into a list of event dicts."""
    events = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events
