"""Append-only benchmark history: the perf trajectory behind the sentinel.

`BENCH_history.jsonl` holds one flat record per (benchmark, metric) per
run, keyed by an environment fingerprint (python/jax/backend/device — the
things that make two timings comparable) and the git SHA that produced it.
`benchmarks/run.py --append-history` folds its payload in after every run;
`--check-regressions` (see `repro.obs.regress`) compares the current
payload against the trailing window of comparable history before anything
is appended, so a run is never its own baseline.

Record schema (HISTORY_SCHEMA_VERSION = 1):

  {"schema_version": 1, "benchmark": "fed", "metric": "seconds",
   "value": 1.23, "direction": "lower" | "higher" | null,
   "fingerprint": "ab12…", "git_sha": "…" | null, "git_dirty": bool|null,
   "tiny": bool, "ok": bool, "repeat_values": [..] | null,
   "payload_schema_version": 3, "blessed": bool}

`direction` is the regression sign: "lower" means smaller is better
(seconds), "higher" means larger is better (throughput headlines); null
metrics are recorded for trajectory but never gated. `blessed` marks an
intentional perf change: the sentinel only baselines records at or after
the most recent blessed one, so `--bless` resets the comparison window
without rewriting history. Loading tolerates a truncated final line
(crashed writer) and skips records from a FUTURE schema version — old
readers keep working when the schema grows.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Optional

from repro.obs import sinks as sinks_lib

HISTORY_SCHEMA_VERSION = 1

# env keys that make two timings comparable: same interpreter, same jax
# stack, same device story. Deliberately excludes platform minutiae
# (hostname, exact kernel) so CI runners share a baseline.
_FINGERPRINT_KEYS = ("python", "jax", "jaxlib", "backend", "device_kind",
                     "device_count", "repro_force_pallas")

# metric name -> regression direction, for metrics every benchmark shares.
# Headline metrics ("headline.<key>") default to ungated (direction None)
# unless the payload record carries its own "directions" hint.
DEFAULT_DIRECTIONS = {"seconds": "lower"}


def env_fingerprint(env: dict, tiny: Optional[bool] = None) -> str:
    """Stable short hash of the comparability-relevant env fields (+ the
    --tiny flag: tiny and full sweeps must never share a baseline)."""
    basis = {k: env.get(k) for k in _FINGERPRINT_KEYS}
    if tiny is not None:
        basis["tiny"] = bool(tiny)
    blob = json.dumps(basis, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:12]


def records_from_payload(payload: dict) -> list[dict]:
    """Flatten a `benchmarks/run.py` JSON payload (schema v2 or v3 — v2
    simply lacks git_sha/git_dirty) into history records: one per
    (benchmark, metric). Metrics: "seconds" always; every numeric
    `headline.<key>`; numeric directions come from the benchmark record's
    optional "directions" {key: "lower"|"higher"} hint."""
    env = payload.get("env", {})
    tiny = bool(payload.get("tiny"))
    fp = env_fingerprint(env, tiny)
    out = []
    for rec in payload.get("benchmarks", []):
        name = rec.get("name")
        if not name:
            continue
        hints = rec.get("directions") or {}
        metrics: dict = {}
        if isinstance(rec.get("seconds"), (int, float)):
            metrics["seconds"] = float(rec["seconds"])
        headline = rec.get("headline")
        if isinstance(headline, dict):
            for key, value in headline.items():
                if (isinstance(value, (int, float))
                        and not isinstance(value, bool)):
                    metrics[f"headline.{key}"] = float(value)
        for metric, value in sorted(metrics.items()):
            short = metric.split(".", 1)[-1]
            direction = (hints.get(metric) or hints.get(short)
                         or DEFAULT_DIRECTIONS.get(metric))
            repeats = rec.get("repeat_seconds") if metric == "seconds" \
                else None
            out.append({
                "schema_version": HISTORY_SCHEMA_VERSION,
                "benchmark": name, "metric": metric, "value": value,
                "direction": direction, "fingerprint": fp,
                "git_sha": env.get("git_sha"),
                "git_dirty": env.get("git_dirty"),
                "tiny": tiny, "ok": bool(rec.get("ok")),
                "repeat_values": list(repeats) if repeats else None,
                "payload_schema_version": payload.get("schema_version"),
                "blessed": False,
            })
    return out


def append(path: str, records: list[dict]) -> int:
    """Append records to the history file (created on first use); returns
    how many were written."""
    if not records:
        return 0
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "a") as f:
        for rec in records:
            f.write(json.dumps(rec, sort_keys=True,
                               separators=(",", ":")) + "\n")
    return len(records)


def load(path: str) -> sinks_lib.EventList:
    """Load history records in file (= chronological) order. Missing file →
    empty list; truncated final line → parsed prefix with
    `.truncated=True`; records from a future schema version or without the
    required keys are skipped (old reader, new writer)."""
    out = sinks_lib.EventList()
    if not os.path.exists(path):
        return out
    raw = sinks_lib.load_jsonl(path)
    out.truncated = raw.truncated
    for rec in raw:
        if not isinstance(rec, dict):
            continue
        if rec.get("schema_version", 0) > HISTORY_SCHEMA_VERSION:
            continue
        if "benchmark" in rec and "metric" in rec and "value" in rec:
            out.append(rec)
    return out
