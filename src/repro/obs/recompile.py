"""Recompile tracker: compilation-cache sizes of named jitted programs.

jax 0.4.x jitted callables expose `_cache_size()` — the number of distinct
(shape/dtype/static-arg) specializations compiled so far. Every jit factory
in the hot layers registers its program here under a stable name
("fed.round.cohort", "dist.step", "serve.decode_step", …); `counts()`
aggregates live cache sizes per name, so a snapshot/delta pair attributes
NEW compiles to whatever ran in between. This is how the observability
contract "obs adds zero recompiles" and the CI pin on the cohort round
program are enforced — compile churn (e.g. cohort-key drift past the
hysteresis guards) shows up as a counts() delta instead of silent latency.

Registration is always on (one dict insert per jit *factory* call, never on
the step path) and holds only weakrefs, so registering costs nothing at
call time and keeps nothing alive. An active `repro.obs` session pins the
programs registered while it is enabled (via `add_callback`) so their final
cache sizes survive into the session summary even if the owning object
(e.g. a benchmark's Federation) is dropped before the summary is read;
`counts()` also remembers the last observed size of every entry, so
programs that die between polls still report the size they last showed.
"""
from __future__ import annotations

import itertools
import weakref
from typing import Callable, Optional

_REGISTRY: dict[int, dict] = {}   # id -> {name, ref, last, annotations}
_IDS = itertools.count()
_CALLBACKS: list[Callable] = []   # called as cb(name, fn) on every register


def cache_size(fn) -> Optional[int]:
    """Compiled-specialization count of a jitted callable, or None when the
    object exposes no cache introspection (non-jit callables pass through
    factories in some tests)."""
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return None
    try:
        return int(probe())
    except Exception:
        return None


def register(name: str, fn, **annotations):
    """Track `fn`'s compilation cache under `name`. Returns `fn` (so call
    sites can wrap: `return register("x", jax.jit(f))`).

    Keyword `annotations` attach static facts the cost model reads per
    program — e.g. `span="fed.round.aggregate"` (which measured span this
    program's device work should be attributed to) or
    `wire_bytes_per_call=...` (the analytic minimum-traffic bytes one call
    puts on the wire). Re-registering a name merges annotations
    (`annotations_by_name` folds entries left-to-right)."""
    try:
        ref = weakref.ref(fn)
    except TypeError:                     # non-weakrefable: hold it
        ref = (lambda fn=fn: fn)
    _REGISTRY[next(_IDS)] = {"name": name, "ref": ref, "last": 0,
                             "annotations": dict(annotations)}
    for cb in list(_CALLBACKS):
        cb(name, fn)
    return fn


def annotations_by_name() -> dict:
    """{program name: merged annotation dict} over all registrations."""
    out: dict[str, dict] = {}
    for entry in _REGISTRY.values():
        ann = entry.get("annotations")
        if ann:
            out.setdefault(entry["name"], {}).update(ann)
    return out


def add_callback(cb: Callable) -> None:
    _CALLBACKS.append(cb)


def remove_callback(cb: Callable) -> None:
    if cb in _CALLBACKS:
        _CALLBACKS.remove(cb)


def counts() -> dict:
    """{program name: total compiled specializations} over all registered
    programs. Live programs report their current `_cache_size()`; dead ones
    report the last size observed before they were collected."""
    out: dict[str, int] = {}
    for entry in _REGISTRY.values():
        fn = entry["ref"]()
        if fn is not None:
            size = cache_size(fn)
            if size is not None:
                entry["last"] = size
        out[entry["name"]] = out.get(entry["name"], 0) + entry["last"]
    return out


def delta(before: dict, after: dict) -> dict:
    """Per-name compiles in `after` not yet present in `before` (clamped at
    0 — a program collected between snapshots can't "un-compile")."""
    out = {}
    for name, n in after.items():
        d = n - before.get(name, 0)
        if d > 0:
            out[name] = d
    return out


def clear() -> None:
    """Drop every registration (test isolation only)."""
    _REGISTRY.clear()
