"""repro.codecs — the single home for compression.

Composable, jit-safe stages (`repro.codecs.stages`) assemble into the
`TreeCodec` `(key, tree, budget)` convention (`repro.codecs.base`); the
registry (`repro.codecs.registry`) names the assembled pipelines:

    from repro import codecs

    codec = codecs.make("ndsc", budget=1.5, chunk=128)
    wire  = codec.encode(key, tree, round_idx)
    tree2 = codec.decode(wire, codec.meta(tree))

Wire codecs: `ndsc` (the paper's chunked near-democratic codec, fused
Pallas encode), `ratq` (adaptive fixed-length baseline),
`sparsify_then_embed` (top-k/rand-k survivors democratically embedded),
`dsc` (dense per-leaf frames), `identity`. Simulation-only baselines:
`sign`, `ternary`, `qsgd`, `naive`, `dither`, `topk`, `randk`.

This package supersedes `repro.fed.registry` (now a deprecation shim).
"""
from repro.codecs import base, registry, stages
from repro.codecs.base import TreeCodec, TreeMeta, total_dims, tree_meta
from repro.codecs.registry import (available, codec_spec,
                                   gradcomp_config_for_budget, make, register)
from repro.codecs.stages import (Pack, Pipeline, Quantize, Sparsify,
                                 Transform)

__all__ = [
    "Pack", "Pipeline", "Quantize", "Sparsify", "Transform", "TreeCodec",
    "TreeMeta", "available", "base", "codec_spec",
    "gradcomp_config_for_budget", "make", "register", "registry", "stages",
    "total_dims", "tree_meta",
]
