"""Composable, jit-safe codec stages and the `Pipeline` that assembles them.

Every wire codec in this repo is four stages applied per leaf:

    transform  ─►  sparsify  ─►  quantize  ─►  pack
    hadamard       none          uniform       int32
    identity       chunk_drop    dithered      none
                   topk          ratq
                   randk

`Pipeline` composes one choice per stage into the `TreeCodec`
`(key, tree, budget)` convention (see `repro.codecs.base`). Three leaf
implementations back the supported stage combinations:

  * **NDSC** (`hadamard` + `none`/`chunk_drop` + `uniform`/`dithered` +
    `int32`): delegates to `repro.dist.gradcomp` — the chunked
    sign-flip → FWHT → ℓ∞-scale → quantize → bit-pack chain that runs as one
    fused Pallas kernel on TPU. Delegation (not reimplementation) is what
    keeps the pipeline wire payloads BIT-IDENTICAL to the historical
    gradcomp path and preserves the fused `encode_ef` residual.
  * **RATQ** (`hadamard` + `none`/`chunk_drop` + `ratq` + `int32`): the
    adaptive fixed-length quantizer of Mayekar & Tyagi — rotate, then pick
    each chunk's dynamic range from a per-leaf geometric ladder
    e_j = 2^(j−(h−1))·‖rot‖∞ and quantize at the chosen rung. The per-chunk
    side information is ⌈log2 h⌉ bits (vs NDSC's 32-bit f32 scale); one f32
    gain rides per leaf. All shapes are static, so sweeping round_idx never
    recompiles.
  * **sparsify-then-embed** (`hadamard` + `topk`/`randk` + `uniform`/
    `dithered` + `int32`): the paper's sparsification extension — select
    k survivors in ORIGINAL space, gather them into a dense length-k
    vector, then democratically embed + quantize that vector (the Fig. 1d
    recipe). Indices ride the wire; the audit charges log2 C(n,k) for them,
    the same convention as the `core.baselines` top-k/rand-k compressors.

Stochastic draws (dither, keep-masks, rand-k subsets) are pre-drawn from
`fold_in`-derived keys OUTSIDE any kernel, so forcing the Pallas path can
never change a payload. Analytic `wire_bits` and realized `wire_bytes` are
computed from the same per-leaf formulas, so the fed ledger matches the
audit to the byte for every deterministic-size codec.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.codecs import base
from repro.codecs.base import TreeCodec, TreeMeta
from repro.dist import gradcomp as G
from repro.kernels import ops as kernel_ops

TRANSFORMS = ("hadamard", "identity")
SPARSIFIERS = ("none", "chunk_drop", "topk", "randk")
QUANTIZERS = ("uniform", "dithered", "ratq")
PACKERS = ("int32", "none")

PACKABLE_BITS = (1, 2, 4, 8)


@dataclasses.dataclass(frozen=True)
class Transform:
    """Per-chunk orthonormal rotation applied before quantization.

    `hadamard` is the randomized frame S = D·H from `core.frames`: a pure
    function of (seed, leaf index), so every worker builds the same frame
    and payloads decode identically everywhere."""

    kind: str = "hadamard"
    seed: int = 0

    def __post_init__(self):
        if self.kind not in TRANSFORMS:
            raise ValueError(f"transform must be one of {TRANSFORMS}, "
                             f"got {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class Sparsify:
    """Which coordinates make it onto the wire.

    `chunk_drop` subsamples whole chunks AFTER the transform (the paper's
    sub-linear R < 1 regime; `exact` keeps exactly ⌈fraction·C⌉ chunks so
    realized bytes equal the analytic audit). `topk` / `randk` select
    `fraction·n` coordinates in ORIGINAL space BEFORE the transform and
    compact the survivors — the sparsify-then-embed hybrid. `rescale`
    divides the decode by `fraction` for unbiasedness (DQ-PSGD); error-
    feedback paths stay contractive and leave it False."""

    kind: str = "none"
    fraction: float = 1.0
    exact: bool = True
    rescale: bool = False

    def __post_init__(self):
        if self.kind not in SPARSIFIERS:
            raise ValueError(f"sparsify must be one of {SPARSIFIERS}, "
                             f"got {self.kind!r}")
        if not 0.0 < self.fraction <= 1.0:
            raise ValueError(
                f"sparsify fraction must be in (0, 1], got {self.fraction}")


@dataclasses.dataclass(frozen=True)
class Quantize:
    """Scalar quantizer for the (transformed, surviving) coordinates.

    `uniform` / `dithered` use one f32 ℓ∞ scale per chunk; `ratq` replaces
    it with a ⌈log2 ladder⌉-bit index into a geometric range ladder shared
    with the decoder, plus one f32 gain per leaf."""

    kind: str = "uniform"
    bits: int = 4
    ladder: int = 16              # ratq: number of geometric range rungs h

    def __post_init__(self):
        if self.kind not in QUANTIZERS:
            raise ValueError(f"quantize must be one of {QUANTIZERS}, "
                             f"got {self.kind!r}")
        if self.bits not in PACKABLE_BITS:
            raise ValueError(
                f"bits must be in {PACKABLE_BITS} (int32 packing), "
                f"got {self.bits}")
        if self.kind == "ratq" and self.ladder < 2:
            raise ValueError(f"ratq ladder needs ≥ 2 rungs, got {self.ladder}")


@dataclasses.dataclass(frozen=True)
class Pack:
    """Wire representation of the quantized indices."""

    kind: str = "int32"

    def __post_init__(self):
        if self.kind not in PACKERS:
            raise ValueError(f"pack must be one of {PACKERS}, "
                             f"got {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class Pipeline:
    """One choice per stage + the chunk length, composed into a TreeCodec.

    Frozen and hashable: a Pipeline is a value, and `tree_codec` built from
    equal pipelines encode/decode identically (all randomness derives from
    seeds and keys, never object identity)."""

    transform: Transform = Transform()
    sparsify: Sparsify = Sparsify()
    quantize: Quantize = Quantize()
    pack: Pack = Pack()
    chunk: int = 128

    def leaf(self):
        """The per-leaf stage codec implementing this combination."""
        return _leaf_codec(self)

    def tree_codec(self, name: str, rate: Optional[float] = None) -> TreeCodec:
        return tree_codec(name, self, rate=rate)


# ---------------------------------------------------------------------------
# Pipeline -> leaf-codec dispatch
# ---------------------------------------------------------------------------
def _gradcomp_config(p: Pipeline) -> G.GradCompConfig:
    """The GradCompConfig equivalent of a chunked pipeline.

    gradcomp folds the decode-side unbiased rescale into
    `dithered and not error_feedback`, so `error_feedback` here is just the
    inverse of the sparsify stage's `rescale` flag."""
    drop = p.sparsify.kind == "chunk_drop"
    dithered = p.quantize.kind == "dithered"
    return G.GradCompConfig(
        bits=p.quantize.bits, chunk=p.chunk,
        keep_fraction=p.sparsify.fraction if drop else 1.0,
        exact_keep=p.sparsify.exact if drop else False,
        dithered=dithered,
        error_feedback=not (p.sparsify.rescale and dithered and drop),
        seed=p.transform.seed)


@functools.lru_cache(maxsize=None)
def _leaf_codec(p: Pipeline):
    if p.sparsify.kind in ("topk", "randk"):
        if (p.transform.kind, p.quantize.kind, p.pack.kind) not in (
                ("hadamard", "uniform", "int32"),
                ("hadamard", "dithered", "int32")):
            raise ValueError(
                "topk/randk sparsify composes with transform='hadamard', "
                "quantize='uniform'|'dithered', pack='int32' "
                "(sparsify-then-embed); got "
                f"{p.transform.kind}/{p.quantize.kind}/{p.pack.kind}")
        return SparsifyEmbedLeaf(_gradcomp_config(p), p.sparsify.kind,
                                 p.sparsify.fraction)
    if p.transform.kind != "hadamard" or p.pack.kind != "int32":
        raise ValueError(
            "chunked pipelines need transform='hadamard' and pack='int32' "
            f"(got {p.transform.kind}/{p.pack.kind}); identity-transform "
            "baselines are built with `sim_pipeline`")
    if p.quantize.kind == "ratq":
        return RatqLeaf(_gradcomp_config(p), p.quantize.ladder)
    return NdscLeaf(_gradcomp_config(p))


# ---------------------------------------------------------------------------
# NDSC: delegate to repro.dist.gradcomp (the fused-kernel stage impl)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class NdscLeaf:
    """hadamard + (chunk_drop) + uniform/dithered + int32.

    Thin delegation to `repro.dist.gradcomp` — the chain runs as ONE fused
    Pallas kernel on the TPU dispatch path and its payloads are bit-exact
    with the historical gradcomp/registry encode by construction."""

    cfg: G.GradCompConfig
    fused_ef = True               # encode_ef emits the residual in-tile

    @property
    def effective_bits(self) -> float:
        return self.cfg.effective_bits

    def encode(self, x, leaf_idx, round_idx=0, key=None):
        return G.encode_leaf(x, leaf_idx, self.cfg, round_idx, key=key)

    def encode_ef(self, x, leaf_idx, round_idx=0, key=None,
                  residual_dtype=None):
        return G.encode_leaf_ef(x, leaf_idx, self.cfg, round_idx, key=key,
                                residual_dtype=residual_dtype)

    def decode(self, payload, leaf_idx, size, shape, dtype, extra_lead=0):
        return G.decode_leaf(payload, leaf_idx, size, shape, dtype, self.cfg,
                             extra_lead=extra_lead)

    def wire_bits(self, size: int) -> float:
        template = jax.ShapeDtypeStruct((int(size),), jnp.float32)
        return G.wire_bytes_tree([template], self.cfg)["payload_bytes"] * 8.0

    def wire_bytes(self, payload, size: int) -> float:
        return G.wire_bytes_payload(payload, self.cfg)


def ndsc_leaf(cfg: G.GradCompConfig) -> NdscLeaf:
    """The NDSC stage codec for an explicit GradCompConfig (what
    `repro.dist.step` routes its consensus encode/decode through)."""
    return NdscLeaf(cfg)


# ---------------------------------------------------------------------------
# RATQ: rotate + adaptive geometric range + fixed-length quantize
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RatqLeaf:
    """hadamard + (chunk_drop) + ratq + int32 (Mayekar & Tyagi).

    Per leaf: rotate chunk-wise, take one f32 gain = ‖rot‖∞ over the leaf,
    then give each chunk the smallest ladder rung e_j = 2^(j−(h−1)) ≥
    ‖row‖∞/gain and quantize the row at scale gain·e_j. The wire carries
    the packed words, the ⌈log2 h⌉-bit rung index per chunk and the gain —
    fixed length, so round_idx sweeps never change a shape."""

    cfg: G.GradCompConfig         # bits/chunk/keep_fraction/exact_keep/seed
    ladder: int
    fused_ef = False

    @property
    def _ridx_bits(self) -> int:
        return max(1, math.ceil(math.log2(self.ladder)))

    def _scales(self, ridx, gain):
        safe = jnp.maximum(gain, jnp.finfo(jnp.float32).tiny)
        return safe * jnp.exp2((ridx - (self.ladder - 1)).astype(jnp.float32))

    def encode(self, x, leaf_idx, round_idx=0, key=None):
        cfg = self.cfg
        chunks = G._to_chunks(x, cfg.chunk)
        signs = G._frame_signs(leaf_idx, cfg).astype(jnp.float32)
        _, mask = G._leaf_draws(leaf_idx, chunks.shape[0], chunks.shape[0],
                                cfg, round_idx, key)
        rot = kernel_ops.rotate(chunks, signs)
        gain = jnp.max(jnp.abs(rot)).reshape(1, 1)
        safe = jnp.maximum(gain, jnp.finfo(jnp.float32).tiny)
        rel = jnp.max(jnp.abs(rot), axis=-1, keepdims=True) / safe  # ∈ [0, 1]
        floor = 2.0 ** (1 - self.ladder)                  # the lowest rung
        ridx = jnp.clip(
            jnp.ceil(jnp.log2(jnp.maximum(rel, floor))).astype(jnp.int32)
            + (self.ladder - 1), 0, self.ladder - 1)
        words = kernel_ops.quantize_pack(rot, self._scales(ridx, gain),
                                         cfg.bits)
        if mask is not None:
            # dropped chunks emit all-zero words + rung 0: no ghost info
            words = words * mask.astype(words.dtype)
            ridx = ridx * mask.astype(ridx.dtype)
        payload = {"words": words, "ridx": ridx, "gain": gain}
        if mask is not None:
            payload["mask"] = mask
        return payload

    def decode(self, payload, leaf_idx, size, shape, dtype, extra_lead=0):
        cfg = self.cfg
        words = payload["words"]
        scale = self._scales(payload["ridx"], payload["gain"])
        x_hat = kernel_ops.unpack_dequant(words, scale, cfg.bits, cfg.chunk)
        mask = payload.get("mask")
        if mask is not None:
            x_hat = x_hat * mask
            if cfg.dithered and not cfg.error_feedback:
                x_hat = x_hat / cfg.keep_fraction
        signs = G._frame_signs(leaf_idx, cfg).astype(x_hat.dtype)
        y = kernel_ops.unrotate(x_hat, signs)
        lead = tuple(words.shape[:extra_lead])
        flat = y.reshape(lead + (-1,))[..., :size]
        return flat.reshape(lead + tuple(shape)).astype(dtype)

    def _leaf_bytes(self, c: int, kept) -> float:
        per_chunk = (self.cfg.chunk * self.cfg.bits + self._ridx_bits) / 8.0
        total = kept * per_chunk + 4.0                    # + the f32 gain
        if self.cfg.keep_fraction < 1.0:
            total += (c + 7) // 8                         # the keep mask
        return total

    def wire_bits(self, size: int) -> float:
        c = -(-int(size) // self.cfg.chunk)
        if self.cfg.keep_fraction >= 1.0:
            kept = c
        elif self.cfg.exact_keep:
            kept = self.cfg.kept_chunks(c)
        else:
            kept = self.cfg.keep_fraction * c
        return self._leaf_bytes(c, kept) * 8.0

    def wire_bytes(self, payload, size: int) -> float:
        c = payload["ridx"].shape[-2]
        mask = payload.get("mask")
        kept = c if mask is None else float(jnp.sum(mask))
        return self._leaf_bytes(c, kept)


def _log2_comb(n: int, k: int) -> float:
    """log2 C(n,k) — exact for small n (matching `core.baselines`), Stirling
    via lgamma past the point where the exact big-int gets expensive."""
    if n <= 65536:
        return math.log2(math.comb(n, k))
    lg = (math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1))
    return lg / math.log(2.0)


# ---------------------------------------------------------------------------
# sparsify-then-embed: original-space selection, embedded-space quantization
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SparsifyEmbedLeaf:
    """topk/randk + hadamard + uniform/dithered + int32 (paper Fig. 1d).

    Selection happens in ORIGINAL space; the k survivors are gathered into
    a dense length-k vector and NDSC-encoded (rotate, ℓ∞ scale, quantize,
    pack), flattening the survivors' dynamic range so coarse bits suffice.
    Indices ride the wire; the audit charges log2 C(n,k) for them — the
    same convention as `core.baselines.topk`/`randk`, so equal-total-bits
    comparisons against plain sparsification are apples-to-apples."""

    cfg: G.GradCompConfig         # bits/chunk/dithered/seed (keep = 1)
    mode: str                     # "topk" | "randk"
    fraction: float
    fused_ef = False

    def _k(self, size: int) -> int:
        return max(1, min(int(size), int(round(self.fraction * size))))

    def encode(self, x, leaf_idx, round_idx=0, key=None):
        cfg = self.cfg
        flat = x.astype(jnp.float32).reshape(-1)
        n, k = flat.size, self._k(x.size)
        if self.mode == "topk":
            idx = jnp.sort(jax.lax.top_k(jnp.abs(flat), k)[1])
        else:
            if key is None:
                key = G._stoch_key(leaf_idx, round_idx, cfg)
            draw = jax.random.uniform(jax.random.fold_in(key, 3), (n,))
            # rank trick: exactly k survivors, ties broken by index —
            # identical on every worker (cf. gradcomp._exact_keep_mask)
            idx = jnp.sort(jnp.argsort(draw)[:k])
        vals = flat[idx]
        chunks = G._to_chunks(vals, cfg.chunk)
        signs = G._frame_signs(leaf_idx, cfg).astype(jnp.float32)
        dither, _ = G._leaf_draws(leaf_idx, chunks.shape[0], chunks.shape[0],
                                  cfg, round_idx, key)
        words, scale = kernel_ops.encode(chunks, signs, cfg.bits,
                                         dither=dither, mask=None)
        return {"indices": idx.astype(jnp.int32), "words": words,
                "scale": scale}

    def decode(self, payload, leaf_idx, size, shape, dtype, extra_lead=0):
        if extra_lead:
            raise ValueError("sparsify_then_embed does not decode stacked "
                             "payloads (extra_lead > 0)")
        cfg = self.cfg
        idx = payload["indices"]
        x_hat = kernel_ops.unpack_dequant(payload["words"], payload["scale"],
                                          cfg.bits, cfg.chunk)
        signs = G._frame_signs(leaf_idx, cfg).astype(x_hat.dtype)
        vals = kernel_ops.unrotate(x_hat, signs).reshape(-1)[:idx.shape[-1]]
        flat = jnp.zeros((size,), jnp.float32).at[idx].set(vals)
        return flat.reshape(shape).astype(dtype)

    def wire_bits(self, size: int) -> float:
        n = int(size)
        k = self._k(n)
        c = -(-k // self.cfg.chunk)
        payload_bits = c * (self.cfg.chunk * self.cfg.bits + 32)
        return payload_bits + _log2_comb(n, k)

    def wire_bytes(self, payload, size: int) -> float:
        return self.wire_bits(size) / 8.0        # fixed-size wire, realized
                                                 # == analytic every round


# ---------------------------------------------------------------------------
# tree assembly: per-leaf stage codecs -> the TreeCodec convention
# ---------------------------------------------------------------------------
def tree_codec(name: str, pipeline, rate: Optional[float] = None,
               fused_ef: bool = True) -> TreeCodec:
    """Assemble a Pipeline (or one Pipeline per leaf) into a TreeCodec.

    Per-leaf keys fold in the leaf index; `meta.extra` carries the per-leaf
    stage codecs so decode/audit never re-derive them. When every leaf
    supports the fused encode+EF path (NDSC) the codec exposes `encode_ef`,
    otherwise the fed engine composes decode(encode(u)) itself."""
    shared = isinstance(pipeline, Pipeline)
    pipes = None if shared else list(pipeline)

    def leaves_for(n: int) -> list:
        if shared:
            return [pipeline.leaf()] * n
        if len(pipes) != n:
            raise ValueError(f"{len(pipes)} per-leaf pipelines for "
                             f"{n} leaves")
        return [p.leaf() for p in pipes]

    def encode(key, tree, round_idx=0):
        leaves, treedef = jax.tree.flatten(tree)
        lcs = leaves_for(len(leaves))
        payloads = [lc.encode(x, i, round_idx,
                              key=jax.random.fold_in(key, i))
                    for i, (x, lc) in enumerate(zip(leaves, lcs))]
        return jax.tree.unflatten(treedef, payloads)

    def meta(tree):
        treedef, infos = base.tree_meta(tree)
        return TreeMeta(treedef, infos, extra=leaves_for(len(infos)))

    def decode(wire, meta):
        plist = meta.treedef.flatten_up_to(wire)
        outs = [lc.decode(p, i, size, shape, dtype)
                for i, (p, (size, shape, dtype), lc) in
                enumerate(zip(plist, meta.infos, meta.extra))]
        return jax.tree.unflatten(meta.treedef, outs)

    def wire_bits(tree):
        leaves, _ = jax.tree.flatten(tree)
        lcs = leaves_for(len(leaves))
        return sum(lc.wire_bits(int(np.prod(x.shape)) if x.shape else 1)
                   for x, lc in zip(leaves, lcs))

    def wire_bytes(wire, meta):
        plist = meta.treedef.flatten_up_to(wire)
        return sum(lc.wire_bytes(p, info[0])
                   for p, info, lc in zip(plist, meta.infos, meta.extra))

    encode_ef = None
    probe = leaves_for(len(pipes) if pipes else 1)
    if fused_ef and all(lc.fused_ef for lc in probe):
        def encode_ef(key, tree, meta, round_idx=0):
            leaves = meta.treedef.flatten_up_to(tree)
            pairs = [lc.encode_ef(x, i, round_idx,
                                  key=jax.random.fold_in(key, i),
                                  residual_dtype=info[2])
                     for i, (x, lc, info) in
                     enumerate(zip(leaves, meta.extra, meta.infos))]
            wire = jax.tree.unflatten(meta.treedef, [p for p, _ in pairs])
            resid = jax.tree.unflatten(meta.treedef, [r for _, r in pairs])
            return wire, resid

    return TreeCodec(name, encode, decode, meta, wire_bits, wire_bytes,
                     rate=rate, encode_ef=encode_ef)


# ---------------------------------------------------------------------------
# simulation-only wrapper: core.baselines compressors as one-stage pipelines
# ---------------------------------------------------------------------------
def sim_pipeline(comp) -> TreeCodec:
    """A `core.baselines.Compressor` as a degenerate single-stage pipeline
    (identity transform, quantize-only, no pack): the wire is the decoded
    tree itself (`sim_only=True`), with the compressor's analytic bits as
    both audit and ledger."""

    def encode(key, tree, round_idx=0):
        leaves, treedef = jax.tree.flatten(tree)
        outs = []
        for i, x in enumerate(leaves):
            kk = jax.random.fold_in(jax.random.fold_in(key, i), round_idx)
            flat = x.astype(jnp.float32).reshape(-1)
            outs.append(comp.roundtrip(kk, flat))
        return jax.tree.unflatten(treedef, outs)

    def meta(tree):
        treedef, infos = base.tree_meta(tree)
        return TreeMeta(treedef, infos)

    def decode(wire, meta):
        return jax.tree.unflatten(meta.treedef, [
            y.reshape(shape).astype(dtype)
            for y, (_, shape, dtype) in
            zip(meta.treedef.flatten_up_to(wire), meta.infos)])

    def wire_bits(tree):
        return sum(comp.wire_bits(int(np.prod(x.shape)) if x.shape else 1)
                   for x in jax.tree.leaves(tree))

    def wire_bytes(wire, meta):
        return sum(comp.wire_bits(size) for size, _, _ in meta.infos) / 8.0

    return TreeCodec(comp.name, encode, decode, meta, wire_bits, wire_bytes,
                     sim_only=True)
