"""Named codec factories over the stage pipelines: one registry, one call
convention for every compressor in the repo.

    codec = registry.make("ndsc", budget=1.5, chunk=128)
    wire  = codec.encode(key, tree, round_idx)        # jit-safe pytree
    meta  = codec.meta(tree)                          # static, host-side
    tree' = codec.decode(wire, meta)                  # jit-safe
    bits  = codec.wire_bits(tree)                     # analytic audit
    bytes = codec.wire_bytes(wire, meta)              # realized ledger entry

Budgets are bits per ORIGINAL model dimension. For the NDSC backend the
budget maps onto `GradCompConfig` so that `effective_bits == budget` exactly
(bits ∈ {1,2,4,8} plus a fractional chunk keep rate with `exact_keep`), which
makes the realized ledger match the analytic audit to the byte. A budget may
also be a per-leaf sequence (see `repro.fed.budget.split_leaf_budgets`).

Wire codecs (`ndsc`, `ratq`, `sparsify_then_embed`) are stage pipelines from
`repro.codecs.stages`; `core.baselines` compressors ride as single-stage
simulation-only pipelines (the wire is the decoded tree); `dsc` binds the
dense per-leaf frame `core.coding.Codec`. This module lived at
`repro.fed.registry` before the codec stack was promoted to its own package —
that path remains as a deprecation shim.
"""
from __future__ import annotations

import dataclasses
import difflib
import inspect
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.codecs import stages
from repro.codecs.base import (TreeCodec, TreeMeta, _tree_meta,  # noqa: F401
                               _total_dims, tree_meta, total_dims)
from repro.core import baselines as B
from repro.core import frames as frames_lib
from repro.core.coding import Codec, CodecConfig
from repro.dist import gradcomp as G

_REGISTRY: dict = {}


def register(name: str):
    def deco(factory):
        _REGISTRY[name] = factory
        return factory
    return deco


def available() -> tuple:
    return tuple(sorted(_REGISTRY))


def _unknown_name_error(name) -> ValueError:
    """List what IS registered and the nearest spelling, so a typo'd codec
    name fails with the fix in the message."""
    names = available()
    close = difflib.get_close_matches(str(name), names, n=1)
    hint = f"; did you mean {close[0]!r}?" if close else ""
    return ValueError(f"unknown codec {name!r}{hint} "
                      f"(available: {', '.join(names)})")


def codec_spec(name: str, budget, kwargs: dict) -> tuple:
    """The hashable identity of a `make` call.

    Two codecs with equal specs encode/decode identically (factories are
    deterministic in (name, budget, kwargs) — frames and keep-masks derive
    from the seed, never from object identity), so `repro.fed.rounds` uses
    the spec as its cohort key and shares one compiled vmapped program among
    all clients whose codecs compare equal.

    The kwargs are CANONICALIZED against the factory signature before they
    enter the spec: `make("ndsc", 1.5)` and `make("ndsc", 1.5, chunk=128)`
    build identical codecs, so they must land in one cohort — leaving the
    caller's kwargs raw would split that cohort in two and compile every
    vmapped round/decode program twice. Keywords a factory swallows through
    `**_` stay as written (they don't have defaults to bind)."""
    if name not in _REGISTRY:
        raise _unknown_name_error(name)
    sig = inspect.signature(_REGISTRY[name])
    params = list(sig.parameters.values())
    bound = sig.bind(budget, **kwargs)
    bound.apply_defaults()
    budget_val = bound.arguments[params[0].name]
    items: dict = {}
    for p in params[1:]:
        if p.kind is inspect.Parameter.VAR_KEYWORD:
            items.update(bound.arguments.get(p.name, {}))
        else:
            items[p.name] = bound.arguments[p.name]
    budget_key = (float(budget_val) if np.isscalar(budget_val)
                  else tuple(float(b) for b in budget_val))
    return (name, budget_key, tuple(sorted(items.items())))


_UNSET = object()


def make(name, budget=_UNSET, **kwargs) -> TreeCodec:
    """Instantiate a registered compressor at a bits-per-dimension budget.

    Two call forms:

      make("ndsc", 1.5, chunk=64)        # name + budget + kwargs
      make(spec)                         # the canonical spec tuple

    where `spec` is the hashable identity produced by `codec_spec(...)` (and
    carried on every codec as `TreeCodec.spec`):

      (name, budget, kwargs_items)
        name          registered factory name, e.g. "ndsc"
        budget        float bits/dim, or a tuple of per-leaf floats
        kwargs_items  sorted ((key, value), ...) of the factory kwargs,
                      canonicalized against the factory signature

    The forms round-trip by spec equality — `make(c.spec).spec == c.spec`
    for every codec `c` — so checkpoints, benchmarks and cohort keys can
    rebuild a codec from its spec alone, without re-plumbing the original
    kwargs. The spec form takes no extra arguments (they are already baked
    into the tuple)."""
    if isinstance(name, (tuple, list)):
        if budget is not _UNSET or kwargs:
            raise ValueError("make(spec) takes no extra arguments: the "
                             "budget and kwargs are part of the spec")
        try:
            name, budget, items = name
            kwargs = dict(items)
        except (TypeError, ValueError):
            raise ValueError(f"malformed codec spec {name!r}; expected "
                             "(name, budget, kwargs_items) from codec_spec")
        if isinstance(budget, tuple):       # per-leaf budgets
            budget = list(budget)
    elif budget is _UNSET:
        budget = 4.0
    if name not in _REGISTRY:
        raise _unknown_name_error(name)
    codec = _REGISTRY[name](budget, **kwargs)
    return dataclasses.replace(codec, spec=codec_spec(name, budget, kwargs))


# ---------------------------------------------------------------------------
# identity — the no-compression reference (f32 wire)
# ---------------------------------------------------------------------------
@register("identity")
def _identity(budget: float = 32.0, **_) -> TreeCodec:
    def encode(key, tree, round_idx=0):
        return jax.tree.map(lambda x: x.astype(jnp.float32), tree)

    def decode(wire, meta):
        return jax.tree.map(
            lambda x, info: x.astype(info[2]), wire,
            jax.tree.unflatten(meta.treedef, meta.infos))

    def meta(tree):
        treedef, infos = tree_meta(tree)
        return TreeMeta(treedef, infos)

    return TreeCodec(
        "identity", encode, decode, meta,
        wire_bits=lambda tree: 32.0 * total_dims(tree),
        wire_bytes=lambda wire, meta: 4.0 * sum(i[0] for i in meta.infos),
        rate=32.0)


# ---------------------------------------------------------------------------
# ndsc — the chunked Hadamard-frame pipeline (fused gradcomp stage impl)
# ---------------------------------------------------------------------------
def gradcomp_config_for_budget(budget: float, chunk: int = 128,
                               dithered: bool = False, exact_keep: bool = True,
                               seed: int = 0) -> G.GradCompConfig:
    """Map a fractional bits/dim budget onto a GradCompConfig with
    `effective_bits == budget`: the smallest packable word size that covers
    the budget, with a chunk keep-fraction making up the fractional part."""
    if not 0.0 < budget <= 8.0:
        raise ValueError(f"ndsc budget must be in (0, 8], got {budget}")
    bits = next(b for b in (1, 2, 4, 8) if b >= budget)
    return G.GradCompConfig(
        bits=bits, chunk=chunk, keep_fraction=min(budget / bits, 1.0),
        exact_keep=exact_keep, dithered=dithered,
        error_feedback=not dithered, seed=seed)


def _chunked_pipeline(cfg: G.GradCompConfig,
                      quantize_kind: Optional[str] = None,
                      ladder: int = 16) -> stages.Pipeline:
    """The stage-pipeline spelling of a GradCompConfig (+ quantizer choice)."""
    if cfg.keep_fraction < 1.0:
        sparsify = stages.Sparsify(
            "chunk_drop", fraction=cfg.keep_fraction, exact=cfg.exact_keep,
            rescale=cfg.dithered and not cfg.error_feedback)
    else:
        sparsify = stages.Sparsify("none")
    kind = quantize_kind or ("dithered" if cfg.dithered else "uniform")
    return stages.Pipeline(
        transform=stages.Transform("hadamard", seed=cfg.seed),
        sparsify=sparsify,
        quantize=stages.Quantize(kind, bits=cfg.bits, ladder=ladder),
        pack=stages.Pack("int32"), chunk=cfg.chunk)


@register("ndsc")
def _ndsc(budget, *, chunk: int = 128, dithered: bool = False,
          exact_keep: bool = True, seed: int = 0) -> TreeCodec:
    scalar = np.isscalar(budget)
    budgets = None if scalar else list(budget)

    def pipeline_for(b: float) -> stages.Pipeline:
        return _chunked_pipeline(
            gradcomp_config_for_budget(b, chunk, dithered, exact_keep, seed))

    if scalar:
        pipeline = pipeline_for(budget)
        rate = gradcomp_config_for_budget(budget, chunk).effective_bits
        return stages.tree_codec(f"ndsc(R={budget:g})", pipeline, rate=rate)
    tag = f"ndsc(R per leaf={[round(float(b), 3) for b in budgets]})"
    return stages.tree_codec(tag, [pipeline_for(b) for b in budgets])


# ---------------------------------------------------------------------------
# ratq — adaptive fixed-length quantizer baseline (Mayekar & Tyagi)
# ---------------------------------------------------------------------------
@register("ratq")
def _ratq(budget, *, chunk: int = 128, ladder: int = 16,
          exact_keep: bool = True, seed: int = 0) -> TreeCodec:
    """RATQ at a bits/dim budget: same bits × keep-fraction split as ndsc,
    but per-chunk scales come from a ⌈log2 ladder⌉-bit geometric rung index
    instead of a 32-bit f32 — the adaptive fixed-length head-to-head."""
    if not np.isscalar(budget):
        raise ValueError("ratq takes a scalar bits/dim budget")
    cfg = gradcomp_config_for_budget(float(budget), chunk,
                                     exact_keep=exact_keep, seed=seed)
    pipeline = _chunked_pipeline(cfg, quantize_kind="ratq", ladder=ladder)
    return stages.tree_codec(f"ratq(R={budget:g},h={ladder})", pipeline,
                             rate=cfg.effective_bits)


# ---------------------------------------------------------------------------
# sparsify_then_embed — top-k/rand-k survivors, democratically embedded
# ---------------------------------------------------------------------------
@register("sparsify_then_embed")
def _sparsify_then_embed(budget, *, mode: str = "topk", bits: int = 4,
                         chunk: int = 128, dithered: bool = False,
                         k_fraction: Optional[float] = None,
                         seed: int = 0) -> TreeCodec:
    """The paper's sparsification extension: keep `k_fraction·n` coordinates
    in original space (top-k by magnitude, or a shared random-k subset),
    then NDSC-encode the survivors. Defaults spend `budget` bits per
    original dim on quantized survivors (k = budget/bits · n), with the
    log2 C(n,k) index cost charged on top — the identical convention to the
    plain `topk`/`randk` baselines, so equal-bits comparisons are fair."""
    if mode not in ("topk", "randk"):
        raise ValueError(f"mode must be 'topk' or 'randk', got {mode!r}")
    kf = min(1.0, float(budget) / bits) if k_fraction is None else k_fraction
    kf = min(max(kf, 1e-4), 1.0)
    pipeline = stages.Pipeline(
        transform=stages.Transform("hadamard", seed=seed),
        sparsify=stages.Sparsify(mode, fraction=kf),
        quantize=stages.Quantize("dithered" if dithered else "uniform",
                                 bits=bits),
        pack=stages.Pack("int32"), chunk=chunk)
    return stages.tree_codec(
        f"sparsify_then_embed({mode},R={budget:g})", pipeline)


# ---------------------------------------------------------------------------
# dsc — the dense frame Codec from core.coding (per-leaf Hadamard frames)
# ---------------------------------------------------------------------------
@register("dsc")
def _dsc(budget, *, dithered: bool = False, embedding: str = "near_democratic",
         seed: int = 0) -> TreeCodec:
    from repro.core.embeddings import EmbeddingSpec
    codec_cache: dict = {}

    def codec_for(leaf_idx: int, n: int) -> Codec:
        k = (leaf_idx, n)
        if k not in codec_cache:
            key = jax.random.fold_in(jax.random.key(seed), leaf_idx)
            frame = frames_lib.hadamard_frame(key, n)
            codec_cache[k] = Codec(frame, CodecConfig(
                bits_per_dim=float(budget), dithered=dithered,
                embedding=EmbeddingSpec(kind=embedding)))
        return codec_cache[k]

    def encode(key, tree, round_idx=0):
        leaves, treedef = jax.tree.flatten(tree)
        outs = []
        for i, x in enumerate(leaves):
            c = codec_for(i, int(np.prod(x.shape)) if x.shape else 1)
            kk = jax.random.fold_in(jax.random.fold_in(key, i), round_idx)
            p = c.encode(x.astype(jnp.float32).reshape(-1), kk)
            outs.append({"indices": p.indices, "scale": p.scale}
                        | ({"mask": p.mask} if p.mask is not None else {}))
        return jax.tree.unflatten(treedef, outs)

    def meta(tree):
        treedef, infos = tree_meta(tree)
        return TreeMeta(treedef, infos)

    def decode(wire, meta):
        from repro.core.coding import Payload
        plist = meta.treedef.flatten_up_to(wire)
        outs = []
        for i, (p, (size, shape, dtype)) in enumerate(
                zip(plist, meta.infos)):
            c = codec_for(i, size)
            y = c.decode(Payload(p["indices"], p["scale"], p.get("mask")))
            outs.append(y.reshape(shape).astype(dtype))
        return jax.tree.unflatten(meta.treedef, outs)

    def wire_bits(tree):
        leaves, _ = jax.tree.flatten(tree)
        return sum(
            codec_for(i, int(np.prod(x.shape)) if x.shape else 1).wire_bits()
            + 32.0 for i, x in enumerate(leaves))

    def wire_bytes(wire, meta):
        total = 0.0
        for i, (p, (size, _, _)) in enumerate(
                zip(meta.treedef.flatten_up_to(wire), meta.infos)):
            c = codec_for(i, size)
            per_idx = 1.0 if c.sublinear else math.log2(c.levels)
            if "mask" in p:
                # the keep mask is NOT charged: it comes from the shared
                # PRNG key, so the decoder regenerates it (same convention
                # as Codec.wire_bits, which counts kept coordinates only)
                total += float(jnp.sum(p["mask"])) * per_idx / 8.0 + 4.0
                continue
            total += (c.N * per_idx) / 8.0 + 4.0
        return total

    return TreeCodec(f"dsc(R={budget:g})", encode, decode, meta,
                     wire_bits, wire_bytes, rate=float(budget))


# ---------------------------------------------------------------------------
# core.baselines — simulation-only single-stage pipelines
# ---------------------------------------------------------------------------
@register("sign")
def _sign(budget=1.0, *, scaled: bool = True, **_) -> TreeCodec:
    return stages.sim_pipeline(B.sign_compressor(scaled))


@register("ternary")
def _ternary(budget=math.log2(3), **_) -> TreeCodec:
    return stages.sim_pipeline(B.ternary())


@register("qsgd")
def _qsgd(budget=4.0, **_) -> TreeCodec:
    # n(1 + log2(s+1)) + 32 bits: sign + stochastic level index per coord
    s = max(1, int(round(2.0 ** (budget - 1.0) - 1.0)))
    return stages.sim_pipeline(B.qsgd(s))


@register("naive")
def _naive(budget=4.0, **_) -> TreeCodec:
    levels = max(2, int(round(2.0 ** budget)))
    return stages.sim_pipeline(B.naive_uniform(levels))


@register("dither")
def _dither(budget=4.0, **_) -> TreeCodec:
    levels = max(2, int(round(2.0 ** budget)))
    return stages.sim_pipeline(B.standard_dither(levels))


@register("topk")
def _topk(budget=4.0, *, k_fraction: Optional[float] = None,
          quant_levels: Optional[int] = 256, **_) -> TreeCodec:
    per_val = 32.0 if quant_levels is None else math.log2(quant_levels)
    kf = budget / per_val if k_fraction is None else k_fraction
    return stages.sim_pipeline(B.topk(min(max(kf, 1e-4), 1.0), quant_levels))


@register("randk")
def _randk(budget=4.0, *, k_fraction: Optional[float] = None,
           quant_levels: Optional[int] = 256, unbiased: bool = False,
           **_) -> TreeCodec:
    per_val = 32.0 if quant_levels is None else math.log2(quant_levels)
    kf = budget / per_val if k_fraction is None else k_fraction
    return stages.sim_pipeline(
        B.randk(min(max(kf, 1e-4), 1.0), quant_levels, unbiased))
