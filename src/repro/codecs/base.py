"""The `TreeCodec` convention — the one call surface every codec implements.

A codec moves a parameter/gradient pytree onto the wire and back:

    wire  = codec.encode(key, tree, round_idx)        # jit-safe pytree
    meta  = codec.meta(tree)                          # static, host-side
    tree' = codec.decode(wire, meta)                  # jit-safe
    bits  = codec.wire_bits(tree)                     # analytic audit
    bytes = codec.wire_bytes(wire, meta)              # realized ledger entry

The fed engine, the dist consensus step and the figure scripts all program
against this interface; `repro.codecs.stages` builds instances out of
composable stages and `repro.codecs.registry` names them.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import numpy as np


class TreeMeta:
    """Static decode-side metadata for one tree template."""

    def __init__(self, treedef, infos, extra=None):
        self.treedef = treedef
        self.infos = infos            # [(size, shape, dtype), ...]
        self.extra = extra            # backend-specific (e.g. per-leaf stages)


@dataclasses.dataclass(frozen=True)
class TreeCodec:
    """The unified `(key, tree, budget) -> (payload, bits)` convention."""

    name: str
    encode: Callable      # (key, tree, round_idx=0) -> wire pytree (jit-safe)
    decode: Callable      # (wire, meta) -> tree (jit-safe)
    meta: Callable        # (tree template) -> TreeMeta (host-side, static)
    wire_bits: Callable   # (tree template) -> float — analytic audit
    wire_bytes: Callable  # (wire, meta) -> float — realized ledger entry
    rate: Optional[float] = None   # effective bits/dim when well-defined
    sim_only: bool = False         # True: `wire` is the decoded tree itself
    spec: Optional[tuple] = None   # hashable identity: equal specs ⇒ the
                                   # codecs are interchangeable (same factory,
                                   # budget and kwargs) — the cohort-key unit
    encode_ef: Optional[Callable] = None
    # (key, tree, meta, round_idx=0) -> (wire, residual tree). Fused
    # encode + error-feedback residual u − D(E(u)): same wire as `encode`
    # under the same key, residual emitted without a separate decode pass
    # (on TPU, without the decoded f32 tree round-tripping HBM). Backends
    # without a fused path leave this None and the fed engine composes
    # decode(encode(u)) itself.

    def compress(self, key, tree, round_idx=0):
        """One-shot (payload, analytic bits) — the ISSUE's convenience form."""
        return self.encode(key, tree, round_idx), self.wire_bits(tree)


def tree_meta(tree) -> tuple:
    """(treedef, [(size, shape, dtype), ...]) of a tree template."""
    leaves, treedef = jax.tree.flatten(tree)
    return treedef, [(int(np.prod(x.shape)) if x.shape else 1,
                      tuple(x.shape), x.dtype) for x in leaves]


def total_dims(tree) -> int:
    return sum(int(np.prod(x.shape)) if x.shape else 1
               for x in jax.tree.leaves(tree))


# the pre-move (repro.fed.registry) spellings, kept for the shim
_tree_meta = tree_meta
_total_dims = total_dims
