"""Democratic and near-democratic embeddings (paper §2).

Near-democratic (NDE):   x_nd = Sᵀy   (closed form for Parseval frames, Eq. (8)).
Democratic (DE):         argmin ‖x‖∞ s.t. y = Sx   (Eq. (5)), computed with the
Lyubarskii–Vershynin iterative truncation algorithm [10] — the same algorithm
the paper uses for its n=1000 simulations (§5). Geometric convergence: after k
rounds the residual is η^k‖y‖₂ and ‖x‖∞ ≤ η‖y‖₂ / ((1−η)√(δN)) = K_u‖y‖₂/√N.

Both run under jit (lax.fori_loop); frames are pytrees.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.frames import Frame

# Default uncertainty-principle parameters for Haar orthonormal frames with
# aspect ratio λ=2 ([10] Thm 4.1 gives η<1, δ=Ω(1); these empirical values give
# K_u ≈ 2.1 and reliable convergence — matching the paper's K_u = O(1) claim).
DEFAULT_ETA = 0.65
DEFAULT_DELTA = 0.4


def near_democratic(frame: Frame, y: jax.Array) -> jax.Array:
    """x_nd = Sᵀ y (paper Eq. (8)). y: (..., n) → (..., N)."""
    return frame.apply_t(y)


def inverse(frame: Frame, x: jax.Array) -> jax.Array:
    """y = S x — the (linear) decode map shared by DE and NDE."""
    return frame.apply(x)


@partial(jax.jit, static_argnames=("iters",))
def democratic(frame: Frame, y: jax.Array, eta: float = DEFAULT_ETA,
               delta: float = DEFAULT_DELTA, iters: int = 30) -> jax.Array:
    """Kashin/democratic embedding via LV iterative truncation [10, Thm 3.5].

    repeat: u = Sᵀr;  û = clip(u, ±M) with M = η‖r‖₂/√(δN);  x += û;  r −= Sû.
    Residual contracts by η each round, so `iters=30` leaves η^30 ≈ 2.4e-6 of
    the signal unembedded (negligible vs quantization error).
    """
    N = frame.N
    lead = y.shape[:-1]

    def body(_, carry):
        x, r = carry
        u = frame.apply_t(r)
        m = eta * jnp.linalg.norm(r, axis=-1, keepdims=True) / jnp.sqrt(delta * N)
        u_hat = jnp.clip(u, -m, m)
        x = x + u_hat
        r = r - frame.apply(u_hat)
        return x, r

    x0 = jnp.zeros(lead + (N,), y.dtype)
    x, r = jax.lax.fori_loop(0, iters, body, (x0, y))
    # Fold the (tiny) final residual back via the ℓ2 solution so y = Sx exactly
    # holds up to float precision even at small iters.
    return x + frame.apply_t(r)


def kashin_constant_upper(eta: float = DEFAULT_ETA, delta: float = DEFAULT_DELTA) -> float:
    """K_u = η / ((1−η)√δ) for Parseval frames (paper Lemma 1)."""
    return eta / ((1.0 - eta) * delta ** 0.5)


@dataclasses.dataclass(frozen=True)
class EmbeddingSpec:
    """Which embedding to use inside a codec."""

    kind: str = "near_democratic"  # or "democratic"
    eta: float = DEFAULT_ETA
    delta: float = DEFAULT_DELTA
    iters: int = 30

    def embed(self, frame: Frame, y: jax.Array) -> jax.Array:
        if self.kind == "near_democratic":
            return near_democratic(frame, y)
        if self.kind == "democratic":
            return democratic(frame, y, self.eta, self.delta, self.iters)
        raise ValueError(f"unknown embedding kind {self.kind!r}")
