"""The paper's optimization algorithms (§4).

  * DGD-DEF  (Alg. 1) — Distributed GD with Democratically Encoded Feedback:
      z_t = x̂_t + α e_{t−1};  u_t = ∇f(z_t) − e_{t−1};  v = E(u_t);
      e_t = D(v) − u_t;  x̂_{t+1} = x̂_t − α D(v).
    Deterministic codec + error feedback; rate max{ν, β}^T (Thm. 2).
  * DQGD baseline — same loop with any compressor roundtrip in place of (E, D)
    (the naive-scalar-quantizer comparator of [6] / Fig. 1b).
  * DQ-PSGD  (Alg. 2) — projected stochastic subgradient descent with a
    dithered (unbiased) codec; no error feedback needed; Thm. 3 rate.
  * DQ-PSGD multi-worker (Alg. 3) — consensus mean of per-worker decodes at
    the parameter server.

Everything is pure JAX: loops are `lax.scan`, oracles are closures, codecs are
pytree-closable objects from repro.core.coding.
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.coding import Codec


class Trace(NamedTuple):
    x_final: jax.Array
    x_avg: jax.Array          # uniform iterate average (PSGD output)
    dist_history: jax.Array   # ‖x_t − x*‖₂ per step (if x_star given, else ‖x_t‖)


def _dist(x, x_star):
    ref = x if x_star is None else x - x_star
    return jnp.linalg.norm(ref)


# ---------------------------------------------------------------------------
# Smooth & strongly convex: DGD-DEF (Alg. 1)
# ---------------------------------------------------------------------------
def dgd_def(grad_fn: Callable[[jax.Array], jax.Array], x0: jax.Array,
            codec: Codec, alpha: float, steps: int,
            key: Optional[jax.Array] = None,
            x_star: Optional[jax.Array] = None) -> Trace:
    """Paper Algorithm 1. `codec` should be deterministic (dithered=False);
    a key is still threaded for sub-linear/randomized modes."""
    if key is None:
        key = jax.random.key(0)

    def step(carry, k):
        x_hat, e_prev = carry
        z = x_hat + alpha * e_prev                     # gradient access point
        u = grad_fn(z) - e_prev                        # error feedback
        payload = codec.encode(u, k)                   # source encoding
        q_t = codec.decode(payload)                    # server decoding
        e = q_t - u                                    # error for next step
        x_next = x_hat - alpha * q_t                   # descent step
        return (x_next, e), _dist(x_next, x_star)

    keys = jax.random.split(key, steps)
    (x_fin, _), hist = jax.lax.scan(step, (x0, jnp.zeros_like(x0)), keys)
    return Trace(x_fin, x_fin, hist)


def dqgd(grad_fn: Callable[[jax.Array], jax.Array], x0: jax.Array,
         compressor_roundtrip: Callable[[jax.Array, jax.Array], jax.Array],
         alpha: float, steps: int, key: Optional[jax.Array] = None,
         x_star: Optional[jax.Array] = None) -> Trace:
    """Error-feedback QGD with an arbitrary compressor (the naive baseline)."""
    if key is None:
        key = jax.random.key(0)

    def step(carry, k):
        x_hat, e_prev = carry
        z = x_hat + alpha * e_prev
        u = grad_fn(z) - e_prev
        q_t = compressor_roundtrip(k, u)
        e = q_t - u
        x_next = x_hat - alpha * q_t
        return (x_next, e), _dist(x_next, x_star)

    keys = jax.random.split(key, steps)
    (x_fin, _), hist = jax.lax.scan(step, (x0, jnp.zeros_like(x0)), keys)
    return Trace(x_fin, x_fin, hist)


def dqgd_schedule(grad_fn, x0, levels: int, alpha: float, steps: int,
                  L: float, mu: float, D: float, n: int,
                  x_star=None) -> Trace:
    """DQGD of Lin–Kostina–Hassibi [6] (the paper's Fig. 1b comparator).

    Nearest-neighbour scalar quantization over a PREDEFINED shrinking
    dynamic-range sequence r_t — no scale is transmitted (that is the point
    of [6]); when √n/levels exceeds the contraction the range can no longer
    track the error and the iterates stall/diverge: the √n dimension penalty
    the democratic embedding removes.
    """
    sigma = sigma_rate(L, mu)
    rate = min(max(sigma, math.sqrt(n) / levels), 1.05)
    r0 = L * D

    def step(carry, t):
        x_hat, e_prev, r = carry
        z = x_hat + alpha * e_prev
        u = grad_fn(z) - e_prev
        # quantize u coordinate-wise on [-r, r] without sending r
        delta = 2.0 * r / levels
        idx = jnp.clip(jnp.floor((jnp.clip(u, -r, r) + r) / delta),
                       0, levels - 1)
        q_t = -r + (2.0 * idx + 1.0) * delta / 2.0
        e = q_t - u
        x_next = x_hat - alpha * q_t
        return (x_next, e, r * rate), _dist(x_next, x_star)

    (x_fin, _, _), hist = jax.lax.scan(
        step, (x0, jnp.zeros_like(x0), jnp.asarray(r0, x0.dtype)),
        jnp.arange(steps))
    return Trace(x_fin, x_fin, hist)


def gd(grad_fn, x0, alpha, steps, x_star=None) -> Trace:
    """Unquantized gradient descent reference."""

    def step(x, _):
        x_next = x - alpha * grad_fn(x)
        return x_next, _dist(x_next, x_star)

    x_fin, hist = jax.lax.scan(step, x0, jnp.arange(steps))
    return Trace(x_fin, x_fin, hist)


# ---------------------------------------------------------------------------
# General convex non-smooth: DQ-PSGD (Alg. 2) and multi-worker (Alg. 3)
# ---------------------------------------------------------------------------
def dq_psgd(subgrad_fn: Callable[[jax.Array, jax.Array], jax.Array],
            x0: jax.Array, codec: Optional[Codec], alpha: float, steps: int,
            key: jax.Array, project: Callable[[jax.Array], jax.Array] = lambda x: x,
            x_star: Optional[jax.Array] = None,
            compressor_roundtrip=None) -> Trace:
    """Paper Algorithm 2. `codec` should be dithered (unbiased). If
    `compressor_roundtrip` is given it is used instead (naive baselines).
    Output is the iterate average x̄_T = (1/T)Σ x̂_t."""

    def step(carry, k):
        x_hat, x_sum = carry
        ko, kq = jax.random.split(k)
        g = subgrad_fn(ko, x_hat)                      # noisy subgradient
        if compressor_roundtrip is not None:
            q_t = compressor_roundtrip(kq, g)
        elif codec is not None:
            q_t = codec.decode(codec.encode(g, kq))    # encode + decode
        else:
            q_t = g                                    # unquantized reference
        x_next = project(x_hat - alpha * q_t)          # subgradient + projection
        return (x_next, x_sum + x_next), _dist(x_next, x_star)

    keys = jax.random.split(key, steps)
    (x_fin, x_sum), hist = jax.lax.scan(step, (x0, jnp.zeros_like(x0)), keys)
    return Trace(x_fin, x_sum / steps, hist)


def dq_psgd_multiworker(subgrad_fns_key: Callable[[jax.Array, jax.Array, jax.Array], jax.Array],
                        num_workers: int, x0: jax.Array, codec: Optional[Codec],
                        alpha: float, steps: int, key: jax.Array,
                        project: Callable[[jax.Array], jax.Array] = lambda x: x,
                        x_star: Optional[jax.Array] = None,
                        compressor_roundtrip=None) -> Trace:
    """Paper Algorithm 3 (parameter server + m workers).

    `subgrad_fns_key(worker_id, key, x)` returns worker i's noisy subgradient.
    Per step: each worker encodes its subgradient; the server decodes all m
    payloads and takes the consensus mean, then a projected subgradient step.
    """
    worker_ids = jnp.arange(num_workers)

    def one_worker(i, k, x):
        g = subgrad_fns_key(i, k, x)
        if compressor_roundtrip is not None:
            return compressor_roundtrip(k, g)
        if codec is not None:
            return codec.decode(codec.encode(g, k))
        return g

    def step(carry, k):
        x_hat, x_sum = carry
        keys = jax.random.split(k, num_workers)
        decodes = jax.vmap(one_worker, in_axes=(0, 0, None))(worker_ids, keys, x_hat)
        q_t = jnp.mean(decodes, axis=0)                # consensus step
        x_next = project(x_hat - alpha * q_t)
        return (x_next, x_sum + x_next), _dist(x_next, x_star)

    keys = jax.random.split(key, steps)
    (x_fin, x_sum), hist = jax.lax.scan(step, (x0, jnp.zeros_like(x0)), keys)
    return Trace(x_fin, x_sum / steps, hist)


# ---------------------------------------------------------------------------
# Step-size helpers (paper Thm. 2 / Thm. 3)
# ---------------------------------------------------------------------------
def alpha_star(L: float, mu: float) -> float:
    """α* = 2/(L+μ) — the optimal GD step size for F_{μ,L,D} (Thm. 2)."""
    return 2.0 / (L + mu)


def sigma_rate(L: float, mu: float) -> float:
    """σ = (L−μ)/(L+μ) — unquantized linear rate / lower-bound floor."""
    return (L - mu) / (L + mu)


def psgd_alpha(D: float, B: float, Ku: float, R: float, T: int) -> float:
    """α = (D/(B·K_u))·√(min{R,1}/T) (Thm. 3)."""
    return (D / (B * Ku)) * (min(R, 1.0) / T) ** 0.5
