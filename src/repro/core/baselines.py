"""Baseline compression schemes the paper compares against (Table 1, §5).

Each baseline is exposed as a `(key, y) -> y_hat` roundtrip plus a bit audit,
so benchmarks can sweep them uniformly alongside DSC/NDSC. These also serve as
the building blocks that DSC/NDSC wrap via Thm. 4 (compress-in-embedded-space).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import quantizers as q


@dataclasses.dataclass(frozen=True)
class Compressor:
    name: str
    roundtrip: Callable  # (key, y) -> y_hat
    wire_bits: Callable  # (n) -> float  (scalars like norms ride at f32 = 32b)


# -- naive uniform scalar quantizer (the paper's "naive"/DQGD quantizer) ------
def naive_uniform(levels: int) -> Compressor:
    def fn(key, y):
        scale = jnp.max(jnp.abs(y), axis=-1, keepdims=True)
        safe = jnp.maximum(scale, jnp.finfo(y.dtype).tiny)
        return q.uniform_quantize(y / safe, levels) * scale

    return Compressor(f"naive-uniform({levels}l)", fn,
                      lambda n: n * math.log2(levels) + 32)


# -- standard dithering (SD [8] shape; ‖·‖∞ dynamic range) --------------------
def standard_dither(levels: int) -> Compressor:
    def fn(key, y):
        scale = jnp.max(jnp.abs(y), axis=-1, keepdims=True)
        safe = jnp.maximum(scale, jnp.finfo(y.dtype).tiny)
        return q.dithered_quantize(key, y / safe, levels) * scale

    return Compressor(f"standard-dither({levels}l)", fn,
                      lambda n: n * math.log2(levels) + 32)


# -- QSGD [8]: ℓ2-norm scaling, stochastic levels -----------------------------
def qsgd(s: int) -> Compressor:
    """QSGD with s quantization levels on |y_i|/‖y‖₂ ∈ [0,1], sign separate."""

    def fn(key, y):
        norm = jnp.linalg.norm(y, axis=-1, keepdims=True)
        safe = jnp.maximum(norm, jnp.finfo(y.dtype).tiny)
        a = jnp.abs(y) / safe                       # ∈ [0, 1]
        level = a * s
        lo = jnp.floor(level)
        up = jax.random.uniform(key, y.shape) < (level - lo)
        zeta = (lo + up.astype(y.dtype)) / s
        return jnp.sign(y) * zeta * norm

    return Compressor(f"qsgd(s={s})", fn,
                      lambda n: n * (1 + math.log2(s + 1)) + 32)


# -- signSGD [14,15] with ℓ1 scale (EF-SignSGD variant) -----------------------
def sign_compressor(scaled: bool = True) -> Compressor:
    def fn(key, y):
        mag = (jnp.mean(jnp.abs(y), axis=-1, keepdims=True) if scaled
               else jnp.asarray(1.0, y.dtype))
        return jnp.sign(y) * mag

    return Compressor("sign" + ("-l1" if scaled else ""), fn, lambda n: n + 32)


# -- TernGrad [16]: levels {-1, 0, +1}, stochastic, ‖·‖∞ scale ----------------
def ternary() -> Compressor:
    def fn(key, y):
        scale = jnp.max(jnp.abs(y), axis=-1, keepdims=True)
        safe = jnp.maximum(scale, jnp.finfo(y.dtype).tiny)
        p = jnp.abs(y) / safe
        keep = jax.random.uniform(key, y.shape) < p
        return jnp.sign(y) * keep.astype(y.dtype) * scale

    return Compressor("ternary", fn, lambda n: n * math.log2(3) + 32)


# -- top-k sparsification [18] -------------------------------------------------
def topk(k_fraction: float, quant_levels: Optional[int] = None) -> Compressor:
    """Keep the top ⌈fn⌉ coordinates by magnitude; optionally quantize them."""

    def fn(key, y):
        n = y.shape[-1]
        k = max(1, int(round(k_fraction * n)))
        thresh = -jnp.sort(-jnp.abs(y), axis=-1)[..., k - 1:k]
        mask = (jnp.abs(y) >= thresh).astype(y.dtype)
        kept = y * mask
        if quant_levels is None:
            return kept
        scale = jnp.max(jnp.abs(kept), axis=-1, keepdims=True)
        safe = jnp.maximum(scale, jnp.finfo(y.dtype).tiny)
        return q.uniform_quantize(kept / safe, quant_levels) * scale * mask

    def bits(n):
        k = max(1, int(round(k_fraction * n)))
        payload = 32 if quant_levels is None else math.log2(quant_levels)
        return k * payload + math.log2(math.comb(n, k)) + 32

    tag = f"top{int(k_fraction * 100)}%" + (
        f"+{quant_levels}l" if quant_levels else "")
    return Compressor(tag, fn, bits)


# -- random-k sparsification [19] ----------------------------------------------
def randk(k_fraction: float, quant_levels: Optional[int] = None,
          unbiased: bool = False) -> Compressor:
    def fn(key, y):
        km, kq = jax.random.split(key)
        # EXACTLY k survivors per row (uniform random subset), so the
        # realized payload matches the k·payload + log2(C(n,k)) wire audit;
        # an i.i.d. Bernoulli mask only matches it in expectation.
        k = max(1, int(round(k_fraction * y.shape[-1])))
        draw = jax.random.uniform(km, y.shape)
        thresh = jnp.sort(draw, axis=-1)[..., k - 1:k]
        mask = (draw <= thresh).astype(y.dtype)
        kept = y * mask
        if quant_levels is not None:
            scale = jnp.max(jnp.abs(kept), axis=-1, keepdims=True)
            safe = jnp.maximum(scale, jnp.finfo(y.dtype).tiny)
            kept = q.uniform_quantize(kept / safe, quant_levels) * scale * mask
        if unbiased:
            # each coordinate survives w.p. exactly k/n under the exact-k
            # mask (NOT k_fraction, which k was rounded from)
            kept = kept * (y.shape[-1] / k)
        return kept

    def bits(n):
        k = max(1, int(round(k_fraction * n)))
        payload = 32 if quant_levels is None else math.log2(quant_levels)
        return k * payload + math.log2(math.comb(n, k)) + 32

    tag = f"rand{int(k_fraction * 100)}%" + (
        f"+{quant_levels}l" if quant_levels else "")
    return Compressor(tag, fn, bits)


def normalized_error(key: jax.Array, comp: Compressor, y: jax.Array) -> jax.Array:
    """E‖C(y) − y‖₂ / ‖y‖₂ — the metric of paper Fig. 1a / Table 1."""
    y_hat = comp.roundtrip(key, y)
    return jnp.linalg.norm(y_hat - y, axis=-1) / jnp.linalg.norm(y, axis=-1)
