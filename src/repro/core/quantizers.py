"""Scalar quantizers (paper §3, App. E).

  * uniform_quantize      — deterministic R-bit nearest-neighbour on B∞(1)
                            (Eq. (11); used by DSC/NDSC for DGD-DEF).
  * dithered_quantize     — stochastic/unbiased uniform quantizer (App. E, CUQ;
                            used by DQ-PSGD — unbiasedness removes the need for
                            error feedback with stochastic oracles).
  * gain_quantize         — dithered scalar quantizer for the magnitude on [0, B]
                            (Eq. (20)).
  * subsample_mask        — the sub-linear budget (R < 1) path: keep ⌊nR⌋ random
                            coordinates, 1 bit each, unbiased 1/R rescale (App E.2).

All quantizers take `levels` (number of quantization points per dimension)
rather than bits, so fractional effective budgets R/λ are supported exactly:
levels = floor(2^{R/λ}) for the deterministic path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def levels_for_budget(bits_per_dim: float) -> int:
    """Number of uniform levels affordable with `bits_per_dim` bits (≥ 2)."""
    return max(2, int(2.0 ** bits_per_dim))


def uniform_quantize(x: jax.Array, levels: int) -> jax.Array:
    """Deterministic nearest-neighbour uniform quantizer on [-1, 1].

    Quantization points v_i = -1 + (2i+1)Δ/2, Δ = 2/levels (paper §3).
    Max per-coordinate error Δ/2.
    """
    delta = 2.0 / levels
    idx = jnp.clip(jnp.floor((jnp.clip(x, -1.0, 1.0) + 1.0) / delta), 0, levels - 1)
    return -1.0 + (2.0 * idx + 1.0) * delta / 2.0


def quantize_indices(x: jax.Array, levels: int) -> jax.Array:
    """Integer codewords of the deterministic uniform quantizer (for the wire)."""
    delta = 2.0 / levels
    idx = jnp.clip(jnp.floor((jnp.clip(x, -1.0, 1.0) + 1.0) / delta), 0, levels - 1)
    return idx.astype(jnp.int32)


def dequantize_indices(idx: jax.Array, levels: int, dtype=jnp.float32) -> jax.Array:
    delta = 2.0 / levels
    return (-1.0 + (2.0 * idx.astype(dtype) + 1.0) * delta / 2.0)


def dithered_quantize(key: jax.Array, x: jax.Array, levels: int,
                      lo: float | jax.Array = -1.0,
                      hi: float | jax.Array = 1.0) -> jax.Array:
    """Unbiased stochastic uniform quantizer on [lo, hi] (paper Eq. (20)).

    For v ∈ [u_j, u_{j+1}): outputs u_j w.p. (u_{j+1}−v)/Δ else u_{j+1};
    E[Q(v)] = v for v inside the range.
    """
    delta = (hi - lo) / (levels - 1)
    pos = (jnp.clip(x, lo, hi) - lo) / delta           # ∈ [0, levels-1]
    base = jnp.floor(pos)
    frac = pos - base                                   # P[round up]
    up = jax.random.uniform(key, x.shape) < frac
    idx = jnp.clip(base + up.astype(base.dtype), 0, levels - 1)
    return lo + idx * delta


def dithered_quantize_indices(key: jax.Array, x: jax.Array, levels: int,
                              lo: float | jax.Array = -1.0,
                              hi: float | jax.Array = 1.0) -> jax.Array:
    """Integer codewords of the dithered quantizer."""
    delta = (hi - lo) / (levels - 1)
    pos = (jnp.clip(x, lo, hi) - lo) / delta
    base = jnp.floor(pos)
    frac = pos - base
    up = jax.random.uniform(key, x.shape) < frac
    return jnp.clip(base + up.astype(base.dtype), 0, levels - 1).astype(jnp.int32)


def dithered_dequantize_indices(idx: jax.Array, levels: int,
                                lo: float | jax.Array = -1.0,
                                hi: float | jax.Array = 1.0,
                                dtype=jnp.float32) -> jax.Array:
    delta = (hi - lo) / (levels - 1)
    return lo + idx.astype(dtype) * delta


def gain_quantize(key: jax.Array, v: jax.Array, dynamic_range: float,
                  bits: int = 32) -> jax.Array:
    """Dithered magnitude quantizer Q_G on [0, B] (paper Eq. (20)); unbiased."""
    levels = min(2 ** bits, 2 ** 31)
    return dithered_quantize(key, v, levels, lo=0.0, hi=dynamic_range)


def subsample_mask(key: jax.Array, shape: tuple[int, ...], keep_fraction: float) -> jax.Array:
    """Bernoulli keep-mask for the sub-linear regime (App. E.2).

    E[mask] = keep_fraction, so dividing the kept values by keep_fraction is
    unbiased. (The paper samples exactly ⌊nR⌋ without replacement; Bernoulli
    sampling has the same mean budget and is shard-local — no global sort.)
    """
    return (jax.random.uniform(key, shape) < keep_fraction).astype(jnp.float32)
