"""Democratic Source Coding (DSC) and Near-Democratic Source Coding (NDSC).

A source coding scheme is a pair (E, D):  E: R^n → {0,1}^{nR} (worker side),
D: {0,1}^{nR} → R^n (server side). Paper §3:

    E(y) = Q(x / ‖x‖∞),   D(x') = ‖x‖∞ · S x',

with x the (near-)democratic embedding of y w.r.t. frame S. With a budget of
R bits/dim of the *original* vector, the embedded vector (N = λn dims) gets
R/λ bits/dim. The scale ‖x‖∞ rides along at f32 — the paper's nR + O(1) bits.

Two quantization modes:
  * deterministic (nearest-neighbour)  — used by DGD-DEF (error feedback),
  * dithered (unbiased, gain-shape)    — used by DQ-PSGD; for R < 1 the
    sub-linear path subsamples coordinates at rate R and spends 1 bit each.

`Payload` is the exact wire format; `wire_bits()` audits the budget.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import quantizers as q
from repro.core.embeddings import EmbeddingSpec, kashin_constant_upper
from repro.core.frames import Frame


class Payload(NamedTuple):
    """What actually crosses the wire."""

    indices: jax.Array            # int32 codewords, shape (..., N)
    scale: jax.Array              # f32, shape (..., 1) — ‖x‖∞ or gain ‖y‖₂
    mask: Optional[jax.Array]     # f32 0/1 keep-mask (sub-linear regime) or None


@dataclasses.dataclass(frozen=True)
class CodecConfig:
    bits_per_dim: float = 4.0            # R — the budget, per ORIGINAL dimension
    dithered: bool = False               # False: DGD-DEF path; True: DQ-PSGD path
    unbiased_rescale: bool = True        # sub-linear path: divide by keep rate
    embedding: EmbeddingSpec = EmbeddingSpec()


class Codec:
    """(E, D) pair bound to a frame. The frame (a pytree) is jit-closable."""

    def __init__(self, frame: Frame, config: CodecConfig):
        self.frame = frame
        self.config = config
        self.n = frame.n
        self.N = frame.N
        self.aspect_ratio = frame.N / frame.n
        # bits per embedded dimension
        self.embedded_bits = config.bits_per_dim / self.aspect_ratio
        self.sublinear = self.embedded_bits < 1.0
        if self.sublinear:
            self.levels = 2
            self.keep_fraction = float(self.embedded_bits)
        else:
            self.levels = q.levels_for_budget(self.embedded_bits)
            self.keep_fraction = 1.0

    # -- budget audit -------------------------------------------------------
    def wire_bits(self) -> float:
        """Expected bits on the wire per encoded vector (excl. the O(1) scale)."""
        if self.sublinear:
            return self.N * self.keep_fraction * 1.0
        return self.N * math.log2(self.levels)

    # -- encoder (worker) ----------------------------------------------------
    def encode(self, y: jax.Array, key: Optional[jax.Array] = None) -> Payload:
        x = self.config.embedding.embed(self.frame, y)
        scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        safe = jnp.maximum(scale, jnp.finfo(x.dtype).tiny)
        xn = x / safe
        if not self.config.dithered:
            if self.sublinear:
                kq, km = jax.random.split(_require(key))
                mask = q.subsample_mask(km, x.shape, self.keep_fraction)
                idx = q.quantize_indices(xn, 2)
                return Payload(idx, scale, mask)
            return Payload(q.quantize_indices(xn, self.levels), scale, None)
        # dithered / unbiased path
        kq, km = jax.random.split(_require(key))
        if self.sublinear:
            mask = q.subsample_mask(km, x.shape, self.keep_fraction)
            idx = q.dithered_quantize_indices(kq, xn, 2)
            return Payload(idx, scale, mask)
        idx = q.dithered_quantize_indices(kq, xn, self.levels)
        return Payload(idx, scale, None)

    # -- decoder (server) ----------------------------------------------------
    def decode(self, payload: Payload) -> jax.Array:
        idx, scale, mask = payload
        if self.config.dithered and not self.sublinear:
            xn = q.dithered_dequantize_indices(idx, self.levels)
        elif self.config.dithered and self.sublinear:
            xn = q.dithered_dequantize_indices(idx, 2)
        else:
            xn = q.dequantize_indices(idx, self.levels if not self.sublinear else 2)
        if mask is not None:
            xn = xn * mask
            # 1/keep rescale restores unbiasedness for the DITHERED (DQ-PSGD)
            # path; the deterministic (DGD-DEF) path relies on error feedback
            # and a CONTRACTIVE map — rescaling would inflate β past 1.
            if self.config.unbiased_rescale and self.config.dithered:
                xn = xn / self.keep_fraction
        x_hat = xn * scale
        return self.frame.apply(x_hat)

    def roundtrip(self, y: jax.Array, key: Optional[jax.Array] = None) -> jax.Array:
        return self.decode(self.encode(y, key))

    # -- theory --------------------------------------------------------------
    def error_bound(self) -> float:
        """Thm. 1 contraction β: ‖y − Q(y)‖₂ ≤ β‖y‖₂ (w.h.p.)."""
        r_over_lambda = self.config.bits_per_dim / self.aspect_ratio
        if self.config.embedding.kind == "democratic":
            ku = kashin_constant_upper(self.config.embedding.eta,
                                       self.config.embedding.delta)
            return 2.0 ** (1.0 - r_over_lambda) * ku
        return 2.0 ** (2.0 - r_over_lambda) * math.sqrt(math.log(2 * self.N))


def _require(key: Optional[jax.Array]) -> jax.Array:
    if key is None:
        raise ValueError("this codec mode is randomized: a PRNG key is required")
    return key


# ---------------------------------------------------------------------------
# Thm. 4 / App. H: compose ANY unbiased compressor with the embedding.
# ---------------------------------------------------------------------------
def compress_in_embedded_space(frame: Frame, compressor, y: jax.Array,
                               key: Optional[jax.Array] = None,
                               embedding: EmbeddingSpec = EmbeddingSpec()) -> jax.Array:
    """E(y) = C(x), D = S· — inherits dimension-free error (paper Thm. 4).

    `compressor(key, x) -> x_hat` is any (possibly stochastic) compression map.
    """
    x = embedding.embed(frame, y)
    x_hat = compressor(key, x)
    return frame.apply(x_hat)
