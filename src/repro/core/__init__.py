"""The paper's contribution: democratic embeddings, source coding, algorithms."""
from repro.core.frames import (DenseFrame, HadamardFrame, haar_frame,
                               hadamard_frame, subgaussian_frame, make_frame,
                               next_pow2)
from repro.core.embeddings import (EmbeddingSpec, democratic, near_democratic,
                                   kashin_constant_upper)
from repro.core.coding import Codec, CodecConfig, Payload
