"""Randomized frame constructions S ∈ R^{n×N} (n ≤ N) for (near-)democratic embeddings.

All frames here are (approximately) Parseval: S Sᵀ = I_n, so the
near-democratic embedding has the closed form x_nd = Sᵀ y  (paper Eq. (8)).

Three families (paper §2.1, App. J):
  * Haar random orthonormal  — n rows of a Haar-distributed N×N orthogonal matrix.
  * Randomized Hadamard      — S = P D H. Stored as a sign vector (D) and a
                               row-selection index (P); applying S / Sᵀ uses the
                               fast Walsh–Hadamard transform: O(N log N) adds.
  * Sub-Gaussian (Gaussian)  — G/√N i.i.d. entries; approximate Parseval frame.

Frames are immutable pytrees so they can be closed over / passed through jit.
"""
from __future__ import annotations

import dataclasses
from typing import Union

import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DenseFrame:
    """Explicit S ∈ R^{n×N}: Haar orthonormal or sub-Gaussian."""

    S: jax.Array  # (n, N)

    @property
    def n(self) -> int:
        return self.S.shape[0]

    @property
    def N(self) -> int:
        return self.S.shape[1]

    @property
    def aspect_ratio(self) -> float:
        return self.N / self.n

    def apply(self, x: jax.Array) -> jax.Array:
        """y = S x. x: (..., N) → (..., n)."""
        return x @ self.S.T

    def apply_t(self, y: jax.Array) -> jax.Array:
        """x = Sᵀ y. y: (..., n) → (..., N)."""
        return y @ self.S


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HadamardFrame:
    """S = P D H with H the normalized N×N Hadamard matrix (entries ±1/√N).

    Parseval by construction: S Sᵀ = P D H Hᵀ D Pᵀ = I_n.
    `signs` is the diagonal of D (±1, int8); `rows` the indices kept by P.
    Sᵀ y = H D Pᵀ y is computed with an FWHT (Pallas kernel on TPU).
    """

    signs: jax.Array  # (N,) ±1
    rows: jax.Array   # (n,) int32 indices into [0, N)

    @property
    def n(self) -> int:
        return self.rows.shape[0]

    @property
    def N(self) -> int:
        return self.signs.shape[0]

    @property
    def aspect_ratio(self) -> float:
        return self.N / self.n

    def apply(self, x: jax.Array) -> jax.Array:
        """y = S x = P (D (H x)). x: (..., N) → (..., n)."""
        hx = kernel_ops.fwht(x)  # H x (H symmetric, orthonormal)
        dx = hx * self.signs.astype(x.dtype)
        return jnp.take(dx, self.rows, axis=-1)

    def apply_t(self, y: jax.Array) -> jax.Array:
        """x = Sᵀ y = H (D (Pᵀ y)). y: (..., n) → (..., N)."""
        z = jnp.zeros(y.shape[:-1] + (self.N,), y.dtype)
        z = z.at[..., self.rows].set(y)
        return kernel_ops.fwht(z * self.signs.astype(y.dtype))


Frame = Union[DenseFrame, HadamardFrame]


def haar_frame(key: jax.Array, n: int, N: int, dtype=jnp.float32) -> DenseFrame:
    """n random rows of a Haar-distributed N×N orthogonal matrix (paper §2.1)."""
    if n > N:
        raise ValueError(f"need n <= N, got {n} > {N}")
    kq, kp = jax.random.split(key)
    g = jax.random.normal(kq, (N, N), dtype=jnp.float32)
    q, r = jnp.linalg.qr(g)
    # Sign-correct so Q is Haar (QR alone is not Haar-distributed).
    q = q * jnp.sign(jnp.diagonal(r))[None, :]
    rows = jax.random.permutation(kp, N)[:n]
    return DenseFrame(S=q[rows].astype(dtype))


def subgaussian_frame(key: jax.Array, n: int, N: int, dtype=jnp.float32) -> DenseFrame:
    """i.i.d. N(0, 1/N) entries — approximate Parseval frame (paper App. J.1)."""
    if n > N:
        raise ValueError(f"need n <= N, got {n} > {N}")
    return DenseFrame(S=(jax.random.normal(key, (n, N)) / jnp.sqrt(N)).astype(dtype))


def hadamard_frame(key: jax.Array, n: int, N: int | None = None) -> HadamardFrame:
    """Randomized Hadamard frame S = P D H (paper §2.1). N must be a power of 2."""
    if N is None:
        N = next_pow2(n)
    if not _is_pow2(N):
        raise ValueError(f"Hadamard dimension N={N} must be a power of 2")
    if n > N:
        raise ValueError(f"need n <= N, got {n} > {N}")
    ks, kp = jax.random.split(key)
    signs = jax.random.rademacher(ks, (N,), dtype=jnp.int8)
    rows = (jax.random.permutation(kp, N)[:n] if n < N
            else jnp.arange(N, dtype=jnp.int32))
    return HadamardFrame(signs=signs, rows=rows.astype(jnp.int32))


def make_frame(kind: str, key: jax.Array, n: int, N: int | None = None) -> Frame:
    """Factory: kind ∈ {'haar', 'hadamard', 'subgaussian'}."""
    if kind == "hadamard":
        return hadamard_frame(key, n, N)
    if N is None:
        N = n
    if kind == "haar":
        return haar_frame(key, n, N)
    if kind == "subgaussian":
        return subgaussian_frame(key, n, N)
    raise ValueError(f"unknown frame kind: {kind!r}")


def dense_matrix(frame: Frame) -> jax.Array:
    """Materialize S as an explicit (n, N) matrix (tests / small n only)."""
    if isinstance(frame, DenseFrame):
        return frame.S
    eye = jnp.eye(frame.N, dtype=jnp.float32)
    # columns of S are S e_i = apply(e_i)
    return jax.vmap(frame.apply)(eye).T
