"""Distributed compressed-consensus subsystem.

  sharding — PartitionSpec rules for params / batches on the mesh
  gradcomp — chunked NDSC gradient codec + wire audit (the paper's E/D pair)
  step     — train / serve / ZeRO-1 step factories (shard_map over data axes)
  zero     — ZeRO-1 owned layout + compressed all-to-all reduce-scatter
"""
from repro.dist import gradcomp, sharding, step, zero
from repro.dist.gradcomp import (GradCompConfig, compress_tree,
                                 decode_payload, encode_leaf, decode_leaf,
                                 wire_bytes_tree)
from repro.dist.sharding import (batch_specs, data_axes_for, param_spec,
                                 param_specs, shardable)
