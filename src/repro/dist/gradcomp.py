"""Chunked NDSC gradient codec — the fused stage implementation behind the
`repro.codecs` NDSC pipeline (paper §3 at model scale).

This module IS the `hadamard + chunk_drop + uniform/dithered + int32`
combination of `repro.codecs.stages`: the Pipeline delegates its leaf
encode/decode (and the fused encode+EF residual) here rather than
re-composing the stages, which is what keeps registry-built NDSC codecs
bit-identical with the historical gradcomp path and keeps the whole chain
on the single fused Pallas kernel.

Each parameter leaf is flattened, zero-padded to a multiple of `chunk`
(a power of two) and embedded chunk-wise with a randomized Hadamard frame
S = D·H from `core.frames` — the near-democratic embedding that flattens
the per-chunk dynamic range so a single ‖x‖∞ scale + uniform R-bit
quantization achieves the Thm. 1 error 2^(2−R)·√log(2·chunk) per chunk.
The whole encode chain runs as ONE fused Pallas kernel
(`kernels.quantencode` via `kernels.ops.encode`) — sign flip, FWHT, scale,
dither, quantize and int32 bit-pack in a single VMEM pass; its packed-word
output is also the exact wire format audited by `wire_bytes_tree`.

Shared randomness: the frame for leaf i is a pure function of
(cfg.seed, i) — every worker builds the same frame, so gathered payloads
decode identically everywhere (and the ZeRO-1 all-to-all path in
`repro.dist.zero` stays bit-exact with the all-gather consensus). The
stochastic parts (non-subtractive dither, sub-linear chunk keep-mask) fold
in `round_idx` so they refresh every step but still agree across workers.

Wire format per leaf (the payload dict):
  words  int32 (C, chunk·bits/32) — bit-packed codes
  scale  f32   (C, 1)             — per-chunk ‖x‖∞ (the paper's O(1) bits)
  mask   f32   (C, 1)             — only when keep_fraction < 1: which
                                    chunks made it onto the wire this round
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import frames as frames_lib
from repro.kernels import ops as kernel_ops

STRATEGIES = ("psum", "psum_decoded", "allgather_packed", "alltoall_zero1")


@dataclasses.dataclass(frozen=True)
class GradCompConfig:
    """Budget + consensus strategy for compressed gradient exchange.

    bits           R per kept coordinate; {1, 2, 4, 8} (int32 packing).
    chunk          FWHT/frame length; power of two ≥ 32.
    strategy       psum            — exact f32 all-reduce (no compression),
                   psum_decoded    — compress→decode locally, f32 all-reduce
                                     (isolates codec error from wire savings),
                   allgather_packed— all-gather the PACKED payloads, decode
                                     all m, mean (paper's consensus, Alg. 3),
                   alltoall_zero1  — ZeRO-1: compressed reduce-scatter via
                                     all-to-all, owner-sharded optimizer.
    error_feedback per-worker EF state e ← u − D(E(u)) (DGD-DEF path).
    dithered       non-subtractive uniform dither → unbiased codec (Alg. 2 /
                   DQ-PSGD path; lets training drop the params-sized EF).
    keep_fraction  chunk-level subsampling for the sub-linear regime
                   (R_eff = bits·keep_fraction < 1, App. E.2).
    exact_keep     keep EXACTLY ⌈keep_fraction·C⌉ chunks per leaf (a shared
                   random subset of fixed size) instead of i.i.d. Bernoulli —
                   the realized bytes-on-wire then equal the analytic audit
                   every round, which the repro.fed ledger relies on.
    """

    bits: int = 4
    chunk: int = 256
    strategy: str = "allgather_packed"
    error_feedback: bool = True
    dithered: bool = False
    keep_fraction: float = 1.0
    exact_keep: bool = False
    seed: int = 0

    def __post_init__(self):
        if self.bits not in (1, 2, 4, 8):
            raise ValueError(f"bits must be in {{1,2,4,8}}, got {self.bits}")
        if self.chunk < 32 or (self.chunk & (self.chunk - 1)):
            raise ValueError(
                f"chunk must be a power of two ≥ 32, got {self.chunk}")
        if self.strategy not in STRATEGIES:
            raise ValueError(f"strategy must be one of {STRATEGIES}, "
                             f"got {self.strategy!r}")
        if not 0.0 < self.keep_fraction <= 1.0:
            raise ValueError(
                f"keep_fraction must be in (0, 1], got {self.keep_fraction}")

    @property
    def effective_bits(self) -> float:
        """Bits per original dimension actually spent on the wire."""
        return self.bits * self.keep_fraction

    @property
    def words_per_chunk(self) -> int:
        return self.chunk * self.bits // 32

    def kept_chunks(self, c: int) -> int:
        """Chunks on the wire for a leaf of c chunks under exact_keep."""
        if self.keep_fraction >= 1.0:
            return c
        return max(1, int(round(self.keep_fraction * c)))

    @property
    def compresses(self) -> bool:
        return self.strategy != "psum"

    @property
    def uses_ef(self) -> bool:
        return self.compresses and self.error_feedback


# ---------------------------------------------------------------------------
# Deterministic per-leaf randomness (shared across workers)
# ---------------------------------------------------------------------------
def _frame_signs(leaf_idx: int, cfg: GradCompConfig) -> jax.Array:
    """±1 diagonal of the leaf's Hadamard frame S = D·H (P = identity at
    n = N = chunk). Pure function of (cfg.seed, leaf_idx)."""
    key = jax.random.fold_in(jax.random.key(cfg.seed), leaf_idx)
    frame = frames_lib.hadamard_frame(key, cfg.chunk, cfg.chunk)
    return frame.signs


def _stoch_key(leaf_idx, round_idx, cfg: GradCompConfig) -> jax.Array:
    """Key for the per-round stochastic parts (dither / keep-mask)."""
    base = jax.random.fold_in(jax.random.key(cfg.seed), 0x5eed)
    return jax.random.fold_in(jax.random.fold_in(base, leaf_idx), round_idx)


# ---------------------------------------------------------------------------
# Leaf codec
# ---------------------------------------------------------------------------
def _to_chunks(x: jax.Array, chunk: int) -> jax.Array:
    flat = x.astype(jnp.float32).reshape(-1)
    c = -(-flat.size // chunk)
    flat = jnp.pad(flat, (0, c * chunk - flat.size))
    return flat.reshape(c, chunk)


def _pad_rows(t: jax.Array, rows: int) -> jax.Array:
    """Zero-pad the leading axis of t up to `rows`."""
    if t.shape[0] == rows:
        return t
    return jnp.pad(t, ((0, rows - t.shape[0]),) + ((0, 0),) * (t.ndim - 1))


def _exact_keep_mask(draw: jax.Array, k: int) -> jax.Array:
    """Keep EXACTLY the k smallest of the (C, 1) uniform draws.

    A `draw <= kth-smallest` threshold keeps MORE than k chunks when draws
    tie, breaking the ledger == analytic-audit byte contract; double-argsort
    ranking (stable, ties broken by chunk index — identical on every worker)
    keeps exactly k always."""
    rank = jnp.argsort(jnp.argsort(draw[:, 0]))
    return (rank < k)[:, None]


def _leaf_draws(leaf_idx: int, lc: int, rows: int, cfg: GradCompConfig,
                round_idx, key: jax.Array | None) -> tuple:
    """Pre-draw the per-round stochastic kernel inputs for one leaf.

    Returns (dither (rows, chunk) | None, mask f32 (rows, 1) | None). The
    draws happen at the LOGICAL chunk count `lc` from the same
    `fold_in`-derived keys as always, then zero-extend over padding — they
    are handed to the fused kernel as plain inputs, so forcing the Pallas
    path can never change a payload."""
    if key is None and (cfg.dithered or cfg.keep_fraction < 1.0):
        key = _stoch_key(leaf_idx, round_idx, cfg)
    dither = None
    if cfg.dithered:
        delta = 2.0 / (2 ** cfg.bits)
        dither = _pad_rows(jax.random.uniform(
            jax.random.fold_in(key, 1), (lc, cfg.chunk),
            minval=-delta / 2, maxval=delta / 2), rows)
    mask = None
    if cfg.keep_fraction < 1.0:
        draw = jax.random.uniform(jax.random.fold_in(key, 2), (lc, 1))
        if cfg.exact_keep:
            # fixed-size random subset: the k smallest draws stay on the wire
            keep = _exact_keep_mask(draw, cfg.kept_chunks(lc))
        else:
            keep = draw < cfg.keep_fraction
        mask = _pad_rows(keep.astype(jnp.float32), rows)
    return dither, mask


def encode_leaf(x: jax.Array, leaf_idx: int, cfg: GradCompConfig,
                round_idx=0, key: jax.Array | None = None,
                logical_chunks: int | None = None) -> dict:
    """Encode one leaf → payload dict (see module docstring for the format).

    `key` overrides the derived stochastic key (benchmarks that want
    per-worker independent dither); frames are never affected by it.

    `logical_chunks` is the PRE-PAD chunk count ⌈size/chunk⌉ of the leaf;
    pass it when `x` arrives already padded to extra all-zero chunks (the
    ZeRO-1 owned layout pads to a multiple of the worker count). The
    stochastic draws (dither, keep-mask) happen at the logical count and are
    zero-extended over the padding, so the payload of the padded layout is
    bit-exact with the un-padded all-gather encode on the real chunks.

    The whole chain (sign-flip → FWHT → scale → dither → quantize+pack →
    mask) runs in `kernel_ops.encode` — one fused VMEM pass on the Pallas
    path, the composed jnp reference otherwise, bit-identical payloads
    either way (dropped chunks emit all-zero words + zero scale, so the
    wire carries no ghost information)."""
    chunks = _to_chunks(x, cfg.chunk)
    lc = chunks.shape[0] if logical_chunks is None else logical_chunks
    signs = _frame_signs(leaf_idx, cfg).astype(jnp.float32)
    dither, mask = _leaf_draws(leaf_idx, lc, chunks.shape[0], cfg,
                               round_idx, key)
    words, scale = kernel_ops.encode(chunks, signs, cfg.bits,
                                     dither=dither, mask=mask)
    payload = {"words": words, "scale": scale}
    if mask is not None:
        payload["mask"] = mask
    return payload


def encode_leaf_ef(x: jax.Array, leaf_idx: int, cfg: GradCompConfig,
                   round_idx=0, key: jax.Array | None = None,
                   logical_chunks: int | None = None,
                   residual_dtype=None) -> tuple:
    """`encode_leaf` plus the error-feedback residual u − D(E(u)).

    Returns (payload, residual) with residual of x's shape/dtype — what
    the DGD-DEF update stores as the next round's EF state. On the Pallas
    path the kernel decodes its own payload in-tile and emits the residual
    without a second pass over the leaf; on the reference path the composed
    decode replays `decode_leaf`'s op order exactly (including the
    1/keep_fraction rescale only on the dithered-unbiased path and the
    decode-dtype rounding before the subtract). `residual_dtype` is the
    dtype the eager path would decode to (defaults to x's dtype); the fed
    engine passes the PARAM dtype so u − D(E(u)) rounds where a real
    decode would."""
    chunks = _to_chunks(x, cfg.chunk)
    lc = chunks.shape[0] if logical_chunks is None else logical_chunks
    signs = _frame_signs(leaf_idx, cfg).astype(jnp.float32)
    dither, mask = _leaf_draws(leaf_idx, lc, chunks.shape[0], cfg,
                               round_idx, key)
    rescale = (cfg.keep_fraction
               if (mask is not None and cfg.dithered
                   and not cfg.error_feedback) else None)
    rdt = x.dtype if residual_dtype is None else residual_dtype
    words, scale, resid = kernel_ops.encode_ef(
        chunks, signs, cfg.bits, dither=dither, mask=mask,
        rescale=rescale, residual_dtype=rdt)
    payload = {"words": words, "scale": scale}
    if mask is not None:
        payload["mask"] = mask
    residual = resid.reshape(-1)[:x.size].reshape(x.shape).astype(x.dtype)
    return payload, residual


def decode_leaf(payload: dict, leaf_idx: int, size: int, shape, dtype,
                cfg: GradCompConfig, extra_lead: int = 0) -> jax.Array:
    """Decode a payload back to a leaf of `shape`.

    With `extra_lead` = k the payload carries k leading stacked axes (e.g.
    the all-gathered worker axis) and the result is lead + shape.
    """
    words, scale = payload["words"], payload["scale"]
    x_hat = kernel_ops.unpack_dequant(words, scale, cfg.bits, cfg.chunk)
    mask = payload.get("mask")
    if mask is not None:
        x_hat = x_hat * mask
        if cfg.dithered and not cfg.error_feedback:
            # unbiased 1/keep rescale (DQ-PSGD); the EF path must stay
            # contractive, so it never rescales (see core.coding).
            x_hat = x_hat / cfg.keep_fraction
    signs = _frame_signs(leaf_idx, cfg).astype(x_hat.dtype)
    y = kernel_ops.unrotate(x_hat, signs)                    # y = D·H·x̂
    lead = tuple(words.shape[:extra_lead])
    flat = y.reshape(lead + (-1,))[..., :size]
    return flat.reshape(lead + tuple(shape)).astype(dtype)


# ---------------------------------------------------------------------------
# Tree codec (what the consensus strategies move around)
# ---------------------------------------------------------------------------
def compress_tree(tree, cfg: GradCompConfig, round_idx=0):
    """Encode every leaf. Returns (payload tree, (treedef, leaf infos))."""
    leaves, treedef = jax.tree.flatten(tree)
    payloads = [encode_leaf(x, i, cfg, round_idx)
                for i, x in enumerate(leaves)]
    meta = (treedef, [(x.size, tuple(x.shape), x.dtype) for x in leaves])
    return jax.tree.unflatten(treedef, payloads), meta


def decode_payload(payloads, meta, cfg: GradCompConfig, extra_lead: int = 0):
    """Inverse of compress_tree; `extra_lead` as in decode_leaf."""
    treedef, infos = meta
    plist = treedef.flatten_up_to(payloads)
    outs = [decode_leaf(p, i, size, shape, dtype, cfg, extra_lead=extra_lead)
            for i, (p, (size, shape, dtype)) in enumerate(zip(plist, infos))]
    return jax.tree.unflatten(treedef, outs)


# ---------------------------------------------------------------------------
# Wire audit — the analytic bytes-on-wire formula
# ---------------------------------------------------------------------------
def wire_bytes_tree(tree, cfg: GradCompConfig, num_workers: int = 1) -> dict:
    """Exact bytes a worker puts on the wire per step, vs f32 all-reduce.

    Per leaf with C = ⌈size/chunk⌉ chunks, each kept chunk costs
    chunk·bits/8 payload bytes + 4 bytes for its f32 scale; in the
    sub-linear regime (keep_fraction < 1) the kept count is exactly
    `cfg.kept_chunks(C)` under exact_keep (else C·keep_fraction in
    expectation) and a 1-bit-per-chunk keep mask rides along.
    """
    f32_bytes = 0
    payload_bytes = 0.0
    for leaf in jax.tree.leaves(tree):
        size = int(leaf.size)
        f32_bytes += size * jnp.dtype(jnp.float32).itemsize
        c = -(-size // cfg.chunk)
        per_chunk = cfg.chunk * cfg.bits // 8 + 4
        if cfg.keep_fraction < 1.0:
            kept = (cfg.kept_chunks(c) if cfg.exact_keep
                    else cfg.keep_fraction * c)
            payload_bytes += kept * per_chunk + (c + 7) // 8
        else:
            payload_bytes += c * per_chunk
    if cfg.keep_fraction >= 1.0 or cfg.exact_keep:
        payload_bytes = int(payload_bytes)
    return {
        "f32_bytes": f32_bytes,
        "payload_bytes": payload_bytes,
        "compression_x": f32_bytes / payload_bytes,
        "num_workers": num_workers,
        # allgather_packed: each worker sends its payload and receives m−1
        "allgather_rx_bytes": payload_bytes * max(num_workers - 1, 0),
    }


def _payload_leaves(payloads) -> list:
    """Flatten a payload tree to its per-leaf {"words", "scale", ...} dicts."""
    return jax.tree.leaves(
        payloads, is_leaf=lambda d: isinstance(d, dict) and "words" in d)


def wire_bytes_payload(payloads, cfg: GradCompConfig) -> float:
    """Bytes a CONCRETE encoded tree actually puts on the wire.

    Counts only kept chunks (per the realized keep mask) at the packed-words
    + f32-scale cost, plus the 1-bit-per-chunk mask when present — the
    realized counterpart of `wire_bytes_tree`. Under `exact_keep` the two
    agree to the byte every round (the repro.fed ledger asserts this).
    """
    per_chunk = cfg.chunk * cfg.bits // 8 + 4
    total = 0.0
    for p in _payload_leaves(payloads):
        c = p["scale"].shape[-2]
        mask = p.get("mask")
        if mask is None:
            total += c * per_chunk
        else:
            total += float(jnp.sum(mask)) * per_chunk + (c + 7) // 8
    return total
