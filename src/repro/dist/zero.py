"""ZeRO-1 layout + compressed reduce-scatter (all-to-all of packed payloads).

Owned layout: each leaf is flattened, zero-padded so its chunk count is a
multiple of the worker count m, and reshaped (padded_chunks, chunk). Worker
w owns the contiguous row block [w·rows, (w+1)·rows) — its optimizer state
exists only for those rows (the ZeRO-1 memory saving). Reconstruction is
`owned.reshape(-1)[:size].reshape(shape)`.

Consensus: every worker encodes ALL its gradient chunks with the shared
per-leaf frame (repro.dist.gradcomp), then an all-to-all routes each row
block's m payloads to its owner, who decodes the stacked payloads and takes
the mean. Because the frames, quantizer and mean order are identical to the
all-gather consensus, the updated owned shards are BIT-EXACT with the
replicated `allgather_packed` path (tests/test_zero.py asserts this at m=4).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import gradcomp as G


def leaf_layout(shape, chunk: int, num_workers: int) -> tuple:
    """(padded_chunks, rows_per_owner) for a leaf of `shape`."""
    size = int(np.prod(shape)) if shape else 1
    c = -(-size // chunk)
    padded = -(-c // num_workers) * num_workers
    return padded, padded // num_workers


def params_meta(params, gc: G.GradCompConfig, num_workers: int):
    """(treedef, [(size, shape, dtype, (padded_chunks, rows)), ...]).

    `params` may hold arrays or ShapeDtypeStructs (jax.eval_shape output).
    """
    leaves, treedef = jax.tree.flatten(params)
    infos = []
    for x in leaves:
        shape = tuple(x.shape)
        size = int(np.prod(shape)) if shape else 1
        infos.append((size, shape, x.dtype,
                      leaf_layout(shape, gc.chunk, num_workers)))
    return treedef, infos


def to_owned(leaf: jax.Array, chunk: int, num_workers: int) -> jax.Array:
    """Full leaf → f32 (padded_chunks, chunk) owned layout (global view)."""
    padded, _ = leaf_layout(leaf.shape, chunk, num_workers)
    flat = leaf.astype(jnp.float32).reshape(-1)
    flat = jnp.pad(flat, (0, padded * chunk - flat.size))
    return flat.reshape(padded, chunk)


def from_owned(owned: jax.Array, size: int, shape, dtype) -> jax.Array:
    """Inverse of to_owned (drops the zero padding)."""
    return owned.reshape(-1)[:size].reshape(shape).astype(dtype)


def valid_mask(size: int, padded_chunks: int, chunk: int) -> jax.Array:
    """f32 (padded_chunks, chunk): 1 on real coordinates, 0 on padding."""
    pos = (jnp.arange(padded_chunks)[:, None] * chunk
           + jnp.arange(chunk)[None, :])
    return (pos < size).astype(jnp.float32)


def compressed_reduce_scatter(u: jax.Array, leaf_idx: int,
                              gc: G.GradCompConfig, axes, num_workers: int,
                              round_idx=0, logical_chunks: int | None = None):
    """One leaf's ZeRO-1 consensus step, inside shard_map (manual `axes`).

    u: worker-local (padded_chunks, chunk) gradient(+EF) chunks.
    `logical_chunks` is the leaf's PRE-PAD chunk count ⌈size/chunk⌉ — the
    codec draws its stochastic parts (keep-mask, dither) at that count so the
    payload stays bit-exact with the un-padded all-gather encode even at
    keep_fraction < 1 (the padded chunks are always dropped).
    Returns (owned_mean (rows, chunk), decoded_own (padded_chunks, chunk)) —
    the owner-side consensus mean for this worker's rows, and the local
    decode of the worker's OWN payload (for its error-feedback update).
    """
    rows = u.shape[0] // num_workers
    payload = G.encode_leaf(u, leaf_idx, gc, round_idx,
                            logical_chunks=logical_chunks)

    def route(t):
        tm = t.reshape((num_workers, rows) + t.shape[1:])
        if num_workers == 1:
            return tm
        return jax.lax.all_to_all(tm, axes, split_axis=0, concat_axis=0,
                                  tiled=False)

    gathered = jax.tree.map(route, payload)      # (m, rows, …) per wire leaf
    stacked = G.decode_leaf(gathered, leaf_idx, rows * gc.chunk,
                            (rows, gc.chunk), jnp.float32, gc, extra_lead=1)
    owned_mean = jnp.mean(stacked, axis=0)
    decoded_own = G.decode_leaf(payload, leaf_idx, u.size, u.shape,
                                jnp.float32, gc)
    return owned_mean, decoded_own
