"""Train / serve step factories on a ("data","model") mesh.

Training runs as a fully-manual shard_map: gradients cross the data axes
("pod","data") only through the chosen consensus strategy, and params are
replicated over "model" inside the step (partial-auto — manual data axes
over a GSPMD-sharded model axis — crashes the pinned jax 0.4.x partitioner;
see the NOTE in make_train_step). The tensor-parallel sharding from
repro.dist.sharding drives the pure-jit serve / prefill paths.

Consensus strategies (GradCompConfig.strategy):

  psum             exact f32 all-reduce (the uncompressed baseline).
  psum_decoded     every worker round-trips its own gradients through the
                   chunked NDSC codec, then f32 all-reduce of the DECODED
                   gradients — codec error without the wire savings.
  allgather_packed the paper's consensus: all-gather the PACKED int32
                   payloads (bits/32 of the f32 bytes), decode all m on every
                   worker (stacked decode), take the mean. Shared per-leaf
                   frames make the decode identical everywhere.
  alltoall_zero1   ZeRO-1 (make_zero_train_step): compressed reduce-scatter
                   via all-to-all; each worker updates only its owned shard
                   and the optimizer state is 1/m per worker. Bit-exact with
                   allgather_packed under shared randomness.

Error feedback is per-worker: e ← (g + e) − D(E(g + e)), decoded from the
worker's OWN payload, so EF never needs extra communication.

Observability: the returned step callables carry host-side
instrumentation — with a `repro.obs` session active, each call runs under
a "dist.step" span and emits per-step counters for the ANALYTIC per-worker
payload bytes (from `gradcomp.wire_bytes_tree`, computed once at factory
time — never from inside the compiled program). Disabled, the wrapper is
one global load per call; the underlying jit program, its `lower` method
and its compile cache are reachable via the wrapper (`_jitted`), and the
program registers with `obs.recompile` so compile counts are attributable.
Numerics are untouched either way (bit-exactness regression-tested).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.codecs import stages as codec_stages
from repro.dist import gradcomp as G
from repro.dist import zero as zero_lib
from repro.dist.sharding import (data_axes_for, data_axis_names, num_workers,
                                 param_specs)
from repro.models import decode as decode_lib
from repro.models import model as model_lib
from repro.obs import core as obs_lib
from repro.obs import recompile as recompile_lib
from repro.optimizer.optim import (apply_updates, clip_by_global_norm,
                                   global_norm)


def _model_axis(mesh) -> int:
    return mesh.shape.get("model", 1)


def _round_idx(opt_state):
    """Per-step salt for the codec's stochastic parts (dither / keep-mask)."""
    if isinstance(opt_state, dict) and "step" in opt_state:
        return opt_state["step"]
    return 0


def _worker_index(axes, mesh):
    """Row-major worker index over the data axes (matches the stacking order
    of all_gather / all_to_all over the same axis tuple)."""
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def _lead_axes(axes):
    """Leading PartitionSpec entry for a dim sharded over the data axes:
    the tuple for several, the bare name for one, None for none."""
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def _analytic_payload_bytes(cfg, gc: G.GradCompConfig, mesh):
    """Per-worker bytes-on-wire per step, from the analytic audit over the
    model's parameter template (None when the template can't be built, e.g.
    a custom loss over non-model params)."""
    try:
        p_shapes = jax.eval_shape(
            lambda: model_lib.init_params(jax.random.key(0), cfg))
        wire = G.wire_bytes_tree(p_shapes, gc, num_workers(mesh))
        if gc.strategy == "psum":
            return float(wire["f32_bytes"])
        return float(wire["payload_bytes"])
    except Exception:
        return None


def _with_obs(fn, name: str, gc: G.GradCompConfig, payload_bytes):
    """Host-side instrumentation around a jit'd train step. The wrapper is
    call-transparent (same signature, same outputs); `lower` and the
    compile cache stay reachable for the dry-run launcher and the tests."""
    recompile_lib.register(name, fn, wire_bytes_per_call=payload_bytes)

    def stepper(params, opt_state, ef, batch):
        if not obs_lib.enabled():
            return fn(params, opt_state, ef, batch)
        obs_lib.observe_program_call(name, fn,
                                     (params, opt_state, ef, batch),
                                     wire_bytes=payload_bytes)
        with obs_lib.span(name, strategy=gc.strategy):
            out = fn(params, opt_state, ef, batch)
        obs_lib.counter("dist.steps", 1, strategy=gc.strategy)
        if payload_bytes is not None:
            obs_lib.counter("dist.payload_bytes", payload_bytes,
                            strategy=gc.strategy)
        return out

    stepper.lower = fn.lower
    stepper._jitted = fn
    return stepper


# ---------------------------------------------------------------------------
# Consensus
# ---------------------------------------------------------------------------
def _consensus(grads, ef, gc: G.GradCompConfig, axes, round_idx):
    """Returns (consensus grads, new EF state).

    The per-leaf encode/decode routes through the NDSC stage codec from
    `repro.codecs.stages` — the same fused-kernel gradcomp implementation
    the fed engine and the registry use, so wire payloads here stay
    bit-identical with every other consumer of the codec stack."""
    if gc.strategy == "psum":
        return jax.tree.map(lambda g: jax.lax.pmean(g, axes), grads), ef

    leaf_codec = codec_stages.ndsc_leaf(gc)
    leaves, treedef = jax.tree.flatten(grads)
    e_leaves = treedef.flatten_up_to(ef) if gc.uses_ef else [None] * len(leaves)
    outs, new_e = [], []
    for i, (g, e) in enumerate(zip(leaves, e_leaves)):
        u = g.astype(jnp.float32) + (e if e is not None else 0.0)
        resid = None
        if gc.strategy == "allgather_packed" and gc.uses_ef:
            # fused encode + EF: the kernel decodes its own payload in-tile
            # and emits u − D(E(u)) alongside — no second decode pass
            payload, resid = leaf_codec.encode_ef(u, i, round_idx)
        else:
            payload = leaf_codec.encode(u, i, round_idx)
        if gc.strategy == "psum_decoded":
            # the consensus itself needs the decoded leaf here, so EF
            # reuses it (u − (u − d) ≠ d in floats, so the fused residual
            # can't substitute)
            d_own = leaf_codec.decode(payload, i, u.size, u.shape,
                                      jnp.float32)
            cons = jax.lax.pmean(d_own, axes)
            if gc.uses_ef:
                resid = u - d_own
        else:  # allgather_packed
            gathered = jax.tree.map(
                lambda t: jax.lax.all_gather(t, axes, axis=0), payload)
            stacked = leaf_codec.decode(gathered, i, u.size, u.shape,
                                        jnp.float32, extra_lead=1)
            cons = jnp.mean(stacked, axis=0)
        outs.append(cons.astype(g.dtype))
        if gc.uses_ef:
            new_e.append(resid)
    grads = jax.tree.unflatten(treedef, outs)
    return grads, (jax.tree.unflatten(treedef, new_e) if gc.uses_ef else ef)


# ---------------------------------------------------------------------------
# Replicated-parameter train step (psum / psum_decoded / allgather_packed)
# ---------------------------------------------------------------------------
def make_train_step(cfg, opt, gc: G.GradCompConfig, mesh, clip_norm=None,
                    loss_fn=None):
    """jit'd (params, opt_state, ef, batch) → (params, opt_state, ef, metrics).

    Params / optimizer / EF are replicated across ALL mesh axes inside the
    step (see the NOTE at the shard_map below); the batch is sharded over
    the data axes on dim 0.
    """
    if gc.strategy == "alltoall_zero1":
        raise ValueError("strategy 'alltoall_zero1' needs make_zero_train_step")
    axes = data_axis_names(mesh)
    first = _lead_axes(axes)
    loss_of = loss_fn or (lambda p, b: model_lib.loss_fn(cfg, p, b))

    def local_step(params, opt_state, ef, batch):
        loss, grads = jax.value_and_grad(loss_of)(params, batch)
        loss = jax.lax.pmean(loss, axes)
        # EF leaves carry a leading per-worker axis (m, …); local view (1, …)
        ef_local = jax.tree.map(lambda e: e[0], ef)
        grads, ef_local = _consensus(grads, ef_local, gc, axes,
                                     _round_idx(opt_state))
        ef = jax.tree.map(lambda e: e[None], ef_local)
        if clip_norm is not None:
            grads, grad_norm = clip_by_global_norm(grads, clip_norm)
        else:
            grad_norm = global_norm(grads)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, ef, {"loss": loss, "grad_norm": grad_norm}

    batch_spec = P(first)
    ef_spec = P(first) if gc.uses_ef else P()
    # NOTE: ALL mesh axes are manual here — params enter with in_specs=P()
    # and are therefore fully replicated (incl. over "model") inside the
    # train step, on every jax version. Partial-auto shard_map (manual data
    # axes over a GSPMD-sharded model axis) hard-crashes the 0.4.x SPMD
    # partitioner; tensor-parallel param sharding still drives the pure-jit
    # serve/prefill paths. Re-enabling partial-auto (axis_names=set(axes))
    # once the toolchain moves off 0.4.x is tracked in ROADMAP.md.
    fn = shard_map(local_step, mesh=mesh,
                   in_specs=(P(), P(), ef_spec, batch_spec),
                   out_specs=(P(), P(), ef_spec, P()),
                   axis_names=set(mesh.axis_names))
    return _with_obs(jax.jit(fn), "dist.step", gc,
                     _analytic_payload_bytes(cfg, gc, mesh))


def _ef_shapes(params_shapes, gc: G.GradCompConfig, m: int):
    """Per-worker error feedback: (m, *param shape) f32 leaves."""
    if not gc.uses_ef:
        return {}
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((m,) + tuple(x.shape), jnp.float32),
        params_shapes)


def _state_specs_like(state_shapes, params_shapes, pspecs):
    """Optimizer-state PartitionSpecs: subtrees structured like the params
    (mu / nu / vel) inherit the param specs; everything else is replicated."""
    pdef = jax.tree.structure(params_shapes)
    if not isinstance(state_shapes, dict):
        return jax.tree.map(lambda _: P(), state_shapes)
    return {k: (pspecs if jax.tree.structure(v) == pdef
                else jax.tree.map(lambda _: P(), v))
            for k, v in state_shapes.items()}


def _with_shardings(shapes, specs, mesh):
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(
            x.shape, x.dtype, sharding=NamedSharding(mesh, s)),
        shapes, specs)


def train_state_specs(cfg, opt, gc: G.GradCompConfig, mesh):
    """Sharded ShapeDtypeStruct stand-ins for (params, opt_state, ef)."""
    p_shapes = jax.eval_shape(
        lambda: model_lib.init_params(jax.random.key(0), cfg))
    pspecs = param_specs(p_shapes, _model_axis(mesh))
    o_shapes = jax.eval_shape(opt.init, p_shapes)
    e_shapes = _ef_shapes(p_shapes, gc, num_workers(mesh))
    axes = data_axis_names(mesh)
    first = _lead_axes(axes)
    e_specs = (jax.tree.map(lambda x: P(first, *([None] * (len(x.shape) - 1))),
                            e_shapes) if gc.uses_ef else {})
    return (_with_shardings(p_shapes, pspecs, mesh),
            _with_shardings(o_shapes,
                            _state_specs_like(o_shapes, p_shapes, pspecs),
                            mesh),
            _with_shardings(e_shapes, e_specs, mesh))


def init_train_state(cfg, opt, gc: G.GradCompConfig, mesh, key=None):
    """Materialized (params, opt_state, ef) placed per train_state_specs."""
    key = jax.random.key(0) if key is None else key
    params = model_lib.init_params(key, cfg)
    opt_state = opt.init(params)
    m = num_workers(mesh)
    ef = (jax.tree.map(
        lambda p: jnp.zeros((m,) + tuple(p.shape), jnp.float32), params)
        if gc.uses_ef else {})
    specs = train_state_specs(cfg, opt, gc, mesh)
    return tuple(
        jax.device_put(v, jax.tree.map(lambda s: s.sharding, spec))
        for v, spec in zip((params, opt_state, ef), specs))


# ---------------------------------------------------------------------------
# ZeRO-1 train step (alltoall_zero1)
# ---------------------------------------------------------------------------
def make_zero_train_step(cfg, opt, gc: G.GradCompConfig, mesh,
                         gather_dtype=None, clip_norm=None, loss_fn=None):
    """jit'd ZeRO-1 step over OWNED-layout state (see repro.dist.zero).

    State leaves are (padded_chunks, chunk) f32 sharded over the data axes on
    dim 0 — each worker holds and updates only its row block; `gather_dtype`
    optionally down-casts the forward all-gather of the parameters (set None
    for bit-exactness with the replicated path).
    """
    axes = data_axis_names(mesh)
    m = num_workers(mesh)
    loss_of = loss_fn or (lambda p, b: model_lib.loss_fn(cfg, p, b))
    p_shapes = jax.eval_shape(
        lambda: model_lib.init_params(jax.random.key(0), cfg))
    treedef, infos = zero_lib.params_meta(p_shapes, gc, m)

    def local_step(owned_params, opt_state, ef, batch):
        owned_leaves = treedef.flatten_up_to(owned_params)
        full = []
        for owned, (size, shape, dtype, _) in zip(owned_leaves, infos):
            g = owned if gather_dtype is None else owned.astype(gather_dtype)
            if m > 1:
                g = jax.lax.all_gather(g, axes, axis=0, tiled=True)
            full.append(zero_lib.from_owned(g.astype(jnp.float32),
                                            size, shape, dtype))
        params = jax.tree.unflatten(treedef, full)
        loss, grads = jax.value_and_grad(loss_of)(params, batch)
        loss = jax.lax.pmean(loss, axes)
        round_idx = _round_idx(opt_state)

        g_leaves = treedef.flatten_up_to(grads)
        e_leaves = (treedef.flatten_up_to(ef) if gc.uses_ef
                    else [None] * len(g_leaves))
        owned_grads, new_e = [], []
        sq_sum = jnp.zeros((), jnp.float32)
        for i, (g, e, (size, shape, dtype, (padded, rows))) in enumerate(
                zip(g_leaves, e_leaves, infos)):
            u = zero_lib.to_owned(g, gc.chunk, m)
            if e is not None:
                u = u + e[0]
            mean_own, d_own = zero_lib.compressed_reduce_scatter(
                u, i, gc, axes, m, round_idx,
                logical_chunks=-(-size // gc.chunk))
            # zero the padding coords so optimizer state / EF stay clean and
            # the norms match the replicated path exactly
            widx = _worker_index(axes, mesh) if m > 1 else 0
            row0 = widx * rows
            pos = ((row0 + jnp.arange(rows))[:, None] * gc.chunk
                   + jnp.arange(gc.chunk)[None, :])
            mean_own = mean_own * (pos < size).astype(jnp.float32)
            owned_grads.append(mean_own)
            sq_sum = sq_sum + jnp.sum(jnp.square(mean_own))
            if e is not None:
                new_e.append(((u - d_own)
                              * zero_lib.valid_mask(size, padded, gc.chunk)
                              )[None])
        grad_norm = jnp.sqrt(jax.lax.psum(sq_sum, axes))
        owned_grads = jax.tree.unflatten(treedef, owned_grads)
        if clip_norm is not None:
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(grad_norm, 1e-12))
            owned_grads = jax.tree.map(lambda x: x * scale, owned_grads)
        updates, opt_state = opt.update(owned_grads, opt_state, owned_params)
        owned_params = apply_updates(owned_params, updates)
        ef = jax.tree.unflatten(treedef, new_e) if gc.uses_ef else ef
        return owned_params, opt_state, ef, {"loss": loss,
                                             "grad_norm": grad_norm}

    owned_spec = jax.tree.map(
        lambda _: P(_lead_axes(axes)), p_shapes)
    o_shapes = jax.eval_shape(
        opt.init, jax.tree.unflatten(treedef, [
            jax.ShapeDtypeStruct((pc, gc.chunk), jnp.float32)
            for (_, _, _, (pc, _)) in infos]))
    opt_spec = _state_specs_like(
        o_shapes, p_shapes, owned_spec)
    ef_spec = jax.tree.map(
        lambda _: P(_lead_axes(axes)),
        p_shapes) if gc.uses_ef else {}
    batch_spec = P(_lead_axes(axes))
    fn = shard_map(local_step, mesh=mesh,
                   in_specs=(owned_spec, opt_spec, ef_spec, batch_spec),
                   out_specs=(owned_spec, opt_spec, ef_spec, P()),
                   axis_names=set(mesh.axis_names))
    return _with_obs(jax.jit(fn), "dist.step.zero1", gc,
                     _analytic_payload_bytes(cfg, gc, mesh))


def zero_state_specs(cfg, opt, gc: G.GradCompConfig, mesh):
    """Sharded ShapeDtypeStructs for the owned-layout ZeRO-1 state."""
    m = num_workers(mesh)
    axes = data_axis_names(mesh)
    first = _lead_axes(axes)
    p_shapes = jax.eval_shape(
        lambda: model_lib.init_params(jax.random.key(0), cfg))
    treedef, infos = zero_lib.params_meta(p_shapes, gc, m)
    owned = jax.tree.unflatten(treedef, [
        jax.ShapeDtypeStruct((pc, gc.chunk), jnp.float32)
        for (_, _, _, (pc, _)) in infos])
    owned_spec = jax.tree.map(lambda _: P(first, None), owned)
    o_shapes = jax.eval_shape(opt.init, owned)
    o_spec = _state_specs_like(o_shapes, owned, owned_spec)
    ef = (jax.tree.unflatten(treedef, [
        jax.ShapeDtypeStruct((m, pc, gc.chunk), jnp.float32)
        for (_, _, _, (pc, _)) in infos]) if gc.uses_ef else {})
    ef_spec = jax.tree.map(lambda _: P(first, None, None), ef)
    return (_with_shardings(owned, owned_spec, mesh),
            _with_shardings(o_shapes, o_spec, mesh),
            _with_shardings(ef, ef_spec, mesh))


def init_zero_state(cfg, opt, gc: G.GradCompConfig, mesh, key=None):
    """Materialized owned-layout (params, opt_state, ef), sharded over data.

    Uses the same init key as init_train_state so the two paths start from
    identical parameters (the bit-exactness test relies on this).
    """
    m = num_workers(mesh)
    key = jax.random.key(0) if key is None else key
    params = model_lib.init_params(key, cfg)
    owned = jax.tree.map(lambda p: zero_lib.to_owned(p, gc.chunk, m), params)
    opt_state = opt.init(owned)
    ef = (jax.tree.map(
        lambda o: jnp.zeros((m,) + o.shape, jnp.float32), owned)
        if gc.uses_ef else {})
    specs = zero_state_specs(cfg, opt, gc, mesh)
    return tuple(
        jax.device_put(v, jax.tree.map(lambda s: s.sharding, spec))
        for v, spec in zip((owned, opt_state, ef), specs))


# ---------------------------------------------------------------------------
# Serve step
# ---------------------------------------------------------------------------
def make_serve_step(cfg, mesh):
    """jit'd (params, DecodeState, tokens (B,1)) → (logits (B,V), state)."""
    return recompile_lib.register(
        "dist.serve_step",
        jax.jit(functools.partial(decode_lib.decode_step, cfg)))


def serve_state_specs(cfg, mesh, global_batch: int, seq_len: int):
    """Sharded ShapeDtypeStructs for (params, decode state, tokens)."""
    p_shapes = jax.eval_shape(
        lambda: model_lib.init_params(jax.random.key(0), cfg))
    pspecs = param_specs(p_shapes, _model_axis(mesh))
    params = _with_shardings(p_shapes, pspecs, mesh)

    axes = data_axes_for(global_batch, mesh)
    first = _lead_axes(axes)
    state_shapes = decode_lib.decode_state_specs(cfg, global_batch, seq_len)

    def cache_spec(name, leaf):
        if name == "signs" or not axes:          # per-layer constants
            return P(*([None] * len(leaf.shape)))
        return P(None, first, *([None] * (len(leaf.shape) - 2)))

    caches = {k: jax.ShapeDtypeStruct(
        v.shape, v.dtype,
        sharding=NamedSharding(mesh, cache_spec(k, v)))
        for k, v in state_shapes.caches.items()}
    pos = jax.ShapeDtypeStruct(
        state_shapes.pos.shape, state_shapes.pos.dtype,
        sharding=NamedSharding(mesh, P(first) if axes else P(None)))
    state = decode_lib.DecodeState(caches=caches, pos=pos)
    tokens = jax.ShapeDtypeStruct(
        (global_batch, 1), jnp.int32,
        sharding=NamedSharding(mesh, P(first, None) if axes else P(None, None)))
    return params, state, tokens
