"""PartitionSpec rules: how params / batches land on the ("data","model") mesh.

Parameter rules (Megatron-style tensor parallelism over the "model" axis):

  * embed   (V, d)        — vocab-sharded rows: each shard embeds its slice,
                            the gather at lookup is GSPMD's problem.
  * head    (d, V)        — vocab-sharded columns (column-parallel output
                            projection; the softmax reduction stays local
                            per shard in chunked_softmax_xent).
  * wq/wk/wv, w_gate/w_up — column-parallel (shard the output features),
  * wo, w_down            — row-parallel (shard the input features), pairing
                            with the column-parallel producer so the only
                            cross-shard communication is one all-reduce.
  * e_gate/e_up/e_down    — expert-parallel on the expert dim when
                            E % model_axis == 0 (arctic: 128/16), else fall
                            back to the d_ff dim (mixtral: 8 experts).
  * 1-D leaves (norms)    — replicated.

Every rule is guarded by divisibility: a dim is only sharded when
`dim % model_axis == 0`, else the next candidate axis is tried and finally
the leaf is replicated. Block leaves carry a leading stacked-layer axis
(lax.scan over layers) which is never sharded.

Batch rules: leaf dim 0 is the global batch, sharded over the data axes
("pod","data" on the multi-pod mesh) when divisible — `data_axes_for`
drops axes until the batch divides.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import PartitionSpec as P


def shardable(dim: int, axis_size: int) -> bool:
    """Can a dimension of `dim` elements be split `axis_size` ways evenly?"""
    return axis_size > 0 and dim % axis_size == 0


# Candidate eff-axis preferences per leaf basename. Axes are indices into the
# per-layer shape (leading stacked-layer axis stripped); negative = from end.
_AXIS_PREFS = {
    "embed": (0,),              # (V, d): vocab rows
    "head": (-1,),              # (d, V): vocab cols
    "wq": (-1,), "wk": (-1,), "wv": (-1,),      # column-parallel
    "w_gate": (-1,), "w_up": (-1,),
    "wo": (0,), "w_down": (0,),                 # row-parallel
    "router": (-1,),            # (d, E): shard experts when divisible
    "e_gate": (0, -1), "e_up": (0, -1),         # (E, d, f): experts, else d_ff
    "e_down": (0, 1),                           # (E, f, d): experts, else d_ff
}


def param_spec(name: str, shape: tuple, model_axis: int,
               in_blocks: bool) -> P:
    """PartitionSpec for one parameter leaf.

    `name` is the dotted tree path (e.g. ".blocks.wq"), `in_blocks` marks
    leaves with a leading stacked-layer axis (never sharded).
    """
    lead = 1 if in_blocks else 0
    eff = shape[lead:]
    replicated = P(*([None] * len(shape)))
    if model_axis <= 1 or len(eff) < 2:
        return replicated
    base = name.rsplit(".", 1)[-1]
    # unknown leaves (ssm / xlstm inner weights): prefer the last axis, then
    # earlier ones — output-feature sharding composes best with the matmuls.
    prefs = _AXIS_PREFS.get(base, tuple(range(len(eff) - 1, -1, -1)))
    for ax in prefs:
        ax = ax % len(eff)
        if shardable(eff[ax], model_axis):
            entries = [None] * len(eff)
            entries[ax] = "model"
            return P(*([None] * lead), *entries)
    return replicated


def param_specs(params, model_axis: int):
    """Tree of PartitionSpecs matching `params` (arrays or ShapeDtypeStructs)."""

    def name_of(path) -> str:
        parts = []
        for k in path:
            parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
        return "." + ".".join(parts)

    def visit(path, leaf):
        name = name_of(path)
        return param_spec(name, tuple(leaf.shape), model_axis,
                          in_blocks=".blocks." in name + ".")

    return jax.tree_util.tree_map_with_path(visit, params)


def data_axes_for(global_batch: int, mesh) -> tuple:
    """The mesh axes the batch dim shards over (largest divisible prefix)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    while axes and global_batch % math.prod(mesh.shape[a] for a in axes):
        axes = axes[1:]
    return axes


def batch_specs(batch, mesh):
    """PartitionSpecs for a batch pytree: dim 0 over the data axes."""

    def spec(leaf):
        axes = data_axes_for(leaf.shape[0], mesh)
        if not axes:
            return P(*([None] * len(leaf.shape)))
        first = axes if len(axes) > 1 else axes[0]
        return P(first, *([None] * (len(leaf.shape) - 1)))

    return jax.tree.map(spec, batch)
