"""PartitionSpec rules: how params / batches land on the ("data","model") mesh.

Parameter rules (Megatron-style tensor parallelism over the "model" axis):

  * embed   (V, d)        — vocab-sharded rows: each shard embeds its slice,
                            the gather at lookup is GSPMD's problem.
  * head    (d, V)        — vocab-sharded columns (column-parallel output
                            projection; the softmax reduction stays local
                            per shard in chunked_softmax_xent).
  * wq/wk/wv, w_gate/w_up — column-parallel (shard the output features),
  * wo, w_down            — row-parallel (shard the input features), pairing
                            with the column-parallel producer so the only
                            cross-shard communication is one all-reduce.
  * e_gate/e_up/e_down    — expert-parallel on the expert dim when
                            E % model_axis == 0 (arctic: 128/16), else fall
                            back to the d_ff dim (mixtral: 8 experts).
  * 1-D leaves (norms)    — replicated.

Every rule is guarded by divisibility: a dim is only sharded when
`dim % model_axis == 0`, else the next candidate axis is tried and finally
the leaf is replicated. Block leaves carry a leading stacked-layer axis
(lax.scan over layers) which is never sharded.

Batch rules: leaf dim 0 is the global batch, sharded over the data axes
("pod","data" on the multi-pod mesh) when divisible — `data_axes_for`
drops axes until the batch divides.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import PartitionSpec as P


def shardable(dim: int, axis_size: int) -> bool:
    """Can a dimension of `dim` elements be split `axis_size` ways evenly?"""
    return axis_size > 0 and dim % axis_size == 0


# Candidate eff-axis preferences per leaf basename. Axes are indices into the
# per-layer shape (leading stacked-layer axis stripped); negative = from end.
_AXIS_PREFS = {
    "embed": (0,),              # (V, d): vocab rows
    "head": (-1,),              # (d, V): vocab cols
    "wq": (-1,), "wk": (-1,), "wv": (-1,),      # column-parallel
    "w_gate": (-1,), "w_up": (-1,),
    "wo": (0,), "w_down": (0,),                 # row-parallel
    "router": (-1,),            # (d, E): shard experts when divisible
    "e_gate": (0, -1), "e_up": (0, -1),         # (E, d, f): experts, else d_ff
    "e_down": (0, 1),                           # (E, f, d): experts, else d_ff
}


def param_spec(name: str, shape: tuple, model_axis: int,
               in_blocks: bool) -> P:
    """PartitionSpec for one parameter leaf.

    `name` is the dotted tree path (e.g. ".blocks.wq"), `in_blocks` marks
    leaves with a leading stacked-layer axis (never sharded).
    """
    lead = 1 if in_blocks else 0
    eff = shape[lead:]
    replicated = P(*([None] * len(shape)))
    if model_axis <= 1 or len(eff) < 2:
        return replicated
    base = name.rsplit(".", 1)[-1]
    # unknown leaves (ssm / xlstm inner weights): prefer the last axis, then
    # earlier ones — output-feature sharding composes best with the matmuls.
    prefs = _AXIS_PREFS.get(base, tuple(range(len(eff) - 1, -1, -1)))
    for ax in prefs:
        ax = ax % len(eff)
        if shardable(eff[ax], model_axis):
            entries = [None] * len(eff)
            entries[ax] = "model"
            return P(*([None] * lead), *entries)
    return replicated


def param_specs(params, model_axis: int):
    """Tree of PartitionSpecs matching `params` (arrays or ShapeDtypeStructs)."""

    def name_of(path) -> str:
        parts = []
        for k in path:
            parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
        return "." + ".".join(parts)

    def visit(path, leaf):
        name = name_of(path)
        return param_spec(name, tuple(leaf.shape), model_axis,
                          in_blocks=".blocks." in name + ".")

    return jax.tree_util.tree_map_with_path(visit, params)


def data_axis_names(mesh) -> tuple:
    """The data-parallel mesh axes, in major→minor order.

    Shared by the consensus train steps (one worker per data-axis device)
    and the federated mesh backend (cohort lanes placed over the same axes).
    """
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def num_workers(mesh) -> int:
    """Devices along the data axes — workers for repro.dist, lane slots per
    stacked-pytree shard for repro.fed.mesh."""
    return math.prod(mesh.shape[a] for a in data_axis_names(mesh))


def lane_pspec(mesh):
    """PartitionSpec prefix sharding a leading cohort-lane axis over the data
    axes (the stacked-pytree layout of repro.fed placed on devices). Usable
    as a shard_map in/out spec prefix for whole stacked pytrees."""
    axes = data_axis_names(mesh)
    if not axes:
        return P()
    return P(axes if len(axes) > 1 else axes[0])


def padded_lanes(n: int, axis_size: int) -> int:
    """Lane count a stacked cohort pytree is padded to before it shards
    evenly over `axis_size` devices (padding lanes carry zero weight
    downstream).

    Beyond divisibility, every device's slice is kept at ≥ 2 lanes: XLA
    canonicalizes a batch-1 `vmap` body (e.g. squeezing the batch dim out of
    dot_generals) into DIFFERENT reduction orders than the same body at
    batch ≥ 2, so a device holding a single lane would break the bitwise
    contract with the single-device cohort engine. Batches 2, 3, … lower
    identically per lane (empirically, and regression-tested); only the
    1-lane program is special-cased by the compiler. A 1-device "mesh"
    (axis_size == 1) needs no padding at all — it IS the vmap layout."""
    if axis_size <= 0:
        raise ValueError("axis_size must be positive")
    if axis_size == 1:
        return max(n, 1)
    return axis_size * max(2, -(-max(n, 1) // axis_size))


def data_axes_for(global_batch: int, mesh) -> tuple:
    """The mesh axes the batch dim shards over (largest divisible prefix)."""
    axes = data_axis_names(mesh)
    while axes and global_batch % math.prod(mesh.shape[a] for a in axes):
        axes = axes[1:]
    return axes


def batch_specs(batch, mesh):
    """PartitionSpecs for a batch pytree: dim 0 over the data axes."""

    def spec(leaf):
        axes = data_axes_for(leaf.shape[0], mesh)
        if not axes:
            return P(*([None] * len(leaf.shape)))
        first = axes if len(axes) > 1 else axes[0]
        return P(first, *([None] * (len(leaf.shape) - 1)))

    return jax.tree.map(spec, batch)
