"""Checkpointing: pytree ↔ npz with a JSON manifest.

Layout:  <dir>/step_<N>/arrays.npz  +  <dir>/step_<N>/manifest.json

The manifest stores the flattened key paths and dtypes so restore rebuilds
the exact pytree structure (dicts, tuples, NamedTuples via treedef string
matching against a caller-provided template). Restore requires a `like`
template pytree — this keeps the format dependency-free and safe (no pickle).

`save_federation` / `restore_federation` capture a FULL `repro.fed`
Federation — server params, fedopt optimizer state, fedmem memory, every
client's error-feedback tree, PRNG lane (as raw key data) and participation
counter, the adaptive allocator's `NormEMA` + current rates, and the round
counter — so a restored federation continues with the same round indices
(hence the same participant draws, codec salts and re-allocation
boundaries) as an uninterrupted run, bit for bit (regression-tested).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree.flatten(tree)
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(tree)[0]]
    return flat, paths, treedef


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Write `tree` at `directory/step_<step>/`. Returns the path."""
    path = os.path.join(directory, f"step_{step}")
    os.makedirs(path, exist_ok=True)
    flat, paths, _ = _flatten_with_names(tree)
    arrays = {f"a{i}": np.asarray(x) for i, x in enumerate(flat)}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {"step": step,
                "leaves": [{"index": i, "path": p,
                            "shape": list(np.shape(np.asarray(x))),
                            "dtype": str(np.asarray(x).dtype)}
                           for i, (p, x) in enumerate(zip(paths, flat))]}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return path


def restore_checkpoint(directory: str, like: Any,
                       step: Optional[int] = None) -> tuple[Any, int]:
    """Restore into the structure of `like`. Returns (tree, step)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = [z[f"a{leaf['index']}"] for leaf in manifest["leaves"]]
    like_flat, like_paths, treedef = _flatten_with_names(like)
    saved_paths = [leaf["path"] for leaf in manifest["leaves"]]
    if saved_paths != like_paths:
        raise ValueError(
            "checkpoint structure mismatch:\n"
            f"  saved:    {saved_paths[:5]}...\n  template: {like_paths[:5]}...")
    leaves = [np.asarray(a).astype(np.asarray(t).dtype)
              for a, t in zip(flat, like_flat)]
    return jax.tree.unflatten(treedef, leaves), step


# ---------------------------------------------------------------------------
# Federation state (repro.fed) — everything a resumed run needs, bit-exact
# ---------------------------------------------------------------------------
def federation_state(fed) -> dict:
    """One pytree of plain arrays capturing a `repro.fed.Federation`.

    Typed PRNG keys are stored as their raw uint32 key data (npz can't hold
    extended dtypes); shapes/dtypes mirror the live federation, so the tree
    doubles as the `like` template on restore. Codecs, data shards and
    compiled-program caches are NOT state: they are reconstructed by
    building the federation with the same constructor arguments (the
    adaptive rates saved here rebuild the codecs via `set_rates`)."""
    import jax.random as jrandom

    tree = {
        "server": {"params": fed.server.params,
                   "opt_state": fed.server.opt_state,
                   "memory": fed.server.memory},
        "clients": {
            "ef": [s.ef for s in fed.states],
            "key_data": [jrandom.key_data(s.key) for s in fed.states],
            "rounds_seen": [s.rounds_seen for s in fed.states],
        },
        "round": np.asarray(fed.rounds_done, np.int64),
    }
    if fed._ema is not None:
        tree["ema"] = {"norms": fed._ema.norms, "seen": fed._ema.seen,
                       "rates": np.asarray(fed._rates, np.float64)}
    return tree


def save_federation(directory: str, fed, step: Optional[int] = None) -> str:
    """Checkpoint `fed` at `directory/step_<rounds_done>/` (or `step`)."""
    at = fed.rounds_done if step is None else step
    return save_checkpoint(directory, at, federation_state(fed))


def restore_federation(directory: str, fed,
                       step: Optional[int] = None) -> int:
    """Restore a checkpoint into `fed` IN PLACE; returns the restored step.

    `fed` must be constructed with the same arguments as the saved
    federation (same model/clients/aggregator — the manifest's key paths
    are checked against it). After this call `fed.run(cfg)` continues from
    the saved round counter, bit-exact with a run that never stopped."""
    import jax.random as jrandom

    from repro.fed.clients import ClientState

    tree, at = restore_checkpoint(directory, federation_state(fed), step)
    server = fed.server
    fed.server = type(server)(
        params=jax.tree.map(jnp_asarray_like, tree["server"]["params"],
                            server.params),
        opt_state=jax.tree.map(jnp_asarray_like, tree["server"]["opt_state"],
                               server.opt_state),
        memory=jax.tree.map(jnp_asarray_like, tree["server"]["memory"],
                            server.memory))
    fed.rounds_done = int(tree["round"])
    if fed._ema is not None:
        # adopt the saved rates FIRST (rebuilds codecs via the factory;
        # previously seen rates reuse their compiled programs), then the
        # allocator's EMA state
        fed.set_rates(tree["ema"]["rates"].tolist())
        fed._ema.norms = np.asarray(tree["ema"]["norms"], np.float64)
        fed._ema.seen = np.asarray(tree["ema"]["seen"], bool)
    c = tree["clients"]
    fed.states = [
        ClientState(
            ef=jax.tree.map(jnp_asarray_like, c["ef"][i],
                            fed.states[i].ef),
            key=jrandom.wrap_key_data(
                jnp.asarray(c["key_data"][i], np.uint32)),
            rounds_seen=jnp.asarray(c["rounds_seen"][i], np.int32))
        for i in range(len(fed.states))]
    return at


def jnp_asarray_like(x, like):
    """numpy leaf → device array with the template's dtype (bit-preserving:
    restore_checkpoint already cast to the saved dtype). Reads `.dtype`
    directly — valid on numpy and jax arrays alike — so the live template
    never crosses device→host just to be overwritten."""
    return jnp.asarray(x, like.dtype)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None
