"""Checkpointing: pytree ↔ npz with a JSON manifest.

Layout:  <dir>/step_<N>/arrays.npz  +  <dir>/step_<N>/manifest.json

The manifest stores the flattened key paths and dtypes so restore rebuilds
the exact pytree structure (dicts, tuples, NamedTuples via treedef string
matching against a caller-provided template). Restore requires a `like`
template pytree — this keeps the format dependency-free and safe (no pickle).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree.flatten(tree)
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(tree)[0]]
    return flat, paths, treedef


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Write `tree` at `directory/step_<step>/`. Returns the path."""
    path = os.path.join(directory, f"step_{step}")
    os.makedirs(path, exist_ok=True)
    flat, paths, _ = _flatten_with_names(tree)
    arrays = {f"a{i}": np.asarray(x) for i, x in enumerate(flat)}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {"step": step,
                "leaves": [{"index": i, "path": p,
                            "shape": list(np.shape(np.asarray(x))),
                            "dtype": str(np.asarray(x).dtype)}
                           for i, (p, x) in enumerate(zip(paths, flat))]}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return path


def restore_checkpoint(directory: str, like: Any,
                       step: Optional[int] = None) -> tuple[Any, int]:
    """Restore into the structure of `like`. Returns (tree, step)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = [z[f"a{leaf['index']}"] for leaf in manifest["leaves"]]
    like_flat, like_paths, treedef = _flatten_with_names(like)
    saved_paths = [leaf["path"] for leaf in manifest["leaves"]]
    if saved_paths != like_paths:
        raise ValueError(
            "checkpoint structure mismatch:\n"
            f"  saved:    {saved_paths[:5]}...\n  template: {like_paths[:5]}...")
    leaves = [np.asarray(a).astype(np.asarray(t).dtype)
              for a, t in zip(flat, like_flat)]
    return jax.tree.unflatten(treedef, leaves), step


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None
