"""npz + manifest checkpointing for arbitrary pytrees (+ full Federations)."""
from repro.checkpoint.ckpt import (federation_state, latest_step,
                                   restore_checkpoint, restore_federation,
                                   save_checkpoint, save_federation)
