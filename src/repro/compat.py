"""Version-bridging shims for jax APIs that moved between releases.

The repo targets the newest jax spellings (`jax.shard_map`,
`jax.sharding.get_abstract_mesh`, `jax.set_mesh`) but must also run on the
pinned 0.4.x container. Every caller goes through these wrappers so the
version split lives in exactly one file.
"""
from __future__ import annotations

from typing import Optional

import jax


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check=False):
    """`jax.shard_map` with partial-auto support on old and new jax.

    `axis_names` is the set of MANUAL mesh axes (None → all axes manual);
    the remaining axes stay auto (GSPMD-propagated). `check` maps to
    check_vma (new) / check_rep (old) — we default it off because the
    consensus bodies return worker-replicated values only after explicit
    collectives, which the static checker cannot always prove.
    """
    import inspect

    manual = frozenset(axis_names) if axis_names is not None \
        else frozenset(mesh.axis_names)
    auto = frozenset(mesh.axis_names) - manual
    if hasattr(jax, "shard_map"):
        # feature-probe the signature: intermediate releases expose
        # jax.shard_map but still spell check_vma/axis_names the old way
        params = inspect.signature(jax.shard_map).parameters
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
        if "check_vma" in params:
            kwargs["check_vma"] = check
        elif "check_rep" in params:
            kwargs["check_rep"] = check
        if auto:
            if "axis_names" in params:
                kwargs["axis_names"] = set(manual)
            elif "auto" in params:
                kwargs["auto"] = auto
        return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check, auto=auto)


def get_mesh() -> Optional["jax.sharding.Mesh"]:
    """The mesh visible at trace time: the abstract mesh where available,
    else the `with mesh:` context mesh. None when no mesh is active."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    try:  # 0.4.x: abstract mesh lives in jax._src.mesh
        from jax._src import mesh as _mesh_lib
        m = _mesh_lib.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except Exception:  # noqa: BLE001 — internals move between releases
        pass
    try:
        from jax._src import mesh as _mesh_lib
        m = _mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:  # noqa: BLE001
        pass
    return None


def set_mesh(mesh) -> None:
    """Publish `mesh` as the ambient mesh where the API exists.

    On 0.4.x this is a no-op: callers keep the `with mesh:` context manager,
    which get_mesh() falls back to."""
    if hasattr(jax, "set_mesh"):
        jax.set_mesh(mesh)
