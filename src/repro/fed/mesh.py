"""shard_map federation backend: cohort lanes placed on mesh devices.

This is the fed ∘ dist composition the ROADMAP tracked: the cohort engine
(PR 3/4) already lays every cohort out as ONE stacked pytree with a leading
lane axis, but executes all lanes on a single device under `jax.vmap`. Here
the same stacked trees are sharded over the mesh data axes — the axes
`repro.dist.step` runs its consensus workers on — so each device runs its
slice of client lanes (local SGD → encode → decode → per-lane norms) fully
manually inside one `shard_map` program, consistent with the all-manual
pattern proven in `repro.dist.step` (partial-auto shard_map crashes the
pinned 0.4.x partitioner; see the NOTE there).

Lane placement contract:

  * a cohort of n lanes is padded to `padded_lanes(n, axis_size)` by
    repeating lane 0 (`clients.stack_padded`), so the stack shards evenly;
    real lanes keep positions 0..n−1 and padded lanes carry weight 0
    downstream — `server._check_weights` explicitly admits exact zeros.
  * per-lane numerics are IDENTICAL to the vmap cohort engine: shard_map
    merely splits the lane axis across devices, and the round body is the
    same `clients._round_body` vmapped per shard — including the fused
    `codec.encode_ef` path (one `kernels.quantencode` pass per leaf emits
    wire + EF residual together) — so wires, EF states, decoded deltas and
    norms agree bit for bit (regression-tested). Any `repro.codecs`
    TreeCodec rides this path, including the sub-linear R < 1 regime
    (exact-keep chunk drop), whose realized ledger the mesh round reports
    byte-equal to the analytic audit.

Server reduce contract (`ServerConfig.sum_mode`, same words as PR 4):

  "sequential"  every device all-gathers the decoded lane stack (tiled over
                the data axes, so lanes land in global participant order),
                slices off the padding, and replays EXACTLY the
                `server._sequential_weighted_sum` fold of the single-device
                path — one collective, then the reference's float-op order,
                so params / opt_state / EF stay bit-exact with the vmap
                cohort engine (and hence with the PR-2 list reference).
  "pairwise"    each device pairwise-folds its own weighted lanes and the
                partial sums meet in a `psum` over the data axes — the
                truly distributed O(m/devices + log devices) reduce, equal
                to the reference only to float tolerance (padding lanes are
                killed by their zero weights before the psum).

fedmem is not a lane fold (its direction reduces over ALL m_total memory
slots), so the mesh backend gathers the decoded stack and reuses
`server.aggregate_stacked` unchanged — same compiled program, bit-exact by
construction.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.obs import recompile as recompile_lib
from repro.dist.sharding import (data_axis_names, lane_pspec, num_workers,
                                 padded_lanes)
from repro.fed import clients as clients_lib
from repro.fed import server as server_lib
from repro.launch.mesh import make_host_mesh


def default_mesh() -> jax.sharding.Mesh:
    """All visible devices on the "data" axis — the lane-placement mesh a
    `Federation(backend="mesh")` builds when none is passed."""
    return make_host_mesh(data=jax.device_count(), model=1)


def lane_axis_size(mesh) -> int:
    """Devices the lane axis shards over (≥ 1 even on a degenerate mesh)."""
    return max(num_workers(mesh), 1)


# ---------------------------------------------------------------------------
# Client side: one cohort round, lanes sharded over the data axes
# ---------------------------------------------------------------------------
def make_mesh_cohort_round(loss_fn, codec, client_cfg, params_template,
                           mesh) -> callable:
    """jit'd (params, stacked data, stacked states, round_idx) →
    (stacked wires, stacked states, stacked decoded deltas, per-lane norms).

    All stacked arguments/results carry a leading lane axis padded to a
    multiple of the mesh's data-axis size and sharded over it; params and
    round_idx are replicated. Each device vmaps `clients._round_body` over
    its own lane slice AND decodes its lanes' payloads locally — embed →
    quantize → decode runs where the lane lives, nothing m-sized crosses
    devices before the reduce. Per-lane outputs are bitwise identical to
    `clients.make_cohort_round` + the driver's cohort decode (vmap lanes are
    independent, so splitting the lane axis cannot change them)."""
    meta = codec.meta(params_template)
    body = clients_lib._round_body(loss_fn, codec, client_cfg, meta)
    lane = lane_pspec(mesh)

    def local_lanes(params, data, state, round_idx):
        wires, new_state = jax.vmap(body, in_axes=(None, 0, 0, None))(
            params, data, state, round_idx)
        decoded = jax.vmap(lambda w: codec.decode(w, meta))(wires)
        return wires, new_state, decoded, server_lib.stacked_norms(decoded)

    fn = shard_map(local_lanes, mesh=mesh,
                   in_specs=(P(), lane, lane, P()),
                   out_specs=(lane, lane, lane, lane),
                   axis_names=set(mesh.axis_names))
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# Server side: the lane fold as a collective over the data axes
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _mesh_mean_fn(mesh, sum_mode: str, lanes: int):
    """Compiled `(padded stacked, weights) → Σ (w/Σw)_l · lane_l` with the
    lane axis sharded over `mesh`'s data axes. `lanes` is the REAL lane
    count (static); padding lanes beyond it never enter the arithmetic in
    "sequential" mode and are zero-weighted in "pairwise" mode."""
    axes = data_axis_names(mesh)
    lane = lane_pspec(mesh)

    if sum_mode == "sequential":
        # one tiled all_gather puts the full stack (global lane order) on
        # every device; the fold is then literally the single-device
        # reference: same normalize, same materialized weighted lanes, same
        # pure-add fori_loop — bit-exact with server._stacked_mean_fn.
        def fold(stacked, w):
            full = jax.tree.map(
                lambda x: jax.lax.all_gather(x, axes, axis=0, tiled=True),
                stacked)
            real = jax.tree.map(lambda x: x[:lanes], full)
            return server_lib._sequential_weighted_sum(real, w / jnp.sum(w))

        in_specs = (lane, P())
    else:
        # distributed pairwise: local weighted fold per device, partial sums
        # psum'd over the data axes. Padding lanes multiply by weight 0, so
        # they vanish before the collective. Summation order differs from
        # BOTH the sequential reference and the single-device pairwise fold
        # — float-tolerance territory, exactly like sum_mode="pairwise"
        # already is on one device.
        def fold(stacked, w_local):
            total = jax.lax.psum(jnp.sum(w_local), axes)
            partial = server_lib._pairwise_weighted_sum(stacked,
                                                        w_local / total)
            return jax.tree.map(lambda x: jax.lax.psum(x, axes), partial)

        in_specs = (lane, lane)

    return recompile_lib.register(
        "fed.aggregate.mesh",
        jax.jit(shard_map(fold, mesh=mesh, in_specs=in_specs,
                          out_specs=P(),
                          axis_names=set(mesh.axis_names))))


def _place_lanes(tree, mesh):
    """Pad a stacked tree's lane axis to the axis size and shard it over the
    mesh data axes. A tree that already carries its padding (the round
    program's own output, in the single-cohort fast path) passes through —
    the device_put is a no-op when the sharding already matches. Added
    padding lanes are zeros; pre-existing ones are lane-0 copies — either
    way "sequential" never reads them and "pairwise" multiplies them by
    weight exactly 0."""
    lanes = jax.tree.leaves(tree)[0].shape[0]
    total = padded_lanes(lanes, lane_axis_size(mesh))
    if total != lanes:
        tree = jax.tree.map(
            lambda x: jnp.concatenate(
                [x, jnp.zeros((total - lanes,) + x.shape[1:], x.dtype)],
                axis=0), tree)
    spec = lane_pspec(mesh)
    return jax.device_put(tree, NamedSharding(mesh, spec)), total


def mesh_weighted_mean(stacked, weights, mesh, sum_mode: str = "sequential",
                       lanes: Optional[int] = None):
    """Σ (w/Σw)_l · lane_l over the first `lanes` lanes, reduced across the
    mesh.

    `lanes` is the REAL lane count (default: the stack's leading axis);
    lanes past it are padding and contribute nothing. Lane placement (and
    any padding still missing) happens here, so callers may pass either a
    real-lanes-only stack or the round program's already-padded output.
    With `sum_mode="sequential"` the result is bit-exact with
    `server._stacked_mean_fn("sequential")` on the real lanes."""
    if lanes is None:
        lanes = jax.tree.leaves(stacked)[0].shape[0]
    placed, total = _place_lanes(stacked, mesh)
    if sum_mode == "sequential":
        w = jnp.asarray(np.asarray(weights), jnp.float32)
    else:
        w_pad = np.zeros(total, np.float32)
        w_pad[:lanes] = np.asarray(weights, np.float64)
        w = jax.device_put(jnp.asarray(w_pad),
                           NamedSharding(mesh, lane_pspec(mesh)))
    return _mesh_mean_fn(mesh, sum_mode, lanes)(placed, w)


def aggregate_stacked_mesh(state, cfg, stacked, weights, mesh,
                           participant_ids: Optional[Sequence[int]] = None,
                           slot_weights=None, lanes: Optional[int] = None):
    """`server.aggregate_stacked` semantics with the lane fold distributed
    over the mesh data axes.

    Same signature modulo `mesh` and `lanes`; `stacked` carries the
    participant lanes in the same order as `weights` / `participant_ids`,
    optionally followed by padding lanes (`lanes` = real count — the
    single-cohort fast path feeds the round program's padded output
    straight through, so the m×L-sized stack never reshards between decode
    and the fold). The m-independent tail — η_s step, fedopt optimizer —
    replays the reference's eager helpers, so with
    `cfg.sum_mode == "sequential"` the whole step is bit-exact with the
    single-device stacked path (regression-tested)."""
    have = jax.tree.leaves(stacked)[0].shape[0]
    lanes = have if lanes is None else lanes
    if lanes == 0:
        return state
    if np.asarray(weights).shape[0] != lanes:
        raise ValueError(f"{np.asarray(weights).shape[0]} weights for "
                         f"{lanes} stacked lanes")

    if cfg.aggregator in ("fedavg", "fedopt"):
        server_lib._check_weights(weights)
        mean = mesh_weighted_mean(stacked, weights, mesh, cfg.sum_mode,
                                  lanes=lanes)
        if cfg.aggregator == "fedopt":
            return server_lib._fedopt_tail(state, cfg, mean)
        return server_lib.ServerState(
            server_lib._apply_delta(state.params, mean, cfg.server_lr),
            state.opt_state, state.memory)

    # fedmem: the direction is a reduction over ALL m_total memory slots,
    # not a participant-lane fold — replicate the (small-m) decoded stack
    # and reuse the single-device program wholesale, which keeps the slot
    # scatter + slot mean bit-exact with the vmap backend for free.
    if lanes != have:
        stacked = jax.tree.map(lambda a: a[:lanes], stacked)
    replicated = jax.device_put(stacked, NamedSharding(mesh, P()))
    return server_lib.aggregate_stacked(state, cfg, replicated, weights,
                                        participant_ids,
                                        slot_weights=slot_weights)
