"""repro.fed — federated client–server simulation with heterogeneous budgets.

The paper's NDSC codec under its harshest setting: per-client bit budgets
R_i, partial participation, stragglers, error feedback on params-deltas, and
a per-round wire-bytes ledger that matches the analytic audit to the byte.

    from repro.fed import (Federation, FedConfig, ClientConfig, ServerConfig,
                           registry, budget)

    codec = registry.make("ndsc", budget=2.0, chunk=128)
    fed = Federation(loss_fn, params, shards, codec)
    history = fed.run(FedConfig(num_rounds=50), eval_fn=global_loss)
"""
from repro.fed import budget, registry
from repro.fed.clients import (ClientConfig, ClientState, init_client_state,
                               local_sgd, make_client_round,
                               make_cohort_round)
from repro.fed.registry import TreeCodec, available, make
from repro.fed.rounds import FedConfig, Federation
from repro.fed.server import (AGGREGATORS, ServerConfig, ServerState,
                              aggregate, decode_deltas, init_server)

__all__ = [
    "AGGREGATORS", "ClientConfig", "ClientState", "FedConfig", "Federation",
    "ServerConfig", "ServerState", "TreeCodec", "aggregate", "available",
    "budget", "decode_deltas", "init_client_state", "init_server",
    "local_sgd", "make", "make_client_round", "make_cohort_round", "registry",
]
