"""repro.fed — federated client–server simulation with heterogeneous budgets.

The paper's NDSC codec under its harshest setting: per-client bit budgets
R_i, partial participation, stragglers, error feedback on params-deltas, and
a per-round wire-bytes ledger that matches the analytic audit to the byte.
Large-m simulations run cohort-vectorized: clients sharing a
(codec spec, client config, data signature) execute as one vmapped program,
and budgets can re-allocate adaptively from the server-side delta-norm EMA.

    from repro import codecs
    from repro.fed import Federation, FedConfig, ClientConfig, ServerConfig

    codec = codecs.make("ndsc", budget=2.0, chunk=128)
    fed = Federation(loss_fn, params, shards, codec)
    history = fed.run(FedConfig(num_rounds=50), eval_fn=global_loss)

(`repro.fed.registry` is a deprecation shim for the codec registry's old
home; new code imports from `repro.codecs`.)
"""
from repro.codecs import TreeCodec, available, codec_spec, make
from repro.fed import budget, registry
from repro.fed.budget import AdaptiveConfig, NormEMA
from repro.fed.clients import (ClientConfig, ClientState, concat_stacks,
                               data_signature, init_client_state, local_sgd,
                               make_client_round, make_cohort_round,
                               stack_padded, stack_trees, unstack_tree)
from repro.fed.mesh import (aggregate_stacked_mesh, default_mesh,
                            make_mesh_cohort_round, mesh_weighted_mean)
from repro.fed.rounds import (BACKENDS, FedConfig, Federation, cohort_key,
                              partition_cohorts)
from repro.fed.server import (AGGREGATORS, SUM_MODES, ServerConfig,
                              ServerState, aggregate, aggregate_stacked,
                              decode_deltas, delta_norms, init_server,
                              stacked_norms, tree_norm)

__all__ = [
    "AGGREGATORS", "AdaptiveConfig", "BACKENDS", "ClientConfig",
    "ClientState", "FedConfig", "Federation", "NormEMA", "SUM_MODES",
    "ServerConfig", "ServerState", "TreeCodec", "aggregate",
    "aggregate_stacked", "aggregate_stacked_mesh", "available", "budget",
    "codec_spec", "cohort_key", "concat_stacks", "data_signature",
    "decode_deltas", "default_mesh", "delta_norms", "init_client_state",
    "init_server", "local_sgd", "make", "make_client_round",
    "make_cohort_round", "make_mesh_cohort_round", "mesh_weighted_mean",
    "partition_cohorts", "registry", "stack_padded", "stack_trees",
    "stacked_norms", "tree_norm", "unstack_tree",
]
