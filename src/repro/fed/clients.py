"""Client-side state and local update loops for the federated simulation.

A client holds an error-feedback memory (the DGD-DEF mechanism of paper
Alg. 1, applied to params-DELTAS rather than gradients), a PRNG lane and a
round counter. One federated round on client i:

    local   ← local_steps of SGD on the client's shard from params
    Δ_i     ← local − params                      (the params-delta)
    u_i     ← Δ_i + e_i                           (error compensation)
    wire    ← E_i(u_i)          at budget R_i     (repro.codecs TreeCodec)
    e_i     ← u_i − D_i(wire)                     (memory for next round)

When the codec provides a fused `encode_ef` (the ndsc backend does, via the
`repro.kernels.quantencode` Pallas kernel), the last two lines collapse into
one call that emits (wire, e_i) together — the decoded f32 tree never
materializes between separate encode and decode programs.

`ClientState` is a flat pytree of arrays, so a cohort of clients sharing one
(codec, config) pair stacks into a single state and runs under `jax.vmap`
(`make_cohort_round`); heterogeneous-budget clients run one compiled
`make_client_round` per distinct codec (`repro.fed.rounds` caches these).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ClientConfig:
    """Local-update hyperparameters (shared by a cohort, static under jit).

    batch_size None runs full-batch local GD (deterministic given params);
    otherwise each local step samples `batch_size` examples with replacement
    from the client shard using the client's PRNG lane.
    """

    local_steps: int = 1
    lr: float = 0.1
    batch_size: Optional[int] = None
    error_feedback: bool = True


class ClientState(NamedTuple):
    ef: Any               # error-feedback tree (f32, zeros when disabled)
    key: jax.Array        # PRNG lane, split every participated round
    rounds_seen: jax.Array  # int32 participation counter


def init_client_state(params, key: jax.Array,
                      cfg: ClientConfig = ClientConfig()) -> ClientState:
    ef = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
          if cfg.error_feedback else {})
    return ClientState(ef=ef, key=key, rounds_seen=jnp.zeros((), jnp.int32))


def num_examples(data) -> int:
    """Leading-axis length of a client shard (a pytree of stacked arrays)."""
    return int(jax.tree.leaves(data)[0].shape[0])


def local_sgd(loss_fn: Callable, params, data, key: jax.Array,
              cfg: ClientConfig):
    """cfg.local_steps of (mini-batch) SGD on this client's shard."""
    n = num_examples(data)

    def one_step(p, k):
        if cfg.batch_size is None:
            batch = data
        else:
            idx = jax.random.randint(k, (cfg.batch_size,), 0, n)
            batch = jax.tree.map(lambda a: a[idx], data)
        g = jax.grad(loss_fn)(p, batch)
        return jax.tree.map(
            lambda x, gg: (x - cfg.lr * gg.astype(jnp.float32)
                           ).astype(x.dtype), p, g), None

    keys = jax.random.split(key, cfg.local_steps)
    out, _ = jax.lax.scan(one_step, params, keys)
    return out


def _round_body(loss_fn: Callable, codec, cfg: ClientConfig, meta):
    def fn(global_params, data, state: ClientState, round_idx):
        k_local, k_enc, k_next = jax.random.split(state.key, 3)
        local = local_sgd(loss_fn, global_params, data, k_local, cfg)
        delta = jax.tree.map(
            lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
            local, global_params)
        u = (jax.tree.map(jnp.add, delta, state.ef)
             if cfg.error_feedback else delta)
        if cfg.error_feedback and codec.encode_ef is not None:
            # fused path: the codec emits u − D(E(u)) alongside the wire
            # (same payload as `encode` under the same key; on the Pallas
            # backend the residual never round-trips HBM as decoded f32)
            wire, ef = codec.encode_ef(k_enc, u, meta, round_idx)
        elif cfg.error_feedback:
            wire = codec.encode(k_enc, u, round_idx)
            decoded = codec.decode(wire, meta)
            ef = jax.tree.map(jnp.subtract, u, decoded)
        else:
            wire = codec.encode(k_enc, u, round_idx)
            ef = state.ef
        return wire, ClientState(ef=ef, key=k_next,
                                 rounds_seen=state.rounds_seen + 1)

    return fn


def make_client_round(loss_fn: Callable, codec, cfg: ClientConfig,
                      params_template) -> Callable:
    """jit'd (global_params, data, state, round_idx) → (wire, new state).

    `codec` is a `repro.codecs.TreeCodec`; its static meta is taken once from
    `params_template` so the returned function is a pure jit-able closure.
    The wire payload is what the server decodes; the client decodes its OWN
    payload locally for the error-feedback update (no extra communication,
    exactly as in repro.dist.step)."""
    return jax.jit(_round_body(loss_fn, codec, cfg,
                               codec.meta(params_template)))


def make_cohort_round(loss_fn: Callable, codec, cfg: ClientConfig,
                      params_template) -> Callable:
    """vmapped client round for a cohort sharing (codec, cfg).

    (global_params, stacked data, stacked states, round_idx) →
    (stacked wires, stacked states). Each lane uses its own PRNG key, so
    dither / keep-mask draws stay independent across clients while the
    per-leaf FRAMES (pure functions of the codec seed) remain shared — the
    server decodes every lane with the same frames."""
    fn = _round_body(loss_fn, codec, cfg, codec.meta(params_template))
    return jax.jit(jax.vmap(fn, in_axes=(None, 0, 0, None)))


# ---------------------------------------------------------------------------
# Cohort stacking — between the per-client host lists and the vmap lanes
# ---------------------------------------------------------------------------
def stack_trees(trees):
    """Stack a list of identically-shaped pytrees along a new leading axis.

    Works on `ClientState` (NamedTuple pytree: PRNG keys stack into a key
    array, each lane keeps its own stream) and on client data shards alike.
    """
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def stack_padded(trees, total: int):
    """`stack_trees` padded to `total` lanes by repeating the FIRST tree.

    The mesh backend shards stacked cohort pytrees over the data axes, which
    needs the lane count divisible by the axis size; padding with a copy of
    a REAL lane keeps every lane runnable (finite data, a valid PRNG key —
    the duplicated key is harmless because padded-lane outputs are always
    discarded / zero-weighted downstream). Real lanes come first, so
    `lane[:len(trees)]` of any stacked output recovers the true cohort."""
    if total < len(trees):
        raise ValueError(f"cannot pad {len(trees)} lanes down to {total}")
    return stack_trees(list(trees) + [trees[0]] * (total - len(trees)))


def unstack_tree(tree, m: int) -> list:
    """Inverse of `stack_trees`: lane i of every leaf, as m pytrees."""
    return [jax.tree.map(lambda a, i=i: a[i], tree) for i in range(m)]


def concat_stacks(stacks: list, perm=None):
    """Concatenate already-stacked pytrees along the lane axis, optionally
    permuting the lanes of the result.

    This is the server-side join between per-cohort stacked decode outputs
    and the single stacked tree `server.aggregate_stacked` reduces: O(L)
    device ops total (one concatenate + one gather per leaf) instead of the
    O(m·L) per-participant unstack the host-loop path paid. A single stack
    with `perm=None` passes through untouched (the full-participation /
    one-cohort fast path)."""
    out = (stacks[0] if len(stacks) == 1
           else jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *stacks))
    if perm is not None:
        p = jnp.asarray(perm, jnp.int32)
        out = jax.tree.map(lambda a: a[p], out)
    return out


def data_signature(data) -> tuple:
    """Hashable (treedef, leaf shapes/dtypes) — cohort lanes must agree on it
    for `stack_trees` to produce one rectangular batch."""
    leaves, treedef = jax.tree.flatten(data)
    return treedef, tuple((tuple(x.shape), jnp.result_type(x).name)
                          for x in leaves)
