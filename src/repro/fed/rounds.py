"""The federated round driver: cohorts, participation, stragglers, ledger.

`Federation` wires the pieces together: per-client shards + budgets →
registry codecs → compiled client rounds → server decode + aggregate. The
host loop only does participant sampling, straggler dropout, cohort
bookkeeping and the ledger; all numerics run inside jit.

Cohort execution (the large-m path): participants are partitioned by the
hashable cohort key

    (codec.spec, ClientConfig, data signature)

and every cohort of ≥ 2 clients runs through ONE compiled
`make_cohort_round` program (`jax.vmap` over stacked `ClientState` / data
pytrees, one PRNG lane per client) instead of len(cohort) sequential jit
dispatches. Singleton cohorts — and clients whose codec has no spec (built
outside `registry.make`) — fall back to the scalar `make_client_round` path.
Both paths run the SAME `_round_body`, so wires, EF states and the decoded
global delta are bit-exact between them (regression-tested); the wire ledger
stays byte-exact because it sums the per-lane `codec.wire_bytes` audits of
each cohort.

Backend selection (`Federation(backend=...)`): "vmap" runs every cohort's
lanes on one device; "mesh" shards the stacked cohort pytrees over the mesh
data axes via `repro.fed.mesh` — each device runs its lane slice (local SGD
→ encode → decode) under shard_map and the server reduce becomes a
collective fold, bit-exact with "vmap" under `sum_mode="sequential"` even
when the lane count doesn't divide the axis size (zero-weight padding).

Adaptive budget re-allocation: with `adaptive=AdaptiveConfig(...)` the driver
re-runs `budget.allocate` every `realloc_every` rounds from the EMA of the
decoded delta norms the server already holds (no extra communication),
snapped to a rate lattice with a hysteresis guard so cohort keys — and hence
compiled programs — don't churn while the gradient geometry drifts slowly.

Round lifecycle (README has the diagram):

  1. (adaptive only) maybe re-allocate budgets → rebuild codecs via
     `codec_factory`, keeping every previously compiled program cached,
  2. sample ⌈participation·m⌉ clients (deterministic per (seed, round)),
  3. drop each sampled client as a straggler with prob. `dropout`,
  4. partition survivors into cohorts; each cohort (vmapped) or singleton
     (scalar) round fn → payloads + new EF states,
  5. ledger records REALIZED payload bytes (codec.wire_bytes) and the
     analytic audit — computed ONCE per codec spec at `_install_codecs` time
     (`codec.wire_bits` walks the whole params tree on host, so it must not
     run per participant per round) and equal to the realized bytes for the
     NDSC backend under exact_keep,
  6. server decodes every payload with its client's codec — each cohort
     decode is one compiled program that also emits per-lane ℓ2 norms (the
     allocator EMA fetches m scalars, never m decoded trees) — joins the
     per-cohort stacks into ONE stacked device tree in participant order,
     and aggregates it with `server.aggregate_stacked` (a single jit
     program; `sum_mode="sequential"` keeps the reference summation order).
     Decoded deltas never leave the device between decode and the params
     update. `use_cohorts=False` instead drives the PR-2 list-layout
     reference (`server.aggregate`), which the stacked path is regression-
     tested bit-exact against.

Dropped/unsampled clients keep their EF memory and PRNG lane untouched —
they never encoded, so there is nothing to feed back (straggler semantics).

Observability (`repro.obs`): when a session is active, `run_round` emits
host-side spans for the realloc / client-compute / decode / aggregate
stages plus counters and gauges sourced from the round record (realized
vs analytic wire bytes, participant / straggler / cohort counts, lane
histograms) — never from inside jit. Every compiled program registers
with `obs.recompile` under a stable name ("fed.round.cohort", …) so
compile churn is attributable per program. The hard contract, regression-
tested in tests/test_obs_bitexact.py: enabling obs leaves params, EF
states, the ledger and the history BIT-EXACT and adds ZERO recompiles —
spans only time the host's view of each (async) dispatch. `run(...,
obs=session)` opt-in activates a session for the run's duration and
emits a run-level summary event.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import numpy as np

from repro.fed import budget as budget_lib
from repro.fed import clients as clients_lib
from repro.fed import mesh as mesh_lib
from repro.fed import server as server_lib
from repro.obs import core as obs_lib
from repro.obs import recompile as recompile_lib

BACKENDS = ("vmap", "mesh")


@dataclasses.dataclass(frozen=True)
class FedConfig:
    num_rounds: int = 50
    participation: float = 1.0   # fraction of clients sampled per round
    dropout: float = 0.0         # straggler prob. among the sampled
    weighting: str = "uniform"   # "uniform" | "data_size"
    seed: int = 0

    def __post_init__(self):
        if not 0.0 < self.participation <= 1.0:
            raise ValueError("participation must be in (0, 1]")
        if not 0.0 <= self.dropout < 1.0:
            raise ValueError("dropout must be in [0, 1)")
        if self.weighting not in ("uniform", "data_size"):
            raise ValueError(f"unknown weighting {self.weighting!r}")


def cohort_key(codec, client_cfg, data) -> Optional[tuple]:
    """Hashable cohort identity, or None when the client can't be cohorted.

    Clients sharing a key are interchangeable under one vmapped program:
    equal codec specs encode/decode identically (registry contract), equal
    `ClientConfig`s make the local loop static-identical, and equal data
    signatures make the shards stackable into one rectangular batch.
    """
    spec = getattr(codec, "spec", None)
    if spec is None:
        return None
    return (spec, client_cfg, clients_lib.data_signature(data))


def partition_cohorts(ids_and_keys: Sequence) -> list:
    """[(client_id, key-or-None), ...] → [(key, members), ...].

    Members keep the input order within each cohort; cohorts appear in
    first-seen order, with every None-keyed client as its own trailing
    singleton. The member lists are an exact, disjoint partition of the
    input ids (property-tested).
    """
    groups: dict = {}
    order: list = []
    singletons: list = []
    for i, k in ids_and_keys:
        if k is None:
            singletons.append((None, [i]))
            continue
        if k not in groups:
            groups[k] = []
            order.append(k)
        groups[k].append(i)
    return [(k, groups[k]) for k in order] + singletons


class Federation:
    """A client–server simulation over `m = len(datas)` clients.

    codecs / client_cfgs may be a single shared object or one per client
    (heterogeneous budgets). All clients see the same `loss_fn(params,
    batch)`; heterogeneity lives in the data shards and the budgets.

    `use_cohorts=False` forces the scalar sequential path (the reference the
    cohort engine is regression-tested against). `adaptive` + `codec_factory`
    (rate → TreeCodec) turn on adaptive budget re-allocation; the initial
    codecs' `.rate` attributes seed the allocation state.

    `backend` picks where cohort lanes execute:

      "vmap"  (default) all lanes of a cohort on one device, one vmapped
              program — the PR-3/4 engine.
      "mesh"  lanes sharded over the data axes of `mesh` (every visible
              device when None): each device runs its lane slice manually
              under shard_map and the server reduce runs as a collective
              fold (`repro.fed.mesh`). Bit-exact with "vmap" under
              `sum_mode="sequential"`, including lane counts not divisible
              by the axis size (zero-weight padding lanes). Requires
              `use_cohorts=True`; singleton / spec-less clients still fall
              back to the scalar path, exactly as under "vmap".
    """

    def __init__(self, loss_fn: Callable, params, datas: Sequence,
                 codecs, client_cfgs=None,
                 server_cfg: server_lib.ServerConfig = None, seed: int = 0,
                 use_cohorts: bool = True,
                 adaptive: Optional[budget_lib.AdaptiveConfig] = None,
                 codec_factory: Optional[Callable] = None,
                 backend: str = "vmap", mesh=None):
        m = len(datas)
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, "
                             f"got {backend!r}")
        if backend == "mesh" and not use_cohorts:
            raise ValueError('backend="mesh" places cohort lanes on devices '
                             "— it requires use_cohorts=True")
        self.backend = backend
        self.mesh = (mesh if mesh is not None else mesh_lib.default_mesh()) \
            if backend == "mesh" else None
        self.loss_fn = loss_fn
        self.datas = list(datas)
        if client_cfgs is None:
            client_cfgs = clients_lib.ClientConfig()
        self.client_cfgs = (list(client_cfgs)
                            if isinstance(client_cfgs, (list, tuple))
                            else [client_cfgs] * m)
        codecs = (list(codecs) if isinstance(codecs, (list, tuple))
                  else [codecs] * m)
        if len(codecs) != m or len(self.client_cfgs) != m:
            raise ValueError("need one codec / client config per client")
        self.server_cfg = server_cfg or server_lib.ServerConfig()
        self.server = server_lib.init_server(params, self.server_cfg, m)
        key = jax.random.key(seed)
        self.states = [
            clients_lib.init_client_state(params, jax.random.fold_in(key, i),
                                          self.client_cfgs[i])
            for i in range(m)]
        self.use_cohorts = use_cohorts
        self.adaptive = adaptive
        self.codec_factory = codec_factory
        if adaptive is not None:
            if codec_factory is None:
                raise ValueError("adaptive re-allocation needs a "
                                 "codec_factory (rate → TreeCodec)")
            rates = [getattr(c, "rate", None) for c in codecs]
            if any(r is None for r in rates):
                raise ValueError("adaptive re-allocation needs every initial "
                                 "codec to expose a `.rate`")
            self._rates = np.asarray([float(r) for r in rates])
            self._ema = budget_lib.NormEMA(m, adaptive.ema_beta)
        else:
            self._rates = None
            self._ema = None
        # compiled-program caches, persistent across re-allocations: going
        # back to a previously seen (spec, cfg) reuses the compiled fn
        self._round_fns: dict = {}
        self._cohort_fns: dict = {}
        self._cohort_decode_fns: dict = {}
        self._decode_fns: dict = {}    # spec key -> scalar decode+norm fn
        self._audit_bits: dict = {}    # spec key -> analytic wire_bits
        self._stacked_data: dict = {}  # cohort key -> (members, stacked)
        self._mesh_fns: dict = {}      # cohort key -> mesh round program
        self.rounds_done = 0           # rounds driven by run() (ckpt resume)
        self._install_codecs(codecs)

    # -- codec tables --------------------------------------------------------
    def _spec_key(self, i: int):
        # spec-less codecs key by the object itself (a frozen dataclass, so
        # hashable) — keeping it alive in the cache key, which matters
        # because the caches outlive set_rates and a recycled id() could
        # otherwise alias a dead codec's compiled fn / cached audit
        spec = getattr(self.codecs[i], "spec", None)
        return spec if spec is not None else self.codecs[i]

    def _fn_key(self, i: int) -> tuple:
        return (self._spec_key(i), self.client_cfgs[i])

    def _install_codecs(self, codecs: Sequence) -> None:
        m = self.num_clients
        self.codecs = list(codecs)
        self.metas = [c.meta(self.server.params) for c in self.codecs]
        for i in range(m):
            k = self._fn_key(i)
            if k not in self._round_fns:
                self._round_fns[k] = recompile_lib.register(
                    "fed.round.scalar", clients_lib.make_client_round(
                        self.loss_fn, self.codecs[i], self.client_cfgs[i],
                        self.server.params))
        self._fn_of = [self._round_fns[self._fn_key(i)] for i in range(m)]
        self._cohort_keys = [
            cohort_key(self.codecs[i], self.client_cfgs[i], self.datas[i])
            for i in range(m)]
        # analytic wire audit, once per distinct codec spec: wire_bits walks
        # the whole params tree on host, so recomputing it per participant
        # per round was an O(m·L·rounds) hot spot (and the params TEMPLATE —
        # shapes/dtypes — never changes, so the audit can't go stale)
        for i in range(m):
            sk = self._spec_key(i)
            if sk not in self._audit_bits:
                self._audit_bits[sk] = float(
                    self.codecs[i].wire_bits(self.server.params))
        self._analytic_bits = [self._audit_bits[self._spec_key(i)]
                               for i in range(m)]

    def set_rates(self, rates: Sequence[float]) -> None:
        """Adopt new per-client budgets: rebuild codecs via `codec_factory`.

        Compiled round programs are cached by (spec, config) / cohort key, so
        only rates never seen before trigger a compile."""
        if self.codec_factory is None:
            raise ValueError("set_rates needs a codec_factory")
        rates = [float(r) for r in rates]
        self._rates = np.asarray(rates)
        self._install_codecs([self.codec_factory(r) for r in rates])

    @property
    def num_clients(self) -> int:
        return len(self.datas)

    # -- one round -----------------------------------------------------------
    def sample_participants(self, cfg: FedConfig, round_idx: int):
        """(participants, stragglers) — deterministic in (seed, round)."""
        m = self.num_clients
        rng = np.random.default_rng(
            np.random.PCG64(cfg.seed * 1_000_003 + round_idx))
        k = max(1, int(np.ceil(cfg.participation * m)))
        sampled = sorted(rng.choice(m, size=k, replace=False).tolist())
        if cfg.dropout <= 0.0:
            return sampled, []
        keep = rng.random(k) >= cfg.dropout
        participants = [c for c, kp in zip(sampled, keep) if kp]
        stragglers = [c for c, kp in zip(sampled, keep) if not kp]
        return participants, stragglers

    def _maybe_reallocate(self, round_idx: int) -> bool:
        if (self.adaptive is None or round_idx == 0
                or round_idx % self.adaptive.realloc_every != 0):
            return False
        new, changed = budget_lib.reallocate(self.adaptive, self._ema,
                                             self._rates)
        if changed:
            self.set_rates(new)
        return changed

    def _cohort_decode(self, key, i0: int):
        """Compiled vmapped server decode for one cohort (lanes share the
        codec and meta, so the whole cohort decodes as one program). Emits
        (stacked decoded deltas, per-lane ℓ2 norms) — both device arrays."""
        fn = self._cohort_decode_fns.get(key)
        if fn is None:
            codec, meta = self.codecs[i0], self.metas[i0]

            def decode_cohort(wires):
                decoded = jax.vmap(lambda w: codec.decode(w, meta))(wires)
                return decoded, server_lib.stacked_norms(decoded)

            fn = recompile_lib.register("fed.decode.cohort",
                                        jax.jit(decode_cohort))
            self._cohort_decode_fns[key] = fn
        return fn

    def _scalar_decode(self, i: int):
        """Compiled singleton decode+norm, shaped like a 1-lane cohort
        (leading lane axis) so it joins `concat_stacks` uniformly."""
        k = self._spec_key(i)
        fn = self._decode_fns.get(k)
        if fn is None:
            codec, meta = self.codecs[i], self.metas[i]

            def decode_one(wire):
                decoded = codec.decode(wire, meta)
                return (jax.tree.map(lambda x: x[None], decoded),
                        server_lib.tree_norm(decoded)[None])

            fn = recompile_lib.register("fed.decode.scalar",
                                        jax.jit(decode_one))
            self._decode_fns[k] = fn
        return fn

    def _run_clients(self, participants: Sequence[int],
                     round_idx: int) -> tuple:
        """Run every participant through its cohort (vmapped) or scalar
        round fn; returns ({client_id: wire}, [(members, stacked decoded
        deltas, per-lane norms), ...]) and updates states in place.

        The stacked decode outputs STAY on device: only the wires (the
        compressed payloads, for the realized-bytes ledger), the EF trees
        and the round counters cross to host. The decoded dense deltas —
        m × params-sized, the dominant transfer of the old path — flow
        straight into `server.aggregate_stacked`."""
        wires_of: dict = {}
        groups: list = []
        parts = partition_cohorts(
            [(i, self._cohort_keys[i] if self.use_cohorts else None)
             for i in participants])
        for key, members in parts:
            if key is not None and len(members) > 1:
                if self.backend == "mesh":
                    wires, new_states, decoded, norms = self._run_cohort_mesh(
                        key, members, round_idx)
                else:
                    wires, new_states, decoded, norms = self._run_cohort_vmap(
                        key, members, round_idx)
                # one device→host transfer for everything except the PRNG
                # lanes (typed key arrays can't cross into numpy); per-lane
                # numpy views are free, per-lane device slices are not.
                # Mesh-backend stacks carry padding lanes past len(members);
                # only the real lanes are unstacked back into client state.
                h_wires, h_ef, h_seen = jax.device_get(
                    (wires, new_states.ef, new_states.rounds_seen))
                keys = new_states.key
                lanes = len(members)
                u_wires = clients_lib.unstack_tree(h_wires, lanes)
                u_ef = clients_lib.unstack_tree(h_ef, lanes)
                for lane, i in enumerate(members):
                    wires_of[i] = u_wires[lane]
                    self.states[i] = clients_lib.ClientState(
                        ef=u_ef[lane], key=keys[lane],
                        rounds_seen=h_seen[lane])
                groups.append((members, decoded, norms))
            else:
                for i in members:
                    obs_lib.observe_program_call(
                        "fed.round.scalar", self._fn_of[i],
                        (self.server.params, self.datas[i], self.states[i],
                         round_idx), span="fed.clients.compute",
                        wire_bytes=self._analytic_bits[i] / 8.0)
                    with obs_lib.span("fed.clients.compute", lanes=1,
                                      path="scalar"):
                        wires_of[i], self.states[i] = self._fn_of[i](
                            self.server.params, self.datas[i],
                            self.states[i], round_idx)
                    dfn = self._scalar_decode(i)
                    obs_lib.observe_program_call(
                        "fed.decode.scalar", dfn, (wires_of[i],),
                        span="fed.decode")
                    with obs_lib.span("fed.decode", lanes=1, path="scalar"):
                        decoded1, norm1 = dfn(wires_of[i])
                    groups.append(([i], decoded1, norm1))
        return wires_of, groups

    def _run_cohort_vmap(self, key, members: Sequence[int], round_idx: int):
        """One cohort on one device: the PR-3 vmapped round + PR-4 decode."""
        fn = self._cohort_fns.get(key)
        if fn is None:
            i0 = members[0]
            fn = recompile_lib.register(
                "fed.round.cohort", clients_lib.make_cohort_round(
                    self.loss_fn, self.codecs[i0], self.client_cfgs[i0],
                    self.server.params))
            self._cohort_fns[key] = fn
        # shards never change, so the stack is reusable whenever the
        # cohort's membership repeats (always, at full participation); one
        # cached entry per cohort key bounds the memory at one stacked copy
        # of each cohort's data
        mtuple = tuple(members)
        cached = self._stacked_data.get(key)
        if cached is not None and cached[0] == mtuple:
            data = cached[1]
        else:
            data = clients_lib.stack_trees([self.datas[i] for i in members])
            self._stacked_data[key] = (mtuple, data)
        state = clients_lib.stack_trees([self.states[i] for i in members])
        obs_lib.observe_program_call(
            "fed.round.cohort", fn,
            (self.server.params, data, state, round_idx),
            span="fed.clients.compute",
            wire_bytes=len(members) * self._analytic_bits[members[0]] / 8.0)
        with obs_lib.span("fed.clients.compute", lanes=len(members),
                          path="vmap"):
            wires, new_states = fn(self.server.params, data, state,
                                   round_idx)
        dfn = self._cohort_decode(key, members[0])
        obs_lib.observe_program_call("fed.decode.cohort", dfn, (wires,),
                                     span="fed.decode")
        with obs_lib.span("fed.decode", lanes=len(members), path="vmap"):
            decoded, norms = dfn(wires)
        return wires, new_states, decoded, norms

    def _run_cohort_mesh(self, key, members: Sequence[int], round_idx: int):
        """One cohort with its lanes sharded over the mesh data axes.

        The stacked data/state are padded to the axis size by repeating lane
        0 (`clients.stack_padded`) so the shard_map program sees an even
        split. Wires, states and norms come back sliced to the real lanes
        (the padded tail never reaches the ledger, the client states or the
        EMA) — but the m×L-sized DECODED stack keeps its padding and stays
        lane-sharded, so the single-cohort fast path in `run_round` can
        feed it to the collective fold without a reshard; the padding is
        zero-weighted / sliced off there."""
        n = len(members)
        total = mesh_lib.padded_lanes(n, mesh_lib.lane_axis_size(self.mesh))
        fn = self._mesh_fns.get(key)
        if fn is None:
            i0 = members[0]
            fn = recompile_lib.register(
                "fed.round.mesh", mesh_lib.make_mesh_cohort_round(
                    self.loss_fn, self.codecs[i0], self.client_cfgs[i0],
                    self.server.params, self.mesh))
            self._mesh_fns[key] = fn
        mtuple = (tuple(members), total)
        cached = self._stacked_data.get(key)
        if cached is not None and cached[0] == mtuple:
            data = cached[1]
        else:
            data = clients_lib.stack_padded(
                [self.datas[i] for i in members], total)
            self._stacked_data[key] = (mtuple, data)
        state = clients_lib.stack_padded(
            [self.states[i] for i in members], total)
        obs_lib.observe_program_call(
            "fed.round.mesh", fn,
            (self.server.params, data, state, round_idx),
            span="fed.clients.compute",
            wire_bytes=len(members) * self._analytic_bits[members[0]] / 8.0)
        with obs_lib.span("fed.clients.compute", lanes=len(members),
                          padded=total, path="mesh"):
            wires, new_states, decoded, norms = fn(self.server.params, data,
                                                   state, round_idx)
        if total != n:
            wires = jax.tree.map(lambda a: a[:n], wires)
            new_states = jax.tree.map(lambda a: a[:n], new_states)
        return wires, new_states, decoded, norms[:n]

    @staticmethod
    def _combine_groups(groups: Sequence, participants: Sequence[int]):
        """Join per-cohort stacks into ONE stacked tree in participant order
        (the order the sequential reference reduces in) plus the per-lane
        norms in group order with their client ids.

        At full participation with one cohort this is a pass-through; in
        general it costs one concatenate + one gather per leaf — O(L) device
        ops, independent of m."""
        order = [i for members, _, _ in groups for i in members]
        perm = None
        if order != list(participants):
            pos = {c: j for j, c in enumerate(order)}
            perm = np.asarray([pos[c] for c in participants], np.int32)
        stacked = clients_lib.concat_stacks([g[1] for g in groups], perm)
        norms = clients_lib.concat_stacks([g[2] for g in groups])
        return stacked, order, norms

    def run_round(self, cfg: FedConfig, round_idx: int) -> dict:
        with obs_lib.span("fed.round", round=round_idx,
                          backend=self.backend):
            rec, groups = self._run_round(cfg, round_idx)
        if obs_lib.enabled():
            self._emit_round_obs(rec, groups)
        return rec

    def _run_round(self, cfg: FedConfig, round_idx: int) -> tuple:
        with obs_lib.span("fed.round.realloc"):
            realloc = self._maybe_reallocate(round_idx)
        participants, stragglers = self.sample_participants(cfg, round_idx)
        with obs_lib.span("fed.round.clients",
                          participants=len(participants)):
            wires_of, groups = self._run_clients(participants, round_idx)
        realized = analytic = 0.0
        for i in participants:
            realized += self.codecs[i].wire_bytes(wires_of[i], self.metas[i])
            analytic += self._analytic_bits[i] / 8.0
        if participants:
            weights = self._weights(cfg, participants)
            slot_weights = (self._weights(cfg, range(self.num_clients))
                            if (self.server_cfg.aggregator == "fedmem"
                                and cfg.weighting != "uniform") else None)
            with obs_lib.span("fed.round.aggregate",
                              aggregator=self.server_cfg.aggregator,
                              participants=len(participants)):
                self._aggregate(groups, participants, weights, slot_weights)
        return ({"round": round_idx, "participants": participants,
                 "stragglers": stragglers, "wire_bytes": realized,
                 "analytic_bytes": analytic, "realloc": realloc,
                 "rates": (self._rates.tolist()
                           if self._rates is not None else None)},
                groups)

    def _aggregate(self, groups, participants, weights,
                   slot_weights) -> None:
        if (self.backend == "mesh" and self.use_cohorts
                and len(groups) == 1
                and groups[0][0] == list(participants)):
            # single-cohort fast path (the whole round is one mesh
            # program, e.g. full participation of a homogeneous
            # population): the padded, lane-sharded decoded stack feeds
            # the collective fold directly — no slice, no reshard
            members, padded, norms = groups[0]
            if self._ema is not None:
                self._ema.update(members, np.asarray(
                    jax.device_get(norms), np.float64))
            self.server = mesh_lib.aggregate_stacked_mesh(
                self.server, self.server_cfg, padded, weights,
                self.mesh, participants, slot_weights=slot_weights,
                lanes=len(participants))
        elif self.use_cohorts:
            if self.backend == "mesh":
                # multi-group join: strip each mesh cohort's padding
                # before the concat + participant-order gather
                groups = [(mem, jax.tree.map(
                    lambda a, k=len(mem): a[:k], dec), nr)
                    for mem, dec, nr in groups]
            stacked, order, norms = self._combine_groups(groups,
                                                         participants)
            if self._ema is not None:
                self._ema.update(order, np.asarray(
                    jax.device_get(norms), np.float64))
            if self.backend == "mesh":
                self.server = mesh_lib.aggregate_stacked_mesh(
                    self.server, self.server_cfg, stacked, weights,
                    self.mesh, participants, slot_weights=slot_weights)
            else:
                self.server = server_lib.aggregate_stacked(
                    self.server, self.server_cfg, stacked, weights,
                    participants, slot_weights=slot_weights)
        else:
            # PR-2 list-layout reference: per-participant trees, host
            # reduction loop (the oracle the stacked path is tested
            # against; norms come from the same decode programs)
            deltas = [jax.tree.map(lambda x: x[0], g[1]) for g in groups]
            if self._ema is not None:
                norms = np.concatenate(
                    [np.asarray(jax.device_get(g[2]), np.float64)
                     for g in groups])
                self._ema.update([g[0][0] for g in groups], norms)
            self.server = server_lib.aggregate(
                self.server, self.server_cfg, deltas, weights,
                participants, slot_weights=slot_weights)

    def _emit_round_obs(self, rec: dict, groups: Sequence) -> None:
        """Round metrics, sourced from the finished round RECORD (and the
        host-side cohort bookkeeping) — never from inside jit."""
        obs_lib.counter("fed.rounds", 1)
        obs_lib.counter("fed.wire_bytes", rec["wire_bytes"])
        obs_lib.counter("fed.analytic_bytes", rec["analytic_bytes"])
        obs_lib.counter("fed.stragglers", len(rec["stragglers"]))
        if rec["realloc"]:
            obs_lib.counter("fed.reallocs", 1)
        obs_lib.gauge("fed.participants", len(rec["participants"]),
                      round=rec["round"])
        obs_lib.gauge("fed.cohorts", len(groups), round=rec["round"])
        for members, _, _ in groups:
            obs_lib.histogram("fed.cohort_lanes", len(members))

    def _weights(self, cfg: FedConfig, participants) -> np.ndarray:
        if cfg.weighting == "data_size":
            return np.array([clients_lib.num_examples(self.datas[i])
                             for i in participants], dtype=np.float64)
        return np.ones(len(participants))

    # -- full run ------------------------------------------------------------
    def run(self, cfg: FedConfig,
            eval_fn: Optional[Callable[[Any], float]] = None,
            obs: Optional[obs_lib.Obs] = None) -> dict:
        """Drive `cfg.num_rounds` rounds; returns the per-round history.

        Rounds start at `self.rounds_done` (0 on a fresh federation), so a
        federation restored from `repro.checkpoint.restore_federation`
        continues with the SAME round indices — and hence the same
        participant draws, codec salts and re-allocation boundaries — as an
        uninterrupted run (bit-exact, regression-tested).

        `obs` opt-in activates a `repro.obs` session for the duration of
        the run (per-round spans, wire-byte counters, a run-level summary
        event); an already-active global session instruments the run the
        same way without passing anything. The history — like params, EF
        and the ledger — is BIT-EXACT with and without obs.

        history keys: round, loss (if eval_fn), wire_bytes, analytic_bytes,
        cum_bytes, participants, stragglers, realloc, rates.
        """
        ctx = obs_lib.use(obs) if obs is not None else contextlib.nullcontext()
        with ctx:
            hist = {k: [] for k in ("round", "loss", "wire_bytes",
                                    "analytic_bytes", "cum_bytes",
                                    "participants", "stragglers", "realloc",
                                    "rates")}
            cum = 0.0
            start = self.rounds_done
            with obs_lib.span("fed.run", rounds=cfg.num_rounds,
                              start=start, backend=self.backend):
                for t in range(start, start + cfg.num_rounds):
                    rec = self.run_round(cfg, t)
                    self.rounds_done = t + 1
                    cum += rec["wire_bytes"]
                    hist["round"].append(t)
                    hist["wire_bytes"].append(rec["wire_bytes"])
                    hist["analytic_bytes"].append(rec["analytic_bytes"])
                    hist["cum_bytes"].append(cum)
                    hist["participants"].append(rec["participants"])
                    hist["stragglers"].append(rec["stragglers"])
                    hist["realloc"].append(rec["realloc"])
                    hist["rates"].append(rec["rates"])
                    if eval_fn is not None:
                        with obs_lib.span("fed.eval", round=t):
                            hist["loss"].append(
                                float(eval_fn(self.server.params)))
            session = obs_lib.get()
            if session is not None:
                session.meta(
                    "fed.run.summary", rounds=cfg.num_rounds,
                    start_round=start, backend=self.backend,
                    clients=self.num_clients,
                    total_wire_bytes=cum,
                    total_analytic_bytes=sum(hist["analytic_bytes"]),
                    stragglers=sum(len(s) for s in hist["stragglers"]),
                    reallocs=sum(bool(r) for r in hist["realloc"]),
                    final_loss=(hist["loss"][-1] if hist["loss"] else None))
        return hist
