"""The federated round driver: participation, stragglers, wire ledger.

`Federation` wires the pieces together: per-client shards + budgets →
registry codecs → jit-compiled client rounds (compiled ONCE per distinct
(codec, client-config) pair and reused across rounds and clients) → server
decode + aggregate. The host loop only does participant sampling, straggler
dropout and the ledger; all numerics run inside jit.

Round lifecycle (README has the diagram):

  1. sample ⌈participation·m⌉ clients (deterministic per (seed, round)),
  2. drop each sampled client as a straggler with prob. `dropout`,
  3. surviving clients run their compiled round fn → payload + new EF state,
  4. ledger records REALIZED payload bytes (codec.wire_bytes) and the
     analytic audit (codec.wire_bits / 8) — equal to the byte for the NDSC
     backend under exact_keep,
  5. server decodes every payload with its client's codec and aggregates.

Dropped/unsampled clients keep their EF memory and PRNG lane untouched —
they never encoded, so there is nothing to feed back (straggler semantics).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import numpy as np

from repro.fed import clients as clients_lib
from repro.fed import server as server_lib


@dataclasses.dataclass(frozen=True)
class FedConfig:
    num_rounds: int = 50
    participation: float = 1.0   # fraction of clients sampled per round
    dropout: float = 0.0         # straggler prob. among the sampled
    weighting: str = "uniform"   # "uniform" | "data_size"
    seed: int = 0

    def __post_init__(self):
        if not 0.0 < self.participation <= 1.0:
            raise ValueError("participation must be in (0, 1]")
        if not 0.0 <= self.dropout < 1.0:
            raise ValueError("dropout must be in [0, 1)")
        if self.weighting not in ("uniform", "data_size"):
            raise ValueError(f"unknown weighting {self.weighting!r}")


class Federation:
    """A client–server simulation over `m = len(datas)` clients.

    codecs / client_cfgs may be a single shared object or one per client
    (heterogeneous budgets). All clients see the same `loss_fn(params,
    batch)`; heterogeneity lives in the data shards and the budgets.
    """

    def __init__(self, loss_fn: Callable, params, datas: Sequence,
                 codecs, client_cfgs=None,
                 server_cfg: server_lib.ServerConfig = None, seed: int = 0):
        m = len(datas)
        self.loss_fn = loss_fn
        self.datas = list(datas)
        self.codecs = (list(codecs) if isinstance(codecs, (list, tuple))
                       else [codecs] * m)
        if client_cfgs is None:
            client_cfgs = clients_lib.ClientConfig()
        self.client_cfgs = (list(client_cfgs)
                            if isinstance(client_cfgs, (list, tuple))
                            else [client_cfgs] * m)
        if len(self.codecs) != m or len(self.client_cfgs) != m:
            raise ValueError("need one codec / client config per client")
        self.server_cfg = server_cfg or server_lib.ServerConfig()
        self.server = server_lib.init_server(params, self.server_cfg, m)
        key = jax.random.key(seed)
        self.states = [
            clients_lib.init_client_state(params, jax.random.fold_in(key, i),
                                          self.client_cfgs[i])
            for i in range(m)]
        self.metas = [c.meta(params) for c in self.codecs]
        # one compiled round fn per distinct (codec, client config)
        self._round_fns: dict = {}
        for i in range(m):
            k = (id(self.codecs[i]), id(self.client_cfgs[i]))
            if k not in self._round_fns:
                self._round_fns[k] = clients_lib.make_client_round(
                    loss_fn, self.codecs[i], self.client_cfgs[i], params)
        self._fn_of = [
            self._round_fns[(id(self.codecs[i]), id(self.client_cfgs[i]))]
            for i in range(m)]

    @property
    def num_clients(self) -> int:
        return len(self.datas)

    # -- one round -----------------------------------------------------------
    def sample_participants(self, cfg: FedConfig, round_idx: int):
        """(participants, stragglers) — deterministic in (seed, round)."""
        m = self.num_clients
        rng = np.random.default_rng(
            np.random.PCG64(cfg.seed * 1_000_003 + round_idx))
        k = max(1, int(np.ceil(cfg.participation * m)))
        sampled = sorted(rng.choice(m, size=k, replace=False).tolist())
        if cfg.dropout <= 0.0:
            return sampled, []
        keep = rng.random(k) >= cfg.dropout
        participants = [c for c, kp in zip(sampled, keep) if kp]
        stragglers = [c for c, kp in zip(sampled, keep) if not kp]
        return participants, stragglers

    def run_round(self, cfg: FedConfig, round_idx: int) -> dict:
        participants, stragglers = self.sample_participants(cfg, round_idx)
        wires = []
        realized = analytic = 0.0
        for i in participants:
            wire, self.states[i] = self._fn_of[i](
                self.server.params, self.datas[i], self.states[i], round_idx)
            wires.append(wire)
            realized += self.codecs[i].wire_bytes(wire, self.metas[i])
            analytic += self.codecs[i].wire_bits(self.server.params) / 8.0
        if participants:
            deltas = server_lib.decode_deltas(
                wires, [self.codecs[i] for i in participants],
                [self.metas[i] for i in participants])
            weights = self._weights(cfg, participants)
            slot_weights = (self._weights(cfg, range(self.num_clients))
                            if (self.server_cfg.aggregator == "fedmem"
                                and cfg.weighting != "uniform") else None)
            self.server = server_lib.aggregate(
                self.server, self.server_cfg, deltas, weights, participants,
                slot_weights=slot_weights)
        return {"round": round_idx, "participants": participants,
                "stragglers": stragglers, "wire_bytes": realized,
                "analytic_bytes": analytic}

    def _weights(self, cfg: FedConfig, participants) -> np.ndarray:
        if cfg.weighting == "data_size":
            return np.array([clients_lib.num_examples(self.datas[i])
                             for i in participants], dtype=np.float64)
        return np.ones(len(participants))

    # -- full run ------------------------------------------------------------
    def run(self, cfg: FedConfig,
            eval_fn: Optional[Callable[[Any], float]] = None) -> dict:
        """Drive `cfg.num_rounds` rounds; returns the per-round history.

        history keys: round, loss (if eval_fn), wire_bytes, analytic_bytes,
        cum_bytes, participants, stragglers.
        """
        hist = {k: [] for k in ("round", "loss", "wire_bytes",
                                "analytic_bytes", "cum_bytes",
                                "participants", "stragglers")}
        cum = 0.0
        for t in range(cfg.num_rounds):
            rec = self.run_round(cfg, t)
            cum += rec["wire_bytes"]
            hist["round"].append(t)
            hist["wire_bytes"].append(rec["wire_bytes"])
            hist["analytic_bytes"].append(rec["analytic_bytes"])
            hist["cum_bytes"].append(cum)
            hist["participants"].append(rec["participants"])
            hist["stragglers"].append(rec["stragglers"])
            if eval_fn is not None:
                hist["loss"].append(float(eval_fn(self.server.params)))
        return hist
