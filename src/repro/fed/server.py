"""Server-side aggregation: decode heterogeneous payloads, update the model.

Clients ship codec payloads with *different* chunk layouts (per-client
budgets R_i map to different bits / keep-fraction configs), so the server
first decodes every payload with that client's codec into a dense f32 delta
tree — that is the reconciliation point — and only then aggregates:

  fedavg   x ← x + η_s · Σ w_i Δ̂_i                   (weighted delta mean)
  fedopt   server optimizer from repro.optimizer on the pseudo-gradient
           g = −Σ w_i Δ̂_i (FedAdam / FedSGD-momentum, delta-compressed)
  fedmem   EF21-style per-client server memory: slot h_i is refreshed by
           every decoded Δ̂_i and the step uses the mean over ALL slots, so
           non-participants contribute their last known update — smoothing
           partial participation instead of amplifying it.

Two aggregation layouts share those semantics:

  * the LIST layout (`aggregate`) — one decoded tree per participant,
    reduced left-to-right by a host loop of `jax.tree.map`s. This is the
    PR-2 reference: O(m·L) eager dispatches per round, the wall-clock bound
    at large m, kept as the bit-exactness oracle.
  * the STACKED layout (`aggregate_stacked`) — every participant's decoded
    delta is lane l of one stacked device tree and the O(m) lane reduction
    (the wall-clock bound) runs as ONE compiled program.
    `ServerConfig.sum_mode` picks the reduction order:

      "sequential"  lanes reduce left-to-right via `lax.fori_loop` — the
                    SAME float summation order as the list reference, so
                    params / fedmem memory stay bit-exact with it
                    (regression-tested) while the per-participant dispatch
                    and transfer overhead disappears;
      "pairwise"    balanced pairwise tree-reduction — faster and with
                    O(log m) rounding depth instead of O(m), but a
                    DIFFERENT summation order: agrees with the reference
                    only to float tolerance (~1e-6 relative), never bitwise.

    The m-independent tail — η_s step, fedopt optimizer update — then
    replays the EXACT eager ops of the list reference (shared helpers, a
    handful of dispatches regardless of m). This split is deliberate: XLA
    contracts a·b+c chains into FMAs inside a fused program (single
    rounding, ±1 ulp vs the reference's separate eager ops, and
    `lax.optimization_barrier` does not stop it on CPU), so the compiled
    region is arranged so every multiply is materialized before its add —
    the weighted lanes are formed first, then folded with pure adds — and
    everything XLA would re-fuse with the optimizer/step arithmetic stays
    in the reference's op-by-op form. That is what makes "sequential"
    bit-exact rather than merely order-preserving.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import core as obs_lib
from repro.obs import recompile as recompile_lib
from repro.optimizer.optim import Optimizer, apply_updates

AGGREGATORS = ("fedavg", "fedopt", "fedmem")
SUM_MODES = ("sequential", "pairwise")


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    aggregator: str = "fedavg"
    server_lr: float = 1.0                  # fedavg / fedmem step size
    optimizer: Optional[Optimizer] = None   # required for fedopt
    sum_mode: str = "sequential"            # stacked-lane reduction order

    def __post_init__(self):
        if self.aggregator not in AGGREGATORS:
            raise ValueError(f"aggregator must be one of {AGGREGATORS}, "
                             f"got {self.aggregator!r}")
        if self.aggregator == "fedopt" and self.optimizer is None:
            raise ValueError("fedopt needs a repro.optimizer Optimizer")
        if self.sum_mode not in SUM_MODES:
            raise ValueError(f"sum_mode must be one of {SUM_MODES}, "
                             f"got {self.sum_mode!r}")


class ServerState(NamedTuple):
    params: Any
    opt_state: Any    # fedopt only, else {}
    memory: Any       # fedmem: per-client slots stacked on axis 0, else {}


def init_server(params, cfg: ServerConfig, num_clients: int) -> ServerState:
    opt_state = (cfg.optimizer.init(params)
                 if cfg.aggregator == "fedopt" else {})
    memory = (jax.tree.map(
        lambda p: jnp.zeros((num_clients,) + tuple(p.shape), jnp.float32),
        params) if cfg.aggregator == "fedmem" else {})
    return ServerState(params=params, opt_state=opt_state, memory=memory)


def decode_deltas(wires: Sequence, codecs: Sequence, metas: Sequence) -> list:
    """Per-client payloads → dense f32 delta trees (the layout reconciliation
    step: after this point budgets, chunk counts and masks are gone)."""
    return [codec.decode(wire, meta)
            for wire, codec, meta in zip(wires, codecs, metas)]


def tree_norm(tree) -> jax.Array:
    """Global ℓ2 norm of one pytree, jit-safe (f32 accumulation, leaf order
    fixed by `jax.tree.leaves`).

    This is what the cohort decode programs emit per lane: the adaptive
    allocator's signal Σ ‖Δ̂_i‖²·4^{−R_i} needs one scalar per participant,
    so the round driver fetches m scalars instead of m decoded trees."""
    sq = jnp.zeros((), jnp.float32)
    for x in jax.tree.leaves(tree):
        sq = sq + jnp.sum(jnp.square(x.astype(jnp.float32)))
    return jnp.sqrt(sq)


stacked_norms = jax.vmap(tree_norm)   # stacked tree → (lanes,) per-lane norms


def delta_norms(deltas: Sequence) -> list:
    """Host-side float64 reference for per-tree ℓ2 norms.

    Superseded in the round driver by the decode-program-emitted
    `tree_norm` lanes (no per-participant host round trips); kept as the
    high-precision oracle the tests compare the device norms against.
    """
    def norm(tree) -> float:
        sq = 0.0
        for x in jax.tree.leaves(tree):
            flat = np.asarray(x, dtype=np.float64).ravel()
            sq += float(flat @ flat)
        return math.sqrt(sq)

    return [norm(d) for d in deltas]


def _check_weights(weights, what: str = "weights") -> None:
    """Weight sums divide the aggregate: a non-positive (or NaN) sum would
    silently poison the params, e.g. `weighting="data_size"` over empty
    shards. Fail loudly instead.

    Individual weights of EXACTLY 0 are allowed — that is the mesh backend's
    padding contract (lanes padding a cohort stack up to the device-axis
    size carry weight 0 and must contribute nothing) — but negative or
    non-finite entries are rejected: they can cancel inside the sum and
    poison the mean while the total still looks sane."""
    w = np.asarray(jax.device_get(weights), np.float64)
    if w.size and (not np.all(np.isfinite(w)) or np.any(w < 0.0)):
        raise ValueError(
            f"{what} must be finite and non-negative with a positive sum "
            f"(exact zeros are allowed, e.g. padding lanes), got {w.tolist()}")
    total = float(np.sum(w))
    if not (total > 0.0 and math.isfinite(total)):
        raise ValueError(
            f"{what} must have a positive finite sum, got {total} — with "
            f'weighting="data_size" this usually means every participating '
            f"shard is empty")


def weighted_mean(deltas: Sequence, weights) -> Any:
    """List-layout reference: Σ w_i Δ̂_i / Σ w_i, reduced left-to-right."""
    _check_weights(weights)
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.sum(w)
    acc = jax.tree.map(lambda x: w[0] * x.astype(jnp.float32), deltas[0])
    for i, d in enumerate(deltas[1:], start=1):
        acc = jax.tree.map(lambda a, x, i=i: a + w[i] * x.astype(jnp.float32),
                           acc, d)
    return acc


def _apply_delta(params, direction, server_lr: float):
    """x ← x + η_s·direction — the ONE shared implementation both layouts
    step through, so the list reference and the stacked path run literally
    the same eager ops (part of the bit-exactness contract)."""
    return jax.tree.map(
        lambda p, d: (p.astype(jnp.float32)
                      + server_lr * d).astype(p.dtype),
        params, direction)


def _fedopt_tail(state: ServerState, cfg: ServerConfig, mean) -> ServerState:
    """Server-optimizer step from the weighted delta mean (shared by both
    layouts; the optimizer update is m-independent, so it stays in the
    reference's eager form — see the module docstring on FMA contraction)."""
    pseudo_grad = jax.tree.map(jnp.negative, mean)
    updates, opt_state = cfg.optimizer.update(
        pseudo_grad, state.opt_state, state.params)
    return ServerState(apply_updates(state.params, updates),
                       opt_state, state.memory)


def aggregate(state: ServerState, cfg: ServerConfig, deltas: Sequence,
              weights, participant_ids: Optional[Sequence[int]] = None,
              slot_weights=None) -> ServerState:
    """One server step from a LIST of decoded participant deltas (the
    sequential reference; large-m rounds use `aggregate_stacked`).

    `participant_ids` (client indices aligned with `deltas`) is only needed
    by fedmem to refresh the right memory slots; `slot_weights` (one per
    client, ALL clients) weights fedmem's mean over the memory slots — the
    fedmem counterpart of `weights`, which covers participants only."""
    if not deltas:
        return state
    if cfg.aggregator == "fedavg":
        mean = weighted_mean(deltas, weights)
        return ServerState(_apply_delta(state.params, mean, cfg.server_lr),
                           state.opt_state, state.memory)

    if cfg.aggregator == "fedopt":
        return _fedopt_tail(state, cfg, weighted_mean(deltas, weights))

    # fedmem: refresh participating slots, step with the mean over ALL slots
    if participant_ids is None:
        raise ValueError("fedmem aggregation needs participant_ids")
    idx = jnp.asarray(list(participant_ids), jnp.int32)
    stacked = jax.tree.map(
        lambda *xs: jnp.stack([x.astype(jnp.float32) for x in xs]), *deltas)
    memory = jax.tree.map(lambda m, d: m.at[idx].set(d),
                          state.memory, stacked)
    if slot_weights is None:
        direction = jax.tree.map(lambda m: jnp.mean(m, axis=0), memory)
    else:
        _check_weights(slot_weights, "slot_weights")
        sw = jnp.asarray(slot_weights, jnp.float32)
        sw = sw / jnp.sum(sw)
        direction = jax.tree.map(
            lambda m: jnp.tensordot(sw, m, axes=1), memory)
    return ServerState(_apply_delta(state.params, direction, cfg.server_lr),
                       state.opt_state, memory)


# ---------------------------------------------------------------------------
# Stacked-layout aggregation — the O(m) reduction as one compiled program
# ---------------------------------------------------------------------------
def _sequential_weighted_sum(stacked, w):
    """Σ w_l · lane_l reduced LEFT-TO-RIGHT — float-op order AND rounding
    identical to `weighted_mean`'s host loop.

    The weighted lanes are materialized first (one broadcast multiply, the
    same per-element rounding as the reference's scalar multiplies) and the
    `fori_loop` body then folds PURE adds: keeping the multiply out of the
    loop body is what stops XLA contracting w_l·x_l + acc into an FMA,
    which would silently break bitwise equality with the reference."""
    lanes = jax.tree.leaves(stacked)[0].shape[0]
    weighted = jax.tree.map(
        lambda x: w.reshape((-1,) + (1,) * (x.ndim - 1))
        * x.astype(jnp.float32), stacked)
    acc = jax.tree.map(lambda x: x[0], weighted)

    def body(i, acc):
        return jax.tree.map(lambda a, x: a + x[i], acc, weighted)

    return jax.lax.fori_loop(1, lanes, body, acc)


def _pairwise_weighted_sum(stacked, w):
    """Σ w_l · lane_l by balanced pairwise folding (O(log m) depth).

    Different summation order than the sequential reference — opted into via
    `sum_mode="pairwise"` for speed/accuracy at large m, documented as equal
    to the reference only to float tolerance."""
    def reduce_leaf(x):
        y = w.reshape((-1,) + (1,) * (x.ndim - 1)) * x.astype(jnp.float32)
        while y.shape[0] > 1:
            even = (y.shape[0] // 2) * 2
            folded = y[0:even:2] + y[1:even:2]
            if even != y.shape[0]:
                folded = jnp.concatenate([folded, y[even:]], axis=0)
            y = folded
        return y[0]

    return jax.tree.map(reduce_leaf, stacked)


@functools.lru_cache(maxsize=None)
def _stacked_mean_fn(sum_mode: str):
    """Compiled `(stacked, w) → Σ (w/Σw)_l · lane_l` — the fedavg/fedopt
    reduction. XLA re-specializes per participant count (the leading axis
    is a static shape), so partial-participation rounds compile once per
    distinct size — same behavior as the cohort client programs."""
    wsum = (_sequential_weighted_sum if sum_mode == "sequential"
            else _pairwise_weighted_sum)
    return recompile_lib.register(
        "fed.aggregate.mean",
        jax.jit(lambda stacked, w: wsum(stacked, w / jnp.sum(w))),
        span="fed.round.aggregate")


@functools.lru_cache(maxsize=None)
def _stacked_memory_fn(has_slot_weights: bool):
    """Compiled fedmem reduction: scatter the stacked lanes into the
    per-client slots and reduce ALL slots to the step direction. The
    scatter is exact and the slot mean / slot-weighted tensordot lower to
    the same reduce ops as the reference's eager calls, so fedmem stays
    bit-exact without a sum_mode distinction (its direction is a reduction
    over the m_total memory slots, not a lane fold)."""
    def fn(memory, stacked, idx, slot_w):
        memory = jax.tree.map(
            lambda m, d: m.at[idx].set(d.astype(jnp.float32)),
            memory, stacked)
        if has_slot_weights:
            sw = slot_w / jnp.sum(slot_w)
            direction = jax.tree.map(
                lambda m: jnp.tensordot(sw, m, axes=1), memory)
        else:
            direction = jax.tree.map(lambda m: jnp.mean(m, axis=0), memory)
        return memory, direction

    return recompile_lib.register("fed.aggregate.memory", jax.jit(fn),
                                  span="fed.round.aggregate")


def aggregate_stacked(state: ServerState, cfg: ServerConfig, stacked,
                      weights,
                      participant_ids: Optional[Sequence[int]] = None,
                      slot_weights=None) -> ServerState:
    """One server step from STACKED decoded deltas (lane l = participant l).

    `stacked` is one device pytree whose leaves carry a leading participant
    axis, in the same order as `weights` / `participant_ids` — exactly what
    the cohort decode programs emit, so deltas never leave the device
    between decode and the params update. Semantics match `aggregate` on
    the unstacked lanes; with `cfg.sum_mode == "sequential"` the match is
    bit-exact (same float summation order and rounding — regression-
    tested), with "pairwise" it holds to float tolerance."""
    lanes = jax.tree.leaves(stacked)[0].shape[0]
    if lanes == 0:
        return state
    if np.asarray(weights).shape[0] != lanes:
        raise ValueError(f"{np.asarray(weights).shape[0]} weights for "
                         f"{lanes} stacked lanes")
    w = jnp.asarray(np.asarray(weights), jnp.float32)

    # weights only divide the fedavg/fedopt mean — fedmem ignores them (its
    # direction comes from the slots), exactly as in the list reference
    if cfg.aggregator == "fedavg":
        _check_weights(weights)
        mean_fn = _stacked_mean_fn(cfg.sum_mode)
        obs_lib.observe_program_call("fed.aggregate.mean", mean_fn,
                                     (stacked, w),
                                     span="fed.round.aggregate")
        mean = mean_fn(stacked, w)
        return ServerState(_apply_delta(state.params, mean, cfg.server_lr),
                           state.opt_state, state.memory)

    if cfg.aggregator == "fedopt":
        _check_weights(weights)
        mean_fn = _stacked_mean_fn(cfg.sum_mode)
        obs_lib.observe_program_call("fed.aggregate.mean", mean_fn,
                                     (stacked, w),
                                     span="fed.round.aggregate")
        return _fedopt_tail(state, cfg, mean_fn(stacked, w))

    if participant_ids is None:
        raise ValueError("fedmem aggregation needs participant_ids")
    idx = jnp.asarray(list(participant_ids), jnp.int32)
    if slot_weights is not None:
        _check_weights(slot_weights, "slot_weights")
        slot_w = jnp.asarray(np.asarray(slot_weights), jnp.float32)
    else:
        slot_w = jnp.zeros((0,), jnp.float32)
    mem_fn = _stacked_memory_fn(slot_weights is not None)
    obs_lib.observe_program_call("fed.aggregate.memory", mem_fn,
                                 (state.memory, stacked, idx, slot_w),
                                 span="fed.round.aggregate")
    memory, direction = mem_fn(state.memory, stacked, idx, slot_w)
    return ServerState(_apply_delta(state.params, direction, cfg.server_lr),
                       state.opt_state, memory)
