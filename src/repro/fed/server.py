"""Server-side aggregation: decode heterogeneous payloads, update the model.

Clients ship codec payloads with *different* chunk layouts (per-client
budgets R_i map to different bits / keep-fraction configs), so the server
first decodes every payload with that client's codec into a dense f32 delta
tree — that is the reconciliation point — and only then aggregates:

  fedavg   x ← x + η_s · Σ w_i Δ̂_i                   (weighted delta mean)
  fedopt   server optimizer from repro.optimizer on the pseudo-gradient
           g = −Σ w_i Δ̂_i (FedAdam / FedSGD-momentum, delta-compressed)
  fedmem   EF21-style per-client server memory: slot h_i is refreshed by
           every decoded Δ̂_i and the step uses the mean over ALL slots, so
           non-participants contribute their last known update — smoothing
           partial participation instead of amplifying it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.optimizer.optim import Optimizer, apply_updates

AGGREGATORS = ("fedavg", "fedopt", "fedmem")


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    aggregator: str = "fedavg"
    server_lr: float = 1.0                  # fedavg / fedmem step size
    optimizer: Optional[Optimizer] = None   # required for fedopt

    def __post_init__(self):
        if self.aggregator not in AGGREGATORS:
            raise ValueError(f"aggregator must be one of {AGGREGATORS}, "
                             f"got {self.aggregator!r}")
        if self.aggregator == "fedopt" and self.optimizer is None:
            raise ValueError("fedopt needs a repro.optimizer Optimizer")


class ServerState(NamedTuple):
    params: Any
    opt_state: Any    # fedopt only, else {}
    memory: Any       # fedmem: per-client slots stacked on axis 0, else {}


def init_server(params, cfg: ServerConfig, num_clients: int) -> ServerState:
    opt_state = (cfg.optimizer.init(params)
                 if cfg.aggregator == "fedopt" else {})
    memory = (jax.tree.map(
        lambda p: jnp.zeros((num_clients,) + tuple(p.shape), jnp.float32),
        params) if cfg.aggregator == "fedmem" else {})
    return ServerState(params=params, opt_state=opt_state, memory=memory)


def decode_deltas(wires: Sequence, codecs: Sequence, metas: Sequence) -> list:
    """Per-client payloads → dense f32 delta trees (the layout reconciliation
    step: after this point budgets, chunk counts and masks are gone)."""
    return [codec.decode(wire, meta)
            for wire, codec, meta in zip(wires, codecs, metas)]


def delta_norms(deltas: Sequence) -> list:
    """Global ℓ2 norm ‖Δ̂_i‖ of each decoded delta tree.

    This is the free signal the adaptive allocator runs on: the server
    already decoded every participant's payload, so tracking the norms costs
    no communication — exactly the quantity the distortion model
    Σ ‖Δ_i‖²·4^{−R_i} in `repro.fed.budget` wants.
    """
    def norm(tree) -> float:
        # host-side numpy: cohort-path deltas are already fetched numpy
        # arrays, and per-leaf device round-trips would cost a blocking
        # sync per participant per round
        sq = 0.0
        for x in jax.tree.leaves(tree):
            flat = np.asarray(x, dtype=np.float64).ravel()
            sq += float(flat @ flat)
        return math.sqrt(sq)

    return [norm(d) for d in deltas]


def weighted_mean(deltas: Sequence, weights) -> Any:
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.sum(w)
    acc = jax.tree.map(lambda x: w[0] * x.astype(jnp.float32), deltas[0])
    for i, d in enumerate(deltas[1:], start=1):
        acc = jax.tree.map(lambda a, x, i=i: a + w[i] * x.astype(jnp.float32),
                           acc, d)
    return acc


def aggregate(state: ServerState, cfg: ServerConfig, deltas: Sequence,
              weights, participant_ids: Optional[Sequence[int]] = None,
              slot_weights=None) -> ServerState:
    """One server step from the decoded participant deltas.

    `participant_ids` (client indices aligned with `deltas`) is only needed
    by fedmem to refresh the right memory slots; `slot_weights` (one per
    client, ALL clients) weights fedmem's mean over the memory slots — the
    fedmem counterpart of `weights`, which covers participants only."""
    if not deltas:
        return state
    if cfg.aggregator == "fedavg":
        mean = weighted_mean(deltas, weights)
        params = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32)
                          + cfg.server_lr * d).astype(p.dtype),
            state.params, mean)
        return ServerState(params, state.opt_state, state.memory)

    if cfg.aggregator == "fedopt":
        mean = weighted_mean(deltas, weights)
        pseudo_grad = jax.tree.map(jnp.negative, mean)
        updates, opt_state = cfg.optimizer.update(
            pseudo_grad, state.opt_state, state.params)
        return ServerState(apply_updates(state.params, updates),
                           opt_state, state.memory)

    # fedmem: refresh participating slots, step with the mean over ALL slots
    if participant_ids is None:
        raise ValueError("fedmem aggregation needs participant_ids")
    idx = jnp.asarray(list(participant_ids), jnp.int32)
    stacked = jax.tree.map(
        lambda *xs: jnp.stack([x.astype(jnp.float32) for x in xs]), *deltas)
    memory = jax.tree.map(lambda m, d: m.at[idx].set(d),
                          state.memory, stacked)
    if slot_weights is None:
        direction = jax.tree.map(lambda m: jnp.mean(m, axis=0), memory)
    else:
        sw = jnp.asarray(slot_weights, jnp.float32)
        sw = sw / jnp.sum(sw)
        direction = jax.tree.map(
            lambda m: jnp.tensordot(sw, m, axes=1), memory)
    params = jax.tree.map(
        lambda p, d: (p.astype(jnp.float32)
                      + cfg.server_lr * d).astype(p.dtype),
        state.params, direction)
    return ServerState(params, state.opt_state, memory)
