"""Rate-allocation policies: split a global bit budget across clients / leaves.

The paper's codec is optimal for *arbitrary* per-dimension budgets
R ∈ (0, ∞); in the client–server regime the interesting question becomes how
to SPLIT a global per-round budget across heterogeneous clients. With the
NDSC chunked codec the per-client distortion behaves like

    E‖Δ_i − D(E(Δ_i))‖² ≈ ‖Δ_i‖² · 4^{−R_i}            (Thm. 1: error ∝ 2^{−R})

so for a fixed total Σ R_i the aggregate distortion Σ ‖Δ_i‖²·4^{−R_i} is
minimized by water-filling in the log domain — clients with larger update
norms get more bits. Three policies:

  uniform            R_i = R_total / m                 (the homogeneous baseline)
  norm_proportional  R_i ∝ ‖Δ_i‖ (clipped + renormalized to conserve R_total)
  waterfill          greedy ΔR increments to argmax_i ‖Δ_i‖²·4^{−R_i}
                     (exactly minimizes the distortion model above)

All policies conserve the total budget to float precision and respect
[min_rate, max_rate] per-client bounds. `repro.codecs` turns each R_i
into a concrete `GradCompConfig` whose `effective_bits` equals R_i — that
property is the audit unit tying the allocation to the bytes on the wire.

`split_leaf_budgets` applies the same machinery WITHIN one client across the
pytree leaves (cost of a bit differs per leaf: size_l bits buy 1 bit/dim).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
import numpy as np

POLICIES = ("uniform", "norm_proportional", "waterfill")

# greedy water-filling granularity: bits added per increment
_QUANTUM = 1.0 / 64.0


def expected_distortion(norms: Sequence[float],
                        rates: Sequence[float]) -> float:
    """Σ ‖Δ_i‖²·4^{−R_i} — the distortion model the policies optimize."""
    norms = np.asarray(norms, dtype=np.float64)
    rates = np.asarray(rates, dtype=np.float64)
    return float(np.sum(norms ** 2 * 4.0 ** (-rates)))


def allocate(policy: str, total_rate: float, num_clients: int,
             norms: Optional[Sequence[float]] = None,
             min_rate: float = 0.125, max_rate: float = 8.0) -> np.ndarray:
    """Per-client budgets R_i (bits per model dimension), Σ R_i = total_rate.

    `total_rate` is the global per-round budget expressed in bits per model
    dimension summed over clients (total wire bits / model dim); `norms` are
    the (estimated) per-client update norms ‖Δ_i‖ — required by the two
    heterogeneous policies, ignored by `uniform`.
    """
    if policy not in POLICIES:
        raise ValueError(f"policy must be one of {POLICIES}, got {policy!r}")
    m = num_clients
    if m <= 0:
        raise ValueError("num_clients must be positive")
    if not min_rate * m <= total_rate <= max_rate * m:
        raise ValueError(
            f"total_rate={total_rate} outside feasible "
            f"[{min_rate * m}, {max_rate * m}] for m={m} clients")
    if policy == "uniform":
        return np.full(m, total_rate / m)
    if norms is None or len(norms) != m:
        raise ValueError(f"policy {policy!r} needs one norm per client")
    norms = np.maximum(np.asarray(norms, dtype=np.float64), 1e-30)
    if policy == "norm_proportional":
        return _clip_renormalize(total_rate * norms / norms.sum(),
                                 total_rate, min_rate, max_rate)
    return _waterfill(total_rate, norms, min_rate, max_rate)


def _clip_renormalize(rates: np.ndarray, total: float, lo: float,
                      hi: float) -> np.ndarray:
    """Clamp to [lo, hi] and redistribute the imbalance among unclamped
    clients proportionally, preserving Σ R_i = total."""
    rates = rates.copy()
    for _ in range(50):
        clipped = np.clip(rates, lo, hi)
        slack = total - clipped.sum()
        if abs(slack) < 1e-12:
            return clipped
        free = ((clipped > lo) | (slack > 0)) & ((clipped < hi) | (slack < 0))
        if not free.any():
            return clipped
        rates = clipped
        rates[free] += slack * (clipped[free] / max(clipped[free].sum(), 1e-30))
    return np.clip(rates, lo, hi)


def _waterfill(total: float, norms: np.ndarray, lo: float,
               hi: float) -> np.ndarray:
    """Greedy exact water-filling on D(R) = Σ n_i²·4^{−R_i}.

    Marginal gain of a ΔR increment to client i is n_i²·4^{−R_i}(1 − 4^{−ΔR})
    — so each increment goes to argmax n_i²·4^{−R_i}. At convergence the
    marginals equalize for every client strictly inside the bounds.
    """
    m = norms.shape[0]
    rates = np.full(m, lo)
    remaining = total - rates.sum()
    marginal = norms ** 2 * 4.0 ** (-rates)
    capped = rates >= hi - 1e-12
    while remaining > 1e-9 and not capped.all():
        i = int(np.argmax(np.where(capped, -np.inf, marginal)))
        # never step past the per-client cap or the remaining budget
        step = min(_QUANTUM, remaining, hi - rates[i])
        rates[i] += step
        remaining -= step
        marginal[i] *= 4.0 ** (-step)
        capped[i] = rates[i] >= hi - 1e-12
    return rates


# ---------------------------------------------------------------------------
# Adaptive re-allocation — track the CURRENT gradient geometry, not x₀'s
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Re-run the allocator every `realloc_every` rounds from the server-side
    EMA of decoded delta norms (no extra communication — the server already
    decodes every payload).

    `grid` snaps the re-allocated rates to a lattice and `hysteresis` keeps
    the previous allocation unless some client's rate moved by at least that
    much — together they stop cohort keys (and hence compiled vmapped
    programs) churning every re-allocation while the geometry drifts slowly.
    """

    total_rate: float
    policy: str = "waterfill"
    realloc_every: int = 10
    ema_beta: float = 0.6        # n ← β·n + (1−β)·‖Δ̂‖ per participated round
    hysteresis: float = 0.25     # adopt only if max_i |new_i − cur_i| ≥ this
    grid: float = 0.25           # rate lattice (re-allocated R_i are multiples)
    min_rate: float = 0.25
    max_rate: float = 8.0

    def __post_init__(self):
        if self.realloc_every < 1:
            raise ValueError("realloc_every must be ≥ 1")
        if self.grid <= 0.0:
            raise ValueError("grid must be positive")
        if not 0.0 <= self.ema_beta < 1.0:
            raise ValueError("ema_beta must be in [0, 1)")


class NormEMA:
    """Host-side EMA of per-client decoded delta norms ‖Δ̂_i‖.

    Clients that never participated yet fall back to the mean of the seen
    ones (or 1.0 before any round), so the allocator always gets a full norm
    vector. The first observation initializes the lane (no zero-bias)."""

    def __init__(self, num_clients: int, beta: float = 0.6):
        self.beta = beta
        self.norms = np.zeros(num_clients, dtype=np.float64)
        self.seen = np.zeros(num_clients, dtype=bool)

    def update(self, ids: Sequence[int], norms: Sequence[float]) -> None:
        """One vectorized scatter per round (`ids` are distinct participant
        indices, so the fancy-indexed write never collides) — the per-lane
        norms arrive as one device fetch of m scalars, and this keeps the
        host side O(1) numpy calls rather than an O(m) Python loop."""
        idx = np.asarray(list(ids), dtype=np.intp)
        if idx.size == 0:
            return
        vals = np.asarray(list(norms), dtype=np.float64)
        blended = self.beta * self.norms[idx] + (1.0 - self.beta) * vals
        self.norms[idx] = np.where(self.seen[idx], blended, vals)
        self.seen[idx] = True

    def snapshot(self) -> np.ndarray:
        out = self.norms.copy()
        fill = float(out[self.seen].mean()) if self.seen.any() else 1.0
        out[~self.seen] = fill
        return np.maximum(out, 1e-30)


def quantize_rates(rates: Sequence[float], grid: float, total: float,
                   min_rate: float, max_rate: float) -> np.ndarray:
    """Snap rates to the `grid` lattice, conserving Σ R_i to within grid/2.

    Floor-snap each rate to the lattice (clipped into the feasible lattice
    band), then hand out the remaining whole grid steps by largest fractional
    remainder — deterministic, and every output is a lattice point so equal
    allocations compare exactly across re-allocations (stable cohort keys).
    """
    rates = np.asarray(rates, dtype=np.float64)
    lo = math.ceil(min_rate / grid - 1e-9) * grid
    hi = math.floor(max_rate / grid + 1e-9) * grid
    if lo > hi:
        raise ValueError(f"no lattice point of grid={grid} inside "
                         f"[{min_rate}, {max_rate}]")
    base = np.clip(np.floor(rates / grid + 1e-9), lo / grid, hi / grid)
    units = int(round(total / grid)) - int(base.sum())
    frac = rates / grid - base
    order = np.argsort(-frac, kind="stable")
    step = 1 if units > 0 else -1
    bound = hi / grid if units > 0 else lo / grid
    for _ in range(abs(units)):
        movable = [i for i in (order if units > 0 else order[::-1])
                   if base[i] * step < bound * step]
        if not movable:
            break
        i = movable[0]
        base[i] += step
        frac[i] -= step
        order = np.argsort(-frac, kind="stable")
    return base * grid


def reallocate(cfg: AdaptiveConfig, ema: NormEMA,
               current: Sequence[float]) -> tuple[np.ndarray, bool]:
    """One adaptive step: (rates to use next, whether they changed).

    Runs `allocate(cfg.policy)` on the EMA norms, snaps to the lattice, and
    applies the hysteresis guard: the current allocation is kept unless some
    client's snapped rate moved by ≥ cfg.hysteresis.
    """
    current = np.asarray(current, dtype=np.float64)
    raw = allocate(cfg.policy, cfg.total_rate, current.shape[0],
                   norms=ema.snapshot(), min_rate=cfg.min_rate,
                   max_rate=cfg.max_rate)
    new = quantize_rates(raw, cfg.grid, cfg.total_rate,
                         cfg.min_rate, cfg.max_rate)
    if float(np.max(np.abs(new - current))) < cfg.hysteresis:
        return current, False
    return new, True


def split_leaf_budgets(tree, rate: float,
                       norms: Optional[Sequence[float]] = None,
                       policy: str = "waterfill",
                       min_rate: float = 0.125,
                       max_rate: float = 8.0) -> list:
    """Split ONE client's per-dim budget across its pytree leaves.

    A bit/dim for leaf l costs size_l wire bits, so the greedy criterion
    becomes marginal distortion reduction per wire bit: n_l²·4^{−R_l}/size_l.
    Returns one R_l per leaf (flatten order) with Σ size_l·R_l = rate·Σ size_l
    conserved to the granularity of the greedy quantum.
    """
    leaves = jax.tree.leaves(tree)
    sizes = np.array([int(np.prod(x.shape)) if x.shape else 1 for x in leaves],
                     dtype=np.float64)
    if not min_rate <= rate <= max_rate:
        raise ValueError(
            f"rate={rate} outside the feasible [{min_rate}, {max_rate}] "
            f"per-leaf bounds (every leaf is floored at min_rate)")
    if policy == "uniform" or len(leaves) == 1:
        return [rate] * len(leaves)
    if norms is None:
        raise ValueError(f"policy {policy!r} needs one norm per leaf")
    norms = np.maximum(np.asarray(norms, dtype=np.float64), 1e-30)
    total_bits = rate * sizes.sum()
    rates = np.full(len(leaves), min_rate)
    budget = total_bits - (rates * sizes).sum()
    marginal = norms ** 2 * 4.0 ** (-rates) / sizes
    capped = rates >= max_rate
    while budget > 0 and not capped.all():
        i = int(np.argmax(np.where(capped, -np.inf, marginal)))
        step = min(_QUANTUM, budget / sizes[i], max_rate - rates[i])
        if step <= 0:
            break
        rates[i] += step
        budget -= step * sizes[i]
        marginal[i] *= 4.0 ** (-step)
        capped[i] = rates[i] >= max_rate - 1e-12
    return [float(r) for r in rates]
