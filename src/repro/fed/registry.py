"""Deprecated shim — the codec registry moved to `repro.codecs`.

The unified compressor registry grew out of the fed engine but is consumed
by the dist consensus step, the benchmarks and the figure scripts alike, so
it was promoted to its own package:

    repro.fed.registry.make(...)   ->   repro.codecs.make(...)
    repro.fed.registry.<anything>  ->   repro.codecs.registry.<anything>

This module stays importable (warning-free — CI guards that) for one
release so existing imports keep working; only calling `make()` through it
emits a DeprecationWarning. Everything else re-exports the real thing, so
`from repro.fed.registry import TreeCodec, codec_spec, ...` is identical to
importing from `repro.codecs`.
"""
from __future__ import annotations

import warnings

from repro.codecs import registry as _registry
from repro.codecs.base import (TreeCodec, TreeMeta, _total_dims,  # noqa: F401
                               _tree_meta)
from repro.codecs.registry import (_REGISTRY, _UNSET,  # noqa: F401
                                   available, codec_spec,
                                   gradcomp_config_for_budget, register)


def make(name, budget=_UNSET, **kwargs) -> TreeCodec:
    """Deprecated alias of `repro.codecs.make` (see module docstring)."""
    warnings.warn(
        "repro.fed.registry has moved to repro.codecs; call "
        "repro.codecs.make(...) (the repro.fed.registry path will be "
        "removed after one release)", DeprecationWarning, stacklevel=2)
    if budget is _UNSET:
        return _registry.make(name, **kwargs)
    return _registry.make(name, budget, **kwargs)
