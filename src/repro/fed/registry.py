"""Unified tree-compressor registry: one call convention for every codec.

The repo grew three incompatible compressor call conventions:

  * `core.baselines.Compressor`   — (key, y) -> y_hat roundtrips + analytic
                                    `wire_bits(n)` (simulation-only wire),
  * `core.coding.Codec`           — frame-bound (encode, decode) pairs with a
                                    `Payload` wire format,
  * `repro.dist.gradcomp`         — the chunked NDSC codec with packed int32
                                    words and the `wire_bytes_tree` audit.

This module wraps all three behind one `TreeCodec` interface so the fed
engine, the dist consensus benchmarks and the figure scripts stop
hand-rolling adapters:

    codec = registry.make("ndsc", budget=1.5, chunk=128)
    wire  = codec.encode(key, tree, round_idx)        # jit-safe pytree
    meta  = codec.meta(tree)                          # static, host-side
    tree' = codec.decode(wire, meta)                  # jit-safe
    bits  = codec.wire_bits(tree)                     # analytic audit
    bytes = codec.wire_bytes(wire, meta)              # realized ledger entry

Budgets are bits per ORIGINAL model dimension. For the NDSC backend the
budget maps onto `GradCompConfig` so that `effective_bits == budget` exactly
(bits ∈ {1,2,4,8} plus a fractional chunk keep rate with `exact_keep`), which
makes the realized ledger match the analytic audit to the byte. A budget may
also be a per-leaf sequence (see `repro.fed.budget.split_leaf_budgets`).
"""
from __future__ import annotations

import dataclasses
import inspect
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as B
from repro.core import frames as frames_lib
from repro.core.coding import Codec, CodecConfig
from repro.dist import gradcomp as G


class TreeMeta:
    """Static decode-side metadata for one tree template."""

    def __init__(self, treedef, infos, extra=None):
        self.treedef = treedef
        self.infos = infos            # [(size, shape, dtype), ...]
        self.extra = extra            # backend-specific (e.g. per-leaf cfgs)


@dataclasses.dataclass(frozen=True)
class TreeCodec:
    """The unified `(key, tree, budget) -> (payload, bits)` convention."""

    name: str
    encode: Callable      # (key, tree, round_idx=0) -> wire pytree (jit-safe)
    decode: Callable      # (wire, meta) -> tree (jit-safe)
    meta: Callable        # (tree template) -> TreeMeta (host-side, static)
    wire_bits: Callable   # (tree template) -> float — analytic audit
    wire_bytes: Callable  # (wire, meta) -> float — realized ledger entry
    rate: Optional[float] = None   # effective bits/dim when well-defined
    sim_only: bool = False         # True: `wire` is the decoded tree itself
    spec: Optional[tuple] = None   # hashable identity: equal specs ⇒ the
                                   # codecs are interchangeable (same factory,
                                   # budget and kwargs) — the cohort-key unit
    encode_ef: Optional[Callable] = None
    # (key, tree, meta, round_idx=0) -> (wire, residual tree). Fused
    # encode + error-feedback residual u − D(E(u)): same wire as `encode`
    # under the same key, residual emitted without a separate decode pass
    # (on TPU, without the decoded f32 tree round-tripping HBM). Backends
    # without a fused path leave this None and the fed engine composes
    # decode(encode(u)) itself.

    def compress(self, key, tree, round_idx=0):
        """One-shot (payload, analytic bits) — the ISSUE's convenience form."""
        return self.encode(key, tree, round_idx), self.wire_bits(tree)


_REGISTRY: dict[str, Callable] = {}


def register(name: str):
    def deco(factory):
        _REGISTRY[name] = factory
        return factory
    return deco


def available() -> tuple:
    return tuple(sorted(_REGISTRY))


def codec_spec(name: str, budget, kwargs: dict) -> tuple:
    """The hashable identity of a `make` call.

    Two codecs with equal specs encode/decode identically (factories are
    deterministic in (name, budget, kwargs) — frames and keep-masks derive
    from the seed, never from object identity), so `repro.fed.rounds` uses
    the spec as its cohort key and shares one compiled vmapped program among
    all clients whose codecs compare equal.

    The kwargs are CANONICALIZED against the factory signature before they
    enter the spec: `make("ndsc", 1.5)` and `make("ndsc", 1.5, chunk=128)`
    build identical codecs, so they must land in one cohort — leaving the
    caller's kwargs raw would split that cohort in two and compile every
    vmapped round/decode program twice. Keywords a factory swallows through
    `**_` stay as written (they don't have defaults to bind)."""
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown compressor {name!r}; available: {available()}")
    sig = inspect.signature(_REGISTRY[name])
    params = list(sig.parameters.values())
    bound = sig.bind(budget, **kwargs)
    bound.apply_defaults()
    budget_val = bound.arguments[params[0].name]
    items: dict = {}
    for p in params[1:]:
        if p.kind is inspect.Parameter.VAR_KEYWORD:
            items.update(bound.arguments.get(p.name, {}))
        else:
            items[p.name] = bound.arguments[p.name]
    budget_key = (float(budget_val) if np.isscalar(budget_val)
                  else tuple(float(b) for b in budget_val))
    return (name, budget_key, tuple(sorted(items.items())))


_UNSET = object()


def make(name, budget=_UNSET, **kwargs) -> TreeCodec:
    """Instantiate a registered compressor at a bits-per-dimension budget.

    Two call forms:

      make("ndsc", 1.5, chunk=64)        # name + budget + kwargs
      make(spec)                         # the canonical spec tuple

    where `spec` is the hashable identity produced by `codec_spec(...)` (and
    carried on every codec as `TreeCodec.spec`):

      (name, budget, kwargs_items)
        name          registered factory name, e.g. "ndsc"
        budget        float bits/dim, or a tuple of per-leaf floats
        kwargs_items  sorted ((key, value), ...) of the factory kwargs,
                      canonicalized against the factory signature

    The forms round-trip by spec equality — `make(c.spec).spec == c.spec`
    for every codec `c` — so checkpoints, benchmarks and cohort keys can
    rebuild a codec from its spec alone, without re-plumbing the original
    kwargs. The spec form takes no extra arguments (they are already baked
    into the tuple)."""
    if isinstance(name, (tuple, list)):
        if budget is not _UNSET or kwargs:
            raise ValueError("make(spec) takes no extra arguments: the "
                             "budget and kwargs are part of the spec")
        try:
            name, budget, items = name
            kwargs = dict(items)
        except (TypeError, ValueError):
            raise ValueError(f"malformed codec spec {name!r}; expected "
                             "(name, budget, kwargs_items) from codec_spec")
        if isinstance(budget, tuple):       # per-leaf budgets
            budget = list(budget)
    elif budget is _UNSET:
        budget = 4.0
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown compressor {name!r}; available: {available()}")
    codec = _REGISTRY[name](budget, **kwargs)
    return dataclasses.replace(codec, spec=codec_spec(name, budget, kwargs))


def _tree_meta(tree) -> tuple:
    leaves, treedef = jax.tree.flatten(tree)
    return treedef, [(int(np.prod(x.shape)) if x.shape else 1,
                      tuple(x.shape), x.dtype) for x in leaves]


def _total_dims(tree) -> int:
    return sum(int(np.prod(x.shape)) if x.shape else 1
               for x in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# identity — the no-compression reference (f32 wire)
# ---------------------------------------------------------------------------
@register("identity")
def _identity(budget: float = 32.0, **_) -> TreeCodec:
    def encode(key, tree, round_idx=0):
        return jax.tree.map(lambda x: x.astype(jnp.float32), tree)

    def decode(wire, meta):
        return jax.tree.map(
            lambda x, info: x.astype(info[2]), wire,
            jax.tree.unflatten(meta.treedef, meta.infos))

    def meta(tree):
        treedef, infos = _tree_meta(tree)
        return TreeMeta(treedef, infos)

    return TreeCodec(
        "identity", encode, decode, meta,
        wire_bits=lambda tree: 32.0 * _total_dims(tree),
        wire_bytes=lambda wire, meta: 4.0 * sum(i[0] for i in meta.infos),
        rate=32.0)


# ---------------------------------------------------------------------------
# ndsc — the chunked Hadamard-frame codec from repro.dist.gradcomp
# ---------------------------------------------------------------------------
def gradcomp_config_for_budget(budget: float, chunk: int = 128,
                               dithered: bool = False, exact_keep: bool = True,
                               seed: int = 0) -> G.GradCompConfig:
    """Map a fractional bits/dim budget onto a GradCompConfig with
    `effective_bits == budget`: the smallest packable word size that covers
    the budget, with a chunk keep-fraction making up the fractional part."""
    if not 0.0 < budget <= 8.0:
        raise ValueError(f"ndsc budget must be in (0, 8], got {budget}")
    bits = next(b for b in (1, 2, 4, 8) if b >= budget)
    return G.GradCompConfig(
        bits=bits, chunk=chunk, keep_fraction=min(budget / bits, 1.0),
        exact_keep=exact_keep, dithered=dithered,
        error_feedback=not dithered, seed=seed)


@register("ndsc")
def _ndsc(budget, *, chunk: int = 128, dithered: bool = False,
          exact_keep: bool = True, seed: int = 0) -> TreeCodec:
    scalar = np.isscalar(budget)

    def cfgs_for(n_leaves: int) -> list:
        budgets = [budget] * n_leaves if scalar else list(budget)
        if len(budgets) != n_leaves:
            raise ValueError(f"{len(budgets)} per-leaf budgets for "
                             f"{n_leaves} leaves")
        return [gradcomp_config_for_budget(b, chunk, dithered, exact_keep,
                                           seed) for b in budgets]

    def encode(key, tree, round_idx=0):
        leaves, treedef = jax.tree.flatten(tree)
        cfgs = cfgs_for(len(leaves))
        payloads = [
            G.encode_leaf(x, i, c, round_idx,
                          key=jax.random.fold_in(key, i))
            for i, (x, c) in enumerate(zip(leaves, cfgs))]
        return jax.tree.unflatten(treedef, payloads)

    def encode_ef(key, tree, meta, round_idx=0):
        leaves = meta.treedef.flatten_up_to(tree)
        pairs = [
            G.encode_leaf_ef(x, i, c, round_idx,
                             key=jax.random.fold_in(key, i),
                             residual_dtype=info[2])
            for i, (x, c, info) in
            enumerate(zip(leaves, meta.extra, meta.infos))]
        wire = jax.tree.unflatten(meta.treedef, [p for p, _ in pairs])
        resid = jax.tree.unflatten(meta.treedef, [r for _, r in pairs])
        return wire, resid

    def meta(tree):
        treedef, infos = _tree_meta(tree)
        return TreeMeta(treedef, infos, extra=cfgs_for(len(infos)))

    def decode(wire, meta):
        plist = meta.treedef.flatten_up_to(wire)
        outs = [G.decode_leaf(p, i, size, shape, dtype, c)
                for i, (p, (size, shape, dtype), c) in
                enumerate(zip(plist, meta.infos, meta.extra))]
        return jax.tree.unflatten(meta.treedef, outs)

    def wire_bits(tree):
        leaves, _ = jax.tree.flatten(tree)
        cfgs = cfgs_for(len(leaves))
        return sum(
            G.wire_bytes_tree(x, c)["payload_bytes"] * 8.0
            for x, c in zip(leaves, cfgs))

    def wire_bytes(wire, meta):
        plist = meta.treedef.flatten_up_to(wire)
        return sum(G.wire_bytes_payload(p, c)
                   for p, c in zip(plist, meta.extra))

    tag = (f"ndsc(R={budget:g})" if scalar
           else f"ndsc(R per leaf={[round(float(b), 3) for b in budget]})")
    return TreeCodec(tag, encode, decode, meta, wire_bits, wire_bytes,
                     rate=(gradcomp_config_for_budget(
                         budget, chunk).effective_bits if scalar else None),
                     encode_ef=encode_ef)


# ---------------------------------------------------------------------------
# dsc — the dense frame Codec from core.coding (per-leaf Hadamard frames)
# ---------------------------------------------------------------------------
@register("dsc")
def _dsc(budget, *, dithered: bool = False, embedding: str = "near_democratic",
         seed: int = 0) -> TreeCodec:
    from repro.core.embeddings import EmbeddingSpec
    codec_cache: dict = {}

    def codec_for(leaf_idx: int, n: int) -> Codec:
        k = (leaf_idx, n)
        if k not in codec_cache:
            key = jax.random.fold_in(jax.random.key(seed), leaf_idx)
            frame = frames_lib.hadamard_frame(key, n)
            codec_cache[k] = Codec(frame, CodecConfig(
                bits_per_dim=float(budget), dithered=dithered,
                embedding=EmbeddingSpec(kind=embedding)))
        return codec_cache[k]

    def encode(key, tree, round_idx=0):
        leaves, treedef = jax.tree.flatten(tree)
        outs = []
        for i, x in enumerate(leaves):
            c = codec_for(i, int(np.prod(x.shape)) if x.shape else 1)
            kk = jax.random.fold_in(jax.random.fold_in(key, i), round_idx)
            p = c.encode(x.astype(jnp.float32).reshape(-1), kk)
            outs.append({"indices": p.indices, "scale": p.scale}
                        | ({"mask": p.mask} if p.mask is not None else {}))
        return jax.tree.unflatten(treedef, outs)

    def meta(tree):
        treedef, infos = _tree_meta(tree)
        return TreeMeta(treedef, infos)

    def decode(wire, meta):
        from repro.core.coding import Payload
        plist = meta.treedef.flatten_up_to(wire)
        outs = []
        for i, (p, (size, shape, dtype)) in enumerate(
                zip(plist, meta.infos)):
            c = codec_for(i, size)
            y = c.decode(Payload(p["indices"], p["scale"], p.get("mask")))
            outs.append(y.reshape(shape).astype(dtype))
        return jax.tree.unflatten(meta.treedef, outs)

    def wire_bits(tree):
        leaves, _ = jax.tree.flatten(tree)
        return sum(
            codec_for(i, int(np.prod(x.shape)) if x.shape else 1).wire_bits()
            + 32.0 for i, x in enumerate(leaves))

    def wire_bytes(wire, meta):
        total = 0.0
        for i, (p, (size, _, _)) in enumerate(
                zip(meta.treedef.flatten_up_to(wire), meta.infos)):
            c = codec_for(i, size)
            per_idx = 1.0 if c.sublinear else math.log2(c.levels)
            if "mask" in p:
                # the keep mask is NOT charged: it comes from the shared
                # PRNG key, so the decoder regenerates it (same convention
                # as Codec.wire_bits, which counts kept coordinates only)
                total += float(jnp.sum(p["mask"])) * per_idx / 8.0 + 4.0
                continue
            total += (c.N * per_idx) / 8.0 + 4.0
        return total

    return TreeCodec(f"dsc(R={budget:g})", encode, decode, meta,
                     wire_bits, wire_bytes, rate=float(budget))


# ---------------------------------------------------------------------------
# core.baselines wrappers — simulation-only wire (the decoded tree itself)
# ---------------------------------------------------------------------------
def _wrap_baseline(comp: B.Compressor):
    def encode(key, tree, round_idx=0):
        leaves, treedef = jax.tree.flatten(tree)
        outs = []
        for i, x in enumerate(leaves):
            kk = jax.random.fold_in(jax.random.fold_in(key, i), round_idx)
            flat = x.astype(jnp.float32).reshape(-1)
            outs.append(comp.roundtrip(kk, flat))
        return jax.tree.unflatten(treedef, outs)

    def meta(tree):
        treedef, infos = _tree_meta(tree)
        return TreeMeta(treedef, infos)

    def decode(wire, meta):
        return jax.tree.unflatten(meta.treedef, [
            y.reshape(shape).astype(dtype)
            for y, (_, shape, dtype) in
            zip(meta.treedef.flatten_up_to(wire), meta.infos)])

    def wire_bits(tree):
        return sum(comp.wire_bits(int(np.prod(x.shape)) if x.shape else 1)
                   for x in jax.tree.leaves(tree))

    def wire_bytes(wire, meta):
        return sum(comp.wire_bits(size) for size, _, _ in meta.infos) / 8.0

    return TreeCodec(comp.name, encode, decode, meta, wire_bits, wire_bytes,
                     sim_only=True)


@register("sign")
def _sign(budget=1.0, *, scaled: bool = True, **_) -> TreeCodec:
    return _wrap_baseline(B.sign_compressor(scaled))


@register("ternary")
def _ternary(budget=math.log2(3), **_) -> TreeCodec:
    return _wrap_baseline(B.ternary())


@register("qsgd")
def _qsgd(budget=4.0, **_) -> TreeCodec:
    # n(1 + log2(s+1)) + 32 bits: sign + stochastic level index per coord
    s = max(1, int(round(2.0 ** (budget - 1.0) - 1.0)))
    return _wrap_baseline(B.qsgd(s))


@register("naive")
def _naive(budget=4.0, **_) -> TreeCodec:
    levels = max(2, int(round(2.0 ** budget)))
    return _wrap_baseline(B.naive_uniform(levels))


@register("dither")
def _dither(budget=4.0, **_) -> TreeCodec:
    levels = max(2, int(round(2.0 ** budget)))
    return _wrap_baseline(B.standard_dither(levels))


@register("topk")
def _topk(budget=4.0, *, k_fraction: Optional[float] = None,
          quant_levels: Optional[int] = 256, **_) -> TreeCodec:
    per_val = 32.0 if quant_levels is None else math.log2(quant_levels)
    kf = budget / per_val if k_fraction is None else k_fraction
    return _wrap_baseline(B.topk(min(max(kf, 1e-4), 1.0), quant_levels))


@register("randk")
def _randk(budget=4.0, *, k_fraction: Optional[float] = None,
           quant_levels: Optional[int] = 256, unbiased: bool = False,
           **_) -> TreeCodec:
    per_val = 32.0 if quant_levels is None else math.log2(quant_levels)
    kf = budget / per_val if k_fraction is None else k_fraction
    return _wrap_baseline(
        B.randk(min(max(kf, 1e-4), 1.0), quant_levels, unbiased))
