"""Pallas TPU kernel: fused dequantize + flash-decode attention.

Beyond-paper extension: the paper's NDSC codec applied to the KV cache.
Decode is bandwidth-bound on reading the cache; storing K/V packed at R bits
(per-position-per-head vectors, Hadamard-rotated then uniformly quantized —
the same democratic trick, so outlier channels don't blow the per-vector
scale) cuts that traffic R/32×. The catch: dequantize-then-attend at the XLA
level re-materializes the f32 cache in HBM and gives the win back. This
kernel fuses unpack→dequant→(FWHT⁻¹ rotation)→online-softmax attention in
VMEM: packed words stream HBM→VMEM once, f32 never touches HBM.

Layout per (batch, kv-head) grid cell, kv blocks iterated on the last grid
dim with VMEM scratch accumulators (classic flash-decode):

  q:       (B, K, G, dh) f32     — grouped queries (GQA-native)
  kw/vw:   (B, C, K, dh·R/32) i32 — packed cache
  ks/vs:   (B, C, K) f32          — per-vector ‖·‖∞ scales
  out:     (B, K, G, dh) f32

The kernel assumes the Hadamard rotation used a FIXED per-head sign vector
(passed in as ±1 f32 (K, dh)); scores against rotated queries are computed
directly in the rotated basis — ⟨q, k⟩ = ⟨Hq', Hk'⟩ = ⟨q', k'⟩, so K is
attended WITHOUT inverse-rotating (orthonormality of H). Only V needs the
inverse transform, applied to the (G, dh) accumulator ONCE at the end —
O(G·dh·log dh) instead of O(C·dh·log dh).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BLOCK_C = 512


def _unpack_block(words: jax.Array, bits: int, dh: int) -> jax.Array:
    """(bc, dh·bits/32) i32 → (bc, dh) f32 in [-1, 1) mid-rise levels."""
    k = 32 // bits
    m = 2 ** bits
    shifts = (jnp.arange(k, dtype=jnp.uint32) * bits)[None, None, :]
    idx = (words.astype(jnp.uint32)[:, :, None] >> shifts) & jnp.uint32(m - 1)
    idx = idx.reshape(words.shape[0], dh)
    return -1.0 + (2.0 * idx.astype(jnp.float32) + 1.0) / m


def _fwht_rows(x: jax.Array) -> jax.Array:
    """Normalized FWHT along the last axis (rows in VMEM)."""
    rows, n = x.shape
    h = 1
    while h < n:
        x = x.reshape(rows, n // (2 * h), 2, h)
        a, b = x[:, :, 0, :], x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2).reshape(rows, n)
        h *= 2
    return x * (1.0 / math.sqrt(n))


def _qdecode_kernel(q_ref, kw_ref, ks_ref, vw_ref, vs_ref, len_ref, o_ref,
                    acc_ref, m_ref, l_ref, *, bits: int, dh: int,
                    block_c: int, num_blocks: int, inv_rotate_v: bool):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]                                   # (G, dh) — pre-scaled
    kd = _unpack_block(kw_ref[0], bits, dh) * ks_ref[0][:, None]  # (bc, dh)
    s = q @ kd.T                                      # (G, bc)
    pos = ic * block_c + jnp.arange(block_c, dtype=jnp.int32)
    valid = pos < len_ref[0]
    s = jnp.where(valid[None, :], s, -1e30)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])                   # (G, bc)
    corr = jnp.exp(m_prev - m_new)
    vd = _unpack_block(vw_ref[0], bits, dh) * vs_ref[0][:, None]
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ vd
    m_ref[...] = m_new
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1)

    @pl.when(ic == num_blocks - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        if inv_rotate_v:
            out = _fwht_rows(out)                     # H is its own inverse
        o_ref[0, 0] = out


@functools.partial(jax.jit, static_argnames=("bits", "block_c", "interpret",
                                             "inv_rotate_v"))
def quant_decode_attention_pallas(q: jax.Array, kw: jax.Array, ks: jax.Array,
                                  vw: jax.Array, vs: jax.Array,
                                  kv_len: jax.Array, *, bits: int,
                                  block_c: int = DEFAULT_BLOCK_C,
                                  inv_rotate_v: bool = True,
                                  interpret: bool | None = None) -> jax.Array:
    """q: (B,K,G,dh) f32 (already ·dh^-1/4-scaled & rotated);
    kw/vw: (B,C,K,dh·bits/32) i32; ks/vs: (B,C,K) f32; kv_len: (B,) i32.
    Returns (B, K, G, dh) f32 attention output (V un-rotated).
    interpret=None infers from the backend (compiled on TPU)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, kh, g, dh = q.shape
    c = kw.shape[1]
    if c % block_c:
        raise ValueError(f"cache length {c} not divisible by {block_c}")
    nb = c // block_c
    wpv = kw.shape[-1]
    # (B, C, K, w) → (B, K, C, w) so the grid cell slices are contiguous
    kw_t = kw.transpose(0, 2, 1, 3)
    vw_t = vw.transpose(0, 2, 1, 3)
    ks_t = ks.transpose(0, 2, 1)
    vs_t = vs.transpose(0, 2, 1)

    kernel = functools.partial(
        _qdecode_kernel, bits=bits, dh=dh, block_c=block_c, num_blocks=nb,
        inv_rotate_v=inv_rotate_v)
    out = pl.pallas_call(
        kernel,
        grid=(b, kh, nb),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda ib, ik, ic: (ib, ik, 0, 0)),
            pl.BlockSpec((1, block_c, wpv), lambda ib, ik, ic: (ib * kh + ik,
                                                                ic, 0)),
            pl.BlockSpec((1, block_c), lambda ib, ik, ic: (ib * kh + ik, ic)),
            pl.BlockSpec((1, block_c, wpv), lambda ib, ik, ic: (ib * kh + ik,
                                                                ic, 0)),
            pl.BlockSpec((1, block_c), lambda ib, ik, ic: (ib * kh + ik, ic)),
            pl.BlockSpec((1,), lambda ib, ik, ic: (ib,)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), lambda ib, ik, ic: (ib, ik, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g, dh), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
        interpret=interpret,
    )(q, kw_t.reshape(b * kh, c, wpv), ks_t.reshape(b * kh, c),
      vw_t.reshape(b * kh, c, wpv), vs_t.reshape(b * kh, c), kv_len)
    return out
