"""Pallas TPU kernel: fast Walsh–Hadamard transform (the NDSC hot spot).

The Hadamard transform is the compute core of near-democratic source coding
(x_nd = Sᵀy = H D Pᵀ y). On TPU we tile the batch of gradient chunks into
VMEM-resident (block_rows, N) tiles and run the radix-2 butterfly in-register:
log₂N add/sub sweeps — the paper's "O(n log n) additions, no multiplies",
mapped onto the VPU. N ≤ 8192 keeps a (8, 8192) f32 tile at 256 KiB << VMEM.

The lane (last) dimension stays N throughout; butterflies reshape only the
sublane structure, which lowers to cheap VPU shuffles for h ≥ 128 and to
in-lane permutes below. (Validated in interpret mode on CPU; TPU is the
deployment target.)
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_ROWS = 8
MAX_VMEM_N = 8192


def _fwht_kernel(x_ref, o_ref, *, n: int):
    x = x_ref[...]  # (block_rows, n)
    rows = x.shape[0]
    h = 1
    while h < n:
        x = x.reshape(rows, n // (2 * h), 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2)
        x = x.reshape(rows, n)
        h *= 2
    o_ref[...] = x * (1.0 / math.sqrt(n))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fwht_pallas(x: jax.Array, block_rows: int = DEFAULT_BLOCK_ROWS,
                interpret: bool | None = None) -> jax.Array:
    """Normalized FWHT along the last axis via pl.pallas_call.

    x: (..., N) with N a power of 2, N ≤ MAX_VMEM_N. interpret=None infers
    from the backend: compiled on TPU, interpreter elsewhere.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = x.shape[-1]
    if n & (n - 1):
        raise ValueError(f"FWHT length {n} is not a power of 2")
    if n > MAX_VMEM_N:
        raise ValueError(f"N={n} exceeds single-tile VMEM budget {MAX_VMEM_N}")
    orig_shape = x.shape
    flat = x.reshape((-1, n))
    rows = flat.shape[0]
    padded_rows = -(-rows // block_rows) * block_rows
    if padded_rows != rows:
        flat = jnp.pad(flat, ((0, padded_rows - rows), (0, 0)))
    grid = (padded_rows // block_rows,)
    out = pl.pallas_call(
        functools.partial(_fwht_kernel, n=n),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_rows, n), flat.dtype),
        interpret=interpret,
    )(flat)
    return out[:rows].reshape(orig_shape)
