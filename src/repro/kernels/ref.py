"""Pure-jnp reference oracles for the Pallas kernels.

These define the semantics; the Pallas kernels in fwht.py / quantpack.py /
quantencode.py must match them — bitwise for integer wire payloads, to
tolerance for float outputs (tests sweep shapes/dtypes against these).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def fwht(x: jax.Array) -> jax.Array:
    """Normalized fast Walsh–Hadamard transform along the last axis.

    Computes H x with H the N×N Hadamard matrix with entries ±1/√N
    (H = Hᵀ, H Hᵀ = I). N = x.shape[-1] must be a power of 2.
    """
    n = x.shape[-1]
    if n & (n - 1):
        raise ValueError(f"FWHT length {n} is not a power of 2")
    orig_shape = x.shape
    y = x.reshape((-1, n))
    h = 1
    while h < n:
        y = y.reshape((-1, n // (2 * h), 2, h))
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.stack([a + b, a - b], axis=2)
        y = y.reshape((-1, n))
        h *= 2
    scale = jnp.asarray(1.0 / math.sqrt(n), x.dtype)
    return (y * scale).reshape(orig_shape)


def quantize_pack(x: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    """Uniform R-bit nearest-neighbour quantize + bit-pack into int32 words.

    x:     (..., N) float; values assumed (softly) within ±scale.
    scale: broadcastable to x[..., :1] — the per-row dynamic range (‖x‖∞).
    bits:  ∈ {1, 2, 4, 8} — levels M = 2^bits on [-1, 1], v_i = -1 + (2i+1)/M.

    Returns int32 words of shape (..., N * bits / 32); N must be divisible
    by the packing factor k = 32 // bits.
    """
    if bits not in (1, 2, 4, 8):
        raise ValueError(f"bits must be in {{1,2,4,8}}, got {bits}")
    k = 32 // bits
    n = x.shape[-1]
    if n % k:
        raise ValueError(f"N={n} not divisible by packing factor {k}")
    m = 2 ** bits
    delta = 2.0 / m
    normalized = x / jnp.maximum(scale, jnp.finfo(x.dtype).tiny)
    # nearest-neighbour index of v_i = -1 + (2i+1)/M
    idx = jnp.floor((jnp.clip(normalized, -1.0, 1.0) + 1.0) / delta)
    idx = jnp.clip(idx, 0, m - 1).astype(jnp.uint32)
    grouped = idx.reshape(x.shape[:-1] + (n // k, k))
    shifts = (jnp.arange(k, dtype=jnp.uint32) * bits)[(None,) * (grouped.ndim - 1)]
    words = jnp.sum(grouped << shifts, axis=-1, dtype=jnp.uint32)
    return words.astype(jnp.int32)


def encode(chunks: jax.Array, signs: jax.Array, bits: int, *,
           dither: jax.Array | None = None,
           mask: jax.Array | None = None) -> tuple:
    """Composed-reference codec encode: sign-flip → FWHT → ℓ∞ scale →
    (dither) → quantize+pack → (mask). The fused Pallas kernel in
    quantencode.py must match this BIT-EXACTLY.

    chunks: (..., N) float — the pre-embedding rows (one codec chunk each).
    signs:  (N,) ±1 float  — the diagonal D of the Hadamard frame S = D·H.
    dither: optional (..., N), pre-drawn uniform in [-Δ/2, Δ/2]; added as
            `dither · scale` AFTER the scale reduction (non-subtractive).
    mask:   optional (..., 1) 0/1 float — kept rows; dropped rows emit
            all-zero words and a zero scale (no ghost information).

    Returns (words int32 (..., N·bits/32), scale f32 (..., 1)).
    """
    embedded = fwht(chunks * signs)
    scale = jnp.max(jnp.abs(embedded), axis=-1, keepdims=True)
    if dither is not None:
        embedded = embedded + dither * scale
    words = quantize_pack(embedded, scale, bits)
    if mask is not None:
        words = words * mask.astype(words.dtype)
        scale = scale * mask
    return words, scale


def decode_embedded(words: jax.Array, scale: jax.Array, signs: jax.Array,
                    bits: int, n: int, *, mask: jax.Array | None = None,
                    rescale: float | None = None) -> jax.Array:
    """Composed-reference codec decode back to the ORIGINAL domain:
    unpack+dequant → (mask, 1/keep rescale) → FWHT → sign-flip. Mirrors
    `repro.dist.gradcomp.decode_leaf` on a single chunk block."""
    x_hat = unpack_dequant(words, scale, bits, n)
    if mask is not None:
        x_hat = x_hat * mask
        if rescale is not None:
            x_hat = x_hat / rescale
    return fwht(x_hat) * signs.astype(x_hat.dtype)


def encode_ef(chunks: jax.Array, signs: jax.Array, bits: int, *,
              dither: jax.Array | None = None,
              mask: jax.Array | None = None,
              rescale: float | None = None,
              residual_dtype=jnp.float32) -> tuple:
    """`encode` plus the error-feedback residual u − D(E(u)).

    The residual is what the EF update keeps: the encoder's own payload is
    decoded (through `residual_dtype`, the leaf dtype the eager tree-level
    decode would round through) and subtracted from the input rows.
    Returns (words, scale, residual f32 (..., N))."""
    words, scale = encode(chunks, signs, bits, dither=dither, mask=mask)
    y_hat = decode_embedded(words, scale, signs, bits, chunks.shape[-1],
                            mask=mask, rescale=rescale)
    y_hat = y_hat.astype(residual_dtype).astype(jnp.float32)
    # No fusion fence here: under an enclosing jit XLA may contract the
    # decode's multiply→add chains into the subtract (exactly as it could
    # in the pre-fused decode-then-subtract composition), so the residual
    # is bit-stable only eagerly — the EF contract is tolerance-based.
    # (jax.lax.optimization_barrier would pin it, but 0.4.x has no vmap
    # batching rule for it and the fed cohort engine vmaps this path.)
    return words, scale, chunks.astype(jnp.float32) - y_hat


def quant_decode_attention(q: jax.Array, kw: jax.Array, ks: jax.Array,
                           vw: jax.Array, vs: jax.Array, kv_len: jax.Array,
                           *, bits: int, inv_rotate_v: bool = True
                           ) -> jax.Array:
    """Oracle for kernels/quantdecode.py: dequantize the packed rotated KV
    cache and run exact softmax attention, inverse-rotating V at the end.

    q: (B,K,G,dh) f32 (pre-scaled, rotated basis); kw/vw: (B,C,K,dh·bits/32);
    ks/vs: (B,C,K); kv_len: (B,). Returns (B,K,G,dh)."""
    b, kh, g, dh = q.shape
    c = kw.shape[1]
    kd = unpack_dequant(kw, ks[..., None], bits, dh)      # (B,C,K,dh)
    vd = unpack_dequant(vw, vs[..., None], bits, dh)
    s = jnp.einsum("bkgd,bckd->bkgc", q, kd)
    pos = jnp.arange(c, dtype=jnp.int32)
    s = jnp.where((pos[None, :] < kv_len[:, None])[:, None, None, :],
                  s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", p, vd)
    if inv_rotate_v:
        out = fwht(out)
    return out


def unpack_dequant(words: jax.Array, scale: jax.Array, bits: int, n: int,
                   dtype=jnp.float32) -> jax.Array:
    """Inverse of quantize_pack: int32 words → dequantized float (..., n)."""
    if bits not in (1, 2, 4, 8):
        raise ValueError(f"bits must be in {{1,2,4,8}}, got {bits}")
    k = 32 // bits
    m = 2 ** bits
    mask = jnp.uint32(m - 1)
    w = words.astype(jnp.uint32)[..., None]
    shifts = (jnp.arange(k, dtype=jnp.uint32) * bits)[(None,) * (words.ndim)]
    idx = (w >> shifts) & mask
    idx = idx.reshape(words.shape[:-1] + (words.shape[-1] * k,))[..., :n]
    values = -1.0 + (2.0 * idx.astype(dtype) + 1.0) / m
    return values * scale
