"""Pure-jnp reference oracles for the Pallas kernels.

These define the semantics; the Pallas kernels in fwht.py / quantpack.py must
match them (tests sweep shapes/dtypes and assert_allclose against these).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def fwht(x: jax.Array) -> jax.Array:
    """Normalized fast Walsh–Hadamard transform along the last axis.

    Computes H x with H the N×N Hadamard matrix with entries ±1/√N
    (H = Hᵀ, H Hᵀ = I). N = x.shape[-1] must be a power of 2.
    """
    n = x.shape[-1]
    if n & (n - 1):
        raise ValueError(f"FWHT length {n} is not a power of 2")
    orig_shape = x.shape
    y = x.reshape((-1, n))
    h = 1
    while h < n:
        y = y.reshape((-1, n // (2 * h), 2, h))
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.stack([a + b, a - b], axis=2)
        y = y.reshape((-1, n))
        h *= 2
    scale = jnp.asarray(1.0 / math.sqrt(n), x.dtype)
    return (y * scale).reshape(orig_shape)


def quantize_pack(x: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    """Uniform R-bit nearest-neighbour quantize + bit-pack into int32 words.

    x:     (..., N) float; values assumed (softly) within ±scale.
    scale: broadcastable to x[..., :1] — the per-row dynamic range (‖x‖∞).
    bits:  ∈ {1, 2, 4, 8} — levels M = 2^bits on [-1, 1], v_i = -1 + (2i+1)/M.

    Returns int32 words of shape (..., N * bits / 32); N must be divisible
    by the packing factor k = 32 // bits.
    """
    if bits not in (1, 2, 4, 8):
        raise ValueError(f"bits must be in {{1,2,4,8}}, got {bits}")
    k = 32 // bits
    n = x.shape[-1]
    if n % k:
        raise ValueError(f"N={n} not divisible by packing factor {k}")
    m = 2 ** bits
    delta = 2.0 / m
    normalized = x / jnp.maximum(scale, jnp.finfo(x.dtype).tiny)
    # nearest-neighbour index of v_i = -1 + (2i+1)/M
    idx = jnp.floor((jnp.clip(normalized, -1.0, 1.0) + 1.0) / delta)
    idx = jnp.clip(idx, 0, m - 1).astype(jnp.uint32)
    grouped = idx.reshape(x.shape[:-1] + (n // k, k))
    shifts = (jnp.arange(k, dtype=jnp.uint32) * bits)[(None,) * (grouped.ndim - 1)]
    words = jnp.sum(grouped << shifts, axis=-1, dtype=jnp.uint32)
    return words.astype(jnp.int32)


def quant_decode_attention(q: jax.Array, kw: jax.Array, ks: jax.Array,
                           vw: jax.Array, vs: jax.Array, kv_len: jax.Array,
                           *, bits: int, inv_rotate_v: bool = True
                           ) -> jax.Array:
    """Oracle for kernels/quantdecode.py: dequantize the packed rotated KV
    cache and run exact softmax attention, inverse-rotating V at the end.

    q: (B,K,G,dh) f32 (pre-scaled, rotated basis); kw/vw: (B,C,K,dh·bits/32);
    ks/vs: (B,C,K); kv_len: (B,). Returns (B,K,G,dh)."""
    b, kh, g, dh = q.shape
    c = kw.shape[1]
    kd = unpack_dequant(kw, ks[..., None], bits, dh)      # (B,C,K,dh)
    vd = unpack_dequant(vw, vs[..., None], bits, dh)
    s = jnp.einsum("bkgd,bckd->bkgc", q, kd)
    pos = jnp.arange(c, dtype=jnp.int32)
    s = jnp.where((pos[None, :] < kv_len[:, None])[:, None, None, :],
                  s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", p, vd)
    if inv_rotate_v:
        out = fwht(out)
    return out


def unpack_dequant(words: jax.Array, scale: jax.Array, bits: int, n: int,
                   dtype=jnp.float32) -> jax.Array:
    """Inverse of quantize_pack: int32 words → dequantized float (..., n)."""
    if bits not in (1, 2, 4, 8):
        raise ValueError(f"bits must be in {{1,2,4,8}}, got {bits}")
    k = 32 // bits
    m = 2 ** bits
    mask = jnp.uint32(m - 1)
    w = words.astype(jnp.uint32)[..., None]
    shifts = (jnp.arange(k, dtype=jnp.uint32) * bits)[(None,) * (words.ndim)]
    idx = (w >> shifts) & mask
    idx = idx.reshape(words.shape[:-1] + (words.shape[-1] * k,))[..., :n]
    values = -1.0 + (2.0 * idx.astype(dtype) + 1.0) / m
    return values * scale
