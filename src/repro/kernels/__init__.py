"""Pallas TPU kernels for the codec + serving hot spots.

Each kernel ships three artifacts: the pl.pallas_call kernel, a jit'd public
wrapper in ops.py, and a pure-jnp oracle in ref.py that tests sweep against.

  fwht.py        -- fast Walsh-Hadamard transform (NDSC embedding core)
  quantpack.py   -- fused uniform-quantize + bit-pack / unpack + dequant
  quantdecode.py -- fused dequantize + flash-decode attention against the
                    NDSC-packed KV cache (beyond-paper serving path)
"""
