"""jit'd public wrappers around the Pallas kernels, with pure-jnp fallback.

Dispatch policy:
  * On TPU: Pallas kernels (compiled).
  * On CPU (this container): the jnp reference — numerically identical and much
    faster than interpret-mode Pallas. Tests exercise the Pallas path explicitly
    with interpret=True to validate the kernels against the reference oracles.
Set REPRO_FORCE_PALLAS=1 to force the (interpret-mode on CPU) Pallas path.
"""
from __future__ import annotations

import os

import jax

from repro.kernels import fwht as _fwht_kernel
from repro.kernels import quantpack as _quantpack_kernel
from repro.kernels import ref as _ref


def _use_pallas() -> bool:
    if os.environ.get("REPRO_FORCE_PALLAS") == "1":
        return True
    return jax.default_backend() == "tpu"


def fwht(x: jax.Array) -> jax.Array:
    """Normalized Walsh–Hadamard transform along the last axis (power-of-2 len)."""
    if _use_pallas() and x.shape[-1] <= _fwht_kernel.MAX_VMEM_N:
        return _fwht_kernel.fwht_pallas(
            x, interpret=jax.default_backend() != "tpu")
    return _ref.fwht(x)


def quantize_pack(x: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    """Fused uniform-quantize + bit-pack to int32 words (bits ∈ {1,2,4,8})."""
    if _use_pallas():
        return _quantpack_kernel.quantize_pack_pallas(
            x, scale, bits, interpret=jax.default_backend() != "tpu")
    return _ref.quantize_pack(x, scale, bits)


def unpack_dequant(words: jax.Array, scale: jax.Array, bits: int, n: int) -> jax.Array:
    """Fused unpack + dequantize (inverse of quantize_pack)."""
    if _use_pallas():
        return _quantpack_kernel.unpack_dequant_pallas(
            words, scale, bits, n, interpret=jax.default_backend() != "tpu")
    return _ref.unpack_dequant(words, scale, bits, n)
