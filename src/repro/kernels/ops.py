"""jit'd public wrappers around the Pallas kernels, with pure-jnp fallback.

Dispatch policy:
  * On TPU: Pallas kernels (compiled).
  * On CPU (this container): the jnp reference — numerically identical and much
    faster than interpret-mode Pallas. Tests exercise the Pallas path explicitly
    with interpret=True to validate the kernels against the reference oracles.
Set REPRO_FORCE_PALLAS=1 to force the (interpret-mode on CPU) Pallas path.
On the forced path a kernel that CANNOT run (e.g. N over the single-tile
VMEM budget) raises instead of silently substituting the reference — a
silent fallback would make "forced Pallas" tests vacuous.

Observability: every dispatch decision increments the
`kernels.dispatch` counter (attrs: op, path "pallas"|"ref", N, forced)
and a refused forced dispatch increments `kernels.forced_error` BEFORE
raising — so a CI run under REPRO_FORCE_PALLAS=1 can assert "zero
reference-fallback events" from the event stream instead of relying on
the raise alone. These fire at Python call time (i.e. once per trace /
compilation when called under jit, per call when eager), never inside
compiled code, and cost one global load when obs is disabled.

Cost model: each dispatch additionally records the resolved kernel
callable + abstract signature with the active session's cost capture
(`kernels.<op>.<path>` programs, `jit_wrap=True` — the session's
`costs()` snapshot lowers a FRESH never-called jit of the callable, so
the dispatch path itself never gains a jit wrapper or a compile).
Compile-time parameters (bits, n, …) are closed over with
`functools.partial` and keyed into the signature via `static=`.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import fwht as _fwht_kernel
from repro.kernels import quantencode as _quantencode_kernel
from repro.kernels import quantpack as _quantpack_kernel
from repro.kernels import ref as _ref
from repro.obs import core as obs


def _use_pallas() -> bool:
    if os.environ.get("REPRO_FORCE_PALLAS") == "1":
        return True
    return jax.default_backend() == "tpu"


def _forced() -> bool:
    return os.environ.get("REPRO_FORCE_PALLAS") == "1"


def _count_dispatch(op: str, path: str, n) -> None:
    obs.counter("kernels.dispatch", 1, op=op, path=path, n=int(n),
                forced=_forced())


def _count_forced_error(op: str, n) -> None:
    obs.counter("kernels.forced_error", 1, op=op, n=int(n))


def _observe(op: str, path: str, fn, args, kwargs=None, static=None) -> None:
    obs.observe_program_call(f"kernels.{op}.{path}", fn, args, kwargs,
                             static=static, jit_wrap=True)


def fwht(x: jax.Array) -> jax.Array:
    """Normalized Walsh–Hadamard transform along the last axis (power-of-2 len)."""
    if _use_pallas():
        if x.shape[-1] <= _fwht_kernel.MAX_VMEM_N:
            _count_dispatch("fwht", "pallas", x.shape[-1])
            _observe("fwht", "pallas", _fwht_kernel.fwht_pallas, (x,))
            return _fwht_kernel.fwht_pallas(x)
        if _forced():
            _count_forced_error("fwht", x.shape[-1])
            raise ValueError(
                f"REPRO_FORCE_PALLAS=1 but FWHT N={x.shape[-1]} exceeds the "
                f"single-tile VMEM budget {_fwht_kernel.MAX_VMEM_N}; the "
                "forced path refuses to silently fall back to the jnp "
                "reference")
    _count_dispatch("fwht", "ref", x.shape[-1])
    _observe("fwht", "ref", _ref.fwht, (x,))
    return _ref.fwht(x)


def rotate(chunks: jax.Array, signs: jax.Array) -> jax.Array:
    """Apply the randomized-Hadamard frame chunk-wise: H·(D·x) — the
    `transform` stage of `repro.codecs.stages`. Rides the `fwht` dispatch
    (Pallas on TPU, jnp reference on CPU, counters included)."""
    return fwht(chunks * signs)


def unrotate(x: jax.Array, signs: jax.Array) -> jax.Array:
    """Inverse of `rotate` (H orthonormal, D its own inverse): D·(H·x)."""
    return fwht(x) * signs


def quantize_pack(x: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    """Fused uniform-quantize + bit-pack to int32 words (bits ∈ {1,2,4,8})."""
    if _use_pallas():
        _count_dispatch("quantize_pack", "pallas", x.shape[-1])
        _observe("quantize_pack", "pallas",
                 functools.partial(_quantpack_kernel.quantize_pack_pallas,
                                   bits=bits),
                 (x, scale), static=("bits", bits))
        return _quantpack_kernel.quantize_pack_pallas(x, scale, bits)
    _count_dispatch("quantize_pack", "ref", x.shape[-1])
    _observe("quantize_pack", "ref",
             functools.partial(_ref.quantize_pack, bits=bits),
             (x, scale), static=("bits", bits))
    return _ref.quantize_pack(x, scale, bits)


def unpack_dequant(words: jax.Array, scale: jax.Array, bits: int, n: int) -> jax.Array:
    """Fused unpack + dequantize (inverse of quantize_pack)."""
    if _use_pallas():
        _count_dispatch("unpack_dequant", "pallas", n)
        _observe("unpack_dequant", "pallas",
                 functools.partial(_quantpack_kernel.unpack_dequant_pallas,
                                   bits=bits, n=n),
                 (words, scale), static=("bits", bits, "n", n))
        return _quantpack_kernel.unpack_dequant_pallas(words, scale, bits, n)
    _count_dispatch("unpack_dequant", "ref", n)
    _observe("unpack_dequant", "ref",
             functools.partial(_ref.unpack_dequant, bits=bits, n=n),
             (words, scale), static=("bits", bits, "n", n))
    return _ref.unpack_dequant(words, scale, bits, n)


def encode(chunks: jax.Array, signs: jax.Array, bits: int, *,
           dither: jax.Array | None = None,
           mask: jax.Array | None = None) -> tuple:
    """Fused codec encode: sign-flip → FWHT → ℓ∞ scale → (dither) →
    quantize+pack → (mask), one VMEM pass on the Pallas path.

    The Pallas kernel's (words, scale) are bit-exact with the composed
    `ref.encode`, so dispatch never changes a wire payload. Falls back to
    the reference when N exceeds the single-tile budget (raising instead
    under REPRO_FORCE_PALLAS=1, like `fwht`)."""
    if _use_pallas():
        if chunks.shape[-1] <= _quantencode_kernel.MAX_VMEM_N:
            _count_dispatch("encode", "pallas", chunks.shape[-1])
            _observe("encode", "pallas",
                     functools.partial(_quantencode_kernel.encode_pallas,
                                       bits=bits),
                     (chunks, signs), {"dither": dither, "mask": mask},
                     static=("bits", bits))
            return _quantencode_kernel.encode_pallas(
                chunks, signs, bits, dither=dither, mask=mask)
        if _forced():
            _count_forced_error("encode", chunks.shape[-1])
            raise ValueError(
                f"REPRO_FORCE_PALLAS=1 but encode N={chunks.shape[-1]} "
                f"exceeds the single-tile VMEM budget "
                f"{_quantencode_kernel.MAX_VMEM_N}")
    _count_dispatch("encode", "ref", chunks.shape[-1])
    _observe("encode", "ref",
             functools.partial(_ref.encode, bits=bits),
             (chunks, signs), {"dither": dither, "mask": mask},
             static=("bits", bits))
    return _ref.encode(chunks, signs, bits, dither=dither, mask=mask)


def encode_ef(chunks: jax.Array, signs: jax.Array, bits: int, *,
              dither: jax.Array | None = None,
              mask: jax.Array | None = None,
              rescale: float | None = None,
              residual_dtype=None) -> tuple:
    """`encode` plus the in-tile error-feedback residual u − D(E(u)).

    Same payload contract as `encode`; the residual (local EF state, never
    on the wire) matches `ref.encode_ef` to a few f32 ulp on the Pallas
    path. residual_dtype=None means f32 (no leaf-dtype rounding)."""
    rdt = jnp.float32 if residual_dtype is None else residual_dtype
    if _use_pallas():
        if chunks.shape[-1] <= _quantencode_kernel.MAX_VMEM_N:
            _count_dispatch("encode_ef", "pallas", chunks.shape[-1])
            _observe("encode_ef", "pallas",
                     functools.partial(_quantencode_kernel.encode_ef_pallas,
                                       bits=bits, rescale=rescale,
                                       residual_dtype=rdt),
                     (chunks, signs), {"dither": dither, "mask": mask},
                     static=("bits", bits, "rescale", rescale,
                             "rdt", jnp.dtype(rdt).name))
            return _quantencode_kernel.encode_ef_pallas(
                chunks, signs, bits, dither=dither, mask=mask,
                rescale=rescale, residual_dtype=rdt)
        if _forced():
            _count_forced_error("encode_ef", chunks.shape[-1])
            raise ValueError(
                f"REPRO_FORCE_PALLAS=1 but encode N={chunks.shape[-1]} "
                f"exceeds the single-tile VMEM budget "
                f"{_quantencode_kernel.MAX_VMEM_N}")
    _count_dispatch("encode_ef", "ref", chunks.shape[-1])
    _observe("encode_ef", "ref",
             functools.partial(_ref.encode_ef, bits=bits, rescale=rescale,
                               residual_dtype=rdt),
             (chunks, signs), {"dither": dither, "mask": mask},
             static=("bits", bits, "rescale", rescale,
                     "rdt", jnp.dtype(rdt).name))
    return _ref.encode_ef(chunks, signs, bits, dither=dither, mask=mask,
                          rescale=rescale, residual_dtype=rdt)
