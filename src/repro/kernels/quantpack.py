"""Pallas TPU kernels: fused quantize→bit-pack encoder and unpack→dequant decoder.

The second hot spot of the codec: after the FWHT produces the near-democratic
embedding, each chunk is scaled by 1/‖x‖∞, uniformly quantized to R bits and
bit-packed into int32 words — all inside one VMEM tile, so the intermediate
per-element integer codes never round-trip through HBM. The decoder fuses the
inverse. bits ∈ {1, 2, 4, 8} (packing factor k = 32/bits).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_ROWS = 8


def _quantpack_kernel(x_ref, scale_ref, o_ref, *, bits: int, n: int):
    x = x_ref[...]                       # (rows, n) float
    scale = scale_ref[...]               # (rows, 1) float
    k = 32 // bits
    m = 2 ** bits
    delta = 2.0 / m
    normalized = x / jnp.maximum(scale, jnp.finfo(x.dtype).tiny)
    idx = jnp.floor((jnp.clip(normalized, -1.0, 1.0) + 1.0) / delta)
    idx = jnp.clip(idx, 0, m - 1).astype(jnp.uint32)
    grouped = idx.reshape(idx.shape[0], n // k, k)
    shifts = (jnp.arange(k, dtype=jnp.uint32) * bits)[None, None, :]
    words = jnp.sum(grouped << shifts, axis=-1, dtype=jnp.uint32)
    o_ref[...] = words.astype(jnp.int32)


def _unpackdequant_kernel(w_ref, scale_ref, o_ref, *, bits: int, n: int):
    words = w_ref[...].astype(jnp.uint32)   # (rows, n//k)
    scale = scale_ref[...]                   # (rows, 1)
    k = 32 // bits
    m = 2 ** bits
    mask = jnp.uint32(m - 1)
    shifts = (jnp.arange(k, dtype=jnp.uint32) * bits)[None, None, :]
    idx = (words[:, :, None] >> shifts) & mask
    idx = idx.reshape(words.shape[0], n)
    values = -1.0 + (2.0 * idx.astype(o_ref.dtype) + 1.0) / m
    o_ref[...] = values * scale


def _tile(call, flat_inputs, out_shape, block_rows):
    rows = flat_inputs[0].shape[0]
    padded = -(-rows // block_rows) * block_rows
    if padded != rows:
        flat_inputs = [jnp.pad(a, ((0, padded - rows), (0, 0))) for a in flat_inputs]
    out = call(padded, flat_inputs)
    return out[:rows]


@functools.partial(jax.jit, static_argnames=("bits", "block_rows", "interpret"))
def quantize_pack_pallas(x: jax.Array, scale: jax.Array, bits: int,
                         block_rows: int = DEFAULT_BLOCK_ROWS,
                         interpret: bool | None = None) -> jax.Array:
    """x: (..., N) float, scale: (..., 1) → packed int32 (..., N*bits/32).

    interpret=None infers from the backend (compiled on TPU)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if bits not in (1, 2, 4, 8):
        raise ValueError(f"bits must be in {{1,2,4,8}}, got {bits}")
    k = 32 // bits
    n = x.shape[-1]
    if n % k:
        raise ValueError(f"N={n} not divisible by packing factor {k}")
    lead = x.shape[:-1]
    flat_x = x.reshape((-1, n))
    flat_s = jnp.broadcast_to(scale, lead + (1,)).reshape((-1, 1))

    def call(padded_rows, inputs):
        grid = (padded_rows // block_rows,)
        return pl.pallas_call(
            functools.partial(_quantpack_kernel, bits=bits, n=n),
            grid=grid,
            in_specs=[pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
                      pl.BlockSpec((block_rows, 1), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((block_rows, n // k), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((padded_rows, n // k), jnp.int32),
            interpret=interpret,
        )(*inputs)

    out = _tile(call, [flat_x, flat_s], None, block_rows)
    return out.reshape(lead + (n // k,))


@functools.partial(jax.jit, static_argnames=("bits", "n", "block_rows", "interpret"))
def unpack_dequant_pallas(words: jax.Array, scale: jax.Array, bits: int, n: int,
                          block_rows: int = DEFAULT_BLOCK_ROWS,
                          interpret: bool | None = None) -> jax.Array:
    """words: (..., N*bits/32) int32, scale: (..., 1) → float (..., n).

    interpret=None infers from the backend (compiled on TPU)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if bits not in (1, 2, 4, 8):
        raise ValueError(f"bits must be in {{1,2,4,8}}, got {bits}")
    k = 32 // bits
    if n % k:
        raise ValueError(f"N={n} not divisible by packing factor {k}")
    lead = words.shape[:-1]
    flat_w = words.reshape((-1, words.shape[-1]))
    flat_s = jnp.broadcast_to(scale, lead + (1,)).reshape((-1, 1)).astype(jnp.float32)

    def call(padded_rows, inputs):
        grid = (padded_rows // block_rows,)
        return pl.pallas_call(
            functools.partial(_unpackdequant_kernel, bits=bits, n=n),
            grid=grid,
            in_specs=[pl.BlockSpec((block_rows, n // k), lambda i: (i, 0)),
                      pl.BlockSpec((block_rows, 1), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((padded_rows, n), jnp.float32),
            interpret=interpret,
        )(*inputs)

    out = _tile(call, [flat_w, flat_s], None, block_rows)
    return out.reshape(lead + (n,))
