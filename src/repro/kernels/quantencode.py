"""Pallas TPU kernel: the FUSED codec encoder (the NDSC encode hot loop).

Every subsystem's encode path — gradcomp consensus, ZeRO-1, fed cohorts and
the mesh backend — runs sign-flip (D) → FWHT → ℓ∞ scale → (dither) →
uniform quantize → int32 bit-pack on each (C, chunk) block. Composed at the
XLA level those are separate programs with full-precision HBM round-trips
between every stage: the f32 embedding is written out after the FWHT, read
back for the scale reduction, written again after the dither… This kernel
does the whole chain inside one (block_rows, N) VMEM tile, so the f32
embedding NEVER touches HBM — HBM traffic drops to "read y once, write
N·bits/32 words + one f32 scale per row", the codec's information-theoretic
minimum (gated in `benchmarks/codec_roofline.py`).

A fused error-feedback variant (`encode_ef_pallas`) additionally
unpacks/dequantizes its own words in-tile, inverse-rotates, and emits the
EF residual u − D(E(u)) alongside — the DGD-DEF update without a second
pass over the leaf.

Semantics are defined by the composed jnp oracles `ref.encode` /
`ref.encode_ef`. The PAYLOAD contract is strict: (words, scale) are
BIT-EXACT with `ref.encode` (asserted in tests and by the roofline gate) —
deterministically, and on the dithered / sub-linear paths given the same
pre-drawn dither / keep-mask inputs. The stochastic draws happen OUTSIDE
the kernel (in `gradcomp.encode_leaf`, from the same `fold_in`-derived keys
as before), so forcing the Pallas path can never change a payload. The EF
residual is LOCAL state (never on the wire): it matches `ref.encode_ef` to
within a few f32 ulp of the embedding scale — the compiler may contract
the in-tile decode's multiply→add chains into fmas, which tests bound with
a tight tolerance rather than bitwise equality.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fwht import MAX_VMEM_N

DEFAULT_BLOCK_ROWS = 8


def _fwht_tile(x: jax.Array, n: int) -> jax.Array:
    """Radix-2 butterfly sweeps on a resident (rows, n) tile — the same op
    sequence as ref.fwht, so compiled/interpret results match it bitwise."""
    rows = x.shape[0]
    h = 1
    while h < n:
        x = x.reshape(rows, n // (2 * h), 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2)
        x = x.reshape(rows, n)
        h *= 2
    return x * (1.0 / math.sqrt(n))


def _quantize_tile(x: jax.Array, scale: jax.Array, bits: int, n: int):
    """(rows, n) f32 → (rows, n·bits/32) uint32 — same ops as ref.quantize_pack."""
    k = 32 // bits
    m = 2 ** bits
    delta = 2.0 / m
    normalized = x / jnp.maximum(scale, jnp.finfo(x.dtype).tiny)
    idx = jnp.floor((jnp.clip(normalized, -1.0, 1.0) + 1.0) / delta)
    idx = jnp.clip(idx, 0, m - 1).astype(jnp.uint32)
    grouped = idx.reshape(idx.shape[0], n // k, k)
    shifts = (jnp.arange(k, dtype=jnp.uint32) * bits)[None, None, :]
    return jnp.sum(grouped << shifts, axis=-1, dtype=jnp.uint32)


def _dequantize_tile(words: jax.Array, scale: jax.Array, bits: int, n: int):
    """Inverse of _quantize_tile — same ops as ref.unpack_dequant."""
    k = 32 // bits
    m = 2 ** bits
    mask = jnp.uint32(m - 1)
    shifts = (jnp.arange(k, dtype=jnp.uint32) * bits)[None, None, :]
    idx = (words.astype(jnp.uint32)[:, :, None] >> shifts) & mask
    idx = idx.reshape(words.shape[0], n)
    values = -1.0 + (2.0 * idx.astype(jnp.float32) + 1.0) / m
    return values * scale


def _encode_kernel(*refs, bits: int, n: int, dithered: bool, masked: bool,
                   ef: bool, rescale, residual_dtype):
    """One grid step: encode a (block_rows, n) tile fully in VMEM.

    Operand order (inputs): x, signs, [dither], [mask];
    (outputs): words, scale, [decoded]."""
    it = iter(refs)
    x_ref = next(it)
    signs_ref = next(it)
    dither_ref = next(it) if dithered else None
    mask_ref = next(it) if masked else None
    words_ref = next(it)
    scale_ref = next(it)
    residual_ref = next(it) if ef else None

    u = x_ref[...]                                    # (rows, n) f32 input
    signs = signs_ref[...]                            # (1, n) ±1 f32
    embedded = _fwht_tile(u * signs, n)               # x = H·D·u
    scale = jnp.max(jnp.abs(embedded), axis=-1, keepdims=True)
    if dithered:
        embedded = embedded + dither_ref[...] * scale
    words = _quantize_tile(embedded, scale, bits, n)
    out_scale = scale
    out_words = words.astype(jnp.int32)
    if masked:
        mask = mask_ref[...]                          # (rows, 1) 0/1 f32
        out_words = out_words * mask.astype(jnp.int32)
        out_scale = scale * mask
    words_ref[...] = out_words
    scale_ref[...] = out_scale

    if ef:
        # decode the tile's OWN (masked) payload in-tile, replaying
        # decode_leaf's op order exactly: dequant → mask → (1/keep rescale)
        # → FWHT → sign-flip → leaf-dtype rounding → subtract. The residual
        # never leaves VMEM un-reduced: u is already resident, so the EF
        # state costs no second pass over the leaf.
        x_hat = _dequantize_tile(out_words, out_scale, bits, n)
        if masked:
            x_hat = x_hat * mask_ref[...]
            if rescale is not None:
                x_hat = x_hat / rescale
        y_hat = _fwht_tile(x_hat, n) * signs
        y_hat = y_hat.astype(residual_dtype).astype(jnp.float32)
        residual_ref[...] = u - y_hat


@functools.partial(
    jax.jit, static_argnames=("bits", "block_rows", "interpret", "ef",
                              "rescale", "residual_dtype"))
def _encode_call(x, signs, dither, mask, *, bits: int, block_rows: int,
                 interpret, ef: bool, rescale, residual_dtype):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = x.shape[-1]
    if n & (n - 1):
        raise ValueError(f"encode length {n} is not a power of 2")
    if n > MAX_VMEM_N:
        raise ValueError(f"N={n} exceeds single-tile VMEM budget {MAX_VMEM_N}")
    if bits not in (1, 2, 4, 8):
        raise ValueError(f"bits must be in {{1,2,4,8}}, got {bits}")
    k = 32 // bits
    if n % k:
        raise ValueError(f"N={n} not divisible by packing factor {k}")
    lead = x.shape[:-1]
    flat = x.astype(jnp.float32).reshape((-1, n))
    rows = flat.shape[0]
    padded = -(-rows // block_rows) * block_rows
    signs2d = signs.astype(jnp.float32).reshape((1, n))

    def pad(t):
        return (t if t.shape[0] == padded
                else jnp.pad(t, ((0, padded - t.shape[0]), (0, 0))))

    row_spec = pl.BlockSpec((block_rows, n), lambda i: (i, 0))
    inputs = [pad(flat)]
    if dither is not None:
        inputs.append(pad(dither.astype(jnp.float32).reshape((-1, n))))
    if mask is not None:
        inputs.append(pad(mask.astype(jnp.float32).reshape((-1, 1))))
    # signs go FIRST after x in the kernel's operand order
    inputs.insert(1, signs2d)
    in_specs = [row_spec, pl.BlockSpec((1, n), lambda i: (0, 0))]
    if dither is not None:
        in_specs.append(row_spec)
    if mask is not None:
        in_specs.append(pl.BlockSpec((block_rows, 1), lambda i: (i, 0)))

    out_shape = [jax.ShapeDtypeStruct((padded, n // k), jnp.int32),
                 jax.ShapeDtypeStruct((padded, 1), jnp.float32)]
    out_specs = [pl.BlockSpec((block_rows, n // k), lambda i: (i, 0)),
                 pl.BlockSpec((block_rows, 1), lambda i: (i, 0))]
    if ef:
        out_shape.append(jax.ShapeDtypeStruct((padded, n), jnp.float32))
        out_specs.append(row_spec)

    kernel = functools.partial(
        _encode_kernel, bits=bits, n=n, dithered=dither is not None,
        masked=mask is not None, ef=ef, rescale=rescale,
        residual_dtype=residual_dtype)
    outs = pl.pallas_call(
        kernel,
        grid=(padded // block_rows,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*inputs)
    words = outs[0][:rows].reshape(lead + (n // k,))
    scale = outs[1][:rows].reshape(lead + (1,))
    if ef:
        return words, scale, outs[2][:rows].reshape(lead + (n,))
    return words, scale


def encode_pallas(chunks: jax.Array, signs: jax.Array, bits: int, *,
                  dither: jax.Array | None = None,
                  mask: jax.Array | None = None,
                  block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: bool | None = None) -> tuple:
    """Fused codec encode — semantics of `ref.encode` in one VMEM pass.

    chunks: (..., N) float rows (N a power of 2, ≤ MAX_VMEM_N, divisible by
    the 32/bits packing factor); signs: (N,) ±1; dither/mask as in
    `ref.encode` (pre-drawn OUTSIDE the kernel). `interpret=None` infers
    from the backend (compiled on TPU, interpreter elsewhere).
    Returns (words int32 (..., N·bits/32), scale f32 (..., 1)).
    """
    return _encode_call(chunks, signs, dither, mask, bits=bits,
                        block_rows=block_rows, interpret=interpret,
                        ef=False, rescale=None, residual_dtype=jnp.float32)


def encode_ef_pallas(chunks: jax.Array, signs: jax.Array, bits: int, *,
                     dither: jax.Array | None = None,
                     mask: jax.Array | None = None,
                     rescale: float | None = None,
                     residual_dtype=jnp.float32,
                     block_rows: int = DEFAULT_BLOCK_ROWS,
                     interpret: bool | None = None) -> tuple:
    """Fused encode + error-feedback residual — semantics of `ref.encode_ef`.

    Returns (words, scale, residual f32 (..., N)) where residual is
    u − D(E(u)) with the decode replayed and subtracted in-tile
    (`rescale` = keep_fraction for the dithered-unbiased path, None for
    the contractive EF path; `residual_dtype` = the leaf dtype the eager
    tree-level decode rounds through before the f32 subtract). (words,
    scale) keep the bitwise payload contract; the residual matches
    `ref.encode_ef` to a few f32 ulp of the embedding scale."""
    return _encode_call(chunks, signs, dither, mask, bits=bits,
                        block_rows=block_rows, interpret=interpret,
                        ef=True, rescale=rescale,
                        residual_dtype=jnp.dtype(residual_dtype))
