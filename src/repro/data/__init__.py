"""Deterministic synthetic data pipeline (no datasets ship offline)."""
from repro.data.pipeline import (TokenStream, synthetic_lm_batches,
                                 synthetic_regression, synthetic_two_class,
                                 batch_for_shape)
