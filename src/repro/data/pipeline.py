"""Synthetic, deterministic, shard-aware data pipeline.

The LM stream generates order-k Markov token sequences from a fixed random
transition table: learnable structure (so training loss demonstrably falls)
with zero I/O. Batches are pure functions of (seed, step) — every data-parallel
shard can materialize exactly its slice without any host-side state, and a
restart from a checkpoint resumes the stream deterministically.

The convex-experiment generators (regression / two-class) reproduce the data
protocols of the paper's §5 simulations: Gaussian-cubed heavy-tailed design
matrices, Student-t planted models, Gaussian class clouds.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Iterator

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Language-model token stream
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab_size: int
    seq_len: int                 # tokens per example INCLUDING the shift target
    batch_size: int              # global batch
    seed: int = 0
    markov_temperature: float = 0.3

    def _table_key(self) -> jax.Array:
        return jax.random.key(self.seed)

    def batch(self, step: int) -> dict:
        """Global batch at `step`: {"tokens": (B, seq_len+1) int32}."""
        key = jax.random.fold_in(self._table_key(), step + 1)
        return {"tokens": _markov_tokens(
            key, self._table_key(), self.batch_size, self.seq_len + 1,
            self.vocab_size, self.markov_temperature)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


@partial(jax.jit, static_argnames=("vocab",))
def _markov_logits(table_key: jax.Array, vocab: int) -> jax.Array:
    # low-rank logits table: (V, r) @ (r, V) so big vocabs stay cheap
    r = 32
    ka, kb = jax.random.split(table_key)
    a = jax.random.normal(ka, (vocab, r))
    b = jax.random.normal(kb, (r, vocab))
    return a @ b / jnp.sqrt(r)


def _markov_tokens(key, table_key, batch, length, vocab, temperature):
    logits = _markov_logits(table_key, vocab) / temperature

    k0, kscan = jax.random.split(key)
    first = jax.random.randint(k0, (batch,), 0, vocab, jnp.int32)

    def step(tok, k):
        nxt = jax.random.categorical(k, logits[tok])
        return nxt.astype(jnp.int32), nxt.astype(jnp.int32)

    keys = jax.random.split(kscan, length - 1)
    _, rest = jax.lax.scan(step, first, keys)
    return jnp.concatenate([first[None], rest], axis=0).T  # (B, length)


def synthetic_lm_batches(vocab_size: int, seq_len: int, batch_size: int,
                         steps: int, seed: int = 0) -> Iterator[dict]:
    stream = TokenStream(vocab_size, seq_len, batch_size, seed)
    for t in range(steps):
        yield stream.batch(t)


# ---------------------------------------------------------------------------
# Modality-frontend stand-ins + generic batch construction
# ---------------------------------------------------------------------------
def batch_for_shape(cfg, batch_size: int, seq_len: int, step: int = 0,
                    seed: int = 0) -> dict:
    """A real (allocated) batch matching launch.input_specs layouts."""
    key = jax.random.fold_in(jax.random.key(seed), step)
    if cfg.frontend == "audio":
        ke, kt = jax.random.split(key)
        return {
            "embeds": jax.random.normal(ke, (batch_size, seq_len, cfg.d_model),
                                        jnp.float32) * 0.02,
            "targets": jax.random.randint(kt, (batch_size, seq_len), 0,
                                          cfg.vocab_size, jnp.int32),
        }
    if cfg.frontend == "vision":
        ke, kt = jax.random.split(key)
        text_len = seq_len - cfg.num_patches
        return {
            "image_embeds": jax.random.normal(
                ke, (batch_size, cfg.num_patches, cfg.d_model),
                jnp.float32) * 0.02,
            "tokens": jax.random.randint(kt, (batch_size, text_len + 1), 0,
                                         cfg.vocab_size, jnp.int32),
        }
    stream = TokenStream(cfg.vocab_size, seq_len, batch_size, seed)
    return stream.batch(step)


# ---------------------------------------------------------------------------
# Convex-experiment data (paper §5 protocols)
# ---------------------------------------------------------------------------
def synthetic_regression(key: jax.Array, n_samples: int, dim: int,
                         design: str = "gauss3", model: str = "student_t"):
    """b = A x* with heavy-tailed A and/or x* (paper Fig. 3a / Figs. 5–6)."""
    ka, kx = jax.random.split(key)
    a = jax.random.normal(ka, (n_samples, dim))
    if design == "gauss3":
        a = a ** 3
    if model == "student_t":
        x_star = jax.random.t(kx, df=1.0, shape=(dim,))
    elif model == "gauss3":
        x_star = jax.random.normal(kx, (dim,)) ** 3
    else:
        x_star = jax.random.normal(kx, (dim,))
    return a, a @ x_star, x_star


def synthetic_two_class(key: jax.Array, n_per_class: int, dim: int,
                        separation: float = 2.0):
    """Two Gaussian clouds, labels ±1 (paper Fig. 2a–b SVM protocol)."""
    k1, k2 = jax.random.split(key)
    mu = jnp.ones((dim,)) * separation / jnp.sqrt(dim)
    xa = jax.random.normal(k1, (n_per_class, dim)) + mu
    xb = jax.random.normal(k2, (n_per_class, dim)) - mu
    x = jnp.concatenate([xa, xb], axis=0)
    y = jnp.concatenate([jnp.ones(n_per_class), -jnp.ones(n_per_class)])
    return x, y
