"""Fixed-prefix cache: prefill a shared prompt once, reuse its state.

Entries hold the batch-1 `DecodeState` a prefill of the prefix produced,
positionally TRIMMED to the prefix length (`decode.extract_slot`), so a
cached entry costs exactly the slot bytes it covers — for NDSC-quantized
caches that is the packed int32 words + per-vector scales, bits/32 of the
f32 slot. Admission re-seats the entry in full-size caches
(`decode.expand_state`) and continues with the request's own prompt; the
scatter/extract round-trip is bitwise (property-tested per block family),
which is what makes a prefix-hit admission bit-exact with a cold one.

Eviction is LRU over a fixed entry budget. The cache never re-prefills on
its own: `get` misses return None and the engine decides (its registered-
prefix table keeps the token content, so an evicted prefix is rebuilt on
the next cold admission).

Observability: hits / misses / evictions and the bytes a hit saved
(`serve.prefill_bytes_saved` — the slot bytes the admission did not have to
recompute) are counted when a `repro.obs` session is active; the host-side
tallies on the object itself are always maintained.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.models import decode as decode_lib
from repro.obs import core as obs_lib


@dataclasses.dataclass
class PrefixEntry:
    """One cached prefix: its token content, trimmed state, and size."""
    prefix_id: str
    tokens: np.ndarray              # (P,) int32 — validation + extension
    state: decode_lib.DecodeState   # batch-1, positionally trimmed
    nbytes: int                     # state_bytes(state)

    @property
    def length(self) -> int:
        return int(self.tokens.shape[0])


class PrefixCache:
    """LRU map prefix_id -> PrefixEntry with a fixed entry budget."""

    def __init__(self, max_entries: int = 8):
        if max_entries < 1:
            raise ValueError("prefix cache needs max_entries >= 1")
        self.max_entries = max_entries
        self._entries: collections.OrderedDict[str, PrefixEntry] = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, prefix_id: str) -> bool:
        return prefix_id in self._entries

    @property
    def nbytes(self) -> int:
        return sum(e.nbytes for e in self._entries.values())

    def get(self, prefix_id: str) -> PrefixEntry | None:
        """Look up an entry, counting the hit/miss; None on miss."""
        entry = self._entries.get(prefix_id)
        if entry is None:
            self.misses += 1
            obs_lib.counter("serve.prefix.miss", 1, prefix_id=prefix_id)
            return None
        self._entries.move_to_end(prefix_id)
        self.hits += 1
        obs_lib.counter("serve.prefix.hit", 1, prefix_id=prefix_id,
                        prefix_len=entry.length)
        obs_lib.counter("serve.prefill_bytes_saved", entry.nbytes,
                        prefix_id=prefix_id)
        return entry

    def peek(self, prefix_id: str) -> PrefixEntry | None:
        """Entry without touching LRU order or counters (tests, extension)."""
        return self._entries.get(prefix_id)

    def put(self, prefix_id: str, tokens, state) -> PrefixEntry:
        """Insert (or replace) an entry; evicts LRU past the budget."""
        entry = PrefixEntry(prefix_id=prefix_id,
                            tokens=np.asarray(tokens, np.int32),
                            state=state,
                            nbytes=decode_lib.state_bytes(state))
        self._entries[prefix_id] = entry
        self._entries.move_to_end(prefix_id)
        while len(self._entries) > self.max_entries:
            evicted_id, evicted = self._entries.popitem(last=False)
            self.evictions += 1
            obs_lib.counter("serve.prefix.evict", 1, prefix_id=evicted_id,
                            bytes=evicted.nbytes)
        return entry

    def stats(self) -> dict:
        return {"entries": len(self._entries), "bytes": self.nbytes,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions}
