"""Continuous-batching scheduler: slot-level request lifecycle over decode.

The production decode step (repro/dist/step.make_serve_step) runs a fixed
batch of B slots through one token per call. This scheduler keeps those
slots saturated against a request queue:

  * submit(Request)        — enqueue a prompt with a max_new_tokens budget,
  * step()                 — (1) refill any free slot: prefill the next
                             queued prompt in isolation (batch-1) and
                             scatter its caches / position into the slot;
                             (2) run ONE batched decode_step; (3) harvest
                             tokens per active slot, retiring slots that hit
                             their budget or emit `eos_id`,
  * run_to_completion()    — steps until queue and slots drain.

Per-slot positions (DecodeState.pos: (B,)) are what make mid-flight refill
sound: each slot's RoPE phase, ring-cache slot and validity mask depend only
on its own counter. Works with every decode-capable block family, including
the recurrent states (their per-slot rows are scattered the same way) and
the NDSC-quantized cache.

Observability: with a `repro.obs` session active, every `step()` reports
queue depth and batch occupancy gauges, spans around the prefill and the
batched decode dispatch, a per-step harvested-token counter, and — per
retired request — a wall-clock latency histogram (submit → done) plus a
`serve.requests` counter tagged with the retirement reason. Disabled, the
scheduler pays one global load per step; generated tokens are identical
either way.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import decode as decode_lib
from repro.obs import core as obs_lib
from repro.obs import recompile as recompile_lib


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jax.Array            # (S,) int32
    max_new_tokens: int = 32
    tokens_out: list = dataclasses.field(default_factory=list)
    done: bool = False
    # obs bookkeeping (perf_counter stamps; None while obs is disabled)
    submit_time: Optional[float] = None
    finish_time: Optional[float] = None


def _scatter_slot(batched, single, slot: int):
    """Write the batch-1 pytree `single` into slot `slot` of `batched`.

    Cache leaves are (L, B, ...); pos is (B,). Leaves that don't carry a
    batch axis in that position (e.g. the per-layer rotation signs, which
    are identical across slots) are left as-is.
    """

    def put(b, s):
        if b.ndim >= 2 and s.ndim == b.ndim and s.shape[1] == 1 \
                and b.shape[0] == s.shape[0] and b.shape[2:] == s.shape[2:]:
            return b.at[:, slot].set(s[:, 0])        # (L, B, …) cache leaf
        if b.ndim >= 1 and s.ndim == b.ndim and s.shape[0] == 1 \
                and b.shape[1:] == s.shape[1:]:
            return b.at[slot].set(s[0])              # (B, …) leaf (pos)
        return b                                      # shared leaf (signs)

    caches = jax.tree.map(put, batched.caches, single.caches)
    pos = batched.pos.at[slot].set(single.pos[0])
    return decode_lib.DecodeState(caches=caches, pos=pos)


class BatchScheduler:
    def __init__(self, cfg, params, *, slots: int, max_seq: int,
                 eos_id: Optional[int] = None, greedy: bool = True):
        if not cfg.decode_supported:
            raise ValueError(f"{cfg.name} is encoder-only")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.state = decode_lib.init_decode_state(cfg, slots, max_seq)
        self.active: list[Optional[Request]] = [None] * slots
        self.last_token = jnp.zeros((slots, 1), jnp.int32)
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._step = recompile_lib.register(
            "serve.decode_step", jax.jit(
                lambda p, st, t: decode_lib.decode_step(cfg, p, st, t)))
        self._prefill = recompile_lib.register(
            "serve.prefill", jax.jit(
                lambda p, t: decode_lib.prefill(cfg, p, t, max_seq)))

    # -- client API ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        if obs_lib.enabled():
            req.submit_time = time.perf_counter()
            obs_lib.counter("serve.submitted", 1, prompt_len=len(req.prompt))
        self.queue.append(req)

    def idle(self) -> bool:
        return not self.queue and all(r is None for r in self.active)

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while not self.idle() and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

    # -- engine --------------------------------------------------------------
    def step(self) -> None:
        self._refill()
        occupancy = sum(r is not None for r in self.active)
        if obs_lib.enabled():
            obs_lib.gauge("serve.queue_depth", len(self.queue))
            obs_lib.gauge("serve.active_slots", occupancy, slots=self.slots)
            obs_lib.histogram("serve.batch_occupancy",
                              occupancy / self.slots)
        if occupancy == 0:
            return
        with obs_lib.span("serve.decode_step", occupancy=occupancy):
            logits, self.state = self._step(self.params, self.state,
                                            self.last_token)
        obs_lib.counter("serve.tokens", occupancy)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.last_token = next_tok[:, None]
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(next_tok[slot])
            req.tokens_out.append(tok)
            hit_eos = self.eos_id is not None and tok == self.eos_id
            if hit_eos or len(req.tokens_out) >= req.max_new_tokens \
                    or int(self.state.pos[slot]) >= self.max_seq - 1:
                req.done = True
                self._retire(req, "eos" if hit_eos else
                             ("budget" if len(req.tokens_out)
                              >= req.max_new_tokens else "max_seq"))
                self.active[slot] = None

    def _retire(self, req: Request, reason: str) -> None:
        self.finished.append(req)
        if not obs_lib.enabled():
            return
        req.finish_time = time.perf_counter()
        obs_lib.counter("serve.requests", 1, reason=reason,
                        tokens=len(req.tokens_out))
        if req.submit_time is not None:
            obs_lib.histogram("serve.request_latency_s",
                              req.finish_time - req.submit_time,
                              rid=req.rid)

    def _refill(self) -> None:
        for slot in range(self.slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            with obs_lib.span("serve.prefill", slot=slot,
                              prompt_len=len(req.prompt)):
                logits1, state1 = self._prefill(self.params,
                                                req.prompt[None, :])
            self.state = _scatter_slot(self.state, state1, slot)
            first = int(jnp.argmax(logits1[0]))
            req.tokens_out.append(first)
            self.last_token = self.last_token.at[slot, 0].set(first)
            self.active[slot] = req
