"""Deprecation shim: `BatchScheduler` moved to `repro.serve.engine.Engine`.

The v1 continuous-batching scheduler grew into the v2 engine (fixed-prefix
cache, explicit exhaustion status, TTFT accounting); this module keeps the
old name importable — same shim pattern as `benchmarks/roofline.py` →
`hlo_report.py`. Constructing `BatchScheduler` emits `DeprecationWarning`;
importing this module does not (the CI guard pins that).

Behavior changes folded into the alias on purpose:

  * `run_to_completion` now RAISES `EngineExhausted` when `max_steps` runs
    out with requests still queued/active — the v1 scheduler silently
    returned partial results, which was a bug, not a contract.
"""
from __future__ import annotations

import warnings
from typing import Optional

from repro.models.decode import scatter_slot as _scatter_slot  # noqa: F401
#    (re-export: the slot-scatter helper was private here in v1; it is now
#     public API in repro.models.decode, with cache-extract as its inverse)
from repro.serve.engine import Engine, Request, ServeConfig  # noqa: F401


class BatchScheduler(Engine):
    """Deprecated v1 constructor signature over the v2 `Engine`."""

    def __init__(self, cfg, params, *, slots: int, max_seq: int,
                 eos_id: Optional[int] = None, greedy: bool = True):
        warnings.warn(
            "repro.serve.BatchScheduler is deprecated; use "
            "repro.serve.Engine(cfg, params, ServeConfig(slots=..., "
            "max_seq=..., eos_id=...))", DeprecationWarning, stacklevel=2)
        super().__init__(cfg, params,
                         ServeConfig(slots=slots, max_seq=max_seq,
                                     eos_id=eos_id, greedy=greedy))
