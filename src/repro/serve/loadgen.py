"""Bursty open-loop request generation: arrivals don't wait for the engine.

Arrival times follow a piecewise-Poisson process: a base rate with periodic
burst phases at a (much) higher rate, which is what makes saturation
OBSERVABLE — an open-loop clock keeps admitting work while the engine falls
behind, so queue depth and time-to-first-token grow instead of the load
politely throttling itself (closed-loop generators hide exactly this; see
the coordinated-omission literature).

`generate` draws the whole trace up front (deterministic in the seed):
arrival time, prompt length / output budget from uniform mixes, and a
prefix flag with probability `prefix_ratio` (those requests carry
`prefix_id` and a SHORT suffix prompt; the rest carry the full
prefix+suffix tokens, so both classes process the same token count and the
TTFT gap is pure prefill amortization).

`play` replays a trace against an engine on the wall clock without
back-pressure: requests are submitted the moment their arrival time passes
(stamped with the SCHEDULED time, so queueing delay lands in TTFT), and the
engine steps continuously in between.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.serve.engine import Engine, EngineExhausted, Request


@dataclasses.dataclass(frozen=True)
class LoadConfig:
    """Knobs of the open-loop trace (all times in seconds)."""
    n_requests: int = 64
    base_rate: float = 20.0        # arrivals/s outside bursts
    burst_rate: float = 100.0      # arrivals/s inside bursts
    burst_period_s: float = 2.0    # one burst every period
    burst_len_s: float = 0.5       # burst duration within the period
    prompt_len: tuple = (4, 12)    # uniform [lo, hi] suffix tokens
    max_new_tokens: tuple = (4, 16)  # uniform [lo, hi] output budget
    prefix_ratio: float = 0.5      # P(request reuses the shared prefix)
    seed: int = 0

    def rate_at(self, t: float) -> float:
        if self.burst_period_s <= 0:
            return self.base_rate
        return (self.burst_rate
                if (t % self.burst_period_s) < self.burst_len_s
                else self.base_rate)


@dataclasses.dataclass
class Arrival:
    time: float
    request: Request


def generate(cfg: LoadConfig, vocab_size: int, *,
             prefix_id: Optional[str] = None,
             prefix_tokens: Optional[np.ndarray] = None) -> list[Arrival]:
    """Draw the open-loop trace. With `prefix_id`, a `prefix_ratio` share of
    requests reference it (suffix-only prompts); the others get
    `prefix_tokens` prepended so every request covers the same tokens."""
    if prefix_id is not None and prefix_tokens is None:
        raise ValueError("prefix_id needs prefix_tokens for the cold class")
    rng = np.random.default_rng(cfg.seed)
    arrivals: list[Arrival] = []
    t = 0.0
    for rid in range(cfg.n_requests):
        t += rng.exponential(1.0 / cfg.rate_at(t))
        lo, hi = cfg.prompt_len
        suffix = rng.integers(0, vocab_size, rng.integers(lo, hi + 1),
                              dtype=np.int32)
        lo_n, hi_n = cfg.max_new_tokens
        budget = int(rng.integers(lo_n, hi_n + 1))
        use_prefix = (prefix_id is not None
                      and rng.random() < cfg.prefix_ratio)
        if use_prefix:
            prompt, pid = suffix, prefix_id
        else:
            pid = None
            prompt = (np.concatenate([np.asarray(prefix_tokens, np.int32),
                                      suffix])
                      if prefix_tokens is not None else suffix)
        arrivals.append(Arrival(t, Request(
            rid=rid, prompt=jnp.asarray(prompt), max_new_tokens=budget,
            prefix_id=pid)))
    return arrivals


def play(engine: Engine, arrivals: list[Arrival], *,
         max_steps: int = 100_000) -> dict:
    """Replay `arrivals` open-loop on the wall clock until everything
    retires. Returns wall time, decode steps, and the finished requests.
    Raises `EngineExhausted` past `max_steps` (a stuck engine must not
    report throughput)."""
    pending = sorted(arrivals, key=lambda a: a.time)
    t0 = time.perf_counter()
    steps = 0
    i = 0
    while i < len(pending) or not engine.idle():
        now = time.perf_counter() - t0
        while i < len(pending) and pending[i].time <= now:
            req = pending[i].request
            req.submit_time = t0 + pending[i].time   # scheduled, not actual
            engine.submit(req)
            i += 1
        if engine.idle():
            # nothing to decode yet: sleep to (at most) the next arrival
            time.sleep(min(max(pending[i].time - now, 0.0), 0.01))
            continue
        if steps >= max_steps:
            raise EngineExhausted(steps, engine.finished,
                                  len(engine.queue) + len(pending) - i,
                                  sum(r is not None for r in engine.active))
        engine.step()
        steps += 1
    return {"wall_s": time.perf_counter() - t0, "steps": steps,
            "finished": engine.finished}
