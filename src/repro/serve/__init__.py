"""Serving runtime: continuous-batching request scheduler."""
from repro.serve.scheduler import BatchScheduler, Request
