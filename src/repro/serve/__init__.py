"""`repro.serve` — the serving runtime's stable public surface.

    from repro.serve import Engine, ServeConfig, Request

    engine = Engine(model_cfg, params, ServeConfig(slots=8, max_seq=512))
    engine.register_prefix("system", system_tokens, prefill=True)
    engine.submit(Request(rid=0, prompt=suffix, prefix_id="system"))
    finished = engine.run_to_completion()

`BatchScheduler` (the v1 scheduler) remains importable as a deprecated
alias of `Engine` — construction emits `DeprecationWarning`; importing this
package does not.
"""
from repro.serve.engine import (Engine, EngineExhausted, Request,
                                ServeConfig, verify_prefix_contract)
from repro.serve.loadgen import Arrival, LoadConfig, generate, play
from repro.serve.prefixcache import PrefixCache, PrefixEntry
from repro.serve.scheduler import BatchScheduler

__all__ = [
    "Engine",
    "EngineExhausted",
    "Request",
    "ServeConfig",
    "verify_prefix_contract",
    "PrefixCache",
    "PrefixEntry",
    "LoadConfig",
    "Arrival",
    "generate",
    "play",
    "BatchScheduler",
]
