"""Serve engine v2: continuous batching with a fixed-prefix cache.

The engine keeps a fixed batch of decode slots saturated against a request
queue (one batched `decode_step` per `step()` call), and amortizes prefill
across requests that share a prefix:

  * `register_prefix(id, tokens)` — declare a shared prefix (system prompt,
    chat history). Its prefill state is cached after the first admission
    that needs it (or eagerly with `prefill=True`), stored positionally
    trimmed — for NDSC-quantized caches, the packed words + scales.
  * `extend_prefix(id, tokens)`   — append-only growth: a chat history
    extends its cached entry with `decode_tokens` over the new tokens
    instead of re-prefilling from scratch.
  * `submit(Request)`             — `Request.prefix_id` (optional) names a
    registered prefix; the prompt is then the suffix after it.
  * `step()` / `run_to_completion()` — admission + one batched decode;
    `run_to_completion` RAISES `EngineExhausted` when `max_steps` runs out
    with work still queued (the v1 scheduler silently returned partials).

The prefix bit-exactness contract: an admission that HITS the cache and an
admission that MISSES (prefilling the prefix on the spot) run the same two
programs — `prefill(prefix)` then `decode_tokens(prompt)` — with a cache
round-trip (`extract_slot` → `scatter_slot`) in between that is bitwise the
identity. Quantized K/V words, positions, and every subsequent greedy token
are therefore bitwise identical between hit and cold admissions, for both
quantized and unquantized cache configs; `verify_prefix_contract` checks
exactly this and `benchmarks/serve_load.py` refuses to report unless it
holds.

Observability (zero-overhead when disabled, bit-identical tokens either
way): queue depth / occupancy gauges, prefill + extend + decode spans, a
time-to-first-token histogram (`serve.ttft_s`, tagged by admission kind),
prefix hit/miss/evict and prefill-bytes-saved counters, and a
`serve.exhausted` counter when `run_to_completion` gives up.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode as decode_lib
from repro.obs import core as obs_lib
from repro.obs import recompile as recompile_lib
from repro.serve import prefixcache as prefixcache_lib


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """The engine's knobs. `slots` decode lanes, sequences up to `max_seq`
    total positions, retirement on `eos_id` (None: budget/max_seq only),
    and an LRU prefix cache of `prefix_cache_entries` entries."""
    slots: int
    max_seq: int
    eos_id: Optional[int] = None
    prefix_cache_entries: int = 8
    greedy: bool = True       # only greedy decoding is implemented

    def __post_init__(self):
        if self.slots < 1:
            raise ValueError("ServeConfig.slots must be >= 1")
        if not self.greedy:
            raise NotImplementedError("only greedy decoding is implemented")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jax.Array                    # (S,) int32 — suffix after prefix
    max_new_tokens: int = 32
    prefix_id: Optional[str] = None      # a prefix registered on the engine
    tokens_out: list = dataclasses.field(default_factory=list)
    done: bool = False
    admission: Optional[str] = None      # cold | prefix_hit | prefix_cold
    # host-side stamps (perf_counter); loadgen pre-sets submit_time to the
    # scheduled arrival so TTFT under saturation measures queueing too
    submit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def ttft_s(self) -> Optional[float]:
        if self.submit_time is None or self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time


class EngineExhausted(RuntimeError):
    """`run_to_completion(max_steps)` ran out of steps with work pending.

    Carries the partial results: `.finished` (retired requests), `.pending`
    (queued count), `.active` (mid-flight count), `.steps`."""

    def __init__(self, steps: int, finished: list, pending: int, active: int):
        self.steps = steps
        self.finished = finished
        self.pending = pending
        self.active = active
        super().__init__(
            f"engine exhausted after {steps} steps with {pending} queued + "
            f"{active} active requests ({len(finished)} finished)")


@functools.lru_cache(maxsize=64)
def _compiled(cfg, max_seq: int):
    """The jitted programs of an engine, shared process-wide per (model
    config, max_seq): engines over the same model reuse one compilation
    cache, so a warmed server admits new engines (and the benchmark's
    warmup pass covers its timed pass) without recompiling. Admissions run
    as single fused programs (`admit_cold` / `admit_prefix`) with the slot
    index traced — one specialization per prompt length, not per slot."""
    step = recompile_lib.register(
        "serve.decode_step", jax.jit(
            lambda p, st, t: decode_lib.decode_step(cfg, p, st, t)))
    prefill = recompile_lib.register(
        "serve.prefill", jax.jit(
            lambda p, t: decode_lib.prefill(cfg, p, t, max_seq)))
    extend = recompile_lib.register(
        "serve.extend", jax.jit(
            lambda p, st, t: decode_lib.decode_tokens(cfg, p, st, t)))
    admit_cold = recompile_lib.register(
        "serve.admit_cold", jax.jit(
            lambda p, bst, t, slot: decode_lib.prefill_into(
                cfg, p, bst, t, slot, max_seq)))
    admit_prefix = recompile_lib.register(
        "serve.admit_prefix", jax.jit(
            lambda p, bst, est, t, slot: decode_lib.extend_into(
                cfg, p, bst, est, t, slot, max_seq)))
    return step, prefill, extend, admit_cold, admit_prefix


class Engine:
    """The v2 continuous-batching scheduler. See the module docstring."""

    def __init__(self, cfg, params, config: ServeConfig):
        if not cfg.decode_supported:
            raise ValueError(f"{cfg.name} is encoder-only")
        self.cfg = cfg
        self.params = params
        self.config = config
        self.state = decode_lib.init_decode_state(cfg, config.slots,
                                                  config.max_seq)
        self.active: list[Optional[Request]] = [None] * config.slots
        self.last_token = jnp.zeros((config.slots, 1), jnp.int32)
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.prefix_cache = prefixcache_lib.PrefixCache(
            config.prefix_cache_entries)
        self._prefixes: dict[str, np.ndarray] = {}   # id -> tokens
        (self._step, self._prefill, self._extend, self._admit_cold,
         self._admit_prefix) = _compiled(cfg, config.max_seq)

    # -- prefix registry -----------------------------------------------------
    def register_prefix(self, prefix_id: str, tokens, *,
                        prefill: bool = False) -> None:
        """Declare a prefix. With `prefill=True` its state is computed and
        cached now (warmup); otherwise lazily on the first admission."""
        toks = np.asarray(tokens, np.int32)
        if toks.ndim != 1 or toks.shape[0] < 1:
            raise ValueError("prefix tokens must be a non-empty 1-D array")
        if toks.shape[0] >= self.config.max_seq:
            raise ValueError(f"prefix of {toks.shape[0]} tokens cannot fit "
                             f"max_seq={self.config.max_seq}")
        self._prefixes[prefix_id] = toks
        if prefill:
            self._prefill_prefix(prefix_id)

    def extend_prefix(self, prefix_id: str, tokens) -> None:
        """Append-only growth: extend the registered prefix (and its cached
        entry, if present) with `tokens` — a growing chat history pays
        `decode_tokens` over the NEW tokens only, never a re-prefill."""
        more = np.asarray(tokens, np.int32)
        if more.ndim != 1 or more.shape[0] < 1:
            raise ValueError("extension tokens must be a non-empty 1-D array")
        if prefix_id not in self._prefixes:
            raise KeyError(f"unknown prefix {prefix_id!r}: register it first")
        joined = np.concatenate([self._prefixes[prefix_id], more])
        if joined.shape[0] >= self.config.max_seq:
            raise ValueError(f"extended prefix of {joined.shape[0]} tokens "
                             f"cannot fit max_seq={self.config.max_seq}")
        self._prefixes[prefix_id] = joined
        entry = self.prefix_cache.peek(prefix_id)
        if entry is None:
            return                       # rebuilt lazily on next admission
        full = decode_lib.expand_state(self.cfg, entry.state,
                                       self.config.max_seq)
        more_arr = jnp.asarray(more[None, :])
        obs_lib.observe_program_call("serve.extend", self._extend,
                                     (self.params, full, more_arr))
        with obs_lib.span("serve.prefix_extend", prefix_id=prefix_id,
                          new_tokens=int(more.shape[0])):
            _, full = self._extend(self.params, full, more_arr)
        self.prefix_cache.put(prefix_id, joined,
                              decode_lib.extract_slot(full, 0))

    def _prefill_prefix(self, prefix_id: str) -> prefixcache_lib.PrefixEntry:
        toks = self._prefixes[prefix_id]
        toks_arr = jnp.asarray(toks[None, :])
        obs_lib.observe_program_call("serve.prefill", self._prefill,
                                     (self.params, toks_arr))
        with obs_lib.span("serve.prefill", prefix_id=prefix_id,
                          prompt_len=int(toks.shape[0])):
            _, state1 = self._prefill(self.params, toks_arr)
        return self.prefix_cache.put(prefix_id, toks,
                                     decode_lib.extract_slot(state1, 0))

    # -- client API ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.prefix_id is not None and req.prefix_id not in self._prefixes:
            raise KeyError(f"unknown prefix {req.prefix_id!r}: "
                           "register_prefix before submitting against it")
        if len(req.prompt) < 1:
            raise ValueError("requests need a non-empty prompt")
        if req.submit_time is None:
            req.submit_time = time.perf_counter()
        obs_lib.counter("serve.submitted", 1, prompt_len=len(req.prompt),
                        prefix=req.prefix_id or "")
        self.queue.append(req)

    def idle(self) -> bool:
        return not self.queue and all(r is None for r in self.active)

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        """Step until queue and slots drain. Raises `EngineExhausted` if
        `max_steps` runs out first — never silently returns partials."""
        steps = 0
        while not self.idle():
            if steps >= max_steps:
                pending = len(self.queue)
                active = sum(r is not None for r in self.active)
                obs_lib.counter("serve.exhausted", 1, steps=steps,
                                pending=pending, active=active)
                raise EngineExhausted(steps, self.finished, pending, active)
            self.step()
            steps += 1
        return self.finished

    # -- engine --------------------------------------------------------------
    def step(self) -> None:
        self._admit()
        occupancy = sum(r is not None for r in self.active)
        if obs_lib.enabled():
            obs_lib.gauge("serve.queue_depth", len(self.queue))
            obs_lib.gauge("serve.active_slots", occupancy,
                          slots=self.config.slots)
            obs_lib.histogram("serve.batch_occupancy",
                              occupancy / self.config.slots)
        if occupancy == 0:
            return
        obs_lib.observe_program_call(
            "serve.decode_step", self._step,
            (self.params, self.state, self.last_token))
        with obs_lib.span("serve.decode_step", occupancy=occupancy):
            logits, self.state = self._step(self.params, self.state,
                                            self.last_token)
        obs_lib.counter("serve.tokens", occupancy)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.last_token = next_tok[:, None]
        eos = self.config.eos_id
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(next_tok[slot])
            req.tokens_out.append(tok)
            hit_eos = eos is not None and tok == eos
            if hit_eos or len(req.tokens_out) >= req.max_new_tokens \
                    or int(self.state.pos[slot]) >= self.config.max_seq - 1:
                req.done = True
                self._retire(req, "eos" if hit_eos else
                             ("budget" if len(req.tokens_out)
                              >= req.max_new_tokens else "max_seq"))
                self.active[slot] = None

    def _retire(self, req: Request, reason: str) -> None:
        req.finish_time = time.perf_counter()
        self.finished.append(req)
        if not obs_lib.enabled():
            return
        obs_lib.counter("serve.requests", 1, reason=reason,
                        tokens=len(req.tokens_out))
        if req.submit_time is not None:
            obs_lib.histogram("serve.request_latency_s",
                              req.finish_time - req.submit_time, rid=req.rid)

    # -- admission -----------------------------------------------------------
    def _admit(self) -> None:
        for slot in range(self.config.slots):
            if self.active[slot] is not None or not self.queue:
                continue
            self._admit_one(self.queue.pop(0), slot)

    def _admit_one(self, req: Request, slot: int) -> None:
        slot_idx = jnp.int32(slot)
        if req.prefix_id is not None:
            entry = self.prefix_cache.get(req.prefix_id)
            if entry is None:
                req.admission = "prefix_cold"
                entry = self._prefill_prefix(req.prefix_id)
            else:
                req.admission = "prefix_hit"
            obs_lib.observe_program_call(
                "serve.admit_prefix", self._admit_prefix,
                (self.params, self.state, entry.state, req.prompt,
                 slot_idx))
            with obs_lib.span("serve.admit_prefix", slot=slot,
                              prompt_len=len(req.prompt),
                              admission=req.admission):
                self.state, logits1 = self._admit_prefix(
                    self.params, self.state, entry.state, req.prompt,
                    slot_idx)
        else:
            req.admission = "cold"
            obs_lib.observe_program_call(
                "serve.admit_cold", self._admit_cold,
                (self.params, self.state, req.prompt, slot_idx))
            with obs_lib.span("serve.admit_cold", slot=slot,
                              prompt_len=len(req.prompt)):
                self.state, logits1 = self._admit_cold(
                    self.params, self.state, req.prompt, slot_idx)
        first = int(jnp.argmax(logits1))
        req.tokens_out.append(first)
        req.first_token_time = time.perf_counter()
        self.last_token = self.last_token.at[slot, 0].set(first)
        self.active[slot] = req
        if obs_lib.enabled() and req.ttft_s is not None:
            obs_lib.histogram("serve.ttft_s", req.ttft_s,
                              admission=req.admission,
                              prompt_len=len(req.prompt))


# ---------------------------------------------------------------------------
# The prefix bit-exactness contract, as an executable check
# ---------------------------------------------------------------------------
def verify_prefix_contract(cfg, params, serve_cfg: ServeConfig,
                           prefix_tokens, prompt_tokens,
                           max_new_tokens: int = 4) -> dict:
    """Prove the prefix-cache contract on (cfg, params): a prefix-HIT
    admission's slot state (quantized K/V words / f32 cache, positions) and
    its full greedy token stream are bitwise identical to a COLD admission
    that prefills the same prefix on the spot. Raises AssertionError on any
    mismatch; returns the compared evidence sizes."""

    def admit_and_finish(warm: bool):
        eng = Engine(cfg, params, serve_cfg)
        eng.register_prefix("ctr", prefix_tokens, prefill=warm)
        eng.submit(Request(rid=0, prompt=jnp.asarray(prompt_tokens),
                           max_new_tokens=max_new_tokens, prefix_id="ctr"))
        eng.step()                                   # admission + 1st decode
        snap = decode_lib.extract_slot(eng.state, 0, trim=False)
        finished = eng.run_to_completion()
        entry = eng.prefix_cache.peek("ctr")
        return snap, finished[0], entry

    cold_state, cold_req, cold_entry = admit_and_finish(warm=False)
    hit_state, hit_req, hit_entry = admit_and_finish(warm=True)
    assert cold_req.admission == "prefix_cold", cold_req.admission
    assert hit_req.admission == "prefix_hit", hit_req.admission
    assert hit_req.tokens_out == cold_req.tokens_out, \
        (hit_req.tokens_out, cold_req.tokens_out)
    leaves = 0
    for a, b in [(cold_state, hit_state),
                 (cold_entry.state, hit_entry.state)]:
        la, lb = jax.tree.leaves((a.caches, a.pos)), \
            jax.tree.leaves((b.caches, b.pos))
        assert len(la) == len(lb)
        for x, y in zip(la, lb):
            assert np.array_equal(np.asarray(x), np.asarray(y)), \
                "prefix contract violated: slot state differs bitwise"
        leaves += len(la)
    return {"tokens": len(cold_req.tokens_out), "state_leaves": leaves,
            "entry_bytes": cold_entry.nbytes}
