"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory) [arXiv:2405.04517].

mLSTM recurrence (per head, exponential gating with stabilizer m):
    m_t = max(f̃_t + m_{t−1}, ĩ_t)
    i'  = exp(ĩ_t − m_t),  f' = exp(f̃_t + m_{t−1} − m_t)
    C_t = f'·C_{t−1} + i'·v_t k_tᵀ ,  n_t = f'·n_{t−1} + i'·k_t
    h_t = (C_t q_t) / max(|n_tᵀ q_t|, 1) ,  out = σ(o_t) ⊙ h_t

sLSTM keeps a scalar-memory cell per hidden unit with a per-head recurrent
matrix R. Both run as sequential `lax.scan` over time for training and carry
O(1)-per-token state for decoding, which is what makes long_500k decode
feasible for this architecture. xlstm-350m alternates mLSTM/sLSTM blocks; the
scanned unit here is an (mLSTM, sLSTM) pair — num_layers must be even.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class MLSTMParams(NamedTuple):
    wq: jax.Array   # (d, H*dh)
    wk: jax.Array
    wv: jax.Array
    wi: jax.Array   # (d, H) input-gate pre-activation
    wf: jax.Array   # (d, H) forget-gate pre-activation
    wo: jax.Array   # (d, d) output gate
    w_out: jax.Array  # (H*dh, d)


class SLSTMParams(NamedTuple):
    w_in: jax.Array   # (d, 4*d) — i, f, z, o pre-activations from input
    r_rec: jax.Array  # (H, dh, 4*dh) — per-head recurrent weights
    w_out: jax.Array  # (d, d)


class MLSTMState(NamedTuple):
    c: jax.Array  # (B, H, dh, dh)
    n: jax.Array  # (B, H, dh)
    m: jax.Array  # (B, H)


class SLSTMState(NamedTuple):
    c: jax.Array  # (B, d)
    n: jax.Array  # (B, d)
    h: jax.Array  # (B, d)


def mlstm_zero_state(bsz: int, heads: int, dh: int) -> MLSTMState:
    return MLSTMState(jnp.zeros((bsz, heads, dh, dh), jnp.float32),
                      jnp.zeros((bsz, heads, dh), jnp.float32),
                      jnp.full((bsz, heads), -1e30, jnp.float32))


def slstm_zero_state(bsz: int, d: int) -> SLSTMState:
    z = jnp.zeros((bsz, d), jnp.float32)
    return SLSTMState(z, z, z)


def _mlstm_step(qkvif, state: MLSTMState):
    q, k, v, i_pre, f_pre = qkvif            # (B,H,dh)×3, (B,H)×2
    c, n, m = state
    f_log = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    i_log = i_pre.astype(jnp.float32)
    m_new = jnp.maximum(f_log + m, i_log)
    i_g = jnp.exp(i_log - m_new)[..., None]                     # (B,H,1)
    f_g = jnp.exp(f_log + m - m_new)[..., None]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    c = f_g[..., None] * c + i_g[..., None] * vf[..., :, None] * kf[..., None, :]
    n = f_g * n + i_g * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhvk,bhk->bhv", c, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)), 1.0)
    h = num / den[..., None]
    return MLSTMState(c, n, m_new), h                            # h: (B,H,dh)


def mlstm_block(p: MLSTMParams, x: jax.Array, heads: int,
                state: MLSTMState | None = None):
    """x: (B, S, d) → (y: (B, S, d), final state)."""
    bsz, s, d = x.shape
    dh = p.wq.shape[-1] // heads
    if state is None:
        state = mlstm_zero_state(bsz, heads, dh)
    q = (x @ p.wq).reshape(bsz, s, heads, dh)
    k = (x @ p.wk).reshape(bsz, s, heads, dh) * dh ** -0.5
    v = (x @ p.wv).reshape(bsz, s, heads, dh)
    i_pre = (x @ p.wi).reshape(bsz, s, heads)
    f_pre = (x @ p.wf).reshape(bsz, s, heads)
    o_gate = jax.nn.sigmoid(x @ p.wo)                            # (B, S, d)

    def step(st, t):
        st, h = _mlstm_step(t, st)
        return st, h

    xs = tuple(a.transpose(1, 0, 2, 3) if a.ndim == 4 else a.transpose(1, 0, 2)
               for a in (q, k, v, i_pre, f_pre))
    state, hs = jax.lax.scan(step, state, xs)
    h = hs.transpose(1, 0, 2, 3).reshape(bsz, s, heads * dh).astype(x.dtype)
    return (o_gate * (h @ p.w_out)), state


def mlstm_decode_step(p: MLSTMParams, x: jax.Array, heads: int,
                      state: MLSTMState):
    """x: (B, 1, d) → (y: (B, 1, d), state')."""
    y, state = mlstm_block(p, x, heads, state)
    return y, state


def slstm_block(p: SLSTMParams, x: jax.Array, heads: int,
                state: SLSTMState | None = None):
    """x: (B, S, d) → (y, final state). Gates see h_{t−1} via per-head R."""
    bsz, s, d = x.shape
    dh = d // heads
    if state is None:
        state = slstm_zero_state(bsz, d)
    pre_in = x @ p.w_in                                           # (B, S, 4d)

    def step(st, pre_t):
        c, n, h = st.c, st.n, st.h
        h_heads = h.reshape(bsz, heads, dh)
        rec = jnp.einsum("bhk,hkj->bhj", h_heads,
                         p.r_rec.astype(jnp.float32)).reshape(bsz, 4 * d)
        pre = pre_t.astype(jnp.float32) + rec
        i_pre, f_pre, z_pre, o_pre = jnp.split(pre, 4, axis=-1)
        i_g = jnp.exp(jnp.minimum(i_pre, 10.0))       # exp gating, clamped
        f_g = jax.nn.sigmoid(f_pre)
        z = jnp.tanh(z_pre)
        o = jax.nn.sigmoid(o_pre)
        c = f_g * c + i_g * z
        n = f_g * n + i_g
        h = o * c / jnp.maximum(jnp.abs(n), 1.0)
        return SLSTMState(c, n, h), h

    state, hs = jax.lax.scan(step, state, pre_in.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype) @ p.w_out
    return y, state


def slstm_decode_step(p: SLSTMParams, x: jax.Array, heads: int,
                      state: SLSTMState):
    y, state = slstm_block(p, x, heads, state)
    return y, state


def init_mlstm(key, d: int, heads: int, dtype=jnp.float32) -> MLSTMParams:
    ks = jax.random.split(key, 7)
    sc = 0.02
    f = lambda k, shape: (jax.random.normal(k, shape) * sc).astype(dtype)
    return MLSTMParams(wq=f(ks[0], (d, d)), wk=f(ks[1], (d, d)),
                       wv=f(ks[2], (d, d)), wi=f(ks[3], (d, heads)),
                       wf=f(ks[4], (d, heads)) + 3.0, wo=f(ks[5], (d, d)),
                       w_out=f(ks[6], (d, d)))


def init_slstm(key, d: int, heads: int, dtype=jnp.float32) -> SLSTMParams:
    ks = jax.random.split(key, 3)
    sc = 0.02
    dh = d // heads
    f = lambda k, shape: (jax.random.normal(k, shape) * sc).astype(dtype)
    return SLSTMParams(w_in=f(ks[0], (d, 4 * d)),
                       r_rec=f(ks[1], (heads, dh, 4 * dh)),
                       w_out=f(ks[2], (d, d)))
