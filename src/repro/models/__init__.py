"""Model zoo: composable block families + decode paths."""
