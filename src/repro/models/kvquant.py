"""NDSC-quantized KV cache (beyond-paper: the codec applied to serving).

Each cache entry — one (dh,)-vector per (position, kv-head) — is stored
Hadamard-rotated (fixed per-head sign vector D_h, shared-randomness contract
as in the gradient codec) and uniformly quantized at `bits` per element with
a per-vector ‖·‖∞ scale. The democratic flattening is exactly why this works
at 4–8 bits: attention K/V vectors have outlier channels, and rotating
spreads them so one scale covers the vector (the same argument as paper
Thm. 1, at N = dh).

Orthonormality does the rest: ⟨q, k⟩ = ⟨Hq', Hk'⟩, so queries are rotated
once per step and attention runs entirely in the rotated basis; only the
(G, dh) output accumulator is inverse-rotated. Deployment path is the fused
Pallas kernel (repro/kernels/quantdecode.py) — packed words stream HBM→VMEM
once, bits/32 of the f32 traffic.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref
from repro.kernels import quantdecode as qd_kernel


class QuantKVCache(NamedTuple):
    k_words: jax.Array    # (L, B, C, K, dh·bits/32) int32
    k_scale: jax.Array    # (L, B, C, K) f32
    v_words: jax.Array
    v_scale: jax.Array


def head_signs(seed: int, layer: jax.Array | int, num_kv: int,
               dh: int) -> jax.Array:
    """±1 rotation signs per (kv-head, channel), deterministic per layer."""
    key = jax.random.fold_in(jax.random.key(seed ^ 0x5EED), layer)
    return jax.random.rademacher(key, (num_kv, dh),
                                 dtype=jnp.int8).astype(jnp.float32)


def rotate(x: jax.Array, signs: jax.Array) -> jax.Array:
    """x: (..., K, dh) → H(D x): rotated basis."""
    return kernel_ops.fwht(x * signs)


def init_cache(num_layers: int, batch: int, cache_len: int, num_kv: int,
               dh: int, bits: int) -> QuantKVCache:
    wpv = dh * bits // 32
    z = lambda *s: jnp.zeros(s, jnp.int32)
    zf = lambda *s: jnp.zeros(s, jnp.float32)
    return QuantKVCache(
        k_words=z(num_layers, batch, cache_len, num_kv, wpv),
        k_scale=zf(num_layers, batch, cache_len, num_kv),
        v_words=z(num_layers, batch, cache_len, num_kv, wpv),
        v_scale=zf(num_layers, batch, cache_len, num_kv),
    )


def encode_entry(x: jax.Array, signs: jax.Array, bits: int):
    """x: (B, 1, K, dh) new K or V → (words (B,1,K,wpv), scale (B,1,K))."""
    xr = rotate(x.astype(jnp.float32), signs)
    scale = jnp.max(jnp.abs(xr), axis=-1)
    words = kernel_ops.quantize_pack(xr, scale[..., None], bits)
    return words, scale


def quant_decode_attention(q: jax.Array, cache_layer: tuple, kv_len,
                           signs: jax.Array, bits: int,
                           use_pallas: bool = False) -> jax.Array:
    """q: (B, 1, H, dh); cache_layer: (kw, ks, vw, vs) for ONE layer with
    shapes (B, C, K, …). Returns (B, 1, H, dh)."""
    b, _, h, dh = q.shape
    kw, ks, vw, vs = cache_layer
    kh = kw.shape[2]
    g = h // kh
    scale = dh ** -0.5
    qg = q.reshape(b, kh, g, dh).astype(jnp.float32) * scale
    qr = kernel_ops.fwht(qg * signs[:, None, :])          # rotate queries
    if use_pallas:
        out = qd_kernel.quant_decode_attention_pallas(
            qr, kw, ks, vw, vs, jnp.broadcast_to(kv_len, (b,)), bits=bits)
    else:
        out = kernel_ref.quant_decode_attention(
            qr, kw, ks, vw, vs, jnp.broadcast_to(kv_len, (b,)), bits=bits)
    # inverse of the per-head D sign (H already inverted inside)
    out = out * signs[:, None, :]
    return out.reshape(b, 1, h, dh).astype(q.dtype)
