"""Composable model zoo: one config schema, six block families.

Block families (selected by ModelConfig.block):
  attn_mlp        — dense decoder (phi3 / yi / llama3.2 / mistral-large / pixtral)
  attn_moe        — attention + top-k MoE FFN (mixtral, SWA)
  attn_moe_dense  — attention + [dense-residual MLP ∥ MoE] (arctic)
  hybrid          — parallel attention + Mamba heads, then MLP (hymba)
  xlstm_pair      — (mLSTM, sLSTM) pair per scanned unit (xlstm)
  encoder         — bidirectional encoder, frame classifier head (hubert)

All stacks run as `lax.scan` over stacked layer weights (compile time O(1) in
depth), with optional `jax.checkpoint` remat per layer. Decode paths carry
explicit caches (ring-buffered KV for sliding-window attention, O(1) SSM /
xLSTM state), which is what makes decode_32k and long_500k lower with bounded
memory.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    block: str = "attn_mlp"
    causal: bool = True
    attention_kind: str = "full"        # full | sliding
    window: int = 4096
    rope_theta: float = 500000.0
    # moe
    num_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    moe_aux_coeff: float = 0.01
    # ssm (hybrid)
    ssm_state: int = 16
    d_inner: Optional[int] = None
    ssm_scan: str = "sequential"         # or "associative" (log-depth,
    #   trades a (B,S,di,n) intermediate for sequence parallelism — §Perf)
    # io / frontends (vlm & audio backbones consume precomputed embeddings)
    frontend: Optional[str] = None       # None | vision | audio
    num_patches: int = 1024
    norm_eps: float = 1e-5
    dtype: str = "float32"
    vocab_pad_multiple: int = 256
    remat: bool = True
    seq_parallel: bool = False           # shard S over "model" at block edges
    kv_quant_bits: Optional[int] = None  # NDSC-packed KV cache (4 or 8);
    #   decode reads bits/32 of the f32 cache bytes (fused Pallas kernel on
    #   TPU — repro/kernels/quantdecode.py)
    source: str = ""                     # citation for the config

    # -- derived -------------------------------------------------------------
    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.dh

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.dh

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    @property
    def di(self) -> int:
        return self.d_inner or self.d_model

    @property
    def num_scanned(self) -> int:
        if self.block == "xlstm_pair":
            if self.num_layers % 2:
                raise ValueError("xlstm_pair needs an even layer count")
            return self.num_layers // 2
        return self.num_layers

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def decode_supported(self) -> bool:
        return self.block != "encoder"

    @property
    def subquadratic(self) -> bool:
        """Can this arch run long_500k (sub-quadratic sequence mixing)?"""
        return (self.block in ("xlstm_pair",)
                or self.attention_kind == "sliding")

    def window_or_none(self) -> Optional[int]:
        return self.window if self.attention_kind == "sliding" else None

    def decode_cache_len(self, seq_len: int) -> int:
        if self.attention_kind == "sliding":
            return min(self.window, seq_len)
        return seq_len


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------
def _norm(d, dtype):
    return jnp.ones((d,), dtype)


def _dense(key, shape, dtype, scale=0.02):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def init_block(key: jax.Array, cfg: ModelConfig) -> dict:
    dt = cfg.compute_dtype
    d = cfg.d_model
    ks = iter(jax.random.split(key, 24))
    p: dict[str, Any] = {}
    has_attn = cfg.block in ("attn_mlp", "attn_moe", "attn_moe_dense",
                             "hybrid", "encoder")
    if has_attn:
        p["attn_norm"] = _norm(d, dt)
        p["wq"] = _dense(next(ks), (d, cfg.q_dim), dt)
        p["wk"] = _dense(next(ks), (d, cfg.kv_dim), dt)
        p["wv"] = _dense(next(ks), (d, cfg.kv_dim), dt)
        p["wo"] = _dense(next(ks), (cfg.q_dim, d), dt)
    if cfg.block == "hybrid":
        p["mamba"] = ssm_lib.init_mamba(next(ks), d, cfg.di, cfg.ssm_state, dt)
    if cfg.block in ("attn_mlp", "hybrid", "attn_moe_dense"):
        p["mlp_norm"] = _norm(d, dt)
        p["w_gate"] = _dense(next(ks), (d, cfg.d_ff), dt)
        p["w_up"] = _dense(next(ks), (d, cfg.d_ff), dt)
        p["w_down"] = _dense(next(ks), (cfg.d_ff, d), dt)
    if cfg.block == "encoder":
        p["mlp_norm"] = _norm(d, dt)
        p["w_up"] = _dense(next(ks), (d, cfg.d_ff), dt)
        p["w_down"] = _dense(next(ks), (cfg.d_ff, d), dt)
    if cfg.block in ("attn_moe", "attn_moe_dense"):
        p["moe_norm"] = _norm(d, dt)
        p["router"] = _dense(next(ks), (d, cfg.num_experts), dt)
        p["e_gate"] = _dense(next(ks), (cfg.num_experts, d, cfg.d_ff), dt)
        p["e_up"] = _dense(next(ks), (cfg.num_experts, d, cfg.d_ff), dt)
        p["e_down"] = _dense(next(ks), (cfg.num_experts, cfg.d_ff, d), dt)
    if cfg.block == "xlstm_pair":
        p["m_norm"] = _norm(d, dt)
        p["mlstm"] = xlstm_lib.init_mlstm(next(ks), d, cfg.num_heads, dt)
        p["s_norm"] = _norm(d, dt)
        p["slstm"] = xlstm_lib.init_slstm(next(ks), d, cfg.num_heads, dt)
    return p


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    dt = cfg.compute_dtype
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    block_keys = jax.random.split(k_blocks, cfg.num_scanned)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(block_keys)
    params = {"blocks": blocks, "final_norm": _norm(cfg.d_model, dt)}
    if cfg.frontend != "audio":
        params["embed"] = _dense(k_embed, (cfg.padded_vocab, cfg.d_model), dt)
    params["head"] = _dense(k_head, (cfg.d_model, cfg.padded_vocab), dt)
    return params


def param_count(cfg: ModelConfig) -> int:
    import math
    shapes = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))
    return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top-k of E experts active)."""
    total = param_count(cfg)
    if cfg.num_experts:
        expert_leaf = 3 * cfg.num_experts * cfg.d_model * cfg.d_ff * cfg.num_layers
        active = expert_leaf * cfg.top_k // cfg.num_experts
        return total - expert_leaf + active
    return total


# ---------------------------------------------------------------------------
# Block forward (training / prefill share this; decode has its own path)
# ---------------------------------------------------------------------------
def _attn_qkv(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array):
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, cfg.num_heads, cfg.dh)
    k = (x @ p["wk"]).reshape(b, s, cfg.num_kv_heads, cfg.dh)
    v = (x @ p["wv"]).reshape(b, s, cfg.num_kv_heads, cfg.dh)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _self_attention(cfg: ModelConfig, p: dict, h: jax.Array,
                    positions: jax.Array, collect_kv: bool):
    b, s, _ = h.shape
    x = L.rmsnorm(h, p["attn_norm"], cfg.norm_eps)
    q, k, v = _attn_qkv(cfg, p, x, positions)
    o = L.blockwise_attention(q, k, v, causal=cfg.causal,
                              window=cfg.window_or_none())
    out = o.reshape(b, s, cfg.q_dim) @ p["wo"]
    return (out, (k, v)) if collect_kv else (out, None)


def block_forward(cfg: ModelConfig, p: dict, h: jax.Array,
                  positions: jax.Array, collect_kv: bool = False):
    """One scanned unit. Returns (h, aux_loss, kv or None)."""
    aux = jnp.zeros((), jnp.float32)
    kv = None
    if cfg.block in ("attn_mlp", "attn_moe", "attn_moe_dense", "encoder"):
        attn_out, kv = _self_attention(cfg, p, h, positions, collect_kv)
        h = h + attn_out
    if cfg.block == "hybrid":
        attn_out, kv = _self_attention(cfg, p, h, positions, collect_kv)
        x = L.rmsnorm(h, p["attn_norm"], cfg.norm_eps)
        scan_fn = (ssm_lib.mamba_assoc_scan if cfg.ssm_scan == "associative"
                   else ssm_lib.mamba_scan)
        mamba_out, _ = scan_fn(p["mamba"], x)
        h = h + 0.5 * (attn_out + mamba_out)
    if cfg.block in ("attn_mlp", "hybrid"):
        x = L.rmsnorm(h, p["mlp_norm"], cfg.norm_eps)
        h = h + L.swiglu(x, p["w_gate"], p["w_up"], p["w_down"])
    if cfg.block == "encoder":
        x = L.rmsnorm(h, p["mlp_norm"], cfg.norm_eps)
        h = h + L.gelu_mlp(x, p["w_up"], p["w_down"])
    if cfg.block in ("attn_moe", "attn_moe_dense"):
        x = L.rmsnorm(h, p["moe_norm"], cfg.norm_eps)
        moe_out, moe_aux = moe_lib.moe_ffn(
            x, p["router"], p["e_gate"], p["e_up"], p["e_down"],
            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
            return_aux=True)
        aux = aux + moe_aux["load_balance_loss"]
        if cfg.block == "attn_moe_dense":       # arctic: dense-residual ∥ MoE
            xm = L.rmsnorm(h, p["mlp_norm"], cfg.norm_eps)
            moe_out = moe_out + L.swiglu(xm, p["w_gate"], p["w_up"], p["w_down"])
        h = h + moe_out
    if cfg.block == "xlstm_pair":
        x = L.rmsnorm(h, p["m_norm"], cfg.norm_eps)
        m_out, _ = xlstm_lib.mlstm_block(p["mlstm"], x, cfg.num_heads)
        h = h + m_out
        x = L.rmsnorm(h, p["s_norm"], cfg.norm_eps)
        s_out, _ = xlstm_lib.slstm_block(p["slstm"], x, cfg.num_heads)
        h = h + s_out
    return h, aux, kv


# ---------------------------------------------------------------------------
# Full forward / loss
# ---------------------------------------------------------------------------
def _embed_inputs(cfg: ModelConfig, params: dict, batch: dict):
    """Returns (h, positions, targets)."""
    dt = cfg.compute_dtype
    if cfg.frontend == "audio":
        h = batch["embeds"].astype(dt)
        positions = jnp.arange(h.shape[1], dtype=jnp.int32)[None, :]
        return h, positions, batch.get("targets")
    if cfg.frontend == "vision":
        img = batch["image_embeds"].astype(dt)            # (B, P, d)
        toks = batch["tokens"]                            # (B, S_text + 1)
        tok_in, targets = toks[:, :-1], toks[:, 1:]
        th = L.embed(tok_in, params["embed"]).astype(dt)
        h = jnp.concatenate([img, th], axis=1)
        positions = jnp.arange(h.shape[1], dtype=jnp.int32)[None, :]
        # only text positions contribute to the loss
        pad = jnp.full(img.shape[:2], -1, targets.dtype)
        return h, positions, jnp.concatenate([pad, targets], axis=1)
    toks = batch["tokens"]
    tok_in, targets = toks[:, :-1], toks[:, 1:]
    h = L.embed(tok_in, params["embed"]).astype(dt)
    positions = jnp.arange(h.shape[1], dtype=jnp.int32)[None, :]
    return h, positions, targets


def forward_hidden(cfg: ModelConfig, params: dict, h: jax.Array,
                   positions: jax.Array):
    """Scan the block stack. Returns (h, total_aux)."""
    seq_spec = None
    if cfg.seq_parallel:
        # Megatron-SP (§Perf iteration 3): pin the residual stream to
        # sequence-sharded over the tensor-parallel axis at block boundaries.
        # GSPMD then lowers the per-block boundary communication as
        # reduce-scatter + all-gather pairs instead of full all-reduces, and
        # the resident activations between blocks shrink by the model-axis
        # size. Raw PartitionSpec: resolves against the context mesh (works
        # under shard_map's manual data axes; "model" stays auto).
        from jax.sharding import PartitionSpec as P
        seq_spec = P(None, "model", None)

    def body(carry, block_p):
        hh, aux = carry
        if seq_spec is not None:
            hh = jax.lax.with_sharding_constraint(hh, seq_spec)
        hh, a, _ = block_forward(cfg, block_p, hh, positions)
        return (hh, aux + a), None

    fn = jax.checkpoint(body) if cfg.remat else body
    (h, aux), _ = jax.lax.scan(fn, (h, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    return L.rmsnorm(h, params["final_norm"], cfg.norm_eps), aux


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    h, positions, targets = _embed_inputs(cfg, params, batch)
    h, aux = forward_hidden(cfg, params, h, positions)
    ce = L.chunked_softmax_xent(h, params["head"], targets)
    return ce + cfg.moe_aux_coeff * aux


def logits_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    """Full (B, S, V) logits — small models / tests only."""
    h, positions, _ = _embed_inputs(cfg, params, batch)
    h, _ = forward_hidden(cfg, params, h, positions)
    return (h @ params["head"]).astype(jnp.float32)
