"""Decode (serving) path: one-token steps against explicit caches.

Per-layer caches are stacked on a leading L axis so the whole stack runs as a
single `lax.scan` over (block params, block cache) — mirrors the training
forward. Cache kinds per block family:

  attention    — KV cache (L, B, C, K, dh). For sliding-window attention the
                 cache is a ring buffer of C = window slots (position p lives
                 in slot p % C); softmax is permutation invariant so ring order
                 never needs unrotating, and slot validity is simply
                 slot < pos. This is what bounds long_500k decode state for
                 hymba / mixtral to the window, not the 524k sequence.
  hybrid       — KV ring cache + Mamba state (L, B, di, n): O(1) per token.
  xlstm_pair   — mLSTM matrix state (L, B, H, dh, dh) + sLSTM scalar state:
                 O(1) per token, the reason xlstm runs long_500k natively.
  moe          — KV cache only (experts are stateless).
  encoder      — no decode (raises; callers consult cfg.decode_supported).

`pos` is a per-slot (B,) counter: the assigned decode shapes advance in
lockstep, and the continuous-batching scheduler (repro/serve) refills
finished slots independently mid-flight.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models import moe as moe_lib
from repro.models.model import ModelConfig


class DecodeState(NamedTuple):
    """Stacked per-layer caches + per-slot position counters.

    `pos` is (B,) — each batch slot advances independently, which is what
    lets the continuous-batching scheduler (repro/serve) refill finished
    slots with fresh prompts mid-flight."""

    caches: dict            # leaves with leading (num_scanned,) axis
    pos: jax.Array          # (B,) int32 — tokens already in each slot


# ---------------------------------------------------------------------------
# State construction
# ---------------------------------------------------------------------------
def cache_len(cfg: ModelConfig, max_seq: int) -> int:
    return cfg.decode_cache_len(max_seq)


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int,
                      dtype=None) -> DecodeState:
    """Zero caches sized for decoding up to `max_seq` total positions."""
    if not cfg.decode_supported:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    dt = dtype or cfg.compute_dtype
    nl = cfg.num_scanned
    c = cache_len(cfg, max_seq)
    caches: dict = {}
    if cfg.block in ("attn_mlp", "attn_moe", "attn_moe_dense", "hybrid"):
        if cfg.kv_quant_bits:
            from repro.models import kvquant
            bits = cfg.kv_quant_bits
            wpv = cfg.dh * bits // 32
            for side in ("k", "v"):
                caches[f"{side}_words"] = jnp.zeros(
                    (nl, batch, c, cfg.num_kv_heads, wpv), jnp.int32)
                caches[f"{side}_scale"] = jnp.zeros(
                    (nl, batch, c, cfg.num_kv_heads), jnp.float32)
            caches["signs"] = jnp.stack([
                kvquant.head_signs(0, layer, cfg.num_kv_heads, cfg.dh)
                for layer in range(nl)])
        else:
            caches["k"] = jnp.zeros((nl, batch, c, cfg.num_kv_heads, cfg.dh),
                                    dt)
            caches["v"] = jnp.zeros((nl, batch, c, cfg.num_kv_heads, cfg.dh),
                                    dt)
    if cfg.block == "hybrid":
        caches["ssm_h"] = jnp.zeros((nl, batch, cfg.di, cfg.ssm_state),
                                    jnp.float32)
    if cfg.block == "xlstm_pair":
        dh = cfg.d_model // cfg.num_heads
        caches["m_c"] = jnp.zeros((nl, batch, cfg.num_heads, dh, dh), jnp.float32)
        caches["m_n"] = jnp.zeros((nl, batch, cfg.num_heads, dh), jnp.float32)
        caches["m_m"] = jnp.full((nl, batch, cfg.num_heads), -1e30, jnp.float32)
        caches["s_c"] = jnp.zeros((nl, batch, cfg.d_model), jnp.float32)
        caches["s_n"] = jnp.zeros((nl, batch, cfg.d_model), jnp.float32)
        caches["s_h"] = jnp.zeros((nl, batch, cfg.d_model), jnp.float32)
    return DecodeState(caches=caches, pos=jnp.zeros((batch,), jnp.int32))


def decode_state_specs(cfg: ModelConfig, batch: int, max_seq: int) -> DecodeState:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    shapes = jax.eval_shape(
        lambda: init_decode_state(cfg, batch, max_seq))
    return shapes


# ---------------------------------------------------------------------------
# One-layer decode
# ---------------------------------------------------------------------------
def _attn_decode(cfg: ModelConfig, p: dict, cache: dict, h: jax.Array,
                 pos: jax.Array, c: int):
    """Self-attention for one new token; returns (out, new k/v cache)."""
    b = h.shape[0]
    x = L.rmsnorm(h, p["attn_norm"], cfg.norm_eps)
    q = (x @ p["wq"]).reshape(b, 1, cfg.num_heads, cfg.dh)
    k = (x @ p["wk"]).reshape(b, 1, cfg.num_kv_heads, cfg.dh)
    v = (x @ p["wv"]).reshape(b, 1, cfg.num_kv_heads, cfg.dh)
    positions = pos[:, None]                     # (B, 1) per-slot positions
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    rows = jnp.arange(b)
    slot = jnp.mod(pos, c)                      # (B,) ring slots
    kv_len = jnp.minimum(pos + 1, c)            # (B,) valid lengths

    if cfg.kv_quant_bits:                        # NDSC-packed cache path
        from repro.models import kvquant
        bits = cfg.kv_quant_bits
        signs = cache["signs"]                   # (K, dh) — this layer's D
        new_cache = {"signs": signs}
        for side, new in (("k", k), ("v", v)):
            words, scale = kvquant.encode_entry(new, signs, bits)
            new_cache[f"{side}_words"] = \
                cache[f"{side}_words"].at[rows, slot].set(words[:, 0])
            new_cache[f"{side}_scale"] = \
                cache[f"{side}_scale"].at[rows, slot].set(scale[:, 0])
        o = kvquant.quant_decode_attention(
            q, (new_cache["k_words"], new_cache["k_scale"],
                new_cache["v_words"], new_cache["v_scale"]),
            kv_len, signs, bits)
        out = o.reshape(b, 1, cfg.q_dim) @ p["wo"]
        return out, new_cache

    k_cache = cache["k"].at[rows, slot].set(k[:, 0])
    v_cache = cache["v"].at[rows, slot].set(v[:, 0])
    o = L.decode_attention(q, k_cache, v_cache, kv_len=kv_len)
    out = o.reshape(b, 1, cfg.q_dim) @ p["wo"]
    return out, {"k": k_cache, "v": v_cache}


def block_decode(cfg: ModelConfig, p: dict, cache: dict, h: jax.Array,
                 pos: jax.Array, c: int):
    """One scanned unit, one token. h: (B, 1, d) → (h, new cache)."""
    new_cache: dict = {}
    if cfg.block in ("attn_mlp", "attn_moe", "attn_moe_dense"):
        attn_out, kv = _attn_decode(cfg, p, cache, h, pos, c)
        new_cache.update(kv)
        h = h + attn_out
    if cfg.block == "hybrid":
        attn_out, kv = _attn_decode(cfg, p, cache, h, pos, c)
        new_cache.update(kv)
        x = L.rmsnorm(h, p["attn_norm"], cfg.norm_eps)
        mamba_out, ssm_h = ssm_lib.mamba_decode_step(p["mamba"], x,
                                                     cache["ssm_h"])
        new_cache["ssm_h"] = ssm_h
        h = h + 0.5 * (attn_out + mamba_out)
    if cfg.block in ("attn_mlp", "hybrid"):
        x = L.rmsnorm(h, p["mlp_norm"], cfg.norm_eps)
        h = h + L.swiglu(x, p["w_gate"], p["w_up"], p["w_down"])
    if cfg.block in ("attn_moe", "attn_moe_dense"):
        x = L.rmsnorm(h, p["moe_norm"], cfg.norm_eps)
        moe_out = moe_lib.moe_ffn(
            x, p["router"], p["e_gate"], p["e_up"], p["e_down"],
            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor)
        if cfg.block == "attn_moe_dense":
            xm = L.rmsnorm(h, p["mlp_norm"], cfg.norm_eps)
            moe_out = moe_out + L.swiglu(xm, p["w_gate"], p["w_up"], p["w_down"])
        h = h + moe_out
    if cfg.block == "xlstm_pair":
        x = L.rmsnorm(h, p["m_norm"], cfg.norm_eps)
        m_state = xlstm_lib.MLSTMState(cache["m_c"], cache["m_n"], cache["m_m"])
        m_out, m_state = xlstm_lib.mlstm_decode_step(p["mlstm"], x,
                                                     cfg.num_heads, m_state)
        h = h + m_out
        x = L.rmsnorm(h, p["s_norm"], cfg.norm_eps)
        s_state = xlstm_lib.SLSTMState(cache["s_c"], cache["s_n"], cache["s_h"])
        s_out, s_state = xlstm_lib.slstm_decode_step(p["slstm"], x,
                                                     cfg.num_heads, s_state)
        h = h + s_out
        new_cache.update(m_c=m_state.c, m_n=m_state.n, m_m=m_state.m,
                         s_c=s_state.c, s_n=s_state.n, s_h=s_state.h)
    return h, new_cache


# ---------------------------------------------------------------------------
# Full-stack decode step
# ---------------------------------------------------------------------------
def decode_step(cfg: ModelConfig, params: dict, state: DecodeState,
                tokens: jax.Array):
    """tokens: (B, 1) int32 → (logits (B, padded_vocab) f32, new state)."""
    if not cfg.decode_supported:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    dt = cfg.compute_dtype
    h = L.embed(tokens, params["embed"]).astype(dt)          # (B, 1, d)
    if "k" in state.caches:
        c = state.caches["k"].shape[2]
    elif "k_words" in state.caches:
        c = state.caches["k_words"].shape[2]
    else:
        c = 0

    def body(hh, xs):
        block_p, block_cache = xs
        hh, new_cache = block_decode(cfg, block_p, block_cache, hh,
                                     state.pos, c)
        return hh, new_cache

    h, new_caches = jax.lax.scan(body, h, (params["blocks"], state.caches))
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = (h[:, 0] @ params["head"]).astype(jnp.float32)  # (B, V)
    return logits, DecodeState(caches=new_caches, pos=state.pos + 1)


def greedy_token(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]


def decode_tokens(cfg: ModelConfig, params: dict, state: DecodeState,
                  tokens: jax.Array):
    """Feed a (B, S) block of KNOWN tokens through S decode steps.

    The continuation primitive behind prefix-cache admission: restoring a
    cached prefix state and decode_tokens-ing the prompt is, by construction,
    the same sequence of `decode_step` applications a cold admission runs —
    which is what makes the prefix-hit bit-exactness contract structural
    rather than numerical. Returns (logits after the LAST token (B, V),
    state advanced by S)."""
    if tokens.ndim != 2 or tokens.shape[1] < 1:
        raise ValueError(f"decode_tokens needs (B, S>=1) tokens, "
                         f"got {tokens.shape}")

    def body(st, t):
        logits, st = decode_step(cfg, params, st, t[:, None])
        return st, logits

    state, logits_seq = jax.lax.scan(body, state, jnp.swapaxes(tokens, 0, 1))
    return logits_seq[-1], state


# ---------------------------------------------------------------------------
# Slot scatter / extract: the continuous-batching and prefix-cache primitives
# ---------------------------------------------------------------------------
# Cache leaves indexed (L, B, C, ...) by position along axis 2 — the leaves a
# prefix-cache entry trims to its own length. Everything else with a batch
# axis (recurrent states, pos) is per-slot but position-free; "signs" is the
# per-layer rotation shared by every slot.
POSITIONAL_CACHE_KEYS = frozenset(
    {"k", "v", "k_words", "k_scale", "v_words", "v_scale"})
SHARED_CACHE_KEYS = frozenset({"signs"})


def scatter_slot(batched: DecodeState, single: DecodeState,
                 slot: int) -> DecodeState:
    """Write the batch-1 `single` into slot `slot` of `batched`.

    Positional leaves of `single` may be trimmed to a prefix length C' <= C
    (see `extract_slot`); the slot's remaining C - C' positions are zeroed,
    so the result is bitwise the state a fresh batch-1 prefill of the same
    tokens would produce — the prefix-cache bit-exactness contract."""
    caches = {}
    for name, b in batched.caches.items():
        s = single.caches[name]
        if name in SHARED_CACHE_KEYS:
            caches[name] = b
        elif name in POSITIONAL_CACHE_KEYS:
            col = jnp.zeros(b.shape[:1] + b.shape[2:], b.dtype)  # (L, C, ...)
            col = col.at[:, :s.shape[2]].set(s[:, 0])
            caches[name] = b.at[:, slot].set(col)
        else:                                   # per-slot, position-free
            caches[name] = b.at[:, slot].set(s[:, 0])
    return DecodeState(caches=caches,
                       pos=batched.pos.at[slot].set(single.pos[0]))


def extract_slot(state: DecodeState, slot: int, *,
                 trim: bool = True) -> DecodeState:
    """Gather slot `slot` of a batched state into a batch-1 state.

    With `trim` (the default) positional cache leaves keep only their
    occupied columns — min(pos, C) of them; ring caches past their window
    keep all C. `scatter_slot(init, extract_slot(st, i), j)` reproduces
    slot i of `st` bitwise in slot j (zeros elsewhere), which is the
    round-trip the prefix cache and the property tests rely on."""
    length = int(state.pos[slot])
    caches = {}
    for name, x in state.caches.items():
        if name in SHARED_CACHE_KEYS:
            caches[name] = x
        elif name in POSITIONAL_CACHE_KEYS:
            col = x[:, slot:slot + 1]
            if trim:
                col = col[:, :, :min(length, x.shape[2])]
            caches[name] = col
        else:
            caches[name] = x[:, slot:slot + 1]
    return DecodeState(caches=caches, pos=state.pos[slot:slot + 1])


def expand_state(cfg: ModelConfig, single: DecodeState,
                 max_seq: int) -> DecodeState:
    """Inverse of `extract_slot`'s trim: a (possibly trimmed) batch-1 state
    re-seated in full-size caches for decoding up to `max_seq`."""
    return scatter_slot(init_decode_state(cfg, 1, max_seq), single, 0)


def prefill_into(cfg: ModelConfig, params: dict, batched: DecodeState,
                 tokens: jax.Array, slot, max_seq: int):
    """Cold admission as ONE program: batch-1 prefill of `tokens` (S,)
    scattered into slot `slot` of `batched`. Returns (new batched state,
    last-token logits (V,)). `slot` may be traced — one compiled
    specialization serves every slot at a given prompt length."""
    logits, single = prefill(cfg, params, tokens[None, :], max_seq)
    return scatter_slot(batched, single, slot), logits[0]


def extend_into(cfg: ModelConfig, params: dict, batched: DecodeState,
                entry: DecodeState, tokens: jax.Array, slot, max_seq: int):
    """Prefix admission as ONE program: re-seat the (trimmed) batch-1
    `entry` in full-size caches, decode the (S,) prompt continuation, and
    scatter the result into slot `slot` of `batched`. Returns (new batched
    state, last-token logits (V,)). Hit and miss admissions both run this
    on bitwise-equal entries — the prefix contract."""
    single = expand_state(cfg, entry, max_seq)
    logits, single = decode_tokens(cfg, params, single, tokens[None, :])
    return scatter_slot(batched, single, slot), logits[0]


def state_bytes(state: DecodeState) -> int:
    """Device bytes held by the per-slot leaves of `state` (shared leaves —
    the rotation signs — excluded): what a prefix-cache hit avoids
    recomputing and rewriting."""
    total = state.pos.size * state.pos.dtype.itemsize
    for name, x in state.caches.items():
        if name not in SHARED_CACHE_KEYS:
            total += x.size * x.dtype.itemsize
    return int(total)


# ---------------------------------------------------------------------------
# Prefill: run the training forward once, collect the caches
# ---------------------------------------------------------------------------
def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            max_seq: int):
    """tokens: (B, S) prompt → (last-token logits (B, V), DecodeState at S).

    Uses the blockwise training forward with collect_kv; for sliding-window
    ring caches only the last `window` positions are written (ring layout
    slot = position % C, matching decode_step's insert rule).
    """
    if not cfg.decode_supported:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    dt = cfg.compute_dtype
    b, s = tokens.shape
    c = cache_len(cfg, max_seq)
    from repro.models.model import block_forward  # local import (cycle)
    h = L.embed(tokens, params["embed"]).astype(dt)
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    state = init_decode_state(cfg, b, max_seq)

    if s <= c:
        ring_slots = jnp.arange(s)                        # contiguous
    else:  # ring: last c positions land at slots (s-c+i) % c
        ring_slots = jnp.mod(jnp.arange(s - c, s), c)

    def body(hh, xs):
        block_p, signs = xs
        hh, _, kv = block_forward(cfg, block_p, hh, positions, collect_kv=True)
        if kv is None:
            return hh, {}
        k, v = kv
        if s > c:
            k, v = k[:, s - c:], v[:, s - c:]
        if cfg.kv_quant_bits:
            # quantize into the packed NDSC cache with this layer's rotation
            # signs — the same encode_entry decode_step writes per token, so
            # the cache stays one wire format across prefill and decode
            out = {}
            for side, val in (("k", k), ("v", v)):
                words, scale = kvquant.encode_entry(val, signs,
                                                    cfg.kv_quant_bits)
                out[f"{side}_words"] = jnp.zeros(
                    (b, c) + words.shape[2:],
                    jnp.int32).at[:, ring_slots].set(words)
                out[f"{side}_scale"] = jnp.zeros(
                    (b, c) + scale.shape[2:],
                    jnp.float32).at[:, ring_slots].set(scale)
            return hh, out
        kc = jnp.zeros((b, c) + k.shape[2:], dt).at[:, ring_slots].set(k)
        vc = jnp.zeros((b, c) + v.shape[2:], dt).at[:, ring_slots].set(v)
        return hh, {"k": kc, "v": vc}

    if cfg.block in ("attn_mlp", "attn_moe", "attn_moe_dense"):
        if cfg.kv_quant_bits:
            from repro.models import kvquant
            signs_stack = state.caches["signs"]           # (L, K, dh)
        else:
            signs_stack = jnp.zeros((cfg.num_scanned,), jnp.float32)
        h, kv_stack = jax.lax.scan(body, h, (params["blocks"], signs_stack))
        caches = dict(state.caches)
        caches.update(kv_stack)
        state = DecodeState(caches=caches, pos=jnp.full((b,), s, jnp.int32))
        h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
        logits = (h[:, -1] @ params["head"]).astype(jnp.float32)
        return logits, state

    # Recurrent / hybrid families: prefill by stepping decode token-by-token
    # (correct for any family; used by examples at small scale).
    def step(carry, t):
        st, _ = carry
        logits, st = decode_step(cfg, params, st, tokens[:, t][:, None])
        return (st, logits), None

    (state, logits), _ = jax.lax.scan(
        step, (state, jnp.zeros((b, params["head"].shape[-1]), jnp.float32)),
        jnp.arange(s))
    return logits, state
