"""Selective state-space (Mamba-style) block, TPU-adapted.

State update (per channel c, state dim n):
    h_t = exp(Δ_t A) ⊙ h_{t−1} + (Δ_t x_t) B_tᵀ ,   y_t = h_t C_t + D x_t
with input-dependent Δ, B, C (selective scan). Two execution modes:
  * `mamba_scan`       — sequential `lax.scan` over time (O(state) memory;
                         default for training and the only option for decode).
  * `mamba_assoc_scan` — `lax.associative_scan` over time (log-depth, exposes
                         sequence parallelism to XLA at the cost of an
                         (B, S, d, n) intermediate; a §Perf hillclimb option).

The depthwise causal conv of the reference implementation is folded away
(DESIGN.md §7): it contributes <1% FLOPs and no structural sharding behavior.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class MambaParams(NamedTuple):
    in_proj: jax.Array    # (d, 2*di) → x, z
    w_bc: jax.Array       # (di, 2n) → B, C
    w_dt: jax.Array       # (di, dt_rank)
    w_dt_up: jax.Array    # (dt_rank, di)
    dt_bias: jax.Array    # (di,)
    a_log: jax.Array      # (di, n)
    d_skip: jax.Array     # (di,)
    out_proj: jax.Array   # (di, d)


def _inputs(p: MambaParams, x: jax.Array):
    di = p.out_proj.shape[0]
    n = p.a_log.shape[-1]
    xz = x @ p.in_proj
    x_in, z = xz[..., :di], xz[..., di:]
    bc = x_in @ p.w_bc                                    # (B, S, 2n)
    b_t, c_t = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus((x_in @ p.w_dt) @ p.w_dt_up + p.dt_bias)  # (B, S, di)
    a = -jnp.exp(p.a_log.astype(jnp.float32))             # (di, n)
    return x_in, z, b_t, c_t, dt, a


def _finish(p: MambaParams, y: jax.Array, x_in: jax.Array, z: jax.Array):
    y = y + p.d_skip * x_in
    return (y * jax.nn.silu(z)) @ p.out_proj


def mamba_scan(p: MambaParams, x: jax.Array, h0: jax.Array | None = None):
    """x: (B, S, d) → (y: (B, S, d), h_final: (B, di, n))."""
    bsz = x.shape[0]
    di, n = p.a_log.shape
    x_in, z, b_t, c_t, dt, a = _inputs(p, x)
    if h0 is None:
        h0 = jnp.zeros((bsz, di, n), jnp.float32)

    def step(h, t):
        x_t, b_tt, c_tt, dt_t = t
        da = jnp.exp(dt_t[..., None].astype(jnp.float32) * a)       # (B, di, n)
        h = da * h + (dt_t * x_t)[..., None].astype(jnp.float32) * b_tt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_tt.astype(jnp.float32))
        return h, y.astype(x.dtype)

    xs = (x_in.transpose(1, 0, 2), b_t.transpose(1, 0, 2),
          c_t.transpose(1, 0, 2), dt.transpose(1, 0, 2))
    h_fin, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2)                                        # (B, S, di)
    return _finish(p, y, x_in, z), h_fin


def mamba_assoc_scan(p: MambaParams, x: jax.Array, h0: jax.Array | None = None):
    """Associative-scan variant: h_t = a_t h_{t−1} + u_t composed in log depth."""
    bsz, s, _ = x.shape
    di, n = p.a_log.shape
    x_in, z, b_t, c_t, dt, a = _inputs(p, x)
    da = jnp.exp(dt[..., None].astype(jnp.float32) * a)              # (B,S,di,n)
    u = (dt * x_in)[..., None].astype(jnp.float32) * b_t[:, :, None, :]
    if h0 is not None:
        u = u.at[:, 0].add(da[:, 0] * h0)

    def combine(left, right):
        a1, u1 = left
        a2, u2 = right
        return a1 * a2, a2 * u1 + u2

    a_cum, h = jax.lax.associative_scan(combine, (da, u), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", h, c_t.astype(jnp.float32)).astype(x.dtype)
    return _finish(p, y, x_in, z), h[:, -1]


def mamba_decode_step(p: MambaParams, x: jax.Array, h: jax.Array):
    """x: (B, 1, d), h: (B, di, n) → (y: (B, 1, d), h')."""
    x_in, z, b_t, c_t, dt, a = _inputs(p, x)
    da = jnp.exp(dt[:, 0, :, None].astype(jnp.float32) * a)
    h = da * h + (dt[:, 0] * x_in[:, 0])[..., None].astype(jnp.float32) \
        * b_t[:, 0, None, :]
    y = jnp.einsum("bdn,bn->bd", h, c_t[:, 0].astype(jnp.float32))
    y = y.astype(x.dtype)[:, None, :]
    return _finish(p, y, x_in, z), h


def init_mamba(key: jax.Array, d: int, di: int, n: int,
               dtype=jnp.float32) -> MambaParams:
    dt_rank = max(1, d // 16)
    ks = jax.random.split(key, 6)
    sc = 0.02
    return MambaParams(
        in_proj=(jax.random.normal(ks[0], (d, 2 * di)) * sc).astype(dtype),
        w_bc=(jax.random.normal(ks[1], (di, 2 * n)) * sc).astype(dtype),
        w_dt=(jax.random.normal(ks[2], (di, dt_rank)) * sc).astype(dtype),
        w_dt_up=(jax.random.normal(ks[3], (dt_rank, di)) * sc).astype(dtype),
        dt_bias=jnp.full((di,), -4.6, dtype),   # softplus⁻¹(0.01)
        a_log=jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32),
                                       (di, n))).astype(dtype),
        d_skip=jnp.ones((di,), dtype),
        out_proj=(jax.random.normal(ks[4], (di, d)) * sc).astype(dtype),
    )
