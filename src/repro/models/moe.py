"""Mixture-of-Experts block: top-k routing with sort-based capacity dispatch.

Tokens are dispatched into a dense (E, C, d) buffer via scatter (capacity
C = ⌈cf·k·T/E⌉, overflow dropped — GShard-style), experts run as one batched
einsum, and outputs are combined with the router weights. Compiled FLOPs are
therefore ≈ cf × the *active* FLOPs (top-k of E), not E× — which keeps the
roofline's MODEL_FLOPS/HLO_FLOPs ratio honest for arctic's 128 experts.

Expert weights are sharded over the `model` axis on the expert dim when
E % model_axis == 0 (arctic: 128/16 = 8 experts/shard), else on d_ff
(mixtral: 8 experts, d_ff 16384/16). Token → expert traffic then lowers to
the expected all-to-all / all-gather pattern under GSPMD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _hint_expert_sharding(x: jax.Array) -> jax.Array:
    """Pin dim 0 (experts) to the tensor-parallel axis when legal.

    §Perf iteration (MoE dispatch): without this hint GSPMD materializes the
    full (E, C, d) dispatch buffer replicated and all-reduces it across the
    model axis every layer (≈4 TB/device/step on arctic×prefill_32k). With
    the output of the scatter pinned expert-sharded, the scatter partitions
    by index-masking per shard and the buffer never crosses the ICI.
    """
    from repro.compat import get_mesh
    mesh = get_mesh()
    if (mesh is not None and "model" in mesh.axis_names
            and mesh.shape["model"] > 1
            and x.shape[0] % mesh.shape["model"] == 0):
        from jax.sharding import PartitionSpec as P
        spec = P("model", *([None] * (x.ndim - 1)))
        try:
            return jax.lax.with_sharding_constraint(x, spec)
        except Exception:  # noqa: BLE001 — inside a fully-manual shard_map
            return x       # region the axis is unavailable; hint is optional
    return x


def moe_ffn(x: jax.Array, router: jax.Array, w_gate: jax.Array, w_up: jax.Array,
            w_down: jax.Array, *, top_k: int, capacity_factor: float = 1.25,
            return_aux: bool = False):
    """x: (B, S, d); router: (d, E); w_gate/up: (E, d, f); w_down: (E, f, d)."""
    b, s, d = x.shape
    e = router.shape[-1]
    t = b * s
    flat = x.reshape(t, d)

    logits = (flat @ router).astype(jnp.float32)             # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, expert_idx = jax.lax.top_k(probs, top_k)        # (T, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    flat_e = expert_idx.reshape(t * top_k)                   # assignment list
    flat_w = weights.reshape(t * top_k).astype(x.dtype)
    token_of = jnp.arange(t * top_k, dtype=jnp.int32) // top_k

    capacity = max(1, int(capacity_factor * t * top_k / e))
    # rank of each assignment within its expert (stable sort by expert id)
    order = jnp.argsort(flat_e, stable=True)
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(t * top_k, dtype=jnp.int32) - starts[flat_e[order]]
    rank = jnp.zeros(t * top_k, jnp.int32).at[order].set(rank_sorted)
    keep = rank < capacity
    rank_c = jnp.minimum(rank, capacity - 1)

    # dispatch: 2D-indexed scatter into the expert-sharded (E, C, d) buffer;
    # dropped assignments contribute zero instead of an OOB slot so the
    # scatter stays partitionable on the expert dim.
    buf = jnp.zeros((e, capacity, d), x.dtype)
    src = flat[token_of] * keep.astype(x.dtype)[:, None]
    buf = buf.at[flat_e, rank_c].add(src)
    buf = _hint_expert_sharding(buf)

    # expert compute: batched SwiGLU
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate))
    up = jnp.einsum("ecd,edf->ecf", buf, w_up)
    y = jnp.einsum("ecf,efd->ecd", gate * up, w_down)
    y = _hint_expert_sharding(y)

    # combine
    gathered = y[flat_e, rank_c]
    gathered = gathered * (flat_w * keep.astype(x.dtype))[:, None]
    out = jnp.zeros((t, d), x.dtype).at[token_of].add(gathered)
    out = out.reshape(b, s, d)

    if return_aux:
        # load-balance auxiliary loss (Switch-style): E · Σ_e f_e · p_e
        frac_tokens = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e), axis=0)
        frac_probs = jnp.mean(probs, axis=0)
        aux = e * jnp.sum(frac_tokens * frac_probs)
        dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
        return out, {"load_balance_loss": aux, "drop_fraction": dropped}
    return out
