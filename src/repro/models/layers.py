"""Shared transformer layer primitives: norms, RoPE, blockwise attention, MLPs.

Attention is implemented blockwise (online-softmax over KV chunks, lax.scan)
so that S=32k prefill and 4k training never materialize (S, S) score tensors —
this is what makes the 32k/500k shapes fit HBM in the dry-run. On TPU the XLA
fusion of this scan is the standard flash-equivalent; a Pallas flash kernel is
a drop-in replacement at deployment time.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Norms / embeddings
# ---------------------------------------------------------------------------
def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight).astype(dtype)


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, dh); positions: (B, S) or (S,)."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                     # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, dh/2)
    cos = jnp.cos(angles)[..., None, :]                     # (B, S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise attention (flash-style online softmax, pure JAX)
# ---------------------------------------------------------------------------
NEG_INF = -1e30


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, K, dh) → (B, S, K*groups, dh) for GQA."""
    if groups == 1:
        return k
    b, s, kh, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, groups, dh)
                            ).reshape(b, s, kh * groups, dh)


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool, window: Optional[int] = None,
                        q_offset: int | jax.Array = 0,
                        kv_len: Optional[jax.Array] = None,
                        block_q: int = 512, block_kv: int = 1024) -> jax.Array:
    """Online-softmax attention, GQA-native.

    q: (B, Sq, H, dh); k, v: (B, Skv, K, dh) with H % K == 0 (GQA).
    causal: mask position q_offset+i attends kv positions ≤ q_offset+i.
    window: sliding-window width (attend only last `window` kv positions).
    kv_len: optional (B,) valid kv length (decode with ring/padded caches).
    Never materializes more than (block_q, block_kv) scores per head, and
    never materializes H/K-repeated KV (§Perf iteration 1: queries are
    grouped (B, K, G, bq, dh) and matmul broadcasts over G — HBM traffic for
    KV drops by the group factor G).
    """
    b, sq, h, dh = q.shape
    skv, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = dh ** -0.5

    pad_q = (-sq) % block_q
    pad_kv = (-skv) % block_kv
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0))) if pad_kv else k
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0))) if pad_kv else v
    nq, nkv = qp.shape[1] // block_q, kp.shape[1] // block_kv

    # grouped q blocks: (nq, B, K, G, bq, dh); KV blocks stay at K heads and
    # are cast to f32 ONCE here (outside the q-block loop)
    qb = (qp.reshape(b, nq, block_q, kh, g, dh)
          .transpose(1, 0, 3, 4, 2, 5) * scale).astype(jnp.float32)
    kb = kp.reshape(b, nkv, block_kv, kh, dh).transpose(1, 0, 3, 4, 2) \
        .astype(jnp.float32)                      # (nkv, B, K, dh, bkv)
    vb = vp.reshape(b, nkv, block_kv, kh, dh).transpose(1, 0, 3, 2, 4) \
        .astype(jnp.float32)                      # (nkv, B, K, bkv, dh)

    q_pos_base = jnp.asarray(q_offset, jnp.int32)

    def one_q_block(iq, qblk):
        # qblk: (B, K, G, bq, dh)
        q_pos = q_pos_base + iq * block_q + jnp.arange(block_q, dtype=jnp.int32)

        def kv_step(carry, inputs):
            acc, m, l = carry
            ikv, kblk, vblk = inputs
            kv_pos = ikv * block_kv + jnp.arange(block_kv, dtype=jnp.int32)
            # (B,K,G,bq,dh) @ (B,K,1,dh,bkv) → (B,K,G,bq,bkv)
            s = qblk @ kblk[:, :, None]
            mask = jnp.ones((block_q, block_kv), bool)
            if causal:
                mask &= kv_pos[None, :] <= q_pos[:, None]
            if window is not None:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
            mask &= kv_pos[None, :] < skv                    # kv padding
            s = jnp.where(mask, s, NEG_INF)
            if kv_len is not None:
                s = jnp.where(kv_pos < kv_len[:, None, None, None, None],
                              s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + p @ vblk[:, :, None]
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, kh, g, block_q, dh), jnp.float32)
        m0 = jnp.full((b, kh, g, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, block_q), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (jnp.arange(nkv, dtype=jnp.int32), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out                                    # (B, K, G, bq, dh)

    outs = jax.lax.map(lambda args: one_q_block(*args),
                       (jnp.arange(nq, dtype=jnp.int32), qb))
    # (nq, B, K, G, bq, dh) → (B, nq·bq, H, dh)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * block_q, h, dh)
    return out[:, :sq].astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
                     kv_len: jax.Array, window: Optional[int] = None) -> jax.Array:
    """Single-token attention against a cache. q: (B, 1, H, dh);
    caches: (B, S, K, dh); kv_len: (B,) number of valid positions.

    GQA-native (§Perf iteration 1): queries are grouped (B, K, G, dh) and
    contracted directly against the K-head cache — the cache is read ONCE
    (the bandwidth floor of decode) instead of G× through a repeated copy.
    """
    b, _, h, dh = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    qg = q.reshape(b, kh, g, dh).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg,
                        k_cache.astype(jnp.float32)) * dh ** -0.5
    pos = jnp.arange(s, dtype=jnp.int32)
    valid = pos[None, :] < kv_len[:, None]
    if window is not None:
        valid &= pos[None, :] >= (kv_len[:, None] - window)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs,
                     v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def gelu_mlp(x: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ w_up) @ w_down


# ---------------------------------------------------------------------------
# Chunked cross-entropy (never materializes (B, S, V) logits)
# ---------------------------------------------------------------------------
def chunked_softmax_xent(h: jax.Array, head: jax.Array, targets: jax.Array,
                         chunk: int = 512) -> jax.Array:
    """h: (B, S, d); head: (d, V); targets: (B, S) int32 → mean CE (scalar).

    Scans over sequence chunks so the logits live one (B, chunk, V) at a time.
    """
    b, s, d = h.shape
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
    nc = h.shape[1] // chunk
    hc = h.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, nc, chunk).transpose(1, 0, 2)

    def step(carry, xs):
        total, count = carry
        hh, tt = xs
        logits = (hh @ head).astype(jnp.float32)             # (B, chunk, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(tt, 0)[..., None],
                                   axis=-1)[..., 0]
        valid = (tt >= 0).astype(jnp.float32)
        total = total + jnp.sum((lse - gold) * valid)
        count = count + jnp.sum(valid)
        return (total, count), None

    (total, count), _ = jax.lax.scan(step, (0.0, 0.0), (hc, tc))
    return total / jnp.maximum(count, 1.0)
