"""Production mesh construction (TPU v5e pods; CPU stand-ins for the dry-run).

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
jax initialization, and every process must control its own device count
(tests force 4 virtual host devices in conftest for the fed mesh backend;
the bench-smoke lane forces 2; plain scripts see the 1 physical device).
"""
from __future__ import annotations

import numpy as np

import jax


SINGLE_POD = (16, 16)                  # 256 chips / pod
MULTI_POD = (2, 16, 16)                # 2 pods = 512 chips
SINGLE_AXES = ("data", "model")
MULTI_AXES = ("pod", "data", "model")


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16×16 ("data","model") or 2×16×16 ("pod","data","model").

    Uses the first `prod(shape)` available devices so one 512-device process
    can build both meshes.
    """
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = MULTI_AXES if multi_pod else SINGLE_AXES
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices but only {len(devices)} are "
            "visible — the dry-run entrypoint must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before any "
            "jax import")
    return jax.sharding.Mesh(np.asarray(devices[:need]).reshape(shape), axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many devices this host actually has
    (tests / examples: usually 1×1 on the CPU container)."""
    need = data * model
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(f"need {need} devices, have {len(devices)}")
    return jax.sharding.Mesh(
        np.asarray(devices[:need]).reshape(data, model), ("data", "model"))
