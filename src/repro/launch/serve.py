"""Serving driver: batched prefill + greedy decode against explicit caches.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x22b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.dist import step as step_lib
from repro.launch.mesh import make_host_mesh
from repro.models import decode as decode_lib
from repro.models import model as model_lib


def serve(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0):
    if not cfg.decode_supported:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode")
    mesh = make_host_mesh(data=1, model=1)
    key = jax.random.key(seed)
    params = model_lib.init_params(key, cfg)
    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (batch, prompt_len), 0, cfg.vocab_size,
                                 jnp.int32)
    max_seq = prompt_len + gen

    t0 = time.time()
    logits, state = jax.jit(
        lambda p, t: decode_lib.prefill(cfg, p, t, max_seq))(params, prompts)
    print(f"prefill[{batch}×{prompt_len}] {time.time()-t0:.2f}s "
          f"(cache_len={decode_lib.cache_len(cfg, max_seq)})")

    sstep = step_lib.make_serve_step(cfg, mesh)
    tok = decode_lib.greedy_token(logits)
    out = [tok]
    t0 = time.time()
    for _ in range(gen - 1):
        logits, state = sstep(params, state, tok)
        tok = decode_lib.greedy_token(logits)
        out.append(tok)
    dt = time.time() - t0
    seqs = jnp.concatenate(out, axis=1)
    print(f"decode {gen-1} steps in {dt:.2f}s "
          f"({(gen-1)*batch/max(dt,1e-9):.1f} tok/s)")
    for b in range(min(batch, 4)):
        print(f"  seq[{b}]: {seqs[b].tolist()}")
    return seqs


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="yi-6b", choices=configs.ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)
    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get(args.arch))
    serve(cfg, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen)


if __name__ == "__main__":
    main()
