import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch × shape × mesh) lowers AND compiles.

The two lines above MUST stay first: jax locks the device count at first
initialization, and the production meshes (16×16 and 2×16×16) need 512
placeholder host devices. Do not set this flag anywhere global — tests and
benches must see 1 device.

For each combination this entrypoint:
  1. builds the production mesh (single- or multi-pod),
  2. constructs sharded ShapeDtypeStruct stand-ins for every input
     (params / optimizer state / error-feedback / batch, or decode caches),
  3. jits the step with those shardings, .lower().compile(),
  4. prints compiled.memory_analysis() (bytes/device) and cost_analysis()
     (FLOPs / bytes for §Roofline), plus the collective-op byte census parsed
     from the partitioned HLO text.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --arch yi-6b --shape decode_32k --multi-pod
  python -m repro.launch.dryrun --sweep --json-out results.json
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro import configs
from repro.configs.shapes import SHAPES, applicable, input_specs
from repro.dist import step as step_lib
from repro.dist.gradcomp import GradCompConfig
from repro.dist.sharding import batch_specs
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_lib
from repro.optimizer import adamw, sgd


def _sharded_batch_specs(cfg, shape, mesh):
    batch = input_specs(cfg, shape)
    specs = batch_specs(batch, mesh)
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype,
                                          sharding=NamedSharding(mesh, s)),
        batch, specs)


def build_lowered(cfg, shape, mesh, gc: GradCompConfig, opt_name: str):
    """Returns (lowered, model_flops)."""
    if shape.mode == "train":
        opt = (adamw(1e-4, weight_decay=0.1) if opt_name == "adamw"
               else sgd(1e-2, momentum=0.9))
        if gc.strategy == "alltoall_zero1":
            tstep = step_lib.make_zero_train_step(cfg, opt, gc, mesh,
                                                  gather_dtype=jnp.bfloat16)
            params, opt_state, ef = step_lib.zero_state_specs(cfg, opt, gc,
                                                              mesh)
        else:
            tstep = step_lib.make_train_step(cfg, opt, gc, mesh)
            params, opt_state, ef = step_lib.train_state_specs(cfg, opt, gc,
                                                               mesh)
        batch = _sharded_batch_specs(cfg, shape, mesh)
        lowered = tstep.lower(params, opt_state, ef, batch)
        tokens = shape.global_batch * shape.seq_len
        return lowered, hlo_analysis.model_flops_train(cfg, tokens)

    if shape.mode == "prefill":
        def fwd(params, batch):
            h, positions, _ = model_lib._embed_inputs(cfg, params, batch)
            h, _ = model_lib.forward_hidden(cfg, params, h, positions)
            return (h[:, -1] @ params["head"]).astype(jnp.float32)

        from repro.dist.sharding import param_specs
        params_shape = jax.eval_shape(
            lambda: model_lib.init_params(jax.random.key(0), cfg))
        pspecs = param_specs(params_shape, mesh.shape.get("model", 1))
        params = jax.tree.map(
            lambda x, s: jax.ShapeDtypeStruct(
                x.shape, x.dtype, sharding=NamedSharding(mesh, s)),
            params_shape, pspecs)
        batch = _sharded_batch_specs(cfg, shape, mesh)
        lowered = jax.jit(fwd).lower(params, batch)
        toks = shape.global_batch * shape.seq_len
        return lowered, hlo_analysis.model_flops_train(cfg, toks) / 3.0  # fwd

    if shape.mode == "decode":
        sstep = step_lib.make_serve_step(cfg, mesh)
        params, state, tokens = step_lib.serve_state_specs(
            cfg, mesh, shape.global_batch, shape.seq_len)
        lowered = sstep.lower(params, state, tokens)
        return lowered, hlo_analysis.model_flops_decode(cfg,
                                                        shape.global_batch)

    raise ValueError(shape.mode)


def run_combo(arch: str, shape_name: str, multi_pod: bool,
              gc: GradCompConfig, opt_name: str = "adamw",
              verbose: bool = True, kv_quant: int | None = None) -> dict:
    cfg = configs.get(arch)
    if kv_quant:
        import dataclasses
        cfg = dataclasses.replace(cfg, kv_quant_bits=kv_quant)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "mode": shape.mode, "strategy": gc.strategy, "bits": gc.bits}
    ok, reason = applicable(cfg, shape)
    if not ok:
        rec.update(status="SKIP", reason=reason)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        # `with mesh:` provides the device context; set_mesh additionally
        # publishes the abstract mesh so in-model sharding hints
        # (with_sharding_constraint on raw PartitionSpecs, e.g. the MoE
        # expert-parallel dispatch buffer) resolve during tracing.
        # (compat: no-op on jax 0.4.x, where the `with mesh:` context below
        # is what repro.compat.get_mesh falls back to.)
        from repro.compat import set_mesh
        set_mesh(mesh)
        with mesh:
            lowered, model_flops = build_lowered(cfg, shape, mesh, gc,
                                                 opt_name)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):  # jax 0.4.x: one dict/device
                cost = cost[0]
            text = compiled.as_text()
        n_dev = mesh.size
        roof = hlo_analysis.roofline_terms(cost, text, model_flops, n_dev)
        from repro.launch import hlo_static
        coll = hlo_static.analyze(text)
        rec.update(
            xla_cost={"flops": cost.get("flops"),
                      "bytes_accessed": cost.get("bytes accessed")},
            status="OK",
            compile_s=round(time.time() - t0, 1),
            num_devices=n_dev,
            memory={k: getattr(mem, k) for k in
                    ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes")},
            bytes_per_device=mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes,
            roofline=roof.table_row(),
            collectives=coll.collectives_by_kind,
        )
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_name}] OK "
                  f"({rec['compile_s']}s compile)")
            print(f"  memory/device: args={mem.argument_size_in_bytes/2**30:.2f}"
                  f"GiB out={mem.output_size_in_bytes/2**30:.2f}GiB "
                  f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB")
            print(f"  flops/device={roof.flops_per_device:.3e} "
                  f"hbm_bytes={roof.hbm_bytes_per_device:.3e} "
                  f"coll_bytes={roof.collective_bytes_per_device:.3e}")
            print(f"  terms: compute={roof.compute_s*1e3:.2f}ms "
                  f"memory={roof.memory_s*1e3:.2f}ms "
                  f"collective={roof.collective_s*1e3:.2f}ms "
                  f"→ {roof.dominant}-bound")
            if roof.useful_flops_ratio:
                print(f"  MODEL_FLOPS/HLO_FLOPS = "
                      f"{roof.useful_flops_ratio:.3f}")
            print(f"  collectives: {coll.collectives_by_kind}")
    except Exception as e:  # noqa: BLE001 — a failed combo is a data point
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   compile_s=round(time.time() - t0, 1))
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_name}] FAIL: "
                  f"{rec['error']}")
            traceback.print_exc()
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=configs.ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--sweep", action="store_true",
                    help="all (arch × shape) on the selected mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--bits", type=int, default=4, choices=(1, 2, 4, 8))
    ap.add_argument("--strategy", default="allgather_packed",
                    choices=("psum", "psum_decoded", "allgather_packed",
                             "alltoall_zero1"))
    ap.add_argument("--opt", default="adamw", choices=("adamw", "sgd"))
    ap.add_argument("--kv-quant", type=int, default=None, choices=(4, 8),
                    help="NDSC-packed KV cache bits for decode shapes")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    gc = GradCompConfig(bits=args.bits, strategy=args.strategy)
    records = []
    if args.sweep:
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        for arch in configs.ARCH_NAMES:
            for shape_name in SHAPES:
                for mp in meshes:
                    records.append(run_combo(arch, shape_name, mp, gc,
                                             args.opt))
                    jax.clear_caches()
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required (or --sweep)")
        records.append(run_combo(args.arch, args.shape, args.multi_pod, gc,
                                 args.opt, kv_quant=args.kv_quant))

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records → {args.json_out}")
    failures = [r for r in records if r["status"] == "FAIL"]
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
