"""Roofline-term extraction from compiled XLA artifacts.

Sources (ROOFLINE ANALYSIS spec):
  * compiled.cost_analysis()  → HLO_FLOPs, HLO bytes accessed
  * compiled.as_text()        → collective ops; the SPMD-partitioned module
    carries PER-DEVICE shapes, so operand bytes summed here are per-device —
    the roofline's collective_bytes/(chips·link_bw) therefore uses link_bw
    directly (the ÷chips is already baked into the per-device program).

Per-kind operand-size conventions (result shapes are what the text shows):
  all-gather       operand = result / group      (input shard)
  all-reduce       operand = result              (in-place reduce)
  reduce-scatter   operand = result × group      (input, pre-scatter)
  all-to-all       operand = result              (bytes in = bytes out)
  collective-permute operand = result

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s ICI
per link.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

# v5e per-chip constants
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+ = (.*?) "
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_NEW_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_text: str) -> int:
    """Total bytes of a (possibly tuple) HLO shape string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_NEW_RE.search(line)          # replica_groups=[G,S]
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)              # replica_groups={{0,1,...},...}
    if m:
        return max(1, m.group(1).count(",") + 1)
    return 1


@dataclasses.dataclass
class CollectiveStats:
    operand_bytes: int = 0          # per-device, per the spec's convention
    wire_bytes: int = 0             # ring-model bytes actually crossing links
    by_kind: dict = dataclasses.field(default_factory=dict)
    count: int = 0


def collective_stats(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_text, kind = m.group(1), m.group(2)
        line = hlo_text[m.start():hlo_text.find("\n", m.start())]
        if "-done(" in line:        # async pair: count only the start
            continue
        result = _shape_bytes(shape_text)
        g = _group_size(line)
        if kind == "all-gather":
            operand = result // max(g, 1)
            wire = result - operand                   # (g-1)/g × result
        elif kind == "all-reduce":
            operand = result
            wire = 2 * result * (g - 1) // max(g, 1)  # ring AR
        elif kind == "reduce-scatter":
            operand = result * g
            wire = result * (g - 1)
        else:                                          # a2a / permute
            operand = result
            wire = result
        stats.operand_bytes += operand
        stats.wire_bytes += wire
        k = stats.by_kind.setdefault(kind, {"count": 0, "operand_bytes": 0})
        k["count"] += 1
        k["operand_bytes"] += operand
        stats.count += 1
    return stats


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    collective_wire_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: Optional[float] = None
    useful_flops_ratio: Optional[float] = None

    def table_row(self) -> dict:
        return dataclasses.asdict(self)


def roofline_terms(cost: dict, hlo_text: str,
                   model_flops: Optional[float] = None,
                   num_devices: int = 1) -> Roofline:
    """cost = compiled.cost_analysis(); hlo_text = compiled.as_text().

    Primary numerators come from the trip-count-aware static analyzer
    (repro.launch.hlo_static) — XLA's cost_analysis counts while bodies once,
    which undercounts scanned layer stacks by L× and recurrent time scans by
    S×. All numbers are per-device (the partitioned module).
    """
    from repro.launch import hlo_static
    static = hlo_static.analyze(hlo_text)
    flops = float(static.flops)
    bytes_acc = float(static.bytes_accessed)
    del cost  # xla aggregate kept by the caller for reference only
    coll = CollectiveStats(
        operand_bytes=int(static.collective_operand_bytes),
        wire_bytes=int(static.collective_wire_bytes),
        by_kind=static.collectives_by_kind,
        count=sum(v["count"] for v in static.collectives_by_kind.values()))
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    collective_s = coll.operand_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    ratio = None
    if model_flops:
        # model_flops is global; HLO flops are per-device
        ratio = model_flops / max(flops * num_devices, 1.0)
    return Roofline(
        flops_per_device=flops, hbm_bytes_per_device=bytes_acc,
        collective_bytes_per_device=float(coll.operand_bytes),
        collective_wire_bytes=float(coll.wire_bytes),
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops,
        useful_flops_ratio=ratio)


def model_flops_train(cfg, tokens: int) -> float:
    """6·N_active·D (fwd+bwd) — the roofline's MODEL_FLOPS."""
    from repro.models.model import active_param_count
    return 6.0 * active_param_count(cfg) * tokens


def model_flops_decode(cfg, batch: int) -> float:
    """2·N_active per generated token (fwd only)."""
    from repro.models.model import active_param_count
    return 2.0 * active_param_count(cfg) * batch
