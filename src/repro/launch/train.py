"""Training driver: end-to-end LM training with compressed gradient consensus.

Runs for real on whatever devices exist (the CPU container: a 1×1 host mesh,
where the shard_map collectives degenerate but the full codec path — FWHT
embedding, R-bit pack, decode, error feedback, optimizer — executes exactly).

  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \
      --steps 50 --batch 8 --seq 128 --bits 4

For the ~100M-scale end-to-end deliverable see examples/train_lm.py, which
drives this module with a fixed recipe.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import configs
from repro.checkpoint import save_checkpoint
from repro.data import batch_for_shape
from repro.dist import step as step_lib
from repro.dist.gradcomp import GradCompConfig, wire_bytes_tree
from repro.launch.mesh import make_host_mesh
from repro.optimizer import adamw, warmup_cosine


def train(cfg, *, steps: int, batch_size: int, seq_len: int,
          gc: GradCompConfig, lr: float = 3e-4, log_every: int = 10,
          ckpt_dir: str | None = None, mesh=None, seed: int = 0):
    mesh = mesh or make_host_mesh(data=1, model=1)
    opt = adamw(warmup_cosine(lr, max(steps // 20, 1), steps),
                weight_decay=0.1)
    tstep = step_lib.make_train_step(cfg, opt, gc, mesh, clip_norm=1.0)
    params, opt_state, ef = step_lib.init_train_state(
        cfg, opt, gc, mesh, jax.random.key(seed))

    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model={cfg.name} params={n_params/1e6:.1f}M "
          f"workers={step_lib.num_workers(mesh)} strategy={gc.strategy} "
          f"R={gc.effective_bits if gc.compresses else 32} bits/dim")
    if gc.compresses:
        audit = wire_bytes_tree(params, gc, step_lib.num_workers(mesh))
        print(f"wire audit: f32={audit['f32_bytes']/2**20:.1f}MiB → "
              f"payload={audit['payload_bytes']/2**20:.1f}MiB "
              f"({audit['compression_x']:.1f}× smaller)")
    else:
        print("wire audit: uncompressed f32 all-reduce (psum)")

    losses = []
    t0 = time.time()
    for step in range(steps):
        batch = batch_for_shape(cfg, batch_size, seq_len, step, seed)
        params, opt_state, ef, metrics = tstep(params, opt_state, ef, batch)
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d}  loss {losses[-1]:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"({dt:.1f}s)", flush=True)
    if ckpt_dir:
        path = save_checkpoint(ckpt_dir, steps, {"params": params,
                                                 "opt_state": opt_state})
        print(f"checkpoint → {path}")
    return params, losses


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="yi-6b", choices=configs.ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--bits", type=int, default=4, choices=(1, 2, 4, 8))
    ap.add_argument("--strategy", default="allgather_packed",
                    choices=("psum", "psum_decoded", "allgather_packed"))
    ap.add_argument("--keep-fraction", type=float, default=1.0,
                    help="chunk keep rate: R_eff = bits × keep (< 1 is the "
                         "paper's sub-linear regime)")
    ap.add_argument("--dithered", action="store_true",
                    help="unbiased dithered codec — drops the params-sized "
                         "error-feedback state")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get(args.arch))
    gc = GradCompConfig(bits=args.bits, strategy=args.strategy,
                        keep_fraction=args.keep_fraction,
                        dithered=args.dithered,
                        error_feedback=not args.dithered)
    train(cfg, steps=args.steps, batch_size=args.batch, seq_len=args.seq,
          gc=gc, lr=args.lr, ckpt_dir=args.ckpt_dir)


if __name__ == "__main__":
    main()
