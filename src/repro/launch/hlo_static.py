"""Trip-count-aware static analysis of optimized HLO text.

Why this exists: `compiled.cost_analysis()` counts a `while` body ONCE, but
every model here runs its layer stack (and the recurrent archs their time
dimension) under `lax.scan` → FLOPs/bytes/collectives inside loops are
undercounted by the trip count (88× for mistral-large's layer scan, 4096× for
xlstm's time scan). The optimized HLO text carries
`backend_config={"known_trip_count":{"n":...}}` on each while op, so an exact
static correction is possible:

  1. parse the module into computations (name → instructions),
  2. build the call graph (while body/condition, fusion calls, to_apply,
     branches) and propagate a multiplier = product of enclosing trip counts,
  3. charge per instruction:
       flops   — dot (2·|result|·K), elementwise math (1/elem), reductions;
       bytes   — operands + result of top-level (non-fused) instructions,
                 the standard fusion-boundary HBM-traffic convention;
       collectives — operand-size census by kind (same conventions as
                 hlo_analysis), multiplied like everything else.

The result is the per-device roofline numerator used by the §Roofline tables;
`cost_analysis()` numbers are reported alongside for reference.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
# shape = shortest prefix before the first `opcode(` token — tuple shapes may
# contain /*index=N*/ comments and per-member layout braces, so the shape part
# cannot be matched structurally; the opcode is always a bare word glued to
# its operand paren.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s([\w\-]+)\((.*)$")
_CALLSITE_RE = re.compile(
    r"(?:calls|body|condition|to_apply|branch_computations)="
    r"(?:\{(%?[\w.\-]+(?:,\s*%?[\w.\-]+)*)\}|%?([\w.\-]+))")
_TRIP_RE = re.compile(r'known_trip_count[\\"]*:\s*\{[\\"]*n[\\"]*:[\\"]*(\d+)')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "cosine",
    "sine", "logistic", "expm1", "log1p", "atan2", "remainder", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "cbrt", "erf",
}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "copy", "after-all", "partition-id", "replica-id",
               "iota"}


def _shape_elems_bytes(shape_text: str) -> tuple[int, int]:
    elems = bytes_ = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dtype]
    return elems, bytes_


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str           # everything after the opening paren

    @property
    def result_elems(self) -> int:
        return _shape_elems_bytes(self.shape)[0]

    @property
    def result_bytes(self) -> int:
        return _shape_elems_bytes(self.shape)[1]


def parse_computations(text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    current: Optional[str] = None
    for line in text.splitlines():
        if current is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and "{" in line:
                current = m.group(1)
                comps[current] = []
            continue
        if line.strip() == "}":
            current = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[current].append(Instr(*m.groups()))
    return comps


def _dot_flops(instr: Instr, shapes: dict[str, str]) -> int:
    """2 × |result| × K, K = product of lhs contracting-dim sizes."""
    out_elems = instr.result_elems
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    ops = _OPERAND_RE.findall(instr.rest)
    if not m or not ops:
        return 2 * out_elems
    lhs_shape = shapes.get(ops[0], "")
    dims_m = _SHAPE_RE.search(lhs_shape)
    if not dims_m:
        return 2 * out_elems
    lhs_dims = [int(d) for d in dims_m.group(2).split(",") if d]
    k = 1
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(lhs_dims):
            k *= lhs_dims[idx]
    return 2 * out_elems * k


def _group_size(rest: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", rest)
    if m:
        return m.group(1).count(",") + 1
    return 1


@dataclasses.dataclass
class StaticCosts:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_operand_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collectives_by_kind: dict = dataclasses.field(
        default_factory=lambda: defaultdict(lambda: {"count": 0,
                                                     "operand_bytes": 0}))

    def finalize(self) -> "StaticCosts":
        self.collectives_by_kind = {k: dict(v) for k, v
                                    in self.collectives_by_kind.items()}
        return self


def analyze(text: str) -> StaticCosts:
    comps = parse_computations(text)
    # name → shape per computation for operand lookups
    shapes_of = {cname: {i.name: i.shape for i in instrs}
                 for cname, instrs in comps.items()}

    # multipliers: start at 1 for the entry computation; propagate through
    # call edges, multiplying by trip count at while ops.
    entry = next((c for c in comps if c.startswith("main")), None)
    if entry is None:
        entry = next(iter(comps), None)
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    trip_of: dict[str, float] = {}       # while-body computation → trip count
    # breadth-first over call edges (the call graph is a DAG in HLO)
    order = [entry]
    seen = {entry}
    idx = 0
    while idx < len(order):
        cname = order[idx]
        idx += 1
        for instr in comps.get(cname, []):
            callees = []
            for m in _CALLSITE_RE.finditer(instr.rest):
                group = m.group(1) or m.group(2)
                for callee in group.split(","):
                    callees.append(callee.strip().lstrip("%"))
            if not callees:
                continue
            k = 1.0
            if instr.op == "while":
                t = _TRIP_RE.search(instr.rest)
                k = float(t.group(1)) if t else 1.0
            for callee in callees:
                if callee in comps:
                    mult[callee] += mult[cname] * k
                    if instr.op == "while":
                        trip_of[callee] = max(trip_of.get(callee, 1.0), k)
                    # propagate the enclosing trip into fusions called from
                    # a while body (their operands may be scan-stacked too)
                    elif cname in trip_of:
                        trip_of.setdefault(callee, trip_of[cname])
                    if callee not in seen:
                        seen.add(callee)
                        order.append(callee)

    fused_bodies = set()
    for cname, instrs in comps.items():
        for instr in instrs:
            if instr.op == "fusion":
                for m in _CALLSITE_RE.finditer(instr.rest):
                    group = m.group(1) or m.group(2)
                    for callee in group.split(","):
                        fused_bodies.add(callee.strip().lstrip("%"))

    costs = StaticCosts()
    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        shapes = shapes_of[cname]
        in_fusion = cname in fused_bodies
        for instr in instrs:
            op = instr.op
            # ---- flops ----
            if op in ("dot", "dot-general"):
                costs.flops += m * _dot_flops(instr, shapes)
            elif op == "convolution":
                costs.flops += m * 2 * instr.result_elems  # lower bound
            elif op in ELEMENTWISE_FLOP_OPS:
                costs.flops += m * instr.result_elems
            elif op == "reduce":
                costs.flops += m * instr.result_elems
            # ---- bytes (fusion-boundary convention, scan-aware) ----
            # Inside a while body with trip count T, scan-stacked tensors
            # (leading dim == T) are touched one slice per iteration: charge
            # bytes/T so the loop total equals one full pass. dynamic-slice /
            # dynamic-update-slice are charged at their slice size (XLA's own
            # in-place convention), not the full buffer.
            if not in_fusion and op not in _SKIP_BYTES:
                trip = trip_of.get(cname, 1.0)

                def _charge(shape_text: str) -> float:
                    bts = _shape_elems_bytes(shape_text)[1]
                    if trip > 1:
                        dm = _SHAPE_RE.search(shape_text)
                        if dm:
                            dims = [int(d) for d in dm.group(2).split(",")
                                    if d]
                            if dims and dims[0] == int(trip):
                                return bts / trip
                    return float(bts)

                if op == "dynamic-slice":
                    b = 2.0 * instr.result_bytes
                elif op == "dynamic-update-slice":
                    opnds = _OPERAND_RE.findall(instr.rest)
                    upd = (_shape_elems_bytes(shapes[opnds[1]])[1]
                           if len(opnds) > 1 and opnds[1] in shapes
                           else instr.result_bytes)
                    b = 2.0 * upd
                else:
                    b = _charge(instr.shape)
                    for opnd in _OPERAND_RE.findall(instr.rest):
                        if opnd in shapes:
                            b += _charge(shapes[opnd])
                costs.bytes_accessed += m * b
            # ---- collectives ----
            base = op.removesuffix("-start").removesuffix("-done")
            if base in COLLECTIVES and not op.endswith("-done"):
                g = _group_size(instr.rest)
                result = instr.result_bytes
                if base == "all-gather":
                    operand = result // max(g, 1)
                    wire = result - operand
                elif base == "all-reduce":
                    operand = result
                    wire = 2 * result * (g - 1) // max(g, 1)
                elif base == "reduce-scatter":
                    operand = result * g
                    wire = result * (g - 1)
                else:
                    operand = wire = result
                costs.collective_operand_bytes += m * operand
                costs.collective_wire_bytes += m * wire
                kind = costs.collectives_by_kind[base]
                kind["count"] += int(m)
                kind["operand_bytes"] += int(m * operand)
    return costs.finalize()
